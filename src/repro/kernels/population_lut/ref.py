"""Numpy reference semantics for the population LUT gather.

Bit-exact mirror of ``accel._batchsim.lut_gather`` on a flat (M, S)
element layout: the kernels and the fused engine are validated against
this, and this in turn is validated against the per-genome loop.
"""

from __future__ import annotations

import numpy as np

__all__ = ["population_lut_gather_ref"]


def population_lut_gather_ref(
    lut: np.ndarray,
    genes: np.ndarray,
    cols: np.ndarray,
    *,
    per_genome: bool = False,
) -> np.ndarray:
    """``out[g, m, s] = lut[genes[g, s], s, cols[m, s]]``.

    ``lut``: (C, S, 256); ``genes``: (G, S) circuit indices; ``cols``:
    table indices, (M, S) shared across the population or (G, M, S)
    per-genome.  Returns (G, M, S) products in ``lut``'s dtype."""
    G, S = genes.shape
    sl = np.arange(S)[None, None, :]
    if per_genome:
        return lut[genes[:, None, :], sl, cols]
    return lut[genes[:, None, :], sl, cols[None]]
