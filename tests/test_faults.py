"""Chaos harness: deterministic fault plans, the injection runtime's
schedule semantics, the http retry/breaker/deadline guards, and the
graceful-degradation paths they drive."""

import json
import os
import subprocess
import sys
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import pytest

from repro import faults, obs
from repro.faults import FaultInjected, FaultPlan, FaultRule
from repro.fleet.http import CircuitBreaker, HttpError, request_json

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# plans: validation + serialization
# ---------------------------------------------------------------------------

def test_plan_roundtrips_through_json(tmp_path):
    plan = (FaultPlan(seed=7, name="drill")
            .add("store.append", "torn_write", times=2, fraction=0.3)
            .add("http.request", "error", status=503, p=0.5, after=3)
            .add("synth.compile", "latency", delay_s=0.01))
    path = plan.save(str(tmp_path / "plan.json"))
    back = FaultPlan.from_file(path)
    assert back.to_dict() == plan.to_dict()
    assert back.seed == 7 and len(back.rules) == 3
    assert back.rules[1].status == 503 and back.rules[1].after == 3


def test_rule_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultRule("x", kind="explode")
    with pytest.raises(ValueError, match="p must be"):
        FaultRule("x", p=1.5)
    with pytest.raises(ValueError, match="fraction"):
        FaultRule("x", kind="torn_write", fraction=1.0)


def test_rule_glob_matching():
    r = FaultRule("store.*")
    assert r.matches("store.append") and r.matches("store.seal")
    assert not r.matches("http.request")


# ---------------------------------------------------------------------------
# injection runtime: zero-cost idle, deterministic armed
# ---------------------------------------------------------------------------

def test_check_is_none_when_disarmed():
    assert not faults.active()
    assert faults.check("store.append") is None
    assert faults.hit("sched.dispatch") is None


def test_schedule_after_times():
    faults.install(FaultPlan(seed=1).add(
        "p.x", "drop", after=2, times=2))
    fired = [faults.check("p.x") is not None for _ in range(6)]
    assert fired == [False, False, True, True, False, False]
    assert faults.stats()["by_point"] == {"p.x": 2}


def test_probability_is_deterministic_per_seed():
    def pattern(seed):
        faults.reset()
        faults.install(FaultPlan(seed=seed).add("p.y", "drop", p=0.5))
        return [faults.check("p.y") is not None for _ in range(32)]

    a, b = pattern(3), pattern(3)
    assert a == b                      # same seed -> same storm
    assert a != pattern(4)             # different seed -> different storm
    assert 1 <= sum(a) <= 31           # the coin actually flips


def test_hit_raises_error_kind_and_sleeps_latency():
    faults.install(FaultPlan().add("p.err", "error", times=1,
                                   status=503, message="boom"))
    with pytest.raises(FaultInjected) as ei:
        faults.hit("p.err")
    assert ei.value.status == 503 and "boom" in str(ei.value)
    assert faults.hit("p.err") is None          # times budget spent

    faults.install(FaultPlan().add("p.lat", "latency", delay_s=0.05))
    t0 = time.perf_counter()
    assert faults.hit("p.lat") is None          # latency self-applies
    assert time.perf_counter() - t0 >= 0.04


def test_first_matching_rule_wins_and_counter_counts():
    faults.install(FaultPlan()
                   .add("p.z", "drop", times=1)
                   .add("p.*", "duplicate"))
    assert faults.check("p.z").kind == "drop"
    assert faults.check("p.z").kind == "duplicate"
    st = faults.stats()
    assert st["injected"] == 2 and st["active"]
    assert obs.REGISTRY.collect("repro_faults_")[
        "repro_faults_injected_total"] >= 2


def test_env_arming_reaches_subprocess(tmp_path):
    """REPRO_FAULTS travels to worker subprocesses: the child sees the
    armed plan at import time and fires deterministically."""
    plan = FaultPlan(seed=9, name="env").add("child.point", "drop",
                                             times=1)
    path = plan.save(str(tmp_path / "plan.json"))
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro import faults;"
         "print(faults.active(), faults.installed().name,"
         "      faults.check('child.point') is not None,"
         "      faults.check('child.point') is not None)"],
        capture_output=True, text=True, check=True,
        env={**os.environ, "PYTHONPATH": SRC, "REPRO_FAULTS": path},
    )
    assert out.stdout.split() == ["True", "env", "True", "False"]


def test_broken_env_plan_is_ignored():
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro import faults; print(faults.active())"],
        capture_output=True, text=True, check=True,
        env={**os.environ, "PYTHONPATH": SRC,
             "REPRO_FAULTS": "/nonexistent/plan.json"},
    )
    assert out.stdout.strip() == "False"


# ---------------------------------------------------------------------------
# http: injected storms ride the real retry path; breaker + deadline
# ---------------------------------------------------------------------------

class _Echo(BaseHTTPRequestHandler):
    def do_GET(self):
        body = json.dumps({"ok": True}).encode()
        self.send_response(200)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *a):
        pass


@pytest.fixture
def echo_server():
    srv = ThreadingHTTPServer(("127.0.0.1", 0), _Echo)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    yield f"http://127.0.0.1:{srv.server_address[1]}"
    srv.shutdown()


def test_injected_503_burst_recovers_via_retry(echo_server):
    faults.install(FaultPlan().add("http.request", "error",
                                   status=503, times=2))
    out = request_json(echo_server + "/x", retries=3, backoff_s=0.01)
    assert out == {"ok": True}
    assert faults.stats()["by_point"]["http.request"] == 2


def test_injected_storm_exhausts_retries(echo_server):
    faults.install(FaultPlan().add("http.request", "error", status=503))
    with pytest.raises(HttpError) as ei:
        request_json(echo_server + "/x", retries=2, backoff_s=0.01)
    assert ei.value.code == 503


def test_total_deadline_caps_the_storm():
    t0 = time.perf_counter()
    with pytest.raises(HttpError):
        request_json("http://127.0.0.1:9", retries=50, backoff_s=0.5,
                     total_deadline_s=0.4)
    assert time.perf_counter() - t0 < 2.0


def test_breaker_opens_fast_fails_and_recloses(echo_server):
    br = CircuitBreaker(threshold=2, reset_s=0.15, name="t")
    faults.install(FaultPlan().add("http.request", "error",
                                   status=503, times=2))
    for _ in range(2):
        with pytest.raises(HttpError):
            request_json(echo_server + "/x", retries=0, breaker=br)
    assert br.state == "open"
    # fast-fail while open: no attempt reaches the wire
    with pytest.raises(HttpError, match="circuit_open"):
        request_json(echo_server + "/x", retries=0, breaker=br)
    time.sleep(0.2)
    assert br.state == "half_open"
    # half-open probe succeeds (fault budget spent) -> circuit recloses
    assert request_json(echo_server + "/x", retries=0,
                        breaker=br) == {"ok": True}
    assert br.state == "closed"


def test_breaker_failed_probe_reopens():
    br = CircuitBreaker(threshold=1, reset_s=0.1)
    br.record_failure()
    assert br.state == "open"
    time.sleep(0.12)
    assert br.allow()           # the probe slot
    assert not br.allow()       # only ONE probe at a time
    br.record_failure()
    assert br.state == "open"   # failed probe restarts the window


def test_nonretryable_4xx_does_not_trip_breaker(echo_server):
    br = CircuitBreaker(threshold=1)
    faults.install(FaultPlan().add("http.request", "error",
                                   status=404, times=1))
    with pytest.raises(HttpError):
        request_json(echo_server + "/x", retries=2, breaker=br)
    assert br.state == "closed"  # caller bug, not peer health


# ---------------------------------------------------------------------------
# graceful degradation through the stack
# ---------------------------------------------------------------------------

def test_scheduler_dispatch_fault_fails_waiters_cleanly():
    import numpy as np

    from repro.accel import MCMAccelerator
    from repro.core.acl.library import default_library
    from repro.service.scheduler import EvalScheduler
    from repro.service.store import EvalContext, InMemoryLabelStore

    ctx = EvalContext(MCMAccelerator(1), default_library(),
                      n_qor_samples=2)
    sched = EvalScheduler(InMemoryLabelStore(), n_workers=1)
    try:
        faults.install(FaultPlan().add("sched.dispatch", "error",
                                       times=1, message="chaos"))
        g = np.zeros((1, len(ctx.accel.slots)), dtype=np.int64)
        with pytest.raises(FaultInjected):
            sched.label(ctx, g, campaign="c1")
        faults.uninstall()
        labels = sched.label(ctx, g, campaign="c1")  # next batch is fine
        assert set(labels) >= {"qor", "energy"}
    finally:
        sched.shutdown()


def test_manager_health_blob():
    from repro.service import CampaignManager

    mgr = CampaignManager(eval_workers=1, campaign_workers=1)
    try:
        h = mgr.health()
        assert h["ok"] is True
        assert h["store"]["writable"] is True
        assert h["scheduler"]["alive"] is True
        assert h["faults"]["active"] is False
        faults.install(FaultPlan(name="armed"))
        assert mgr.health()["faults"]["plan"] == "armed"
    finally:
        mgr.shutdown()


def test_health_endpoint_and_client(tmp_path):
    import threading as _t

    from repro.service import CampaignManager
    from repro.service.api import Client, make_server
    from repro.service.store import open_label_store

    store = open_label_store(str(tmp_path / "labels.segd"))
    mgr = CampaignManager(store, eval_workers=1, campaign_workers=1)
    srv = make_server(mgr, port=0)
    _t.Thread(target=srv.serve_forever, daemon=True).start()
    try:
        cli = Client(f"http://127.0.0.1:{srv.server_address[1]}")
        h = cli.health()
        assert h["ok"] is True
        assert h["store"]["path"].endswith("labels.segd")
        assert "quarantined" in h["store"]
    finally:
        srv.shutdown()
        mgr.shutdown()
        store.close()
