"""End-to-end system behaviour: training converges, serving generates,
fault tolerance + training integrate, the paper's DSE runs on an LM."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ApproxPolicy, reduced


@pytest.fixture(scope="module")
def tiny_cfg():
    return reduced(get_config("gemma-2b"), n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                   vocab_size=128)


def test_training_reduces_loss(tiny_cfg):
    from repro.launch.train import train_loop

    _, losses = train_loop(tiny_cfg, steps=60, batch=8, seq=32,
                           lr=1e-2, log_every=100)
    first = float(np.mean(losses[:5]))
    last = float(np.mean(losses[-5:]))
    assert last < first - 0.3, (first, last)


def test_training_with_compression_and_micro(tiny_cfg):
    from repro.launch.train import train_loop

    _, losses = train_loop(tiny_cfg, steps=25, batch=8, seq=32, n_micro=4,
                           lr=5e-3, compress=True, log_every=100)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_training_restart_resumes(tiny_cfg, tmp_path):
    from repro.launch.train import train_loop

    d = str(tmp_path / "ck")
    train_loop(tiny_cfg, steps=10, batch=4, seq=16, ckpt_dir=d,
               ckpt_every=5, log_every=100)
    # resume to 20 — must pick up at 10, not restart at 0
    _, losses = train_loop(tiny_cfg, steps=20, batch=4, seq=16, ckpt_dir=d,
                           ckpt_every=5, log_every=100)
    assert len(losses) == 10  # only the remaining steps ran


def test_serving_generates(tiny_cfg):
    from repro.launch.serve import serve_batch

    tokens, tps = serve_batch(tiny_cfg, batch=2, prompt_len=8, gen=6)
    assert tokens.shape == (2, 14)
    assert tps > 0
    assert int(tokens.max()) < tiny_cfg.padded_vocab


def test_serving_with_approx_policy(tiny_cfg):
    from repro.launch.serve import serve_batch

    pol = ApproxPolicy({"ffn_in": ("mul8s_trunc2", None)})
    tokens, _ = serve_batch(tiny_cfg, batch=2, prompt_len=8, gen=4,
                            policy=pol)
    assert tokens.shape == (2, 12)


def test_lm_dse_end_to_end():
    """The paper's framework applied to an assigned architecture."""
    from repro.accel.lm import LMAccelerator, proj_classes_for
    from repro.core.acl.library import default_library
    from repro.core.dse import DSEConfig, run_dse
    from repro.core.nsga2 import NSGA2Config

    cfg = get_config("granite-8b")
    classes = proj_classes_for(reduced(cfg))
    assert {"qkv", "ffn_in", "lm_head"} <= {c for c, _ in classes}

    accel = LMAccelerator(cfg, seq=16)
    lib = default_library()
    res = run_dse(accel, lib, DSEConfig(
        n_train=10, n_qor_samples=1,
        nsga=NSGA2Config(pop_size=8, n_parents=4, n_generations=2, seed=0),
    ))
    assert res.front_mask.any()
    # the front reaches a reasonable-QoR corner even at this tiny budget
    assert res.true_objectives[:, 0].min() <= -20.0


def test_moe_family_dse_classes():
    from repro.accel.lm import proj_classes_for

    moe = proj_classes_for(reduced(get_config("phi3.5-moe-42b-a6.6b")))
    assert {"expert_in", "expert_out"} <= {c for c, _ in moe}
    ssm = proj_classes_for(reduced(get_config("falcon-mamba-7b")))
    names = {c for c, _ in ssm}
    assert {"ssm_in", "ssm_out"} <= names
    assert "qkv" not in names  # attention-free
