"""Pallas TPU kernel for the population LUT gather.

Tiling: grid over (genome blocks, element blocks).  The full LUT stack
(C, S, 256) rides along in VMEM — for the repo's libraries that is at
most ~19 x 28 x 256 int32 ≈ 0.5 MB, well under the VMEM budget — and
every (bg, bm) tile performs one flat gather:

    out[g, m, s] = lut[genes[g, s], s, cols[m, s]]

On TPU the gather lowers to VMEM dynamic-slices (same trade as
``approx_matmul.lut_matmul_pallas``); on CPU the kernel runs under
``interpret=True`` for validation only — the fused engine's CPU hot path
uses the plain XLA gather in ``ops.gather_xla``, which fuses into the
surrounding program.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["population_lut_gather_pallas"]


def _pop_lut_kernel(genes_ref, cols_ref, lut_ref, out_ref, *, nslots):
    genes = genes_ref[...]                      # (bg, S) int32
    cols = cols_ref[...]                        # (bm, S) or (bg, bm, S)
    flat = lut_ref[...].reshape(-1)             # (C*S*256,)
    sidx = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nslots), 2)
    if cols.ndim == 2:
        idx = (genes[:, None, :] * nslots + sidx) * 256 + cols[None, :, :]
    else:
        idx = (genes[:, None, :] * nslots + sidx) * 256 + cols
    out_ref[...] = jnp.take(flat, idx.reshape(-1), axis=0).reshape(idx.shape)


@functools.partial(
    jax.jit, static_argnames=("per_genome", "bg", "bm", "interpret")
)
def population_lut_gather_pallas(
    lut: jnp.ndarray,     # (C, S, 256) int32
    genes: jnp.ndarray,   # (G, S) int32
    cols: jnp.ndarray,    # (M, S) or (G, M, S) int32 table indices
    *,
    per_genome: bool = False,
    bg: int = 8,
    bm: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    C, S, _ = lut.shape
    G = genes.shape[0]
    M = cols.shape[-2]
    assert G % bg == 0 and M % bm == 0, (G, M, bg, bm)
    grid = (G // bg, M // bm)
    if per_genome:
        cols_spec = pl.BlockSpec((bg, bm, S), lambda i, j: (i, j, 0))
    else:
        cols_spec = pl.BlockSpec((bm, S), lambda i, j: (j, 0))
    kernel = functools.partial(_pop_lut_kernel, nslots=S)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bg, S), lambda i, j: (i, 0)),
            cols_spec,
            pl.BlockSpec((C, S, 256), lambda i, j: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bg, bm, S), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((G, M, S), lut.dtype),
        interpret=interpret,
    )(genes.astype(jnp.int32), cols.astype(jnp.int32), lut)
