"""Serving driver: batched prefill + autoregressive decode, CPU-runnable
at reduced scale.

    PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
        --reduced --batch 4 --prompt-len 32 --gen 16

The approximate-serving path draws its policy from a stored Pareto
front instead of a hand-picked circuit: ``--front front.json --tier
budget`` loads the front (the ``GET /front`` payload shape, or a
``FrontCatalog.to_json`` file), resolves the tier to a genome, and
decodes it to the ``ApproxPolicy`` baked into the jitted steps.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import ApproxPolicy, reduced
from ..models.common import init_tree
from ..models.transformer import param_specs
from ..train.serve import Generator

__all__ = ["serve_batch", "policy_from_front", "main"]


def serve_batch(
    cfg,
    *,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    policy: ApproxPolicy | None = None,
    seed: int = 0,
    params=None,
    prompts=None,
):
    """Greedy-decode `gen` tokens for a batch of (synthetic by default)
    prompts.  Returns (tokens (b, prompt+gen), tokens/s)."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = init_tree(param_specs(cfg), key)
    if prompts is None:
        prompts = jax.random.randint(
            key, (batch, prompt_len), 0, cfg.vocab_size)
    prompts = jnp.asarray(prompts, jnp.int32)
    g = Generator(cfg, policy=policy, attn_chunk=32, scan_chunk=8)
    return g.generate(params, prompts, gen, key=key)


def policy_from_front(cfg, front_path: str, tier: str = "balanced"):
    """(policy, selection) for ``tier`` of the stored front at
    ``front_path`` — the CLI's bridge from a DSE campaign's output to a
    runnable serving configuration."""
    from ..accel.lm import LMAccelerator
    from ..serving import FrontCatalog

    cat = FrontCatalog.from_file(front_path)
    expect = f"lm:{cfg.name}"
    if cat.accel != expect:
        print(f"[serve] WARNING: front is for {cat.accel!r}, "
              f"serving {expect!r}")
    sel = cat.select(tier=tier)
    accel = LMAccelerator(cfg, use_reduced=False)
    policy = accel.policy_for_genome(
        sel.point.genome_array(), rank_genes=cat.rank_genes)
    return policy, sel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="falcon-mamba-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--approx", default=None,
                    help="hand-picked circuit for ffn_in/ffn_out")
    ap.add_argument("--front", default=None,
                    help="stored front JSON (GET /front shape); the "
                         "policy comes from its --tier operating point")
    ap.add_argument("--tier", default="balanced",
                    choices=("exact", "balanced", "budget"))
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    policy = None
    if args.front and args.approx:
        ap.error("--front and --approx are mutually exclusive")
    if args.front:
        policy, sel = policy_from_front(cfg, args.front, args.tier)
        labels = " ".join(
            f"{k}={v:.3g}" for k, v in sel.point.labels.items())
        print(f"[serve] tier={args.tier} genome={list(sel.point.genome)} "
              f"({labels})")
    elif args.approx:
        policy = ApproxPolicy({
            "ffn_in": (args.approx, None), "ffn_out": (args.approx, None),
        })
    tokens, tps = serve_batch(
        cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen,
        policy=policy,
    )
    print(f"[serve] {cfg.name}: generated {tokens.shape} @ {tps:.1f} tok/s")
    print(tokens[0])


if __name__ == "__main__":
    main()
