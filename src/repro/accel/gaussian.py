"""The paper's motivational accelerator (Fig. 1): a 3x3 Gaussian filter
composed of nine 8-bit multipliers and eight 16-bit adders.

Kernel = [[1,2,1],[2,4,2],[1,2,1]] / 16.  Products are at most 255*4 and
the 9-term adder tree peaks below 2^16, so the 16-bit adder models apply
without wraparound in the exact case.

Deployment form: im2col matmul (n_pix, 9) @ (9, 1) with one K-column per
multiplier slot (DESIGN.md §2).
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.acl.library import Circuit
from .base import Accelerator, Slot
from .images import sample_images

__all__ = ["GaussianFilter", "GAUSS_COEFFS"]

GAUSS_COEFFS = np.array([1, 2, 1, 2, 4, 2, 1, 2, 1], dtype=np.int64)

# adder-tree wiring: pairs reduced in order; 8 adders for 9 operands
# a0=(p0,p1) a1=(p2,p3) a2=(p4,p5) a3=(p6,p7) a4=(a0,a1) a5=(a2,a3)
# a6=(a4,a5) a7=(a6,p8)
_TREE = [(0, 1), (2, 3), (4, 5), (6, 7), (9, 10), (11, 12), (13, 14), (15, 8)]


def _im2col(images: np.ndarray) -> np.ndarray:
    """(n, H, W) -> (n*(H-2)*(W-2), 9) sliding 3x3 windows."""
    n, h, w = images.shape
    cols = []
    for dy in range(3):
        for dx in range(3):
            cols.append(images[:, dy : h - 2 + dy, dx : w - 2 + dx].reshape(n, -1))
    return np.stack(cols, axis=-1).reshape(-1, 9)


class GaussianFilter(Accelerator):
    name = "gaussian3x3"
    slots = [Slot(f"mul{i}", "mul8u", 1.0) for i in range(9)] + [
        Slot(f"add{i}", "add16", 1.0) for i in range(8)
    ]

    def sample_inputs(self, n: int, seed: int = 0) -> np.ndarray:
        return sample_images(n, size=32, seed=seed)

    def _run(self, images: np.ndarray, muls: Sequence, adds: Sequence) -> np.ndarray:
        cols = _im2col(images)  # (m, 9)
        prods = [muls[i](cols[:, i], GAUSS_COEFFS[i]) for i in range(9)]
        vals = list(prods)  # indices 0..8; adder outputs appended as 9..16
        for fn, (ia, ib) in zip(adds, _TREE):
            vals.append(fn(vals[ia], vals[ib]))
        acc = vals[-1]
        out = acc >> 4  # /16
        n, h, w = images.shape
        return out.reshape(n, h - 2, w - 2)

    def simulate(self, circuits: Sequence[Circuit], inputs: np.ndarray) -> np.ndarray:
        muls = [c.fn for c in circuits[:9]]
        adds = [c.fn for c in circuits[9:]]
        return self._run(inputs, muls, adds)

    def exact_output(self, inputs: np.ndarray) -> np.ndarray:
        exact_mul = lambda a, b: a * b
        exact_add = lambda a, b: a + b
        return self._run(inputs, [exact_mul] * 9, [exact_add] * 8)

    # --- deployment -------------------------------------------------------
    def matmul_shape(self) -> Tuple[int, int, int]:
        return (900, 9, 1)  # 32x32 image -> 900 windows

    def slot_groups(self) -> List[Tuple[int, int]]:
        return [(i, i + 1) for i in range(9)]

    def mul_slot_constants(self):
        return [int(c) for c in GAUSS_COEFFS]

    def build_deploy(self, specs: Sequence, inputs: Optional[np.ndarray] = None):
        """-> (jax_fn, args): the rank-k MXU deployment of this variant.

        Weight operand = the Gaussian coefficients (constants); activation
        operand = the im2col'd image windows.
        """
        import jax.numpy as jnp

        from ..kernels.approx_matmul import grouped_matmul

        if inputs is None:
            inputs = self.sample_inputs(1, seed=1)
        x = jnp.asarray(_im2col(inputs))                 # (m, 9)
        w = jnp.asarray(GAUSS_COEFFS.reshape(9, 1))      # (9, 1)
        groups = self.slot_groups()

        def fn(x, w):
            return grouped_matmul(x, w, specs, groups)

        return fn, (x, w)
