"""Pure random search as an ask/tell strategy.

The exploration baseline of the paper's Figs. 8/9: draw genomes
uniformly, keep the non-dominated survivors.  ``random_search`` in
``core.dse`` drives this class with ground-truth labels directly (one
round covering the whole budget, so its labeler sees exactly the legacy
batch); through a ``Campaign`` it spends the same surrogate budget as
NSGA-II, which is what ``benchmarks/strategy_quality.py`` compares.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from ..nsga2 import GenerationLog, NSGA2Result, _select_parents
from ..pareto import non_dominated_mask
from .base import SearchStrategy, decode_array, encode_array

__all__ = ["RandomStrategy"]


class RandomStrategy(SearchStrategy):
    name = "random"

    def __init__(
        self,
        gene_sizes,
        *,
        n_total: int = 1000,
        batch_size: Optional[int] = None,
        n_parents: Optional[int] = None,
        seed: int = 0,
        keep_history: bool = True,
    ):
        self.gene_sizes = np.asarray(gene_sizes, dtype=np.int64)
        self.n_total = int(n_total)
        self.batch_size = int(batch_size) if batch_size else self.n_total
        self.n_parents = n_parents          # None = keep every observation
        self.seed = int(seed)
        self.keep_history = keep_history
        self._rng = np.random.default_rng(self.seed)
        self._drawn = 0
        self._round = 0
        self._pending: Optional[np.ndarray] = None
        self._obs_g: List[np.ndarray] = []  # observed batches, ask order
        self._obs_o: List[np.ndarray] = []
        self.n_evaluated = 0
        self.history: List[GenerationLog] = []

    # ------------------------------------------------------------------
    @property
    def done(self) -> bool:
        return self._drawn >= self.n_total and self._pending is None

    def ask(self) -> np.ndarray:
        if self.done:
            raise RuntimeError("strategy is done; ask() has no next batch")
        if self._pending is None:
            n = min(self.batch_size, self.n_total - self._drawn)
            self._pending = self._rng.integers(
                0, self.gene_sizes[None, :], size=(n, len(self.gene_sizes))
            )
            self._drawn += n
        return self._pending

    def tell(self, genomes, objectives) -> Optional[GenerationLog]:
        genomes = self._check_tell(self._pending, genomes)
        objectives = np.asarray(objectives, dtype=np.float64)
        self._obs_g.append(np.array(genomes))
        self._obs_o.append(objectives)
        self.n_evaluated += len(genomes)
        log = GenerationLog(self._round, np.array(genomes), objectives,
                            self.n_evaluated)
        if self.keep_history:
            self.history.append(log)
        self._round += 1
        self._pending = None
        return log

    def result(self) -> NSGA2Result:
        if not self._obs_g:
            raise RuntimeError("no population evaluated yet")
        G = np.concatenate(self._obs_g)
        O = np.concatenate(self._obs_o)
        if self.n_parents is not None and self.n_parents < len(G):
            G, O, _ = _select_parents(G, O, self.n_parents)
        return NSGA2Result(
            genomes=G,
            objectives=O,
            front_mask=non_dominated_mask(O),
            history=self.history,
            n_evaluated=self.n_evaluated,
        )

    def progress(self) -> Dict:
        return {
            "strategy": self.name,
            "generation": int(self._round),
            "n_generations": -(-self.n_total // self.batch_size),
            "surrogate_evals": int(self.n_evaluated),
            "done": bool(self.done),
        }

    # ------------------------------------------------------------------
    def state(self) -> Dict:
        return {
            "name": self.name,
            "gene_sizes": encode_array(self.gene_sizes),
            "n_total": self.n_total,
            "batch_size": self.batch_size,
            "n_parents": self.n_parents,
            "seed": self.seed,
            "rng": self._rng.bit_generator.state,
            "drawn": self._drawn,
            "round": self._round,
            "pending": encode_array(self._pending),
            "obs_g": [encode_array(a) for a in self._obs_g],
            "obs_o": [encode_array(a) for a in self._obs_o],
            "n_evaluated": self.n_evaluated,
        }

    def restore(self, state: Dict) -> "RandomStrategy":
        self.gene_sizes = decode_array(state["gene_sizes"])
        g = len(self.gene_sizes)
        self.n_total = state["n_total"]
        self.batch_size = state["batch_size"]
        self.n_parents = state["n_parents"]
        self.seed = state["seed"]
        self._rng = np.random.default_rng()
        self._rng.bit_generator.state = state["rng"]
        self._drawn = state["drawn"]
        self._round = state["round"]
        self._pending = decode_array(state["pending"], width=g)
        self._obs_g = [decode_array(a, width=g) for a in state["obs_g"]]
        self._obs_o = [decode_array(a, dtype=np.float64)
                       for a in state["obs_o"]]
        self.n_evaluated = state["n_evaluated"]
        self.history = []
        return self
