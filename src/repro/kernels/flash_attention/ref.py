"""Attention references.

``mha_reference`` — naive O(s^2)-memory softmax attention (the oracle).
``chunked_attention`` — online-softmax over KV chunks via lax.scan:
O(s*chunk) activation memory, differentiable, shardable.  This is the
production attention used by the model stack for long sequences (the
32k-prefill shapes would otherwise materialize multi-PB score tensors).

All functions take (batch, heads, seq, head_dim) layouts and support GQA
via ``kv_heads < heads`` (heads are grouped onto kv heads).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["mha_reference", "chunked_attention", "repeat_kv", "set_chunk_remat"]

NEG_INF = -1e30

# Perf toggle (§Perf hillclimb): remat the KV-chunk body so backward
# recomputes scores per chunk instead of stashing every chunk's
# (b, h, q, chunk) f32 score/prob residuals — the flash-attention
# backward recompute strategy, expressed at the XLA level.
# Default ON since the hillclimb validated it (EXPERIMENTS.md §Perf):
# -33% peak memory, -11% step time on the gemma cell; required for the
# qwen batch-TP variant to approach the HBM budget.
CHUNK_REMAT = True


def set_chunk_remat(on: bool) -> None:
    global CHUNK_REMAT
    CHUNK_REMAT = bool(on)


def repeat_kv(kv: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(b, kvh, s, d) -> (b, kvh*n_rep, s, d) by repetition (GQA)."""
    if n_rep == 1:
        return kv
    b, h, s, d = kv.shape
    return jnp.broadcast_to(kv[:, :, None], (b, h, n_rep, s, d)).reshape(
        b, h * n_rep, s, d
    )


def mha_reference(
    q: jnp.ndarray,           # (b, h, sq, d)
    k: jnp.ndarray,           # (b, kvh, sk, d)
    v: jnp.ndarray,           # (b, kvh, sk, d)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    q_offset: int = 0,        # absolute position of q[0] (decode steps)
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    kvh = k.shape[1]
    k = repeat_kv(k, h // kvh)
    v = repeat_kv(v, h // kvh)
    scale = scale if scale is not None else d ** -0.5
    scores = jnp.einsum("bhqd,bhkd->bhqk", q * scale, k).astype(jnp.float32)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(k.shape[2])
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)


def chunked_attention(
    q: jnp.ndarray,           # (b, h, sq, d)
    k: jnp.ndarray,           # (b, kvh, sk, d)
    v: jnp.ndarray,           # (b, kvh, sk, d)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    chunk: int = 1024,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Flash-style online softmax, scanning KV in chunks.

    Memory O(b*h*sq*(d + chunk)) instead of O(b*h*sq*sk)."""
    b, h, sq, d = q.shape
    kvh = k.shape[1]
    n_rep = h // kvh
    sk = k.shape[2]
    if sk % chunk != 0:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kvalid = sk
        sk = sk + pad
    else:
        kvalid = sk
    n_chunks = sk // chunk
    scale = scale if scale is not None else d ** -0.5
    qs = (q * scale).astype(jnp.float32)
    qpos = jnp.arange(sq) + q_offset

    kc = k.reshape(b, kvh, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, kvh, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)

    def body(carry, inputs):
        m, l, acc = carry
        kb, vb, c_idx = inputs
        kb = repeat_kv(kb, n_rep).astype(jnp.float32)
        vb = repeat_kv(vb, n_rep).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qs, kb)
        kpos = c_idx * chunk + jnp.arange(chunk)
        valid = kpos < kvalid
        if causal:
            valid = valid[None, :] & (kpos[None, :] <= qpos[:, None])
            s = jnp.where(valid[None, None], s, NEG_INF)
        else:
            s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (m_new, l_new, acc_new), None

    if CHUNK_REMAT:
        body = jax.checkpoint(body)

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
