"""Distributed substrate: logical-axis sharding rules and jax-version
compatibility helpers (see ``sharding`` and ``compat``)."""
