"""XLA 'synthesis' — our Vivado tool-chain analogue (ground-truth labels).

The paper's ground truth for one accelerator variant is a full Vivado
synthesis run (minutes/design): LUTs, power, delay.  Ours is a full XLA
lower+compile of the variant's rank-k MXU deployment (seconds/design):
``cost_analysis()`` FLOPs and bytes, turned into roofline latency and
energy on TPU v5e constants (core/hw.py).  The QoR ground truth is the
bit-exact behavioral simulation (accel.simulate).

Both are deliberately the *slow* path; the whole point of the paper is to
call them O(n_train + n_final) times instead of O(|space|).
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import
    from ...accel.base import Accelerator
from ... import faults, obs
from ...core.acl.library import Circuit, Library
from ...segments import SegmentedLog
from .. import hw

__all__ = [
    "SynthResult",
    "SynthCache",
    "JsonlSynthCache",
    "SegmentedSynthCache",
    "open_synth_cache",
    "synthesize_variant",
    "synthesize_batch",
    "circuit_features_synth",
    "label_variants",
    "shared_synth_cache",
    "set_shared_synth_cache",
    "synth_stats",
    "reset_fast_codegen",
    "LABEL_KEYS",
    "DEFAULT_QOR_SEED",
    "SYNTH_AC_DIM",
]

SYNTH_AC_DIM = 6

# the per-genome record label_variants produces (the service label
# store persists exactly these keys — keep the two in sync by import)
LABEL_KEYS = ("qor", "latency", "energy", "flops", "hbm_bytes",
              "synth_time", "sim_time")

# default seed for the QoR evaluation inputs: shared by the in-process
# default labeler (core/dse.py) and the service EvalContext so both
# paths label identically (and derive identical store keys)
DEFAULT_QOR_SEED = 1234


class SynthResult(dict):
    """{'flops', 'hbm_bytes', 'latency', 'energy', 'wall_time'}"""


# --- guarded fast codegen ---------------------------------------------------
# Ground-truth labels read HLO-level quantities (flops, bytes accessed)
# off compiled_cost_analysis; most of the compile wall is backend code
# GENERATION, which does not enter them.  FAST_CODEGEN compiles
# synthesis probes at LLVM opt level 0, without expensive LLVM passes,
# on the non-thunk runtime (~2x faster on multi-slot deploys) — but the
# options are only trusted per GRAPH FAMILY after verification: the
# first compile of each ``fast_key`` runs BOTH ways and compares the
# cost-analysis keys the labels read.  Families where any option leaks
# into HLO-level cost (e.g. the LM forward under the non-thunk runtime)
# are pinned to default codegen, keeping labels byte-identical to the
# seed engine by construction.  REPRO_SYNTH_FAST=0 disables the whole
# mechanism; unknown options degrade to a default compile.
FAST_CODEGEN = os.environ.get("REPRO_SYNTH_FAST", "1") != "0"
_FAST_COMPILER_OPTIONS = {
    "xla_backend_optimization_level": 0,
    "xla_llvm_disable_expensive_passes": True,
    "xla_cpu_use_thunk_runtime": False,
    "xla_cpu_copy_insertion_use_region_analysis": False,
}
_COST_KEYS = ("flops", "bytes accessed")
# The verdict is per graph FAMILY (one accelerator's build_deploy /
# one circuit kind's canonical probe), verified on the family's first
# few distinct graphs rather than every graph — per-graph verification
# would double-compile everything and erase the speedup.  Family-level
# sampling is sound because option leakage into HLO-level cost is
# driven by op-type coverage (e.g. the thunk runtime rewrites
# control-flow ops, which is why the LM forward diverges and is pinned
# to default codegen on its very first compile), and graphs within one
# family share op types, differing only in per-slot rank/width counts.
# Residual risk is bounded by REPRO_SYNTH_FAST=0.
_FAST_VERIFY_SAMPLES = 2
# fast_key -> remaining verifications (int countdown) | False (diverged)
_FAST_VERDICT: Dict[str, object] = {}


# --- structural compile keying ---------------------------------------------
# The compiled cost numbers the labels read (HLO-level flops / bytes
# accessed) are determined by the deployment graph's STRUCTURE — matmul
# shapes, slot-group widths, per-slot deployment class (rank, truncated
# width, signedness), pass count — not by which named circuit fills a
# slot (the rank-1 family alone holds 7 interchangeable circuits, and
# slot PERMUTATIONS of equal-width groups compile to isomorphic graphs).
# Keying compiles on ``Accelerator.deploy_signature`` therefore collapses
# distinct compiles from O(|library|^slots) circuit identities to
# O(distinct structures), and makes the cache survive context changes
# (QoR sample count / seed) and accelerator renames (a pipeline's stage
# view shares the standalone accelerator's compiles).
#
# The invariance is VERIFIED, not assumed, with the proven _FAST_VERDICT
# scheme: the first ``_STRUCT_VERIFY_SAMPLES`` structural collisions of
# each graph FAMILY (one accelerator's builder; classes vary within it)
# compile the colliding identity anyway and compare the cost keys the
# labels read.  A family whose numbers ever diverge is pinned to exact
# identity keys.  REPRO_SYNTH_STRUCTURAL=0 kills structural sharing
# entirely (identity-keyed caching, the seed engine's semantics).
STRUCTURAL_KEYS = os.environ.get("REPRO_SYNTH_STRUCTURAL", "1") != "0"
_STRUCT_VERIFY_SAMPLES = 2

# REPRO_SYNTH_COMPILE_WORKERS>1 compiles a batch's unique structures on a
# thread pool.  Default is serial: jaxlib 0.4.x's CPU client serializes
# compilation internally (measured 0.73-0.89x with 2 threads), so the
# knob only pays off on jaxlibs whose compile path truly releases the
# GIL; batch-level parallelism normally comes from the process pool
# (service/workers.py) instead.
COMPILE_WORKERS = int(os.environ.get("REPRO_SYNTH_COMPILE_WORKERS", "1") or 1)

# cache-key salt: a jax/jaxlib upgrade or a label-semantics change must
# MISS a persisted compile cache instead of serving stale cost numbers
SYNTH_CACHE_SCHEMA_VERSION = 1


def _cache_salt() -> str:
    try:
        import jax

        jv = jax.__version__
    except Exception:  # noqa: BLE001 - digests still stable without jax
        jv = "nojax"
    return f"v{SYNTH_CACHE_SCHEMA_VERSION}|jax{jv}"


def _digest(tag: str, payload: object) -> str:
    h = hashlib.sha256(f"{tag}|{_cache_salt()}|{payload!r}".encode())
    return h.hexdigest()[:24]


def _identity_signature(accel, specs) -> tuple:
    """Exact per-slot circuit identity (the seed engine's cache key)."""
    return (accel.name,) + tuple(
        (s.name, s.rank, s.trunc_bits) for s in specs
    )


def _structural_signature(accel, specs) -> Optional[Tuple[tuple, tuple]]:
    """``(family, classes)`` from the accelerator's signature hook, or
    None when the accelerator opts out (no hook / hook returns None)."""
    hook = getattr(accel, "deploy_signature", None)
    if hook is None:
        return None
    try:
        sig = hook(specs)
    except NotImplementedError:
        return None
    if sig is None:
        return None
    family, classes = sig
    return tuple(family), tuple(classes)


class SynthCache:
    """Shared compile-cost cache with two tiers.

    * identity tier — keyed on the exact per-slot circuit assignment;
      hits are safe unconditionally (same graph, deterministic compile).
    * structural tier — keyed on ``deploy_signature``; a hit recorded by
      a DIFFERENT identity is only served after the graph family passed
      its first-K verification compiles (see module comment).

    One instance is shared process-wide by default (``shared_synth_
    cache``) so every evaluation context, campaign and scheduler worker
    reuses one compile pool; ``JsonlSynthCache`` adds persistence.
    Thread-safe; records hold only the compile-derived numbers
    ({'flops', 'hbm_bytes'}) — everything else in a label is recomputed
    per variant from its circuits and ranks."""

    def __init__(self):
        self._lock = threading.RLock()
        self._by_id: Dict[str, dict] = {}
        self._by_struct: Dict[str, dict] = {}
        # family digest -> remaining verifications | False (pinned)
        self._verdicts: Dict[str, object] = {}
        # registry instruments (idempotent-replace: the live process-
        # shared cache is the one a /metrics scrape sees); increments
        # stay under the cache lock they always ran under
        reg = obs.REGISTRY
        self.hits_identity = reg.counter(
            "repro_synth_identity_hits_total",
            "compiles served from the identity tier")
        self.hits_structural = reg.counter(
            "repro_synth_structural_hits_total",
            "compiles served from the verified structural tier")
        self.compiles = reg.counter(
            "repro_synth_compiles_total", "XLA compiles paid")
        self.verify_compiles = reg.counter(
            "repro_synth_verify_compiles_total",
            "compiles spent verifying a structural family")
        self.pinned_families = reg.counter(
            "repro_synth_pinned_families_total",
            "graph families pinned to identity-only caching")
        self.compile_seconds = reg.histogram(
            "repro_synth_compile_seconds", "wall seconds per XLA compile")

    # -- lookups -------------------------------------------------------
    def get_identity(self, idd: str) -> Optional[dict]:
        with self._lock:
            rec = self._by_id.get(idd)
            if rec is not None:
                self.hits_identity.inc()
            return rec

    def get_structural(self, sdd: str) -> Optional[dict]:
        with self._lock:
            return self._by_struct.get(sdd)

    # -- stores --------------------------------------------------------
    def store(self, rec: dict, *, verify: bool = False) -> None:
        """Record one compile: ``rec`` carries k (identity digest),
        flops, hbm_bytes and optionally s (structural digest) + fam."""
        with self._lock:
            self.compiles.inc()
            if verify:
                self.verify_compiles.inc()
            self._store_locked(dict(rec))

    def store_alias(self, rec: dict) -> None:
        """Record a STRUCTURAL SERVE: the identity now maps to numbers
        another identity compiled.  Counted as a hit, not a compile (and
        persisted, so a warm run answers it from the identity tier)."""
        with self._lock:
            self.hits_structural.inc()
            self._store_locked(dict(rec))

    def _store_locked(self, rec: dict) -> None:
        self._by_id[rec["k"]] = rec
        sdd = rec.get("s")
        if sdd is not None and sdd not in self._by_struct:
            self._by_struct[sdd] = rec

    # -- family verdicts -----------------------------------------------
    def verdict(self, fam: str):
        """Remaining verification compiles for a family (int countdown)
        or False once the family diverged and is identity-pinned."""
        with self._lock:
            return self._verdicts.get(fam, _STRUCT_VERIFY_SAMPLES)

    def verdict_pass(self, fam: str) -> None:
        with self._lock:
            v = self._verdicts.get(fam, _STRUCT_VERIFY_SAMPLES)
            if v is not False and v > 0:
                self._set_verdict_locked(fam, v - 1)

    def verdict_pin(self, fam: str) -> None:
        with self._lock:
            if self._verdicts.get(fam) is not False:
                self.pinned_families.inc()
            self._set_verdict_locked(fam, False)
            # structural records of a pinned family must never serve
            # other identities again
            self._by_struct = {
                s: r for s, r in self._by_struct.items()
                if r.get("fam") != fam
            }

    def _set_verdict_locked(self, fam: str, v) -> None:
        self._verdicts[fam] = v

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._by_id)

    def stats(self) -> Dict[str, float]:
        compiles = int(self.compiles.value)
        served = int(self.hits_identity.value) + int(
            self.hits_structural.value)
        total = served + compiles
        with self._lock:
            return {
                "entries": len(self._by_id),
                "structures": len(self._by_struct),
                "compiles": compiles,
                "verify_compiles": int(self.verify_compiles.value),
                "identity_hits": int(self.hits_identity.value),
                "structural_hits": int(self.hits_structural.value),
                "hit_rate": (served / total) if total else 0.0,
                "pinned_families": int(self.pinned_families.value),
                # v is False means PINNED, not verified — and False == 0
                # in Python, so the identity check is load-bearing
                "verified_families": sum(
                    1 for v in self._verdicts.values()
                    if v is not False and v == 0
                ),
            }


class JsonlSynthCache(SynthCache):
    """Persistent ``SynthCache``: an append-only JSON-lines sidecar next
    to the service's ``JsonlLabelStore``.

    One record per compile: ``{"k": <identity digest>, "s": <structural
    digest|null>, "fam": <family digest|null>, "c": {"flops", "hbm_
    bytes"}}``; family verification progress persists as ``{"fam": ...,
    "v": <countdown|"pinned">}`` lines so a warm process continues where
    the cold one stopped (a fully verified family does ZERO verification
    compiles after a restart).  Concurrent writers (scheduler threads,
    process-pool labeler workers) append under the same torn-tail replay
    discipline as ``JsonlLabelStore``: the tail is re-read before every
    append, so one cache file is safely shared by many processes."""

    def __init__(self, path: str):
        super().__init__()
        self.path = str(path)
        self._offset = 0
        self._fh = None
        self.quarantined = 0  # malformed/torn records dropped, counted
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        with self._lock:
            self._replay_locked()

    def _replay_locked(self) -> None:
        if not os.path.exists(self.path):
            return
        # errors="replace": undecodable bit-rot must fail a line's CRC,
        # not crash the replay
        with open(self.path, errors="replace") as f:
            f.seek(self._offset)
            while True:
                pos = f.tell()
                line = f.readline()
                if not line or not line.endswith("\n"):
                    self._offset = pos   # torn tail: re-read next time
                    return
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    # malformed complete line: dropped, but counted and
                    # logged — never a silent swallow
                    self.quarantined += 1
                    obs.get_logger("synth.cache").warning(
                        "quarantined malformed record in %s @%d",
                        self.path, pos)
                    continue
                if "k" in rec and "c" in rec:
                    # base-class store: replayed records must not be
                    # re-appended to the file they came from
                    SynthCache._store_locked(self, {
                        "k": rec["k"], "s": rec.get("s"),
                        "fam": rec.get("fam"),
                        "flops": float(rec["c"]["flops"]),
                        "hbm_bytes": float(rec["c"]["hbm_bytes"]),
                    })
                elif "fam" in rec and "v" in rec:
                    v = rec["v"]
                    SynthCache._set_verdict_locked(
                        self, rec["fam"], False if v == "pinned" else int(v)
                    )

    def refresh(self) -> int:
        """Pick up records other processes appended since the last read."""
        with self._lock:
            self._replay_locked()
            return len(self._by_id)

    def _append_locked(self, obj: dict) -> None:
        if self._fh is None:
            self._fh = open(self.path, "a")
        # consume any foreign tail BEFORE appending so advancing the
        # offset can never skip another process's records
        self._replay_locked()
        # a torn tail from a dead writer would merge with our record and
        # destroy both; newline-terminate it so it quarantines alone
        try:
            size = os.path.getsize(self.path)
        except OSError:
            size = 0
        if size > self._offset:
            torn = size - self._offset
            self._fh.write("\n")
            self._fh.flush()
            self._offset = self._fh.tell()
            self.quarantined += 1
            obs.get_logger("synth.cache").warning(
                "repaired torn tail in %s (%d bytes quarantined)",
                self.path, torn)
        self._fh.write(json.dumps(obj, sort_keys=True) + "\n")
        self._fh.flush()
        self._offset = self._fh.tell()

    def _store_locked(self, rec: dict) -> None:
        fresh = rec["k"] not in self._by_id
        super()._store_locked(rec)
        if fresh:
            self._append_locked({
                "k": rec["k"], "s": rec.get("s"), "fam": rec.get("fam"),
                "c": {"flops": rec["flops"], "hbm_bytes": rec["hbm_bytes"]},
            })

    def _set_verdict_locked(self, fam: str, v) -> None:
        cur = self._verdicts.get(fam, _STRUCT_VERIFY_SAMPLES)
        # False (pinned) and 0 (verified) compare equal in Python; a pin
        # arriving after the countdown reached 0 MUST still persist, or
        # a warm replay would serve a family proven divergent
        changed = (cur is False) != (v is False) or (
            v is not False and cur != v
        )
        super()._set_verdict_locked(fam, v)
        if changed:
            self._append_locked(
                {"fam": fam, "v": "pinned" if v is False else int(v)}
            )

    def stats(self) -> Dict[str, float]:
        s = super().stats()
        s["path"] = self.path
        s["quarantined"] = self.quarantined
        return s

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __del__(self):  # best-effort
        try:
            self.close()
        except Exception:
            pass


class SegmentedSynthCache(SynthCache):
    """Persistent ``SynthCache`` on the segmented CRC-framed log
    (:mod:`repro.segments`) — the fleet-grade replacement for one big
    ``JsonlSynthCache`` sidecar.

    Record shapes are identical to ``JsonlSynthCache``'s (compiles and
    family-verdict lines), but they live in fixed-size sealed segments
    with per-record CRCs and a manifest: a damaged record or segment is
    quarantined and counted (the lost compiles simply re-compile)
    instead of poisoning a warm replay, and all appends/seals run under
    one cross-process ``flock``.  Replay is eager — the compile cache is
    small next to the label store and every record is needed to answer
    lookups — but it is CRC-verified end to end."""

    def __init__(self, path: str, *, segment_records: int = 4096):
        super().__init__()
        self.path = str(path)
        self._seglog = SegmentedLog(self.path,
                                    segment_records=segment_records,
                                    name="synth")
        self._known_segs = set()
        with self._lock:
            with self._seglog.lock():
                self._sync_cache_locked()

    # -- replay ---------------------------------------------------------
    def _ingest_locked(self, rec) -> None:
        if not isinstance(rec, dict):
            return
        if "k" in rec and "c" in rec:
            SynthCache._store_locked(self, {
                "k": rec["k"], "s": rec.get("s"),
                "fam": rec.get("fam"),
                "flops": float(rec["c"]["flops"]),
                "hbm_bytes": float(rec["c"]["hbm_bytes"]),
            })
        elif "fam" in rec and "v" in rec:
            v = rec["v"]
            SynthCache._set_verdict_locked(
                self, rec["fam"], False if v == "pinned" else int(v))

    def _sync_cache_locked(self) -> None:
        m, tail = self._seglog.sync_locked()
        for e in m["sealed"]:
            name = e["name"]
            if name in self._known_segs:
                continue
            self._known_segs.add(name)
            try:
                recs, bad = self._seglog.read_segment(name)
            except OSError as err:
                recs, bad, reason = [], -1, f"unreadable: {err}"
            else:
                reason = f"{bad} damaged records"
            if bad:
                if bad > 0:
                    self._seglog.quarantined_records += bad
                self._seglog.quarantine_locked(name, reason)
                self._known_segs.discard(name)
                # salvaged records still serve; the rest re-compile
            for rec in recs:
                self._ingest_locked(rec)
        for rec in tail:
            self._ingest_locked(rec)

    def refresh(self) -> int:
        """Pick up records other processes appended/sealed."""
        with self._lock:
            with self._seglog.lock():
                self._sync_cache_locked()
            return len(self._by_id)

    # -- writes ---------------------------------------------------------
    def _append(self, obj: dict) -> None:
        with self._seglog.lock():
            self._sync_cache_locked()
            self._seglog.append_locked([obj])

    def _store_locked(self, rec: dict) -> None:
        fresh = rec["k"] not in self._by_id
        super()._store_locked(rec)
        if fresh:
            self._append({
                "k": rec["k"], "s": rec.get("s"), "fam": rec.get("fam"),
                "c": {"flops": rec["flops"],
                      "hbm_bytes": rec["hbm_bytes"]},
            })

    def _set_verdict_locked(self, fam: str, v) -> None:
        cur = self._verdicts.get(fam, _STRUCT_VERIFY_SAMPLES)
        changed = (cur is False) != (v is False) or (
            v is not False and cur != v
        )
        super()._set_verdict_locked(fam, v)
        if changed:
            self._append(
                {"fam": fam, "v": "pinned" if v is False else int(v)}
            )

    def stats(self) -> Dict[str, float]:
        s = super().stats()
        s["path"] = self.path
        seg = self._seglog.stats()
        s["quarantined"] = seg.pop("quarantined")
        s.update(seg)
        return s

    def close(self) -> None:
        with self._lock:
            self._seglog.close()

    def __del__(self):  # best-effort
        try:
            self.close()
        except Exception:
            pass


def open_synth_cache(path: str, *, migrate: bool = False,
                     **kw) -> SynthCache:
    """Open the right persistent compile cache for ``path``: a legacy
    single-file ``<name>.jsonl`` with ``migrate=True`` auto-migrates
    *warm* into a segmented root at ``<name>.segd`` (old file kept as
    ``.migrated``); without ``migrate`` a ``.jsonl`` path opens the
    already-migrated root when one exists, else the plain
    :class:`JsonlSynthCache` — replicas never rename a file another
    process may still be appending to.  Any other path is a segmented
    root directly."""
    p = str(path)
    if not p.endswith(".jsonl"):
        return SegmentedSynthCache(p, **kw)
    root = p[:-len(".jsonl")] + ".segd"
    if not migrate:
        if os.path.isdir(root) and not os.path.isfile(p):
            return SegmentedSynthCache(root, **kw)
        return JsonlSynthCache(p, **kw)
    cache = SegmentedSynthCache(root, **kw)
    if os.path.isfile(p):
        legacy = []
        with open(p) as f:
            for line in f:
                if not line.endswith("\n"):
                    continue  # torn legacy tail
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if isinstance(rec, dict) and (
                        ("k" in rec and "c" in rec)
                        or ("fam" in rec and "v" in rec)):
                    legacy.append(rec)
        if legacy:
            with cache._lock:
                for rec in legacy:
                    cache._ingest_locked(rec)
                with cache._seglog.lock():
                    cache._seglog.sync_locked()
                    cache._seglog.append_locked(legacy)
        try:
            os.replace(p, p + ".migrated")
        except OSError:  # a concurrent migrator won the rename
            pass
        obs.get_logger("synth.cache").info(
            "migrated %d records from %s into %s", len(legacy), p, root)
    return cache


# the process-wide default cache: every label_variants call that does
# not inject its own cache shares this one, so distinct evaluation
# contexts (different QoR sampling, stage views vs their standalone
# accelerator) stop recompiling each other's structures
_SHARED_CACHE = SynthCache()


def shared_synth_cache() -> SynthCache:
    return _SHARED_CACHE


def set_shared_synth_cache(cache: SynthCache) -> SynthCache:
    """Swap the process-default compile cache (e.g. for a persistent
    ``JsonlSynthCache``); returns the previous one."""
    global _SHARED_CACHE
    prev, _SHARED_CACHE = _SHARED_CACHE, cache
    return prev


def synth_stats() -> Dict[str, object]:
    """Process-wide synthesis engine counters (for ``GET /stats``)."""
    return {
        "structural_keys": STRUCTURAL_KEYS,
        "fast_codegen": FAST_CODEGEN,
        "compile_workers": COMPILE_WORKERS,
        "cache": _SHARED_CACHE.stats(),
    }


def reset_fast_codegen() -> None:
    """Reset every module-global verification/caching state: the fast-
    codegen verdicts AND the structural verdicts + shared compile cache.
    Test fixtures and pool workers call this so one test's (or one
    context's) verification history can never leak into another."""
    global _SHARED_CACHE
    _FAST_VERDICT.clear()
    _SHARED_CACHE = SynthCache()


def _cost_numbers(compiled) -> Dict[str, float]:
    from ...dist.compat import compiled_cost_analysis

    ca = compiled_cost_analysis(compiled)
    return {k: float(ca.get(k, 0.0)) for k in _COST_KEYS}


def _compile_cost(fn, args, *, fast_key: Optional[str] = None) -> Dict[str, float]:
    import jax

    t0 = time.perf_counter()
    lowered = jax.jit(fn).lower(*args)
    compiled = None
    if FAST_CODEGEN and fast_key is not None:
        verdict = _FAST_VERDICT.get(fast_key, _FAST_VERIFY_SAMPLES)
        if verdict is not False and verdict > 0:
            # verification compile: both ways, compare what labels read
            ref = lowered.compile()
            try:
                fast = lowered.compile(dict(_FAST_COMPILER_OPTIONS))
                ok = _cost_numbers(fast) == _cost_numbers(ref)
            except Exception:  # noqa: BLE001 - unknown option / old jax
                ok = False
            _FAST_VERDICT[fast_key] = (verdict - 1) if ok else False
            compiled = ref
        elif verdict is not False:
            try:
                compiled = lowered.compile(dict(_FAST_COMPILER_OPTIONS))
            except Exception:  # noqa: BLE001
                compiled = None
    if compiled is None:
        compiled = lowered.compile()
    wall = time.perf_counter() - t0
    ca = _cost_numbers(compiled)
    flops = ca["flops"]
    byts = ca["bytes accessed"]
    rt = hw.roofline(flops, byts, 0.0)
    return {
        "flops": flops,
        "hbm_bytes": byts,
        "latency": rt.t_serial,
        "energy": rt.energy,
        "wall_time": wall,
    }


def _adjusted_compute(accel, circuits, ranks) -> float:
    """Dtype-aware MXU cost (bf16-MAC equivalents) of the variant's
    faithful deployment: per slot, 2*m*width*n * (dtype_factor +
    rank) — truncation circuits deploy natively at narrow width (cheap),
    exotic circuits pay int8 base + bf16 corrections (DESIGN.md §2)."""
    if hasattr(accel, "adjusted_compute"):
        return accel.adjusted_compute(circuits, ranks)
    mul_idx = accel.mul_slot_indices()
    m, ktot, n = accel.matmul_shape()
    groups = accel.slot_groups()
    passes = getattr(accel, "deploy_passes", 1)
    total = 0.0
    for (s0, e0), i, r in zip(groups, mul_idx, ranks):
        c = circuits[i]
        base = hw.V5E.dtype_cost_factor(c.deploy_width)
        rank = c.deploy_rank if r is None else (
            0 if c.native_width is not None else int(r)
        )
        total += 2.0 * m * (e0 - s0) * n * (base + rank)
    return total * passes


def _finish_record(accel, circuits, ranks, specs, compiled: dict,
                   wall: float, cache_hit: bool) -> SynthResult:
    """Full per-variant label record from the compile-derived numbers.

    Only {'flops', 'hbm_bytes'} come from the (cached) compile; latency
    and energy are recomputed per variant from its circuits/ranks, so a
    structural cache hit can never leak another variant's dtype mix."""
    out = SynthResult()
    out["flops"] = compiled["flops"]
    out["hbm_bytes"] = compiled["hbm_bytes"]
    out["wall_time"] = wall
    adj = _adjusted_compute(accel, circuits, ranks)
    out["mxu_flops_adjusted"] = adj
    rt = hw.roofline(adj, out["hbm_bytes"], 0.0)
    out["latency"] = rt.t_serial
    # energy = the MARGINAL arithmetic energy of the variant (MXU MACs at
    # their dtype rate + the rank-k lookup-table traffic).  Input/output
    # streaming bytes are identical across variants of one accelerator
    # (board-level cost in the paper's terms) and would flatten the
    # objective to a ~0.2% spread on the small MCM matmuls.
    lut_bytes = sum(256.0 * 4 * 2 * sp.rank for sp in specs)
    out["energy"] = adj * hw.V5E.e_flop + lut_bytes * hw.V5E.e_hbm_byte
    out["cache_hit"] = cache_hit
    return out


class _Variant:
    """Per-genome bookkeeping inside synthesize_batch."""

    __slots__ = ("index", "circuits", "ranks", "specs", "ikey", "idd")

    def __init__(self, index, circuits, ranks, specs, ikey, idd):
        self.index = index
        self.circuits = circuits
        self.ranks = ranks
        self.specs = specs
        self.ikey = ikey
        self.idd = idd


def _compile_identity(accel, specs) -> Tuple[dict, float]:
    """One deployment compile; returns (cost numbers, wall seconds)."""
    fn, args = accel.build_deploy(specs)
    cost = _compile_cost(fn, args, fast_key=f"accel:{accel.name}")
    return ({"flops": cost["flops"], "hbm_bytes": cost["hbm_bytes"]},
            cost["wall_time"])


def synthesize_batch(
    accel: Accelerator,
    variants: Sequence[Tuple[Sequence[Circuit], Sequence[Optional[int]]]],
    *,
    cache: Optional[dict] = None,
    synth_cache: Optional[SynthCache] = None,
    compile_workers: Optional[int] = None,
    progress: Optional[callable] = None,
) -> List[SynthResult]:
    """Population-scale synthesis: one call for a whole genome batch.

    ``variants`` is a list of decoded ``(circuits, ranks)`` pairs.  The
    batch is deduplicated at two levels before anything compiles —
    exact circuit identity, then the structural ``deploy_signature``
    (first-K-verified per graph family; see the module comment) — and
    the surviving unique compiles run serially or, with
    ``compile_workers > 1`` (default ``REPRO_SYNTH_COMPILE_WORKERS``),
    on a thread pool.  Results scatter back per genome with the same
    values the serial per-genome loop would produce; the genome that
    paid a compile carries its wall time, riders carry 0.0 (the seed
    cache-hit convention).

    ``cache`` keeps the legacy per-context dict contract (full records
    keyed on circuit identity); ``synth_cache`` is the shared/persistent
    compile tier (default: the process-wide ``shared_synth_cache()``).
    """
    from ...kernels.approx_matmul import from_circuit

    scache = synth_cache if synth_cache is not None else _SHARED_CACHE
    workers = COMPILE_WORKERS if compile_workers is None else compile_workers
    mul_idx = accel.mul_slot_indices()
    n = len(variants)
    results: List[Optional[SynthResult]] = [None] * n

    # -- pass 1: decode specs, serve legacy-dict hits, group identities --
    order: List[str] = []                 # unique identity digests, FIFO
    groups: Dict[str, List[_Variant]] = {}
    for t, (circuits, ranks) in enumerate(variants):
        specs = [from_circuit(circuits[i], r)
                 for i, r in zip(mul_idx, ranks)]
        ikey = _identity_signature(accel, specs)
        if cache is not None and ikey in cache:
            out = SynthResult(cache[ikey])
            out["wall_time"] = 0.0
            out["cache_hit"] = True
            results[t] = out
            continue
        idd = _digest("id", ikey)
        v = _Variant(t, list(circuits), list(ranks), specs, ikey, idd)
        if idd not in groups:
            order.append(idd)
            groups[idd] = []
        groups[idd].append(v)

    structural = STRUCTURAL_KEYS
    sigs: Dict[str, Optional[Tuple[str, str]]] = {}  # idd -> (sdd, fam)
    if structural:
        for idd in order:
            sig = _structural_signature(accel, groups[idd][0].specs)
            if sig is None:
                sigs[idd] = None
            else:
                family, classes = sig
                fam = _digest("fam", family)
                sigs[idd] = (_digest("st", (family, classes)), fam)

    # -- pass 2: resolve each unique identity against the cache tiers --
    # compiled[idd] = (cost numbers, wall paid here)
    compiled: Dict[str, Tuple[dict, float]] = {}

    def _needs_compile(idd: str):
        """None if served from a cache tier, else the compile plan
        ('fresh' stores structurally, 'verify' compares against the
        colliding record, 'pinned' stores identity-only)."""
        rec = scache.get_identity(idd)
        if rec is not None:
            compiled[idd] = ({"flops": rec["flops"],
                              "hbm_bytes": rec["hbm_bytes"]}, 0.0)
            return None
        sd = sigs.get(idd) if structural else None
        if sd is None:
            return ("pinned", None, None)
        sdd, fam = sd
        verdict = scache.verdict(fam)
        if verdict is False:
            return ("pinned", None, None)
        srec = scache.get_structural(sdd)
        if srec is None:
            return ("fresh", sdd, fam)
        if verdict == 0:
            scache.store_alias({"k": idd, "s": sdd, "fam": fam,
                                "flops": srec["flops"],
                                "hbm_bytes": srec["hbm_bytes"]})
            compiled[idd] = ({"flops": srec["flops"],
                              "hbm_bytes": srec["hbm_bytes"]}, 0.0)
            return None
        return ("verify", sdd, fam)

    def _run_compile(idd: str, plan) -> None:
        kind, sdd, fam = plan
        specs = groups[idd][0].specs
        faults.hit("synth.compile", kind=kind, identity=idd[:12])
        with obs.span("synth.compile", kind=kind, identity=idd[:12]):
            cost, wall = _compile_identity(accel, specs)
        cs = getattr(scache, "compile_seconds", None)
        if cs is not None:
            cs.observe(wall)
        if kind == "verify":
            srec = scache.get_structural(sdd)
            same = (srec is not None
                    and cost["flops"] == srec["flops"]
                    and cost["hbm_bytes"] == srec["hbm_bytes"])
            if srec is None:
                pass          # record vanished (pin race): treat as fresh
            elif same:
                scache.verdict_pass(fam)
            else:
                scache.verdict_pin(fam)
            scache.store({"k": idd, "s": sdd if srec is None or same
                          else None,
                          "fam": fam, **cost}, verify=srec is not None)
        else:
            scache.store({"k": idd,
                          "s": sdd if kind == "fresh" else None,
                          "fam": fam, **cost})
        compiled[idd] = (cost, wall)

    # Structural dedup WITHIN the batch needs the first compile of a
    # structure to land before its siblings resolve, so resolution runs
    # in waves: every identity that must compile under the current cache
    # state compiles (possibly in parallel), then the remainder re-
    # resolves against the now-warmer cache.
    batch_span = (
        obs.start_span("synth.batch", n=n, unique=len(order))
        if order else None
    )
    n_waves = n_compiled = 0
    pending = list(order)
    while pending:
        plans = []
        deferred = []
        seen_struct: set = set()
        verify_used: Dict[str, int] = {}
        for idd in pending:
            plan = _needs_compile(idd)
            if plan is None:
                continue
            kind, sdd, fam = plan
            if kind == "fresh" and sdd in seen_struct:
                deferred.append(idd)     # a sibling compiles it this wave
                continue
            if kind == "verify":
                # spend at most the family's REMAINING countdown on
                # verification this wave; the rest re-resolves next wave
                # (and serves structurally once the family is verified)
                used = verify_used.get(fam, 0)
                verdict = scache.verdict(fam)
                if verdict is False or used >= verdict:
                    deferred.append(idd)
                    continue
                verify_used[fam] = used + 1
            if sdd is not None:
                seen_struct.add(sdd)
            plans.append((idd, plan))
        n_waves += 1
        n_compiled += len(plans)
        if plans:
            if workers > 1 and len(plans) > 1:
                from concurrent.futures import ThreadPoolExecutor

                with ThreadPoolExecutor(workers) as pool:
                    list(pool.map(lambda p: _run_compile(*p), plans))
            else:
                for p in plans:
                    _run_compile(*p)
        if not deferred:
            break
        pending = deferred
    if batch_span is not None:
        batch_span.end(waves=n_waves, compiled=n_compiled)

    # -- pass 3: assemble + scatter ------------------------------------
    done = 0
    total = sum(len(g) for g in groups.values())
    for idd in order:
        cost, wall = compiled[idd]
        for j, v in enumerate(groups[idd]):
            out = _finish_record(
                accel, v.circuits, v.ranks, v.specs, cost,
                wall if j == 0 else 0.0,
                cache_hit=(wall == 0.0 or j > 0),
            )
            if cache is not None and v.ikey not in cache:
                cache[v.ikey] = dict(out)
            results[v.index] = out
            done += 1
            if progress is not None:
                progress(done, total)
    return results


def synthesize_variant(
    accel: Accelerator,
    circuits: Sequence[Circuit],
    ranks: Sequence[Optional[int]],
    *,
    cache: Optional[dict] = None,
    synth_cache: Optional[SynthCache] = None,
) -> SynthResult:
    """Ground-truth hardware labels for one variant (XLA compile of its
    deployment).  Cost is shape-determined, so compiles are reused via
    ``cache`` (exact circuit identity, the seed contract) and the shared
    structural ``synth_cache`` (see ``synthesize_batch``).

    The compute term is dtype-adjusted (the CPU compile runs everything
    in f32; the v5e MXU runs int4/int8/bf16 at different rates)."""
    return synthesize_batch(
        accel, [(circuits, ranks)], cache=cache, synth_cache=synth_cache,
    )[0]


def circuit_features_synth(
    c: Circuit, *, rank: Optional[int] = None, m: int = 256, n: int = 128
) -> np.ndarray:
    """Per-AC synthesis features — XLA-compile a canonical (m,256)@(256,n)
    deployment of this single circuit (Vivado-on-AC analogue, pipeline
    B/E).  Returns [flops, log10 bytes, latency, energy, rank, wall_time].
    Adders deploy as an elementwise segmented add (cost-flat by design)."""
    import jax.numpy as jnp

    from ...kernels.approx_matmul import approx_matmul, from_circuit

    if c.kind == "add16":
        # elementwise behavioral map: fixed small cost; use error stats row
        return np.array([256.0 * n, np.log10(256.0 * n * 8), 0.0, 0.0, 0.0, 0.0])
    spec = from_circuit(c, rank)
    rng = np.random.default_rng(0)
    lo, hi = (-128, 128) if c.signed else (0, 256)
    x = jnp.asarray(rng.integers(lo, hi, (m, 256)))
    w = jnp.asarray(rng.integers(lo, hi, (256, n)))

    def fn(x, w):
        return approx_matmul(x, w, spec)

    cost = _compile_cost(fn, (x, w), fast_key=f"circuit:{c.kind}")
    # dtype-aware adjustment (see synthesize_variant)
    adj = 2.0 * m * 256 * n * c.deploy_cost_factor()
    rt = hw.roofline(adj, cost["hbm_bytes"], 0.0)
    cost["flops"] = adj
    cost["latency"] = rt.t_serial
    cost["energy"] = adj * hw.V5E.e_flop         + 256.0 * 4 * 2 * c.deploy_rank * hw.V5E.e_hbm_byte
    return np.array(
        [
            cost["flops"],
            np.log10(1.0 + cost["hbm_bytes"]),
            cost["latency"],
            cost["energy"],
            float(spec.rank),
            cost["wall_time"],
        ]
    )


def label_variants(
    accel: Accelerator,
    genomes: np.ndarray,
    library: Library,
    *,
    rank_genes: bool = False,
    qor_inputs: Optional[np.ndarray] = None,
    cache: Optional[dict] = None,
    synth_cache: Optional[SynthCache] = None,
    progress: Optional[callable] = None,
) -> Dict[str, np.ndarray]:
    """Ground-truth labels for a genome batch: hardware via BATCHED XLA
    synthesis (``synthesize_batch``: identity + structural dedup across
    the whole batch, shared/persistent compile cache), QoR via BATCHED
    behavioral simulation (one vectorized ``qor_batch`` call instead of
    a sim per genome) — values bit-exact versus the per-genome loop.
    Returns arrays keyed
    {'qor','latency','energy','flops','hbm_bytes','synth_time','sim_time'}.
    ``sim_time`` is the batch's wall clock amortized evenly per genome."""
    genomes = np.atleast_2d(genomes)
    n = len(genomes)
    if qor_inputs is None:
        qor_inputs = accel.sample_inputs(4, seed=DEFAULT_QOR_SEED)
    out = {k: np.zeros(n) for k in LABEL_KEYS}
    t0 = time.perf_counter()
    out["qor"][:] = accel.qor_batch(
        genomes, library, qor_inputs, rank_genes=rank_genes
    )
    out["sim_time"][:] = (time.perf_counter() - t0) / max(n, 1)
    variants = [accel.decode(g, library, rank_genes=rank_genes)
                for g in genomes]
    records = synthesize_batch(
        accel, variants, cache=cache, synth_cache=synth_cache,
        progress=progress,
    )
    for t, sr in enumerate(records):
        out["latency"][t] = sr["latency"]
        out["energy"][t] = sr["energy"]
        out["flops"][t] = sr["flops"]
        out["hbm_bytes"][t] = sr["hbm_bytes"]
        out["synth_time"][t] = sr["wall_time"]
    return out
