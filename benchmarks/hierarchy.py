"""Hierarchical vs flat joint-genome search -> BENCH_hierarchy.json.

The paper's scalability claim (§V) on the repo's first multi-stage
workload, ``smoothed_dct`` (Gaussian 3x3 -> HEVC 4x4 DCT, 45-slot joint
genome):

  * **flat**       — one ``run_dse`` campaign over the joint genome
                     (product space ~1e56), via the campaign service,
  * **hierarchical** — one campaign per stage (run CONCURRENTLY through
                     the ``CampaignManager``), per-stage fronts composed
                     with incremental pruning, composed candidates
                     re-labeled end-to-end.

Headline metrics (the ISSUE-2 acceptance criteria):

  * hierarchical ground-truth labels <= 60% of the flat campaign's,
  * verified-front hypervolume >= the flat front's (within 1%),
  * >= 2 per-stage campaigns demonstrably in flight at once.

Run:  PYTHONPATH=src python benchmarks/hierarchy.py
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from common import emit, section  # noqa: E402

# The flat campaign trains on the 45-gene PRODUCT space, so it needs (and
# gets) a much larger ground-truth sample; the hierarchical run must reach
# at least its front quality on <= 60% of the labels.
FLAT = dict(n_train=200, n_qor_samples=2, pop_size=32, n_parents=16,
            n_generations=8, seed=0)
STAGE = dict(n_train=28, n_qor_samples=2, pop_size=24, n_parents=12,
             n_generations=6, seed=0)
K_PER_STAGE = 10
MAX_CANDIDATES = 24


def bench_flat() -> dict:
    from repro.service import CampaignManager, CampaignSpec

    mgr = CampaignManager(eval_workers=2, campaign_workers=1)
    t0 = time.perf_counter()
    cid = mgr.submit(CampaignSpec(accel="smoothed_dct", **FLAT))
    state = mgr.wait(cid, timeout=3600)
    wall = time.perf_counter() - t0
    assert state == "done", mgr.status(cid).get("error")
    res = mgr.result(cid)
    stats = mgr.scheduler.stats()
    out = {
        "wall_s": wall,
        "labels": stats["labeled"],
        "front": res.front_objectives.tolist(),
        "n_designs": int(len(res.true_objectives)),
    }
    mgr.shutdown()
    return out


def bench_hier() -> dict:
    from repro.accel import SmoothedDct
    from repro.hierarchy import HierarchicalConfig, run_hierarchical
    from repro.service import CampaignManager

    mgr = CampaignManager(eval_workers=2, campaign_workers=2)
    cfg = HierarchicalConfig(k_per_stage=K_PER_STAGE,
                             max_candidates=MAX_CANDIDATES, **STAGE)
    t0 = time.perf_counter()
    res = run_hierarchical(SmoothedDct(), cfg=cfg, manager=mgr, verbose=True)
    wall = time.perf_counter() - t0
    out = {
        "wall_s": wall,
        "labels": res.ground_truth_calls["total"],
        "labels_stage": res.ground_truth_calls["stage_campaigns"],
        "labels_final": res.ground_truth_calls["final"],
        "front": res.front_objectives.tolist(),
        "n_candidates": int(len(res.candidate_genomes)),
        "max_concurrent_stages": int(res.max_concurrent_stages),
        "flat_space_size": float(res.flat_space_size),
        "compose": {
            "stage_front_sizes": res.compose_stats.stage_sizes,
            "truncated_sizes": res.compose_stats.truncated_sizes,
            "pairs_evaluated": res.compose_stats.pairs_evaluated,
            "survivors": res.compose_stats.survivors,
        },
        "timings": {k: round(v, 3) for k, v in res.timings.items()},
    }
    mgr.shutdown()
    return out


def hypervolumes(front_a, front_b):
    """2-D hypervolume of each front w.r.t. a shared reference point."""
    from repro.core.pareto import hypervolume_2d

    both = np.concatenate([np.asarray(front_a), np.asarray(front_b)])
    ref = both.max(axis=0) + 0.05 * np.abs(both.max(axis=0) -
                                           both.min(axis=0)) + 1e-12
    return (hypervolume_2d(np.asarray(front_a), ref),
            hypervolume_2d(np.asarray(front_b), ref),
            ref.tolist())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_hierarchy.json"))
    args = ap.parse_args()
    report = {"spec": {"flat": FLAT, "stage": STAGE,
                       "k_per_stage": K_PER_STAGE,
                       "max_candidates": MAX_CANDIDATES}}

    section("flat joint-genome campaign (45-slot genome)")
    flat = bench_flat()
    emit("hierarchy.flat_wall", flat["wall_s"] * 1e6,
         f"labels={flat['labels']}")
    report["flat"] = flat

    section("hierarchical: per-stage campaigns -> compose -> verify")
    hier = bench_hier()
    emit("hierarchy.hier_wall", hier["wall_s"] * 1e6,
         f"labels={hier['labels']}")
    emit("hierarchy.concurrent_stages",
         float(hier["max_concurrent_stages"]),
         f"{hier['max_concurrent_stages']} stages in flight")
    report["hierarchical"] = hier

    hv_flat, hv_hier, ref = hypervolumes(flat["front"], hier["front"])
    label_ratio = hier["labels"] / max(flat["labels"], 1)
    hv_ratio = hv_hier / max(hv_flat, 1e-300)
    emit("hierarchy.label_ratio", label_ratio * 1e6,
         f"{label_ratio:.2f} (target <= 0.60)")
    emit("hierarchy.hv_ratio", hv_ratio * 1e6,
         f"{hv_ratio:.3f} (target >= 0.99)")
    report["hypervolume"] = {"flat": hv_flat, "hier": hv_hier,
                             "ref_point": ref, "ratio": hv_ratio}
    report["label_ratio"] = label_ratio
    report["wall_speedup"] = flat["wall_s"] / max(hier["wall_s"], 1e-9)

    # write the report BEFORE asserting, so a failed acceptance run still
    # leaves the measured data on disk for diagnosis
    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}", file=sys.stderr)

    # acceptance criteria (ISSUE 2)
    assert label_ratio <= 0.60, (
        f"hierarchical spent {label_ratio:.2f}x of flat's labels (> 0.60)")
    assert hv_ratio >= 0.99, (
        f"hierarchical hypervolume ratio {hv_ratio:.3f} < 0.99")
    assert hier["max_concurrent_stages"] >= 2, \
        "stage campaigns did not overlap"


if __name__ == "__main__":
    main()
