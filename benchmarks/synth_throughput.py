"""Structural synthesis engine throughput -> BENCH_synth.json.

XLA synthesis (the Vivado analogue) is the un-amortized half of ground-
truth labeling: PR 3 batched the QoR simulation, but every compile was
still paid per circuit-identity, per evaluation context, per process.
This benchmark measures what the PR 5 structural engine changes, on two
workloads per accelerator:

  * ``context_sweep`` (the headline) — the SAME designs synthesized
    under several evaluation contexts, the service's standard pattern:
    campaigns search at ``n_qor_samples=2`` (the hierarchy/LM configs)
    and report at ``n_qor_samples=4`` (the flat default), and fronts are
    re-evaluated under fresh QoR input draws for robustness.  The PR-4
    engine keeps its compile cache per ``EvalContext``, so every context
    recompiles every design from zero; the structural engine shares one
    compile pool across all of them.
  * ``single_context_random`` (the honest hard case) — one context, one
    batch of fresh random genomes.  Random 25-slot genomes rarely share
    a structural signature, so this measures engine overhead, not cache
    magic; expect ~1x.

Engines compared on identical synthesis streams:

  * ``pr4_serial``          — per-genome ``synthesize_variant`` loop,
    identity-keyed per-context dict cache, structural keying off (the
    PR-4 engine, with its lean trace and guarded fast codegen).
  * ``batched_structural``  — ``synthesize_batch`` + one persistent
    ``JsonlSynthCache`` shared by every context.
  * ``warm_persistent``     — the same stream re-run in a FRESH PROCESS
    against the same cache file: must do ZERO compiles.

Hardware labels must be byte-identical across all three, and the (QoR,
energy) Pareto fronts they induce must match the default engine's.
A thread-pool compile probe (``compile_workers=2``) is also recorded:
on jaxlib 0.4.x CPU, compilation serializes internally, so the measured
ratio documents why the engine defaults to serial compiles.

Run:  PYTHONPATH=src python benchmarks/synth_throughput.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from common import emit, section  # noqa: E402

HW_KEYS = ("flops", "hbm_bytes", "latency", "energy")
DET_KEYS = ("qor",) + HW_KEYS

# the repo's real evaluation contexts for one accelerator: search config
# (n_qor=2: hierarchy stages, LM drivers), reporting config (n_qor=4:
# the flat campaign default), and a robustness re-draw of each (fresh
# QoR inputs, same designs).  Only (n_qor_samples, qor_seed) vary — the
# synthesis side is identical, which is exactly the point.
CONTEXTS = ((2, 1234), (4, 1234), (2, 7), (4, 7))


def _accel(name):
    from repro.service import make_accelerator

    return make_accelerator(name)


def _designs(accel, library, n, seed):
    rng = np.random.default_rng(seed)
    sizes = accel.gene_sizes(library)
    return rng.integers(0, sizes[None, :], size=(n, len(sizes)))


def _variants(accel, library, genomes):
    return [accel.decode(g, library) for g in genomes]


def _front(labels):
    from repro.core.dse import _objective_matrix
    from repro.core.pareto import non_dominated_mask

    obj = _objective_matrix(labels, ("qor", "energy"))
    return obj[non_dominated_mask(obj)]


def warm_fast_codegen(accel, library):
    """Settle the module-global fast-codegen verdict for this graph
    family OUTSIDE the measurements (throwaway designs, throwaway
    cache): a long-lived service holds its verdicts for the process
    lifetime, so steady-state is the honest operating point for BOTH
    engines — and it is symmetric, the PR-3 ``warm_library`` idiom.
    Cold-compile measurements below must therefore NOT reset the
    engine; their cache isolation comes from explicit per-run caches."""
    from repro.core.features import synth

    synth.reset_fast_codegen()
    w = _designs(accel, library, synth._FAST_VERIFY_SAMPLES + 1, seed=1717)
    synth.synthesize_batch(
        accel, _variants(accel, library, w), synth_cache=synth.SynthCache(),
    )


def run_pr4_serial(accel, library, genomes, n_contexts):
    """The PR-4 engine on the context-sweep stream: a fresh identity
    cache per context (EvalContext._synth_cache semantics), serial
    per-genome loop, structural tier off."""
    from repro.core.features import synth

    keep = synth.STRUCTURAL_KEYS
    synth.STRUCTURAL_KEYS = False
    variants = _variants(accel, library, genomes)
    try:
        recs = []
        t0 = time.perf_counter()
        for _ in range(n_contexts):
            # PR-4 semantics: compile reuse stops at the context border —
            # a fresh identity cache per context, and an ISOLATED shared
            # tier (the process-wide cache would otherwise leak the new
            # engine's cross-context sharing into the baseline)
            ctx_cache = {}
            isolated = synth.SynthCache()
            for circuits, ranks in variants:
                recs.append(synth.synthesize_variant(
                    accel, circuits, ranks, cache=ctx_cache,
                    synth_cache=isolated,
                ))
        wall = time.perf_counter() - t0
    finally:
        synth.STRUCTURAL_KEYS = keep
    return recs, wall


def run_batched_structural(accel, library, genomes, n_contexts, cache_path):
    """The structural engine on the same stream: synthesize_batch per
    context batch, ONE persistent cache shared across contexts."""
    from repro.core.features import synth

    cache = synth.JsonlSynthCache(cache_path)
    variants = _variants(accel, library, genomes)
    recs = []
    t0 = time.perf_counter()
    for _ in range(n_contexts):
        recs.extend(synth.synthesize_batch(
            accel, variants, synth_cache=cache,
        ))
    wall = time.perf_counter() - t0
    stats = cache.stats()
    cache.close()
    return recs, wall, stats


def warm_rerun_in_subprocess(accel_name, n_designs, seed, n_contexts,
                             cache_path, out_path):
    """Re-run the structural stream in a FRESH process against the same
    cache file — the process-restart half of the warm claim."""
    cmd = [sys.executable, os.path.abspath(__file__),
           "--_warm-worker", accel_name, str(n_designs), str(seed),
           str(n_contexts), cache_path, out_path]
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    subprocess.run(cmd, check=True, env=env)
    with open(out_path) as f:
        return json.load(f)


def _warm_worker(argv):
    accel_name, n, seed, n_contexts, cache_path, out_path = argv
    from repro.core.acl.library import default_library
    from repro.service.workers import warm_library

    library = default_library()
    warm_library(library)   # steady-state, as in the parent's streams
    accel = _accel(accel_name)
    genomes = _designs(accel, library, int(n), int(seed))
    recs, wall, stats = run_batched_structural(
        accel, library, genomes, int(n_contexts), cache_path,
    )
    with open(out_path, "w") as f:
        json.dump({
            "wall_s": wall,
            "compiles": stats["compiles"],
            "hw": {k: [r[k] for r in recs] for k in HW_KEYS},
        }, f)


def probe_threaded_compiles(accel, library, genomes):
    """compile_workers=2 vs serial on one cold batch (fresh caches)."""
    from repro.core.features import synth

    variants = _variants(accel, library, genomes)
    walls = {}
    for tag, workers in (("serial", 1), ("threads2", 2)):
        synth.reset_fast_codegen()
        t0 = time.perf_counter()
        synth.synthesize_batch(
            accel, variants, synth_cache=synth.SynthCache(),
            compile_workers=workers,
        )
        walls[tag] = time.perf_counter() - t0
    return walls


def main():
    if len(sys.argv) > 1 and sys.argv[1] == "--_warm-worker":
        _warm_worker(sys.argv[2:])
        return

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny designs/context counts (CI: exercise every "
                         "engine path, don't trust the ratios)")
    ap.add_argument("-n", type=int, default=None, help="designs per sweep")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_synth.json"))
    args = ap.parse_args()

    from repro.core.acl.library import default_library
    from repro.core.features import synth
    from repro.service import EvalContext
    from repro.service.workers import warm_library

    library = default_library()
    warm_library(library)

    G = args.n or (2 if args.smoke else 8)
    contexts = CONTEXTS[:2] if args.smoke else CONTEXTS
    S = len(contexts)

    report = {
        "designs": G, "contexts": S, "rounds": (1 if args.smoke else 2),
        "context_configs": [list(c) for c in contexts],
        "smoke": bool(args.smoke),
        "machine": {"os_cpu_count": os.cpu_count()},
        "engine": {
            "structural_keys": synth.STRUCTURAL_KEYS,
            "fast_codegen": synth.FAST_CODEGEN,
            "verify_samples": synth._STRUCT_VERIFY_SAMPLES,
        },
        "workloads": {},
    }

    for name in ("gaussian3x3", "smoothed_dct"):
        accel = _accel(name)
        genomes = _designs(accel, library, G, seed=5)
        labels = S * G

        rounds = 1 if args.smoke else 2
        section(f"{name}: context sweep — {S} contexts x {G} designs "
                f"x {rounds} interleaved rounds")
        warm_fast_codegen(accel, library)
        with tempfile.TemporaryDirectory() as tdir:
            # engines measured INTERLEAVED (shared hosts drift); cold
            # means cold: a fresh cache file per round
            base_walls, new_walls = [], []
            for rnd in range(rounds):
                cache_path = os.path.join(tdir, f"synth_cache{rnd}.jsonl")
                base_recs, base_wall = run_pr4_serial(
                    accel, library, genomes, S)
                base_walls.append(base_wall)
                new_recs, new_wall, cold_stats = run_batched_structural(
                    accel, library, genomes, S, cache_path)
                new_walls.append(new_wall)
            base_wall = float(np.median(base_walls))
            new_wall = float(np.median(new_walls))
            emit(f"synth.{name}.pr4_serial",
                 base_wall / labels * 1e6, f"{labels} labels")
            emit(f"synth.{name}.batched_structural",
                 new_wall / labels * 1e6,
                 f"{cold_stats['compiles']} compiles")

            hw_identical = all(
                a[k] == b[k]
                for a, b in zip(base_recs, new_recs) for k in HW_KEYS
            )

            warm = warm_rerun_in_subprocess(
                name, G, 5, S, cache_path,
                os.path.join(tdir, "warm.json"))
            emit(f"synth.{name}.warm_persistent",
                 warm["wall_s"] / labels * 1e6,
                 f"{warm['compiles']} compiles")
            warm_identical = all(
                [r[k] for r in new_recs] == warm["hw"][k] for k in HW_KEYS
            )

        section(f"{name}: single-context random batch (hard case)")
        hard = _designs(accel, library, G, seed=99)
        hard_base, hard_base_wall = run_pr4_serial(accel, library, hard, 1)
        t0 = time.perf_counter()
        hard_new = synth.synthesize_batch(
            accel, _variants(accel, library, hard),
            synth_cache=synth.SynthCache(),
        )
        hard_new_wall = time.perf_counter() - t0
        hard_identical = all(
            a[k] == b[k] for a, b in zip(hard_base, hard_new)
            for k in HW_KEYS
        )
        emit(f"synth.{name}.single_context_x", 0.0,
             f"{hard_base_wall / hard_new_wall:.2f}x")

        # full labels + fronts once per engine (context 0), byte-compared
        # (resets the engine, so it runs AFTER every timed measurement)
        n_qor, qor_seed = contexts[0]
        synth.reset_fast_codegen()
        keep = synth.STRUCTURAL_KEYS
        synth.STRUCTURAL_KEYS = False
        try:
            ref_labels = EvalContext(
                accel, library, n_qor_samples=n_qor, qor_seed=qor_seed,
            ).ground_truth(genomes)
        finally:
            synth.STRUCTURAL_KEYS = keep
        synth.reset_fast_codegen()
        new_labels = EvalContext(
            accel, library, n_qor_samples=n_qor, qor_seed=qor_seed,
        ).ground_truth(genomes)
        labels_identical = all(
            np.array_equal(ref_labels[k], new_labels[k]) for k in DET_KEYS
        )
        front_identical = bool(np.array_equal(
            _front(ref_labels), _front(new_labels)))

        threaded = probe_threaded_compiles(accel, library, hard)

        report["workloads"][name] = {
            "context_sweep": {
                "labels": labels,
                "per_label_s": {
                    "pr4_serial": base_wall / labels,
                    "batched_structural": new_wall / labels,
                    "warm_persistent": warm["wall_s"] / labels,
                },
                "cold_compiles": {
                    "pr4_serial": S * G,
                    "batched_structural": cold_stats["compiles"],
                },
                "cold_speedup_x": base_wall / new_wall,
                "warm_compiles": warm["compiles"],
                "warm_speedup_x": base_wall / warm["wall_s"],
                "cold_cache_stats": cold_stats,
                "hw_labels_identical": bool(hw_identical),
                "warm_labels_identical": bool(warm_identical),
            },
            "single_context_random": {
                "labels": G,
                "per_label_s": {
                    "pr4_serial": hard_base_wall / G,
                    "batched_structural": hard_new_wall / G,
                },
                "speedup_x": hard_base_wall / hard_new_wall,
                "hw_labels_identical": bool(hard_identical),
            },
            "threaded_compile_probe": {
                "serial_s": threaded["serial"],
                "threads2_s": threaded["threads2"],
                "threads2_speedup_x":
                    threaded["serial"] / threaded["threads2"],
                "note": "jaxlib 0.4.x CPU serializes compilation; the "
                        "engine therefore defaults to serial compiles "
                        "(REPRO_SYNTH_COMPILE_WORKERS overrides)",
            },
            "labels_identical": bool(labels_identical),
            "front_identical": bool(front_identical),
        }
        sweep = report["workloads"][name]["context_sweep"]
        emit(f"synth.{name}.cold_speedup", 0.0,
             f"{sweep['cold_speedup_x']:.2f}x")
        emit(f"synth.{name}.warm_speedup", 0.0,
             f"{sweep['warm_speedup_x']:.2f}x "
             f"({sweep['warm_compiles']} compiles)")
        assert hw_identical, f"{name}: engine hardware labels diverged"
        assert warm_identical, f"{name}: warm labels diverged"
        assert labels_identical, f"{name}: full labels diverged"
        assert front_identical, f"{name}: fronts diverged"
        assert warm["compiles"] == 0, f"{name}: warm rerun compiled"

    wl = report["workloads"]["smoothed_dct"]["context_sweep"]
    if not args.smoke and wl["cold_speedup_x"] < 3.0:
        print(f"WARNING: smoothed_dct cold context-sweep speedup "
              f"{wl['cold_speedup_x']:.2f}x < 3x", file=sys.stderr)

    out_path = os.path.abspath(args.out)
    if args.smoke:
        print(f"smoke mode: not writing {out_path}", file=sys.stderr)
        return
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
