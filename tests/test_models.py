"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates at reduced scale, runs a forward/train step on CPU,
asserts output shapes + no NaNs.  Plus decode-path consistency and the
approximate-projection (paper technique) integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import (
    ApproxPolicy,
    cache_specs,
    decode_step,
    forward,
    param_specs,
    reduced,
)
from repro.models.common import init_tree
from repro.train.serve import make_prefill_step
from repro.train.step import make_loss_fn

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    kwargs = {}
    if cfg.is_encoder_decoder:
        kwargs["enc_embeds"] = (
            jax.random.normal(KEY, (B, 16, cfg.d_model), jnp.float32) * 0.1
        )
    if cfg.frontend == "vision":
        kwargs["embeds"] = (
            jax.random.normal(KEY, (B, cfg.frontend_len, cfg.d_model),
                              jnp.float32) * 0.1
        )
    return tokens, kwargs


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = reduced(get_config(arch))
    params = init_tree(param_specs(cfg), KEY)
    tokens, kwargs = _batch(cfg)
    s_total = S + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    logits, _, aux = forward(params, cfg, tokens, remat=False,
                             attn_chunk=16, scan_chunk=8, **kwargs)
    assert logits.shape == (B, s_total, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    # one train-style grad step
    loss_fn = make_loss_fn(cfg, attn_chunk=16, scan_chunk=8)
    batch = {"tokens": tokens, "labels": tokens, **{
        k: v for k, v in kwargs.items()}}
    (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ["granite-8b", "gemma-2b", "chatglm3-6b",
                                  "falcon-mamba-7b", "phi3.5-moe-42b-a6.6b",
                                  "jamba-1.5-large-398b"])
def test_prefill_decode_matches_forward(arch):
    """serve path == teacher-forcing path at the same positions."""
    cfg = reduced(get_config(arch))
    params = init_tree(param_specs(cfg), KEY)
    tokens, _ = _batch(cfg)
    caches = init_tree(cache_specs(cfg, B, S), KEY)
    prefill = make_prefill_step(cfg, attn_chunk=16, scan_chunk=8)
    lg_last, c2 = prefill(params, {"tokens": tokens[:, : S - 1]}, caches)
    lg_dec, _ = decode_step(params, cfg, c2, tokens[:, S - 1 : S],
                            jnp.int32(S - 1))
    full, _, _ = forward(params, cfg, tokens, remat=False,
                         attn_chunk=16, scan_chunk=8)
    tol = 0.12  # bf16 logits
    assert float(jnp.abs(lg_last[:, 0] - full[:, S - 2]).max()) < tol
    assert float(jnp.abs(lg_dec[:, 0] - full[:, S - 1]).max()) < tol


def test_decode_steps_chain(rng):
    """Multi-step decode: each step's logits match teacher forcing."""
    cfg = reduced(get_config("granite-8b"))
    params = init_tree(param_specs(cfg), KEY)
    tokens = jax.random.randint(KEY, (B, 8), 0, cfg.vocab_size)
    caches = init_tree(cache_specs(cfg, B, 8), KEY)
    prefill = make_prefill_step(cfg, attn_chunk=8, scan_chunk=8)
    _, caches = prefill(params, {"tokens": tokens[:, :4]}, caches)
    full, _, _ = forward(params, cfg, tokens, remat=False, attn_chunk=8,
                         scan_chunk=8)
    for t in range(4, 8):
        lg, caches = decode_step(params, cfg, caches, tokens[:, t : t + 1],
                                 jnp.int32(t))
        err = float(jnp.abs(lg[:, 0] - full[:, t]).max())
        assert err < 0.12, (t, err)


def test_approx_policy_reconstructs_circuit_error():
    """Deployment semantics (DESIGN.md §2): rank 0 = plain int8 (smallest
    deviation from exact); growing the correction rank reproduces the
    approximate circuit's own error more faithfully, so the deviation
    from the exact model GROWS toward the behavioral error and
    saturates."""
    cfg = reduced(get_config("granite-8b"))
    params = init_tree(param_specs(cfg), KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    exact, _, _ = forward(params, cfg, tokens, remat=False, attn_chunk=16)
    errs = {}
    for rank in (0, 2, 16):
        pol = ApproxPolicy({"ffn_in": ("mul8s_mitchell", rank),
                            "ffn_out": ("mul8s_mitchell", rank)})
        out, _, _ = forward(params, cfg, tokens, policy=pol, remat=False,
                            attn_chunk=16)
        errs[rank] = float(jnp.abs(out.astype(jnp.float32)
                                   - exact.astype(jnp.float32)).mean())
    assert errs[2] > errs[0]                  # circuit error applied
    assert abs(errs[16] - errs[2]) < errs[2]  # saturates near behavioral


def test_native_truncation_policy_perturbs():
    """Truncation circuits deploy natively (reduced-width ints): the
    coarser the truncation, the larger the deviation."""
    cfg = reduced(get_config("granite-8b"))
    params = init_tree(param_specs(cfg), KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    exact, _, _ = forward(params, cfg, tokens, remat=False, attn_chunk=16)
    errs = []
    for name in ("mul8s_trunc1", "mul8s_trunc4"):
        pol = ApproxPolicy({"ffn_in": (name, None)})
        out, _, _ = forward(params, cfg, tokens, policy=pol, remat=False,
                            attn_chunk=16)
        errs.append(float(jnp.abs(out.astype(jnp.float32)
                                  - exact.astype(jnp.float32)).mean()))
    assert errs[1] > errs[0] > 0


def test_exact_policy_close_to_no_policy():
    """int8-quantized exact multiplier ~ the bf16 exact path (quantization
    noise only)."""
    cfg = reduced(get_config("gemma-2b"))
    params = init_tree(param_specs(cfg), KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    exact, _, _ = forward(params, cfg, tokens, remat=False, attn_chunk=16)
    pol = ApproxPolicy({"ffn_in": ("mul8s_exact", None)})
    out, _, _ = forward(params, cfg, tokens, policy=pol, remat=False,
                        attn_chunk=16)
    rel = float(jnp.abs(out.astype(jnp.float32) - exact.astype(jnp.float32)).mean()
                / (jnp.abs(exact.astype(jnp.float32)).mean() + 1e-9))
    assert rel < 0.25


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_logical_axes_well_formed(arch):
    from repro.models.common import ParamSpec

    cfg = get_config(arch)  # FULL config: shapes only, no allocation
    specs = param_specs(cfg)
    for leaf in jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, ParamSpec)
    ):
        assert isinstance(leaf, ParamSpec)
        assert len(leaf.shape) == len(leaf.logical)
        assert all(d > 0 for d in leaf.shape)


def test_moe_grouping_equivalence():
    """Sequence grouping (§Perf: bounds GShard dispatch capacity) must not
    change the MoE layer's output when capacity is not binding."""
    import dataclasses

    from repro.models import moe as moe_mod
    from repro.models.moe import moe_layer, moe_param_specs

    cfg = dataclasses.replace(
        reduced(get_config("phi3.5-moe-42b-a6.6b")),
        capacity_factor=8.0,  # capacity never binds -> outputs identical
    )
    p = init_tree(moe_param_specs(cfg), KEY)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model), jnp.float32)
    prev = moe_mod.MOE_GROUP
    try:
        moe_mod.set_moe_group(0)
        y0, a0 = moe_layer(p, x, cfg)
        moe_mod.set_moe_group(8)   # 4 groups of 8 tokens
        y1, a1 = moe_layer(p, x, cfg)
    finally:
        moe_mod.set_moe_group(prev)
    np.testing.assert_allclose(np.asarray(y0, np.float32),
                               np.asarray(y1, np.float32),
                               rtol=2e-2, atol=2e-2)
