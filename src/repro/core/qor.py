"""Quality-of-result metrics.

The paper's QoR is average PSNR of the accelerator's output against the
exact accelerator's output over a set of input samples (images for the
Gaussian filter / HEVC DCT).  For the LM retarget we add logits-PSNR and
cross-entropy delta (DESIGN.md §2).
"""

from __future__ import annotations

import numpy as np

__all__ = ["psnr", "psnr_batch", "psnr_from_mse", "psnr_from_sse",
           "sse_batch_jax", "mean_psnr", "ce_delta", "PSNR_CAP"]

# Identical outputs would give +inf PSNR; the paper's plots saturate around
# this value, and a finite cap keeps regression targets well-conditioned.
PSNR_CAP = 100.0


def psnr(ref: np.ndarray, out: np.ndarray, peak: float | None = None) -> float:
    """Peak signal-to-noise ratio in dB; capped at PSNR_CAP for exactness."""
    ref = np.asarray(ref, dtype=np.float64)
    out = np.asarray(out, dtype=np.float64)
    if peak is None:
        peak = float(np.max(np.abs(ref))) or 1.0
    mse = float(np.mean((ref - out) ** 2))
    if mse == 0.0:
        return PSNR_CAP
    return float(min(10.0 * np.log10(peak * peak / mse), PSNR_CAP))


def psnr_from_mse(mse: np.ndarray, peak: float) -> np.ndarray:
    """Final PSNR formula over a per-genome MSE vector (shared by the
    numpy batched path and the fused device path so both produce the
    same float64 bits from the same MSE)."""
    mse = np.asarray(mse, dtype=np.float64)
    vals = np.full(len(mse), PSNR_CAP, dtype=np.float64)
    nz = mse > 0.0
    vals[nz] = np.minimum(10.0 * np.log10(peak * peak / mse[nz]), PSNR_CAP)
    return vals


def psnr_batch(
    ref: np.ndarray, outs: np.ndarray, peak: float | None = None
) -> np.ndarray:
    """PSNR of a genome-batched output stack against one reference.

    ``outs`` has one leading genome axis over ``ref``'s shape; returns a
    float64 vector of per-genome PSNRs, bit-identical to calling
    ``psnr(ref, outs[g], peak)`` for each g (each genome's MSE reduces
    over the same contiguous block in the same pairwise order)."""
    ref = np.asarray(ref, dtype=np.float64)
    outs = np.asarray(outs, dtype=np.float64)
    if peak is None:
        peak = float(np.max(np.abs(ref))) or 1.0
    d = np.ascontiguousarray(outs - ref[None]) ** 2
    mse = d.reshape(len(outs), -1).mean(axis=1)
    return psnr_from_mse(mse, peak)


def sse_batch_jax(ref, outs):
    """Traceable per-genome INTEGER sum of squared errors for the fused
    engine's device-side QoR tail.

    ``ref``/``outs`` must be integer-valued jnp arrays (``outs`` carries
    the genome axis).  The squared error of two bounded integers is an
    exact int64, and its int64 sum is exact, so ``sse / count`` on the
    host reproduces ``psnr_batch``'s float64 MSE bit-for-bit: numpy's
    pairwise float64 sum of exactly-representable integers below 2^53 is
    association-independent, i.e. also the exact integer sum.  Requires
    x64 to be enabled at trace time (the fused engine traces under
    ``jax.experimental.enable_x64``)."""
    import jax.numpy as jnp

    d = outs.astype(jnp.int64) - ref.astype(jnp.int64)[None]
    sq = d * d
    return sq.reshape(sq.shape[0], -1).sum(axis=1)


def psnr_from_sse(sse: np.ndarray, count: int, peak: float) -> np.ndarray:
    """Host finish of the device-side SSE: same MSE division and the
    shared final formula — bit-identical to ``psnr_batch`` on the same
    outputs (see ``sse_batch_jax``)."""
    mse = np.asarray(sse, dtype=np.float64) / float(count)
    return psnr_from_mse(mse, peak)


def mean_psnr(refs, outs, peak: float | None = None) -> float:
    """Average PSNR over a batch of samples (paper: 'average PSNR ... for a
    set of input signal samples')."""
    vals = [psnr(r, o, peak) for r, o in zip(refs, outs)]
    return float(np.mean(vals))


def ce_delta(logits_ref: np.ndarray, logits_out: np.ndarray, labels: np.ndarray) -> float:
    """Cross-entropy degradation of approximate logits vs exact logits."""

    def ce(logits):
        logits = logits - logits.max(axis=-1, keepdims=True)
        logz = np.log(np.exp(logits).sum(axis=-1))
        n = labels.size
        gold = logits.reshape(n, -1)[np.arange(n), labels.reshape(-1)]
        return float(np.mean(logz.reshape(-1) - gold))

    return ce(np.asarray(logits_out, np.float64)) - ce(np.asarray(logits_ref, np.float64))
