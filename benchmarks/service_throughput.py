"""Service throughput benchmark -> BENCH_service.json.

Measures the two headline properties of the campaign service
(repro.service):

  1. **Cold vs warm wall-clock** — the SAME campaign run in two fresh
     processes sharing one on-disk label store.  The warm run must
     perform ZERO ground-truth labeling calls (100% store hits) and
     complete >= 2x faster.
  2. **Concurrent campaign coalescing** — two identical campaigns
     submitted concurrently to one manager: the scheduler dedupes every
     in-flight genome (each unique genome synthesized once), batches
     carry requests from both campaigns, and both fronts are
     bit-identical to a direct ``run_dse`` of the same seed.

Run:  PYTHONPATH=src python benchmarks/service_throughput.py
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from common import emit, section  # noqa: E402

SPEC = dict(
    accel="mcm2",
    n_train=48,
    n_qor_samples=2,
    pop_size=16,
    n_parents=8,
    n_generations=4,
    seed=0,
)


def run_campaign(store_path: str) -> dict:
    """One campaign against a JSONL store; returns wall + label stats."""
    from repro.service import CampaignManager, CampaignSpec, JsonlLabelStore

    store = JsonlLabelStore(store_path)
    mgr = CampaignManager(store, eval_workers=2, campaign_workers=1)
    t0 = time.perf_counter()
    cid = mgr.submit(CampaignSpec(**SPEC))
    state = mgr.wait(cid, timeout=1800)
    wall = time.perf_counter() - t0
    assert state == "done", mgr.status(cid).get("error")
    res = mgr.result(cid)
    stats = mgr.scheduler.stats()
    out = {
        "wall_s": wall,
        "requests": stats["requests"],
        "store_hits": stats["store_hits"],
        "labeled": stats["labeled"],
        "hit_rate": stats["label_hit_rate"],
        "front": res.front_objectives.tolist(),
    }
    mgr.shutdown()
    store.close()
    return out


def bench_concurrent() -> dict:
    """Two identical campaigns on one manager + a direct-run reference."""
    from repro.core.dse import run_dse
    from repro.service import CampaignManager, CampaignSpec, make_accelerator

    spec = CampaignSpec(**SPEC)
    ref = run_dse(make_accelerator(spec.accel), cfg=spec.dse_config())

    mgr = CampaignManager(eval_workers=2, campaign_workers=2)
    t0 = time.perf_counter()
    c1, c2 = mgr.submit(spec), mgr.submit(spec)
    mgr.wait(c1, timeout=1800)
    mgr.wait(c2, timeout=1800)
    wall = time.perf_counter() - t0
    r1, r2 = mgr.result(c1), mgr.result(c2)
    stats = mgr.scheduler.stats()
    seed_identical = bool(
        np.array_equal(r1.front_objectives, r2.front_objectives)
        and np.allclose(r1.front_objectives, ref.front_objectives)
    )
    out = {
        "wall_s": wall,
        "campaigns_per_min": 2 / (wall / 60.0),
        "seed_identical_fronts": seed_identical,
        "requests": stats["requests"],
        "labeled": stats["labeled"],
        "store_hits": stats["store_hits"],
        "inflight_dedup_hits": stats["inflight_dedup_hits"],
        "coalesced_batches": stats["coalesced_batches"],
        "batches": stats["batches"],
        "mean_batch_size": stats["mean_batch_size"],
    }
    mgr.shutdown()
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true",
                    help="internal: run one campaign and print JSON stats")
    ap.add_argument("--store", default=None)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_service.json"))
    args = ap.parse_args()

    if args.child:
        print("CHILD_JSON " + json.dumps(run_campaign(args.store)))
        return

    report = {}

    # --- 1. cold vs warm across processes ------------------------------
    section("cold vs warm store (fresh process each)")
    tmp = tempfile.mkdtemp(prefix="bench_service_")
    store_path = os.path.join(tmp, "labels.jsonl")
    runs = {}
    for phase in ("cold", "warm"):
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__),
             "--child", "--store", store_path],
            capture_output=True, text=True, timeout=1800,
            env={**os.environ,
                 "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")},
        )
        assert proc.returncode == 0, proc.stderr[-3000:]
        line = [ln for ln in proc.stdout.splitlines()
                if ln.startswith("CHILD_JSON ")][-1]
        runs[phase] = json.loads(line[len("CHILD_JSON "):])
        emit(f"service.{phase}_wall", runs[phase]["wall_s"] * 1e6,
             f"hit_rate={runs[phase]['hit_rate']:.2f}")

    speedup = runs["cold"]["wall_s"] / max(runs["warm"]["wall_s"], 1e-9)
    emit("service.warm_speedup", runs["warm"]["wall_s"] * 1e6,
         f"{speedup:.1f}x")
    report["cold"] = runs["cold"]
    report["warm"] = runs["warm"]
    report["warm_speedup"] = speedup
    report["warm_zero_labeling"] = runs["warm"]["labeled"] == 0
    report["fronts_match_across_processes"] = (
        runs["cold"]["front"] == runs["warm"]["front"]
    )
    assert report["warm_zero_labeling"], (
        f"warm run labeled {runs['warm']['labeled']} genomes (expected 0)")
    assert report["fronts_match_across_processes"], "warm front diverged"
    if speedup < 2.0:
        print(f"WARNING: warm speedup {speedup:.2f}x < 2x", file=sys.stderr)

    # --- 2. concurrent campaigns ---------------------------------------
    section("two concurrent identical campaigns (coalescing + dedup)")
    conc = bench_concurrent()
    emit("service.concurrent_pair", conc["wall_s"] * 1e6,
         f"{conc['campaigns_per_min']:.2f}/min")
    emit("service.inflight_dedup", float(conc["inflight_dedup_hits"]),
         f"coalesced_batches={conc['coalesced_batches']}")
    report["concurrent"] = conc
    assert conc["seed_identical_fronts"], "concurrent fronts diverged"
    # campaigns may or may not overlap in flight depending on machine
    # load; either way each unique genome must be labeled only once
    assert conc["inflight_dedup_hits"] + conc["store_hits"] > 0, \
        "no cross-campaign label reuse observed"
    assert conc["labeled"] < conc["requests"], "duplicate labeling"

    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
