"""Distributed integration: sharding rules, and subprocess tests that run
the real machinery on 8 fake devices (XLA_FLAGS must be set before jax
import, so these spawn fresh interpreters)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# pure rule resolution (no devices needed)
# ---------------------------------------------------------------------------

def test_spec_for_fallback_and_uniqueness():
    import jax

    from repro.dist.sharding import spec_for

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}

    # divisible: sharded
    assert spec_for(("embed", "mlp"), (64, 32), FakeMesh()) == \
        jax.sharding.PartitionSpec("data", "model")
    # non-divisible: falls back to replication
    assert spec_for(("heads", None), (8, 4), FakeMesh()) == \
        jax.sharding.PartitionSpec(None, None)
    # an axis never used twice
    assert spec_for(("embed", "embed"), (64, 64), FakeMesh()) == \
        jax.sharding.PartitionSpec("data", None)
    # tuple axes partially applied: 32 divides pod*data, 4 only pod
    assert spec_for(("batch",), (32,), FakeMesh()) == \
        jax.sharding.PartitionSpec(("pod", "data"))
    assert spec_for(("batch",), (4,), FakeMesh()) == \
        jax.sharding.PartitionSpec(("pod",))


def test_rule_overrides_context():
    from repro.dist.sharding import active_rules, rule_overrides

    assert active_rules().get("kv_seq") is None
    with rule_overrides({"kv_seq": ("data", "model")}):
        assert active_rules()["kv_seq"] == ("data", "model")
        with rule_overrides({"embed": None}):
            assert active_rules()["embed"] is None
            assert active_rules()["kv_seq"] == ("data", "model")
    assert active_rules() == {}


def test_mesh_context_api_coverage():
    """mesh_context must resolve to a usable context manager on every
    jax API generation: set_mesh (new), sharding.use_mesh
    (transitional), or the legacy Mesh-as-context fallback — and the
    ambient mesh must actually be readable inside it."""
    import jax

    from repro.dist.compat import make_mesh, mesh_context

    mesh = make_mesh((1,), ("data",))
    ctx = mesh_context(mesh)
    assert hasattr(ctx, "__enter__") and hasattr(ctx, "__exit__")
    with mesh_context(mesh):
        from repro.dist.sharding import spec_for

        # ambient mesh resolves shard specs without an explicit mesh
        assert spec_for(("batch",), (4,), mesh) is not None
    # the branch taken must match the running jax's API surface
    if hasattr(jax, "set_mesh"):
        pass  # new API: set_mesh context
    elif hasattr(jax.sharding, "use_mesh"):
        assert type(ctx).__module__.startswith(("jax", "contextlib"))
    else:
        assert ctx is mesh  # legacy: Mesh is its own context manager


# ---------------------------------------------------------------------------
# 8-device subprocess integration
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """The sharded (2x4 mesh) train step computes the same loss as an
    unsharded run — SPMD correctness end to end."""
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs import get_config
        from repro.models import reduced, param_specs
        from repro.models.common import init_tree
        from repro.optim.adamw import AdamW
        from repro.train.step import init_state, make_train_step
        from repro.data.pipeline import TokenPipeline

        cfg = reduced(get_config("granite-8b"), d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=64, vocab_size=256, n_layers=2)
        opt = AdamW(lr=1e-3)
        params = init_tree(param_specs(cfg), jax.random.PRNGKey(0))
        pipe = TokenPipeline(cfg.vocab_size, 8, 32, seed=0)
        b = pipe.batch_at(0)
        batch = {k: jnp.asarray(v) for k, v in b.items()}

        # single device
        step1 = jax.jit(make_train_step(cfg, opt, n_micro=2,
                                        attn_chunk=16, scan_chunk=8))
        s1, m1 = step1(init_state(params, opt), batch)

        # 2x4 mesh
        from repro.dist.compat import make_mesh, mesh_context
        mesh = make_mesh((2, 4), ("data", "model"))
        with mesh_context(mesh):
            step2 = jax.jit(make_train_step(cfg, opt, n_micro=2,
                                            attn_chunk=16, scan_chunk=8))
            s2, m2 = step2(init_state(params, opt), batch)
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        d = max(abs(a - b) for a, b in zip(
            np.asarray(jax.tree.leaves(s1["params"])[0], np.float32).ravel(),
            np.asarray(jax.tree.leaves(s2["params"])[0], np.float32).ravel()))
        print("LOSS", l1, l2, "PDIFF", d)
        assert abs(l1 - l2) < 5e-2, (l1, l2)
    """)
    assert "LOSS" in out


@pytest.mark.slow
def test_compressed_psum_shard_map():
    """int8 compressed all-reduce across a pod axis under shard_map."""
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compress import compressed_psum

        from repro.dist.compat import make_mesh
        mesh = make_mesh((8,), ("pod",))
        x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)),
                        jnp.float32)

        f = shard_map(lambda t: compressed_psum(t, "pod"), mesh=mesh,
                      in_specs=P("pod"), out_specs=P("pod"))
        got = np.asarray(f(x))
        want = np.broadcast_to(np.asarray(x).sum(0, keepdims=True), (8, 64))
        rel = np.abs(got - want).max() / np.abs(want).max()
        print("REL", rel)
        assert rel < 0.05, rel
    """)
    assert "REL" in out


@pytest.mark.slow
def test_elastic_checkpoint_reshard():
    """Save on an 8-device mesh, restore onto a 4-device mesh."""
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp, tempfile
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.checkpoint import ckpt

        from repro.dist.compat import make_mesh
        mesh8 = make_mesh((8,), ("data",))
        sh8 = NamedSharding(mesh8, P("data"))
        tree = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8), sh8)}
        d = tempfile.mkdtemp()
        ckpt.save(d, 3, tree)

        devs = jax.devices()[:4]
        mesh4 = jax.sharding.Mesh(np.array(devs), ("data",))
        sh4 = NamedSharding(mesh4, P("data"))
        back = ckpt.restore(d, 3, tree, shardings={"w": sh4})
        assert back["w"].sharding == sh4
        assert np.array_equal(np.asarray(back["w"]),
                              np.arange(64.0).reshape(8, 8))
        print("ELASTIC OK")
    """)
    assert "ELASTIC OK" in out


@pytest.mark.slow
def test_dryrun_entrypoint_single_cell(tmp_path):
    """The dry-run driver itself (512 fake devices) on the smallest arch."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "seamless", "--shape", "decode_32k",
         "--out", str(tmp_path / "dryrun")],
        capture_output=True, text=True, env=env, timeout=420,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "lowered + compiled OK" in out.stdout


def test_cluster_host_rows_partition():
    from repro.launch.cluster import host_rows

    got = []
    for pid in range(8):
        got += list(host_rows(256, pid, 8))
    assert got == list(range(256))


@pytest.mark.slow
def test_cluster_driver_single_process():
    """The multi-host driver degrades gracefully to one process."""
    out = _run("""
        from repro.launch.cluster import main
        main(["--arch", "gemma-2b", "--reduced", "--steps", "3",
              "--batch", "8", "--seq", "32", "--ckpt-dir", "/tmp/ck_cl"])
        print("CLUSTER OK")
    """)
    assert "CLUSTER OK" in out
