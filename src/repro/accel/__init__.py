from .base import RANK_CHOICES, Accelerator, Slot
from .gaussian import GaussianFilter
from .hevc_dct import HEVCDct, MCMAccelerator

__all__ = [
    "Accelerator", "Slot", "RANK_CHOICES",
    "GaussianFilter", "HEVCDct", "MCMAccelerator",
]
