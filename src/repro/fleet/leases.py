"""Fleet bookkeeping records: workers, chunks, leases, batches.

The unit of remote work is a *chunk* — a contiguous slice of one
coalesced label batch, small enough that losing a worker mid-batch only
requeues a slice, large enough to keep the batched simulation
vectorized.  A *lease* binds one chunk to one worker for a bounded
time; a chunk whose lease expires (or whose worker's heartbeats stop)
goes back to the pending queue with its requeue count bumped.  Chunks
requeued past ``max_requeues`` — or stranded with no live worker — are
reclaimed by the orchestrator thread that owns the batch and labeled
in-process, so a batch ALWAYS completes: worker failure costs time,
never labels.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

import numpy as np

__all__ = ["WorkerRecord", "Chunk", "Lease", "FleetBatch"]


@dataclass
class WorkerRecord:
    """One registered worker's live state and counters."""

    id: str
    accels: Set[str] = field(default_factory=lambda: {"*"})
    fingerprints: Set[str] = field(default_factory=set)
    host: str = ""
    pid: Optional[int] = None
    registered_at: float = field(default_factory=time.time)
    last_seen: float = field(default_factory=time.monotonic)  # monotonic
    alive: bool = True
    rejoin_count: int = 0
    # counters
    labels: int = 0
    chunks: int = 0
    store_hits: int = 0
    busy_s: float = 0.0
    rejected_fps: Set[str] = field(default_factory=set)

    def can_serve(self, desc: Dict) -> bool:
        """Advertised-capability gate: the worker serves a context when
        it advertised its accelerator name (or the ``"*"`` wildcard =
        any builtin), has not rejected the fingerprint, and — when it
        advertises verified fingerprints — when the fingerprint is
        among them."""
        fp = desc.get("fingerprint")
        if fp in self.rejected_fps:
            return False
        if fp in self.fingerprints:
            return True
        if "*" in self.accels:
            return True
        # stage views ("smoothed_dct/stage0") ride their pipeline's name
        name = desc.get("accel", "")
        base = name.split("/stage")[0]
        return name in self.accels or base in self.accels

    def labels_per_sec(self) -> float:
        return (self.labels / self.busy_s) if self.busy_s > 0 else 0.0


@dataclass
class Chunk:
    """A slice of one label batch: the remote unit of work."""

    batch: "FleetBatch"
    index: int                      # position within the batch
    desc: Dict                      # wire context descriptor
    genomes: np.ndarray
    state: str = "pending"          # pending | leased | done
    requeues: int = 0
    worker: Optional[str] = None    # worker that completed it
    wire: Optional[Dict] = None     # trace context of the owning batch


@dataclass
class Lease:
    """One chunk bound to one worker until ``deadline`` (monotonic)."""

    id: str
    chunk: Chunk
    worker: str
    issued_at: float
    deadline: float
    span: Optional[object] = None   # fleet.lease lifecycle span handle


class FleetBatch:
    """One coalesced label batch in flight across the fleet.  The
    orchestrator thread that created it blocks on ``done`` and
    reassembles ``parts`` in chunk order."""

    def __init__(self, ctx, chunks: int):
        self.ctx = ctx
        self.parts: List[Optional[Dict[str, np.ndarray]]] = [None] * chunks
        self.remaining = chunks
        self.done = threading.Event()

    def complete(self, chunk: Chunk, labels: Dict[str, np.ndarray]) -> bool:
        """Deliver one chunk's labels (idempotent: a late duplicate of a
        completed chunk is dropped).  Returns True if this call newly
        completed the chunk."""
        if chunk.state == "done":
            return False
        chunk.state = "done"
        self.parts[chunk.index] = labels
        self.remaining -= 1
        if self.remaining == 0:
            self.done.set()
        return True

    def assemble(self) -> Dict[str, np.ndarray]:
        from ..service.store import LABEL_KEYS

        return {
            k: np.concatenate([p[k] for p in self.parts])
            for k in LABEL_KEYS
        }
