"""AdamW with global-norm clipping, configurable moment dtype (fp32
default; bf16 for the 398B-class configs to fit HBM — DESIGN.md §5), and
decoupled weight decay.  Optimizer state is a pytree sharded like the
parameters (XLA SPMD keeps moments on the same shards)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "clip_by_global_norm"]


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100

    def init(self, params) -> Dict[str, Any]:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def schedule(self, step: jnp.ndarray) -> jnp.ndarray:
        warm = jnp.minimum(1.0, (step + 1) / max(self.warmup_steps, 1))
        return self.lr * warm

    def update(
        self, grads, state: Dict[str, Any], params
    ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
        """-> (new_params, new_state, metrics)."""
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        step = state["step"] + 1
        lr = self.schedule(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m_new = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g32
            v_new = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g32 * g32
            mh = m_new / b1c
            vh = v_new / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * (
                p.astype(jnp.float32)
            )
            p_new = p.astype(jnp.float32) - lr * delta
            return (
                p_new.astype(p.dtype),
                m_new.astype(self.moment_dtype),
                v_new.astype(self.moment_dtype),
            )

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return (
            new_params,
            {"m": new_m, "v": new_v, "step": step},
            {"grad_norm": gnorm, "lr": lr},
        )
