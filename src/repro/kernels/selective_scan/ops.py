"""Public op: dispatches between the chunked associative-scan (XLA
composed — differentiable, used by training) and the fused Pallas kernel
(TPU serving/forward path; interpret mode on CPU)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from .kernel import selective_scan_pallas
from .ref import selective_scan_reference

__all__ = ["selective_scan"]


def selective_scan(
    x, dt, A, B, C, h0=None, *, impl: str = "reference",
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if h0 is None:
        h0 = jnp.zeros((x.shape[0], x.shape[2], A.shape[1]), jnp.float32)
    if impl == "pallas":
        return selective_scan_pallas(x, dt, A, B, C, h0, interpret=interpret)
    return selective_scan_reference(x, dt, A, B, C, h0)
