"""Quickstart: the paper's DSE end-to-end on the HEVC MCM accelerator.

    PYTHONPATH=src python examples/quickstart.py

Walks the three framework stages (Fig. 2): label a training sample with
XLA 'synthesis' + behavioral simulation, train the two surrogates (Random
Forest for QoR, Bayesian Ridge for energy), explore with NSGA-II, then
re-synthesize the survivors and print the true Pareto front.

Set REPRO_SMOKE=1 for the CI-sized fast mode.
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.accel import MCMAccelerator
from repro.core.acl.library import default_library
from repro.core.dse import DSEConfig, run_dse
from repro.core.nsga2 import NSGA2Config

SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def main():
    lib = default_library()
    accel = MCMAccelerator(1)  # MCM2 of the HEVC DCT
    print(f"accelerator: {accel.name}  slots={len(accel.slots)} "
          f"(muls={len(accel.mul_slot_indices())})")
    print(f"library: {len(lib)} circuits "
          f"(space ~ {np.prod([float(s) for s in accel.gene_sizes(lib)]):.2e} variants)")

    cfg = DSEConfig(
        pipeline="D",                      # the paper's winning pipeline
        n_train=16 if SMOKE else 80,       # paper: 1000 (reduced here)
        n_qor_samples=2 if SMOKE else 4,
        nsga=NSGA2Config(pop_size=8 if SMOKE else 48,
                         n_parents=4 if SMOKE else 16,
                         n_generations=2 if SMOKE else 10),
    )
    res = run_dse(accel, lib, cfg, verbose=True)

    print(f"\nsurrogate PCC (val): qor={res.val_pcc['qor']:.3f} "
          f"energy={res.val_pcc['energy']:.3f}")
    print(f"timings: {dict((k, round(v, 1)) for k, v in res.timings.items())}")
    print(f"surrogate evaluations: {res.search.n_evaluated} "
          f"(synthesis calls: {cfg.n_train + len(res.search.genomes)})")

    print("\ntrue Pareto front (PSNR dB vs energy J):")
    front = res.front_objectives
    for i in np.argsort(front[:, 0]):
        genome = res.front_genomes[i]
        circuits, _ = accel.decode(genome, lib)
        approx = {s.name: c.name for s, c in zip(accel.slots, circuits)
                  if not c.is_exact}
        print(f"  psnr={-front[i, 0]:7.2f}  energy={front[i, 1]:.3e}  "
              f"{approx or 'all-exact'}")


if __name__ == "__main__":
    main()
