"""The cheap feature extractor — our ABC [23] analogue.

ABC gives the paper synthesis-free structural statistics (AIG size/depth)
in ~30 ms per design.  Our analogue composes, in closed form and fully
vectorized over whole populations:

  * per-circuit error moments (from the exhaustive tables, precomputed),
    conditioned on the slot's constant operand where one exists
    (error-table column stats — much sharper than full-table stats),
  * per-circuit structural cost proxies (pp rows, truncation bits, carry
    window, effective rank),
  * accelerator-level composition: weighted error-moment propagation
    through the slot graph plus the rank-cost model
    cost = sum_groups (1 + rank_g)  (DESIGN.md §2).

Per-variant cost is a few microseconds amortized — reported next to the
paper's 30 ms in the Fig. 5 benchmark.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import numpy as np

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import
    from ...accel.base import Accelerator
from ...core.acl.library import Circuit, Library

__all__ = [
    "circuit_features_cheap",
    "column_error_stats",
    "variant_features",
    "CHEAP_AC_DIM",
]

CHEAP_AC_DIM = 12  # per-circuit cheap feature dim (see circuit_features_cheap)


@functools.lru_cache(maxsize=4096)
def _column_stats_cached(circuit_name: str, const: int, lib_id: int):
    from ...core.acl.library import default_library

    lib = default_library()
    c = lib[circuit_name]
    col = const + 128 if c.signed else const
    e = c.etab[:, col].astype(np.float64)
    ax = np.arange(-128, 128) if c.signed else np.arange(256)
    exact = ax * const
    denom = np.maximum(np.abs(exact), 1.0)
    return np.array(
        [
            e.mean(),
            np.abs(e).mean(),
            (e**2).mean(),
            np.abs(e).max(),
            (e != 0).mean(),
            (np.abs(e) / denom).mean(),
            (e**2).mean() - e.mean() ** 2,
        ]
    )


def column_error_stats(c: Circuit, const: Optional[int]) -> np.ndarray:
    """Error stats of circuit `c` conditioned on second operand == const
    (falls back to full-table stats for variable-operand slots)."""
    if const is None or c.kind == "add16":
        return c.error_features
    return _column_stats_cached(c.name, int(const), 0)


def circuit_features_cheap(c: Circuit) -> np.ndarray:
    """Per-circuit ABC-analogue feature vector (CHEAP_AC_DIM,):
    [me, mae, log10(1+mse), wce, ep, mre, sqrt(var),
     pp_rows, trunc_bits, carry_window, deploy_rank, deploy_cost]."""
    s = c.stats
    cost = c.deploy_cost_factor() if c.kind != "add16" else 0.0
    return np.array(
        [
            s.me,
            s.mae,
            np.log10(1.0 + s.mse),
            s.wce,
            s.ep,
            s.mre,
            np.sqrt(max(s.var, 0.0)),
            float(c.pp_rows),
            float(c.trunc_bits),
            float(c.carry_window),
            float(c.deploy_rank),
            cost,
        ]
    )


def _rank_used(c: Circuit, rank: Optional[int]) -> int:
    if c.kind == "add16":
        return 0
    if rank is None:
        return c.eff_rank
    return min(int(rank), 16)


def variant_features(
    accel: Accelerator,
    genomes: np.ndarray,
    library: Library,
    *,
    ac_features: Optional[np.ndarray] = None,   # optional per-(kind,idx) table
    accel_level: bool = True,
    rank_genes: bool = False,
) -> np.ndarray:
    """(n_variants, d) feature matrix.

    ``ac_features``: dict-free composition table — a {kind: (n_circ, d_ac)}
    mapping (built by the pipeline from cheap or synth per-AC features).
    If given, the composed block is sum / max pooling of per-slot rows.
    ``accel_level``: include the accelerator-level analytic block
    (column-conditional error composition + rank-cost model) — the thing
    pipelines D/E/F add.
    """
    from ...accel.base import RANK_CHOICES  # lazy: avoid circular import

    genomes = np.atleast_2d(np.asarray(genomes, dtype=np.int64))
    n = genomes.shape[0]
    slots = accel.slots
    n_slots = len(slots)
    mul_idx = accel.mul_slot_indices()
    consts = accel.mul_slot_constants()

    blocks: List[np.ndarray] = []

    # --- block 1: composed per-AC features (pipelines B/C/D/E) ------------
    if ac_features is not None:
        per_kind = {}
        for kind, table in ac_features.items():
            per_kind[kind] = np.asarray(table, dtype=np.float64)
        comp_sum = np.zeros((n, next(iter(per_kind.values())).shape[1]))
        comp_max = np.zeros_like(comp_sum)
        for i, s in enumerate(slots):
            rows = per_kind[s.kind][genomes[:, i]]
            comp_sum += rows * s.weight
            comp_max = np.maximum(comp_max, rows)
        blocks += [comp_sum, comp_max]

    # --- block 2: accelerator-level analytic features ---------------------
    if accel_level:
        me = np.zeros(n)
        mae = np.zeros(n)
        var = np.zeros(n)
        wce = np.zeros(n)
        ep = np.zeros(n)
        mre = np.zeros(n)
        add_mae = np.zeros(n)
        add_me = np.zeros(n)
        # per-slot gathered stats (vectorized over population via fancy
        # indexing into a per-slot stats matrix)
        for j, i in enumerate(mul_idx):
            kind = slots[i].kind
            circuits = library.kind(kind)
            stats = np.stack(
                [column_error_stats(c, consts[j]) for c in circuits]
            )  # (n_circ, 7)
            rows = stats[genomes[:, i]]
            me += rows[:, 0]
            mae += rows[:, 1]
            var += rows[:, 6]
            wce = np.maximum(wce, rows[:, 3])
            ep += rows[:, 4]
            mre += rows[:, 5]
        for i, s in enumerate(slots):
            if s.kind != "add16":
                continue
            circuits = library.kind(s.kind)
            stats = np.stack([c.error_features for c in circuits])
            rows = stats[genomes[:, i]]
            add_me += rows[:, 0]
            add_mae += rows[:, 1]

        # rank-cost model: matmul count multiplier sum_groups (1 + rank_g),
        # distinct circuit count, total correction rank
        ranks = np.zeros((n, len(mul_idx)), dtype=np.int64)
        for j, i in enumerate(mul_idx):
            kind = slots[i].kind
            circuits = library.kind(kind)
            native = np.array(
                [c.native_width is not None for c in circuits], dtype=bool
            )[genomes[:, i]]
            if rank_genes:
                rank_gene = genomes[:, n_slots + j]
                eff = np.array([c.deploy_rank for c in circuits])[genomes[:, i]]
                chosen = np.array(
                    [
                        eff[t] if RANK_CHOICES[rank_gene[t]] is None
                        else RANK_CHOICES[rank_gene[t]]
                        for t in range(n)
                    ]
                )
            else:
                chosen = np.array([c.deploy_rank for c in circuits])[genomes[:, i]]
            exact_mask = np.array(
                [c.is_exact for c in circuits], dtype=bool
            )[genomes[:, i]]
            ranks[:, j] = np.where(exact_mask | native, 0, chosen)

        total_rank = ranks.sum(axis=1)
        matmul_mult = (1.0 + ranks).sum(axis=1) / max(len(mul_idx), 1)
        distinct = np.array(
            [len(set(map(tuple, zip(g[mul_idx], ranks[t])))) for t, g in
             enumerate(genomes)],
            dtype=np.float64,
        )
        blocks.append(
            np.stack(
                [
                    me, mae, np.sqrt(np.maximum(var, 0)), wce,
                    ep, mre, add_me, add_mae,
                    total_rank.astype(np.float64),
                    matmul_mult,
                    distinct,
                ],
                axis=1,
            )
        )

    if not blocks:
        raise ValueError("no feature blocks selected")
    return np.concatenate(blocks, axis=1)
