"""Chaos harness + crash-safe durability quickstart.

    PYTHONPATH=src python examples/resilience_quickstart.py

Four stations (see examples/RESILIENCE.md):

  1. deterministic fault plans — the same seed replays the same storm,
  2. the segmented store surviving torn writes and segment bit-rot
     (quarantine-and-continue, lazy warm start),
  3. a DSE campaign completing UNDER a fault storm with a Pareto front
     byte-identical to its fault-free twin and zero lost labels,
  4. the /health endpoint a load balancer (or a human) probes.

Set REPRO_SMOKE=1 for the CI-sized fast mode."""

import os
import shutil
import sys
import tempfile
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import faults
from repro.faults import FaultPlan
from repro.service import CampaignManager, CampaignSpec
from repro.service.api import Client, make_server
from repro.service.store import open_label_store

SMOKE = bool(os.environ.get("REPRO_SMOKE"))
SIZE = dict(n_train=8, n_qor_samples=2, pop_size=8, n_parents=4,
            n_generations=2 if SMOKE else 4)


def banner(msg):
    print(f"\n=== {msg} ===")


def station_plans():
    banner("1. deterministic fault plans")
    plan = (FaultPlan(seed=7, name="demo")
            .add("demo.point", "drop", p=0.5))
    faults.install(plan)
    storm_a = [faults.check("demo.point") is not None for _ in range(12)]
    faults.install(FaultPlan(seed=7, name="demo")
                   .add("demo.point", "drop", p=0.5))
    storm_b = [faults.check("demo.point") is not None for _ in range(12)]
    print(f"seed 7, p=0.5, 12 occurrences : {storm_a}")
    print(f"same seed replayed            : {storm_b}")
    assert storm_a == storm_b, "storms must replay identically"
    print(f"tallies: {faults.stats()['by_point']}")
    faults.uninstall()


def station_store(root):
    banner("2. segmented store: torn writes, bit-rot, warm start")
    from repro.service.store import LABEL_KEYS

    path = os.path.join(root, "labels.segd")
    store = open_label_store(path, segment_records=8)
    # every 2nd append is preceded by a torn foreign record
    faults.install(FaultPlan(seed=1).add(
        "store.append", "torn_write", p=0.5, fraction=0.5))
    for i in range(24):
        store.put(f"k{i:03d}", {k: float(i) for k in LABEL_KEYS})
    faults.uninstall()
    st = store.stats()
    print(f"wrote 24 records -> {st['segments']} sealed segments, "
          f"{st['repaired_tails']} torn tails repaired in-line")
    store.close()

    # bit-rot a sealed segment, then reopen COLD
    seg = sorted(f for f in os.listdir(path)
                 if f.startswith("seg-") and f.endswith(".jsonl"))[0]
    p = os.path.join(path, seg)
    data = bytearray(open(p, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(p, "wb").write(data)

    fresh = open_label_store(path, segment_records=8)
    st = fresh.stats()
    print(f"reopen: {st['segments_loaded']} segment bodies parsed "
          f"(lazy warm start — the index came from sidecars)")
    alive = sum(1 for i in range(24) if fresh.get(f"k{i:03d}"))
    st = fresh.stats()
    print(f"after reading every key: {alive}/24 readable, "
          f"{st['quarantined_segments']} segment quarantined "
          f"({st['quarantined']} records), store still serving")
    fresh.put("probe", {k: 0.0 for k in LABEL_KEYS})
    assert fresh.get("probe") is not None, "must keep accepting writes"
    print("still writable after quarantine: True")
    fresh.close()


def station_storm_campaign(root):
    banner("3. campaign under a storm vs its fault-free twin")
    spec = CampaignSpec(accel="mcm2", **SIZE)

    twin = CampaignManager(eval_workers=2, campaign_workers=1)
    cid = twin.submit(spec)
    assert twin.wait(cid, timeout=600) == "done"
    twin_front = twin.result(cid).front_objectives.copy()
    twin.shutdown()

    store = open_label_store(os.path.join(root, "storm.segd"),
                             segment_records=8)
    mgr = CampaignManager(store, eval_workers=2, campaign_workers=1)
    faults.install(
        FaultPlan(seed=3, name="storm")
        .add("store.append", "torn_write", times=2, fraction=0.5)
        .add("sched.dispatch", "latency", delay_s=0.02, times=3)
        .add("synth.compile", "latency", delay_s=0.02, times=5))
    cid = mgr.submit(spec)
    assert mgr.wait(cid, timeout=600) == "done"
    front = mgr.result(cid).front_objectives.copy()
    print(f"storm injections: {faults.stats()['by_point']}")
    faults.uninstall()

    n_keys = len(store)
    mgr.shutdown()
    store.close()
    fresh = open_label_store(os.path.join(root, "storm.segd"))
    lost = n_keys - len(fresh)
    fresh.close()
    identical = bool(np.array_equal(twin_front, front))
    print(f"front byte-identical to twin: {identical}; "
          f"labels lost across reopen: {lost}")
    assert identical and lost == 0


def station_health(root):
    banner("4. GET /health")
    store = open_label_store(os.path.join(root, "health.segd"))
    mgr = CampaignManager(store, eval_workers=1, campaign_workers=1)
    srv = make_server(mgr, port=0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    cli = Client(f"http://127.0.0.1:{srv.server_address[1]}")
    h = cli.health()
    print(f"ok={h['ok']} store.writable={h['store']['writable']} "
          f"store.quarantined={h['store']['quarantined']} "
          f"scheduler.alive={h['scheduler']['alive']} "
          f"faults.active={h['faults']['active']}")
    srv.shutdown()
    mgr.shutdown()
    store.close()


def main():
    root = tempfile.mkdtemp(prefix="resilience_qs_")
    t0 = time.time()
    try:
        station_plans()
        station_store(root)
        station_storm_campaign(root)
        station_health(root)
    finally:
        faults.uninstall()
        shutil.rmtree(root, ignore_errors=True)
    print(f"\nall stations green in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
