"""Fig. 6 — surrogate-model ablation: Random Forest / Bayesian Ridge / SVR
PCC for QoR and power(energy) across MCM1..MCM4."""

from __future__ import annotations

import numpy as np

from repro.accel import MCMAccelerator
from repro.core.acl.library import default_library
from repro.core.features import synth
from repro.core.features.pipelines import build_extractor
from repro.core.surrogates import make, pcc

from .common import emit

MODELS = ("random_forest", "bayesian_ridge", "svr")


def run(n_train: int = 60, n_test: int = 30, seed: int = 0):
    lib = default_library()
    rng = np.random.default_rng(seed)
    best = {"qor": {}, "energy": {}}
    for row in range(4):
        accel = MCMAccelerator(row)
        sizes = accel.gene_sizes(lib)
        genomes = rng.integers(0, sizes[None, :],
                               size=(n_train + n_test, len(sizes)))
        labels = synth.label_variants(accel, genomes, lib, cache={})
        ext = build_extractor("D", accel, lib)
        X = ext(genomes)
        for target in ("qor", "energy"):
            scores = {}
            for name in MODELS:
                m = make(name, seed=seed).fit(X[:n_train],
                                              labels[target][:n_train])
                scores[name] = pcc(labels[target][n_train:],
                                   m.predict(X[n_train:]))
                emit(f"fig6.mcm{row+1}.{target}.{name}", 0.0,
                     round(scores[name], 3))
            best[target][row] = max(scores, key=scores.get)

    # paper claim: RF best for QoR, Bayesian Ridge best for power
    rf_qor = sum(v == "random_forest" for v in best["qor"].values())
    br_pow = sum(v == "bayesian_ridge" for v in best["energy"].values())
    emit("fig6.rf_wins_qor_of4", 0.0, rf_qor)
    emit("fig6.bayes_wins_energy_of4", 0.0, br_pow)
    return best
