"""Architecture registry: one module per assigned architecture plus the
paper's own accelerators (which live in repro.accel)."""
from importlib import import_module
from typing import Dict, List

_MODULES = {
    "deepseek-67b": "deepseek_67b",
    "gemma-2b": "gemma_2b",
    "chatglm3-6b": "chatglm3_6b",
    "granite-8b": "granite_8b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "jamba-1.5-large-398b": "jamba_15_large",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
}

ARCHS: List[str] = list(_MODULES)


def get_config(name: str):
    """Fetch an architecture config by its assignment id (or a unique
    prefix, e.g. 'jamba')."""
    if name not in _MODULES:
        matches = [k for k in _MODULES if k.startswith(name)]
        if len(matches) != 1:
            raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
        name = matches[0]
    return import_module(f".{_MODULES[name]}", __package__).CONFIG


def all_configs() -> Dict[str, object]:
    return {k: get_config(k) for k in ARCHS}
