from .serve import make_decode_step, make_prefill_step
from .step import cross_entropy, init_state, make_loss_fn, make_train_step

__all__ = [
    "cross_entropy", "make_loss_fn", "make_train_step", "init_state",
    "make_prefill_step", "make_decode_step",
]
