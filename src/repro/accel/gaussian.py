"""The paper's motivational accelerator (Fig. 1): a 3x3 Gaussian filter
composed of nine 8-bit multipliers and eight 16-bit adders.

Kernel = [[1,2,1],[2,4,2],[1,2,1]] / 16.  Products are at most 255*4 and
the 9-term adder tree peaks below 2^16, so the 16-bit adder models apply
without wraparound in the exact case.

Deployment form: im2col matmul (n_pix, 9) @ (9, 1) with one K-column per
multiplier slot (DESIGN.md §2).
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.acl.library import Circuit, Library
from . import fused
from ._batchsim import grouped_apply, lut_gather, mul_lut
from .base import Accelerator, Slot
from .images import sample_images

__all__ = ["GaussianFilter", "GAUSS_COEFFS"]

GAUSS_COEFFS = np.array([1, 2, 1, 2, 4, 2, 1, 2, 1], dtype=np.int64)

# adder-tree wiring: pairs reduced in order; 8 adders for 9 operands
# a0=(p0,p1) a1=(p2,p3) a2=(p4,p5) a3=(p6,p7) a4=(a0,a1) a5=(a2,a3)
# a6=(a4,a5) a7=(a6,p8)
_TREE = [(0, 1), (2, 3), (4, 5), (6, 7), (9, 10), (11, 12), (13, 14), (15, 8)]


def _im2col(images: np.ndarray) -> np.ndarray:
    """(..., n, H, W) -> (..., n*(H-2)*(W-2), 9) sliding 3x3 windows.

    Window element (dy, dx) lands in column 3*dy+dx, matching the slot
    order of the 9 multipliers."""
    win = np.lib.stride_tricks.sliding_window_view(images, (3, 3), axis=(-2, -1))
    return win.reshape(images.shape[:-3] + (-1, 9))


# QoR evaluation re-derives the im2col of the SAME canonical
# sample_inputs(n, seed) images on every label batch of a campaign; keyed
# by content, the windows are built once.  Only shared (n, H, W) inputs
# are cached — per-genome intermediate stacks vary per batch.
_IM2COL_CACHE: "OrderedDict[tuple, np.ndarray]" = OrderedDict()
_IM2COL_CACHE_MAX = 8
_IM2COL_LOCK = threading.Lock()  # scheduler worker threads share this


def _im2col_cached(images: np.ndarray) -> np.ndarray:
    if images.ndim != 3 or images.nbytes > (1 << 22):
        return _im2col(images)
    key = (images.shape, images.dtype.str, images.tobytes())
    with _IM2COL_LOCK:
        cols = _IM2COL_CACHE.get(key)
        if cols is not None:
            _IM2COL_CACHE.move_to_end(key)
            return cols
    cols = _im2col(images)
    cols.setflags(write=False)
    with _IM2COL_LOCK:
        _IM2COL_CACHE[key] = cols
        while len(_IM2COL_CACHE) > _IM2COL_CACHE_MAX:
            _IM2COL_CACHE.popitem(last=False)
    return cols


class GaussianFilter(Accelerator):
    name = "gaussian3x3"
    batched_sim = True
    slots = [Slot(f"mul{i}", "mul8u", 1.0) for i in range(9)] + [
        Slot(f"add{i}", "add16", 1.0) for i in range(8)
    ]

    def sample_inputs(self, n: int, seed: int = 0) -> np.ndarray:
        return sample_images(n, size=32, seed=seed)

    def _run(self, images: np.ndarray, muls: Sequence, adds: Sequence) -> np.ndarray:
        cols = _im2col_cached(images)  # (..., m, 9)
        prods = [muls[i](cols[..., i], GAUSS_COEFFS[i]) for i in range(9)]
        vals = list(prods)  # indices 0..8; adder outputs appended as 9..16
        for fn, (ia, ib) in zip(adds, _TREE):
            vals.append(fn(vals[ia], vals[ib]))
        acc = vals[-1]
        out = acc >> 4  # /16
        h, w = images.shape[-2:]
        return out.reshape(images.shape[:-2] + (h - 2, w - 2))

    def simulate(self, circuits: Sequence[Circuit], inputs: np.ndarray) -> np.ndarray:
        muls = [c.fn for c in circuits[:9]]
        adds = [c.fn for c in circuits[9:]]
        return self._run(inputs, muls, adds)

    def exact_output(self, inputs: np.ndarray) -> np.ndarray:
        exact_mul = lambda a, b: a * b
        exact_add = lambda a, b: a + b
        return self._run(inputs, [exact_mul] * 9, [exact_add] * 8)

    def simulate_batch(
        self,
        genomes: np.ndarray,
        library: Library,
        inputs: np.ndarray,
        *,
        rank_genes: bool = False,
        per_genome_inputs: bool = False,
    ) -> np.ndarray:
        """Vectorized population sim: one (G, m, 9) LUT gather for all
        multiplier slots, adder tree applied per distinct circuit over
        the sub-population that chose it.  Dispatches to the fused XLA
        engine first; this numpy body is the reference it verifies
        against (and the fallback when fusing is off or pinned)."""
        fused_out = fused.try_simulate_batch(
            self, genomes, library, inputs,
            rank_genes=rank_genes, per_genome_inputs=per_genome_inputs,
        )
        if fused_out is not None:
            return fused_out
        genomes = np.atleast_2d(np.asarray(genomes, dtype=np.int64))
        images = np.asarray(inputs)
        G = len(genomes)
        cols = (
            _im2col(images) if per_genome_inputs else _im2col_cached(images)
        )  # (G, m, 9) or (m, 9)
        lut = mul_lut(library, "mul8u", GAUSS_COEFFS, tag=self.name)
        prods = lut_gather(
            lut, genomes[:, :9], cols, per_genome=per_genome_inputs
        )  # (G, m, 9)
        add_fns = [c.fn for c in library.kind("add16")]
        vals = [prods[..., i] for i in range(9)]
        for j, (ia, ib) in enumerate(_TREE):
            vals.append(
                grouped_apply(add_fns, genomes[:, 9 + j], vals[ia], vals[ib])
            )
        out = vals[-1] >> 4
        h, w = images.shape[-2:]
        lead = images.shape[:-2] if per_genome_inputs else (G,) + images.shape[:-2]
        return out.reshape(lead + (h - 2, w - 2))

    # --- deployment -------------------------------------------------------
    def matmul_shape(self) -> Tuple[int, int, int]:
        return (900, 9, 1)  # 32x32 image -> 900 windows

    def slot_groups(self) -> List[Tuple[int, int]]:
        return [(i, i + 1) for i in range(9)]

    def mul_slot_constants(self):
        return [int(c) for c in GAUSS_COEFFS]

    def deploy_signature(self, specs):
        from .base import grouped_deploy_signature

        return grouped_deploy_signature(self, specs)

    def build_deploy(self, specs: Sequence, inputs: Optional[np.ndarray] = None):
        """-> (jax_fn, args): the rank-k MXU deployment of this variant.

        Weight operand = the Gaussian coefficients (constants); activation
        operand = the im2col'd image windows.
        """
        import jax.numpy as jnp

        from ..kernels.approx_matmul import grouped_matmul

        if inputs is None:
            inputs = self.sample_inputs(1, seed=1)
        x = jnp.asarray(_im2col(inputs))                 # (m, 9)
        w = jnp.asarray(GAUSS_COEFFS.reshape(9, 1))      # (9, 1)
        groups = self.slot_groups()

        def fn(x, w):
            return grouped_matmul(x, w, specs, groups)

        return fn, (x, w)


# --- fused engine plan -----------------------------------------------------

@fused.register_fused(GaussianFilter)
def _gaussian_fused_plan(accel, library, eng):
    """Whole-filter XLA program: in-jit im2col (nine shifted slices),
    (G, m, 9) LUT gather, all-circuits adder tree with per-genome
    selection, >>4 normalization.  Integer outputs, so the QoR tail
    (SSE vs the exact filter) also runs on-device."""
    import jax.numpy as jnp

    lut = eng.lut("mul8u", GAUSS_COEFFS, tag=accel.name)

    def stage_fn(genes, x, per_genome):
        h, w = x.shape[-2], x.shape[-1]
        cols = jnp.stack(
            [
                x[..., dy : h - 2 + dy, dx : w - 2 + dx]
                for dy in range(3)
                for dx in range(3)
            ],
            axis=-1,
        )  # (..., n, h-2, w-2, 9), window (dy, dx) in slot column 3*dy+dx
        if per_genome:
            cols = cols.reshape((cols.shape[0], -1, 9))
        else:
            cols = cols.reshape((-1, 9))
        prods = eng.gather(lut, genes[:, :9], cols, per_genome=per_genome)
        vals = [prods[..., i] for i in range(9)]
        for j, (ia, ib) in enumerate(_TREE):
            vals.append(
                eng.select_add(genes[:, 9 + j], vals[ia], vals[ib], signed=False)
            )
        out = vals[-1] >> 4
        lead = x.shape[:-2] if per_genome else (genes.shape[0],) + x.shape[:-2]
        return out.reshape(lead + (h - 2, w - 2))

    return fused.FusedPlan(
        key=(),
        stage_fn=stage_fn,
        prep=lambda inputs: np.ascontiguousarray(
            np.asarray(inputs), dtype=np.int32
        ),
        post=lambda raw, inputs, per_genome: raw.astype(np.int64),
        qor_ref=lambda a, inputs: np.asarray(a.exact_output(inputs)),
    )
