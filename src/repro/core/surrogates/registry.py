"""Registry of the ~20 pre-available surrogate models (paper §IV: 'of the
nearly 20 models pre-available in the autoXFPGAs framework')."""

from __future__ import annotations

from typing import Callable, Dict

from .base import Model
from .kernel import KNN, MLP, SVR, KernelRidgeRBF
from .linear import (
    OLS,
    BayesianRidge,
    ElasticNet,
    Huber,
    Lasso,
    Poly2Ridge,
    Ridge,
    SGDRegressor,
)
from .trees import CART, ExtraTrees, GradientBoosting, RandomForest

__all__ = ["REGISTRY", "make", "available"]

REGISTRY: Dict[str, Callable[..., Model]] = {
    # linear family
    "ols": OLS,
    "ridge": Ridge,
    "ridge_strong": lambda seed=0: Ridge(alpha=10.0, seed=seed),
    "lasso": Lasso,
    "elastic_net": ElasticNet,
    "bayesian_ridge": BayesianRidge,     # paper's power estimator
    "huber": Huber,
    "sgd": SGDRegressor,
    "poly2_ridge": Poly2Ridge,
    # kernel / instance family
    "kernel_ridge_rbf": KernelRidgeRBF,
    "svr": SVR,                          # paper Fig. 6 contender
    "knn3": lambda seed=0: KNN(k=3, seed=seed),
    "knn5": lambda seed=0: KNN(k=5, seed=seed),
    "knn_uniform": lambda seed=0: KNN(k=5, weighted=False, seed=seed),
    # tree family
    "cart": CART,
    "cart_shallow": lambda seed=0: CART(max_depth=4, seed=seed),
    "random_forest": RandomForest,       # paper's QoR estimator
    "random_forest_big": lambda seed=0: RandomForest(n_trees=200, seed=seed),
    "extra_trees": ExtraTrees,
    "gradient_boosting": GradientBoosting,
    # neural
    "mlp": MLP,
}


def make(name: str, seed: int = 0) -> Model:
    return REGISTRY[name](seed=seed)


def available() -> list[str]:
    return sorted(REGISTRY)
