"""Serving steps: prefill (prompt -> last-token logits + filled caches)
and decode (one token against the cache, greedy or sampled).

Prefill slices the residual stream to the final position *before* the
LM head — materializing (B, 32k, vocab) logits would be tens of GB per
device for the large-vocab archs.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import ApproxPolicy
from ..models.config import ModelConfig
from ..models.transformer import (
    _embed,
    _logits,
    _scan_blocks,
    encode,
)
from ..models.common import make_rope

__all__ = ["Generator", "make_prefill_step", "make_decode_step"]


def _inv_freq(cfg: ModelConfig):
    return jnp.asarray(
        make_rope(cfg.resolved_head_dim, cfg.rope_theta,
                  fraction=0.5 if cfg.rope_style == "half" else 1.0)
    )


def make_prefill_step(cfg: ModelConfig, *, policy: Optional[ApproxPolicy] = None,
                      attn_chunk: int = 1024, scan_chunk: int = 128):
    def prefill(params, batch: Dict[str, jnp.ndarray], caches):
        """-> (last_logits (b, 1, V), caches, enc_out|None)"""
        parts = []
        if batch.get("embeds") is not None:
            parts.append(batch["embeds"].astype(jnp.bfloat16))
        if batch.get("tokens") is not None:
            parts.append(_embed(params, cfg, batch["tokens"]))
        x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        if cfg.name.startswith("gemma"):
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = encode(params, cfg, batch["enc_embeds"],
                             policy=policy, remat=False)
        x, caches, _ = _scan_blocks(
            params, cfg, x, _inv_freq(cfg), policy=policy, causal=True,
            caches=caches, pos=None, enc_out=enc_out, remat=False,
            attn_chunk=attn_chunk, scan_chunk=scan_chunk,
        )
        logits = _logits(params, cfg, x[:, -1:, :])
        if cfg.is_encoder_decoder:
            return logits, caches, enc_out
        return logits, caches

    return prefill


def make_decode_step(cfg: ModelConfig, *, policy: Optional[ApproxPolicy] = None,
                     greedy: bool = True):
    from ..models.transformer import decode_step as _ds

    def serve_step(params, caches, tokens, pos, enc_out=None):
        """-> (next_tokens (b, 1), logits, caches)"""
        logits, caches = _ds(params, cfg, caches, tokens, pos,
                             policy=policy, enc_out=enc_out)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, caches

    return serve_step


class Generator:
    """One (config, policy) pair's jitted prefill + decode steps, reused
    across prompt batches.

    The serving tier holds one Generator per front genome (the genome's
    decoded ``ApproxPolicy`` is baked into both jitted steps), so
    steady-state requests at a popular operating point never re-trace;
    ``launch.serve`` drives the same object for one-shot CLI runs.
    Caches are rebuilt per ``generate`` call — they are shape-keyed by
    (batch, prompt_len + gen), so distinct request shapes simply retrace
    the two steps once each."""

    def __init__(self, cfg: ModelConfig, *,
                 policy: Optional[ApproxPolicy] = None,
                 attn_chunk: int = 1024, scan_chunk: int = 128):
        self.cfg = cfg
        self.policy = policy
        self._prefill = jax.jit(make_prefill_step(
            cfg, policy=policy, attn_chunk=attn_chunk,
            scan_chunk=scan_chunk))
        self._decode = jax.jit(make_decode_step(cfg, policy=policy))

    def generate(
        self,
        params,
        prompts,
        gen: int,
        *,
        key: Optional[jax.Array] = None,
    ) -> Tuple[jnp.ndarray, float]:
        """Greedy-decode ``gen`` tokens after ``prompts`` (b, L) int32.
        Synthesizes the frontend extras reduced archs need (encoder
        embeds for enc-dec, vision embeds for vision frontends).
        Returns (tokens (b, L + gen), decode tokens/s)."""
        import time

        from ..models.common import init_tree
        from ..models.transformer import cache_specs

        cfg = self.cfg
        if key is None:
            key = jax.random.PRNGKey(0)
        prompts = jnp.asarray(prompts, jnp.int32)
        batch, prompt_len = prompts.shape
        vis = cfg.frontend_len if cfg.frontend == "vision" else 0
        max_len = prompt_len + int(gen) + vis
        enc_len = 16 if cfg.is_encoder_decoder else 0
        caches = init_tree(
            cache_specs(cfg, batch, max_len, enc_len=enc_len), key)

        batch_in: Dict[str, Any] = {"tokens": prompts}
        if cfg.is_encoder_decoder:
            batch_in["enc_embeds"] = jax.random.normal(
                key, (batch, enc_len, cfg.d_model), jnp.float32) * 0.1
        if cfg.frontend == "vision":
            batch_in["embeds"] = jax.random.normal(
                key, (batch, cfg.frontend_len, cfg.d_model),
                jnp.float32) * 0.1

        out = self._prefill(params, batch_in, caches)
        enc_out = None
        if cfg.is_encoder_decoder:
            logits, caches, enc_out = out
        else:
            logits, caches = out
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]

        toks = [prompts, nxt]
        pos0 = prompt_len + vis
        t0 = time.perf_counter()
        for i in range(int(gen) - 1):
            nxt, logits, caches = self._decode(
                params, caches, nxt, jnp.int32(pos0 + i), enc_out=enc_out
            )
            toks.append(nxt)
        dt = time.perf_counter() - t0
        tokens = jnp.concatenate(toks, axis=1)
        tps = batch * (int(gen) - 1) / max(dt, 1e-9)
        return tokens, tps
