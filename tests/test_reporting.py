"""Reporting/driver layers: roofline table renderer, hillclimb registry,
DSE front invariants."""

import json

import numpy as np
import pytest

from repro.core.pareto import non_dominated_mask


def test_roofline_renderer_handles_ok_and_skip():
    from benchmarks.roofline import render_md

    recs = [
        {"arch": "a", "shape": "train_4k", "mesh": "16x16", "status": "ok",
         "roofline": {"t_compute": 1.0, "t_memory": 2.0, "t_collective": 0.5,
                      "bottleneck": "memory"},
         "memory": {"peak_tpu_estimate_bytes": 8 * 2**30},
         "fits_hbm": True, "useful_flops_ratio": 0.5},
        {"arch": "b", "shape": "long_500k", "mesh": "16x16",
         "status": "SKIP(full-attn)"},
    ]
    md = render_md(recs)
    assert "memory" in md and "SKIP" in md
    assert md.count("|") > 10


def test_hillclimb_registry_well_formed():
    from repro.launch.hillclimb import EXPERIMENTS

    assert len(EXPERIMENTS) >= 15
    for name, (hyp, fn) in EXPERIMENTS.items():
        assert isinstance(hyp, str) and len(hyp) > 5, name
        assert callable(fn), name


def test_dse_front_contains_exact_anchor():
    """The delivered front always includes the exact reference corner
    (PSNR cap) — the stage-1 anchor guarantees it."""
    from repro.accel import MCMAccelerator
    from repro.core.acl.library import default_library
    from repro.core.dse import DSEConfig, run_dse
    from repro.core.nsga2 import NSGA2Config

    lib = default_library()
    res = run_dse(MCMAccelerator(2), lib, DSEConfig(
        n_train=16, n_qor_samples=1,
        nsga=NSGA2Config(pop_size=12, n_parents=6, n_generations=2, seed=3),
    ))
    assert non_dominated_mask(res.front_objectives).all()
    assert (-res.front_objectives[:, 0]).max() >= 99.9  # PSNR cap present


def test_perf_log_schema_if_present():
    import os

    p = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "perf_log.json")
    if not os.path.exists(p):
        pytest.skip("no perf log in this checkout")
    log = json.load(open(p))
    assert len(log) >= 10
    for rec in log:
        assert "experiment" in rec and "hypothesis" in rec
        if rec.get("status") == "ok":
            assert {"t_compute", "t_memory", "t_collective"} <= set(
                rec["roofline"])


def test_dryrun_records_schema():
    import os

    d = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("no dryrun cache")
    files = [f for f in os.listdir(d) if f.endswith(".json")]
    assert len(files) == 80  # 10 archs x 4 shapes x 2 meshes
    ok = skip = 0
    for f in files:
        r = json.load(open(os.path.join(d, f)))
        if r.get("status") == "ok":
            ok += 1
            assert r["fits_hbm"] in (True, False)
            assert r["roofline"]["bottleneck"] in (
                "compute", "memory", "collective")
            assert r["flops_per_device"] > 0
        else:
            skip += 1
            assert r["status"].startswith("SKIP")
    assert ok == 64 and skip == 16
