"""TPU v5e hardware constants and the three-term roofline model.

The paper's DSE optimizes (QoR, power, LUTs, delay) on a Xilinx FPGA.  Our
retarget optimizes (QoR, energy, latency, HBM bytes) on a TPU v5e pod
(DESIGN.md §2).  All absolute constants are documented here; Pareto
orderings only depend on them through ratios, and the §Roofline deliverable
uses exactly these numbers.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

__all__ = [
    "TPUv5e",
    "RooflineTerms",
    "roofline",
    "collective_bytes_from_hlo",
    "DTYPE_BYTES",
]


@dataclass(frozen=True)
class TPUv5e:
    """Per-chip constants (from the assignment brief + public v5e specs)."""

    peak_bf16_flops: float = 197e12   # FLOP/s per chip
    peak_int8_ops: float = 394e12     # MXU int8 = 2x bf16
    peak_int4_ops: float = 788e12     # int4 = 4x bf16 (projected)
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link (assignment constant)
    hbm_bytes: float = 16e9           # capacity per chip

    def dtype_cost_factor(self, width_bits: int) -> float:
        """Relative compute cost per MAC vs bf16 (v5e widens throughput at
        narrow widths; only power-of-two widths are native)."""
        if width_bits <= 4:
            return self.peak_bf16_flops / self.peak_int4_ops
        if width_bits <= 8:
            return self.peak_bf16_flops / self.peak_int8_ops
        return 1.0

    # Energy model (J) — order-of-magnitude literature values; used for the
    # paper's "power" objective analogue.  Consistency matters, absolutes
    # don't (DESIGN.md §2).
    e_flop: float = 0.3e-12           # J per bf16 FLOP
    e_hbm_byte: float = 15e-12        # J per HBM byte
    e_ici_byte: float = 30e-12        # J per ICI byte


V5E = TPUv5e()


@dataclass(frozen=True)
class RooflineTerms:
    """The three §Roofline terms, in seconds per executed step (per chip),
    plus the derived energy (J) and bottleneck label."""

    t_compute: float
    t_memory: float
    t_collective: float
    flops: float              # per-device HLO FLOPs
    hbm_bytes: float          # per-device HLO bytes accessed
    coll_bytes: float         # per-device collective bytes on the wire

    @property
    def t_step(self) -> float:
        # Optimistic (fully-overlapped) execution: max of the three rails.
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def t_serial(self) -> float:
        # Pessimistic (no overlap) execution.
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def energy(self) -> float:
        return (
            self.flops * V5E.e_flop
            + self.hbm_bytes * V5E.e_hbm_byte
            + self.coll_bytes * V5E.e_ici_byte
        )

    def as_dict(self) -> Dict[str, float]:
        return {
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "t_step": self.t_step,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "energy": self.energy,
            "bottleneck": self.bottleneck,
        }


def roofline(
    flops: float,
    hbm_bytes: float,
    coll_bytes: float,
    *,
    hw: TPUv5e = V5E,
) -> RooflineTerms:
    """Three-term roofline from *per-device* FLOPs / HBM bytes / wire bytes.

    compute    = FLOPs / peak;  memory = bytes / HBM bw;
    collective = wire bytes / ICI link bw  (per assignment definition).
    """
    return RooflineTerms(
        t_compute=flops / hw.peak_bf16_flops,
        t_memory=hbm_bytes / hw.hbm_bw,
        t_collective=coll_bytes / hw.ici_bw,
        flops=flops,
        hbm_bytes=hbm_bytes,
        coll_bytes=coll_bytes,
    )


DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# e.g.  "bf16[32,4096,128]{2,1,0} all-gather(...)"
_COLL_RE = re.compile(
    r"(\w+)\[([\d,]*)\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes-on-the-wire per collective class, parsed from the
    partitioned HLO module (shapes in the SPMD module are per-device).

    Ring-algorithm accounting:
      all-reduce       ~ 2 x size    (reduce-scatter + all-gather phases)
      all-gather       ~ 1 x result  (each device receives ~full result)
      reduce-scatter   ~ 1 x operand
      all-to-all       ~ 1 x operand
      collective-permute ~ 1 x operand
    ``-done`` halves of async pairs are skipped (counted at ``-start``).
    """
    out: Dict[str, float] = {
        "all-reduce": 0.0,
        "all-gather": 0.0,
        "reduce-scatter": 0.0,
        "all-to-all": 0.0,
        "collective-permute": 0.0,
        "total": 0.0,
    }
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        size = _shape_bytes(dtype, dims)
        if op == "all-reduce":
            size *= 2.0
        out[op] += size
        out["total"] += size
    return out
