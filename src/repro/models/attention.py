"""Attention layer: GQA/MQA self-attention (causal or full), cross
attention, RoPE variants, KV-cache decode.  Projections route through
``approx_linear.linear`` so the DSE policy applies."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from ..kernels.flash_attention import attention as attn_op
from .approx_linear import ApproxPolicy, linear
from .common import ParamSpec, apply_rope, rms_norm
from .config import ModelConfig

__all__ = [
    "attn_param_specs",
    "self_attention",
    "cross_attention",
    "init_kv_cache_spec",
]


def attn_param_specs(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    p = {
        "norm": ParamSpec((d,), ("norm",), init="zeros"),
        "wq": ParamSpec((d, cfg.n_heads * hd), ("embed", "heads")),
        "wk": ParamSpec((d, cfg.n_kv_heads * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, cfg.n_kv_heads * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((cfg.n_heads * hd, d), ("heads", "embed")),
    }
    return p


def gqa_decode_attention(
    q: jnp.ndarray,    # (b, h, 1, d)
    ck: jnp.ndarray,   # (b, kvh, S, d) — kv_seq sharded on "model"
    cv: jnp.ndarray,
    pos: jnp.ndarray,  # scalar: current position (attend to kpos <= pos)
) -> jnp.ndarray:
    """Single-token decode attention, sharding-aware:

    * KV stays seq-sharded (constrained); the query (one token) is
      replicated across the model axis — replicating q is free, gathering
      a 32k-deep KV cache is not.
    * GQA via grouped einsum — no repeat_kv materialization.
    * softmax over the sharded seq axis lowers to partial reductions +
      a tiny all-reduce (the flash-decode pattern).
    """
    b, h, _, d = q.shape
    kvh, s = ck.shape[1], ck.shape[2]
    rep = h // kvh
    ck = constrain(ck, ("batch", "kv_heads", "kv_seq", None))
    cv = constrain(cv, ("batch", "kv_heads", "kv_seq", None))
    qg = constrain(
        q.reshape(b, kvh, rep, d), ("batch", "kv_heads", None, None)
    )
    scale = d ** -0.5
    scores = jnp.einsum(
        "bgrd,bgsd->bgrs", (qg * scale).astype(jnp.float32),
        ck.astype(jnp.float32),
    )
    scores = constrain(scores, ("batch", "kv_heads", None, "kv_seq"))
    mask = jnp.arange(s) <= pos
    scores = jnp.where(mask[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrs,bgsd->bgrd", probs, cv.astype(jnp.float32))
    return out.reshape(b, h, 1, d).astype(q.dtype)


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1).transpose(0, 2, 1, 3)  # (b, h, s, d)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def self_attention(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                      # (b, s, d)
    cfg: ModelConfig,
    inv_freq: jnp.ndarray,
    *,
    policy: Optional[ApproxPolicy] = None,
    causal: bool = True,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    pos: Optional[jnp.ndarray] = None,   # scalar decode position
    attn_chunk: int = 1024,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Returns (out, new_cache).  Modes:
       * train/prefill: cache=None (new_cache=None) or cache given with
         pos=0 -> cache filled with this sequence's K/V.
       * decode: x is (b, 1, d), cache holds S_max positions, pos = index.
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h = rms_norm(x, p["norm"], cfg.rms_eps)
    q = _split_heads(linear(h, p["wq"], "qkv", policy), cfg.n_heads)
    k = _split_heads(linear(h, p["wk"], "qkv", policy), cfg.n_kv_heads)
    v = _split_heads(linear(h, p["wv"], "qkv", policy), cfg.n_kv_heads)
    q = constrain(q, ("batch", "act_heads", "seq", None))
    k = constrain(k, ("batch", "kv_heads", "seq", None))

    if pos is not None:
        positions = jnp.zeros((s,), jnp.int32) + pos  # decode: (1,)
    q = apply_rope(q, inv_freq, positions)
    k = apply_rope(k, inv_freq, positions)

    new_cache = None
    if cache is not None:
        ck, cv = cache["k"], cache["v"]
        start = 0 if pos is None else pos
        ck = jax.lax.dynamic_update_slice(
            ck, k.astype(ck.dtype), (0, 0, start, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cv, v.astype(cv.dtype), (0, 0, start, 0)
        )
        new_cache = {"k": ck, "v": cv}
        if pos is not None:
            k, v = ck, cv
        # prefill (pos None): attend over the locally-computed k/v, NOT
        # the cache copy — re-reading the seq-sharded cache would force
        # SPMD to replicate it (the chunk reshape splits the sharded dim)

    if pos is not None:
        # decode: dedicated sharding-aware single-token attention
        out = gqa_decode_attention(q, k, v, pos)
    else:
        out = attn_op(
            q, k, v, causal=causal, impl="chunked", chunk=attn_chunk,
        )
    out = constrain(out, ("batch", "act_heads", "seq", None))
    y = linear(_merge_heads(out), p["wo"], "attn_out", policy)
    return y, new_cache


def cross_attention(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                    # (b, s_dec, d)
    enc_out: jnp.ndarray,              # (b, s_enc, d)  (or cached k/v)
    cfg: ModelConfig,
    *,
    policy: Optional[ApproxPolicy] = None,
    cached_kv: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    h = rms_norm(x, p["norm"], cfg.rms_eps)
    q = _split_heads(linear(h, p["wq"], "qkv", policy), cfg.n_heads)
    if cached_kv is None:
        k = _split_heads(linear(enc_out, p["wk"], "qkv", policy), cfg.n_kv_heads)
        v = _split_heads(linear(enc_out, p["wv"], "qkv", policy), cfg.n_kv_heads)
        cached_kv = {"k": k, "v": v}
    else:
        k, v = cached_kv["k"], cached_kv["v"]
    out = attn_op(q, k, v, causal=False, impl="chunked", chunk=1024)
    y = linear(_merge_heads(out), p["wo"], "attn_out", policy)
    return y, cached_kv


def init_kv_cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    """ParamSpec-style declaration of one layer's KV cache (bf16)."""
    hd = cfg.resolved_head_dim
    shape = (batch, cfg.n_kv_heads, max_len, hd)
    logical = ("batch", "kv_heads", "kv_seq", None)
    return {
        "k": ParamSpec(shape, logical, dtype="bfloat16", init="zeros"),
        "v": ParamSpec(shape, logical, dtype="bfloat16", init="zeros"),
    }
