"""seamless-m4t-medium [audio] — encoder-decoder backbone; the speech
frontend is a STUB: input_specs() provides precomputed frame embeddings
(assignment brief) [arXiv:2308.11596]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    is_encoder_decoder=True, n_enc_layers=12,
    frontend="audio",
    notes="12L decoder + 12L encoder; decode shapes lower the decoder "
          "step against a fixed-length encoder context.",
)
