"""The injection runtime: zero-cost when idle, deterministic when armed.

Call sites name their hazard and ask::

    from repro import faults
    ...
    faults.hit("sched.dispatch", batch=len(entries))   # may sleep/raise

With no plan installed, :func:`check`/:func:`hit` are a single global
load and a ``None`` test — the same no-op discipline as ``REPRO_OBS=0``
(hot paths pay nothing for the harness existing).  A plan arms via
:func:`install` or the ``REPRO_FAULTS`` environment variable (a path to
a plan JSON, or inline JSON starting with ``{``), which worker
subprocesses inherit so one plan can storm a whole fleet.

Every firing increments ``repro_faults_injected_total``, records a
``faults.injected`` span, and is tallied per point in :func:`stats` —
drills assert on those tallies instead of hoping the storm happened.
"""

from __future__ import annotations

import os
import random
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from .. import obs
from .plan import FaultPlan, FaultRule

__all__ = [
    "Fault", "FaultInjected", "active", "check", "hit", "install",
    "installed", "reset", "stats", "uninstall",
]


class FaultInjected(RuntimeError):
    """Raised by ``error``-kind rules.  Carries the point and optional
    HTTP ``status`` so transport layers can style it (fleet/http turns
    a status-carrying injection into a retryable HTTPError)."""

    def __init__(self, point: str, kind: str = "error",
                 status: Optional[int] = None, message: str = ""):
        self.point = point
        self.kind = kind
        self.status = status
        super().__init__(
            message or f"injected fault at {point}"
            + (f" (http {status})" if status else ""))


@dataclass
class Fault:
    """Directive handed to a call site when a rule fires."""

    point: str
    kind: str
    rule: FaultRule
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def delay_s(self) -> float:
        return self.rule.delay_s

    @property
    def status(self) -> Optional[int]:
        return self.rule.status

    @property
    def fraction(self) -> float:
        return self.rule.fraction

    def raise_(self) -> None:
        raise FaultInjected(self.point, self.kind, self.rule.status,
                            self.rule.message)


# ---------------------------------------------------------------------
# module state — reads are a single global load; mutation is locked
_LOG = obs.get_logger("faults")
_PLAN: Optional[FaultPlan] = None
_LOCK = threading.Lock()
_HITS: Dict[int, int] = {}       # rule idx -> eligible hits seen
_FIRED: Dict[int, int] = {}      # rule idx -> times fired
_BY_POINT: Dict[str, int] = {}   # point -> injections
_COUNTER: Optional[obs.Counter] = None
_GAUGE: Optional[obs.Gauge] = None


def _decide(seed: int, idx: int, point: str, n: int, p: float) -> bool:
    """Deterministic per-hit coin: pure function of the identifiers (crc
    seeding, not hash(), so worker processes agree with the parent)."""
    if p >= 1.0:
        return True
    if p <= 0.0:
        return False
    key = zlib.crc32(f"{seed}:{idx}:{point}:{n}".encode())
    return random.Random(key).random() < p


def installed() -> Optional[FaultPlan]:
    return _PLAN


def active() -> bool:
    return _PLAN is not None


def install(plan: FaultPlan) -> FaultPlan:
    """Arm a plan (replacing any previous one; schedules restart)."""
    global _PLAN, _COUNTER, _GAUGE
    with _LOCK:
        _HITS.clear()
        _FIRED.clear()
        _BY_POINT.clear()
        _COUNTER = obs.REGISTRY.counter(
            "repro_faults_injected_total",
            "faults injected by the chaos harness")
        _GAUGE = obs.REGISTRY.gauge(
            "repro_faults_active", "1 while a fault plan is installed")
        _GAUGE.set(1.0)
        _PLAN = plan
    return plan


def uninstall() -> None:
    global _PLAN
    with _LOCK:
        _PLAN = None
        if _GAUGE is not None:
            _GAUGE.set(0.0)


def reset() -> None:
    """Disarm and zero tallies (test isolation)."""
    uninstall()
    with _LOCK:
        _HITS.clear()
        _FIRED.clear()
        _BY_POINT.clear()


def stats() -> Dict[str, Any]:
    with _LOCK:
        plan = _PLAN
        return {
            "active": plan is not None,
            "plan": plan.name if plan else None,
            "seed": plan.seed if plan else None,
            "injected": sum(_BY_POINT.values()),
            "by_point": dict(sorted(_BY_POINT.items())),
        }


def check(point: str, **attrs: Any) -> Optional[Fault]:
    """Return a :class:`Fault` directive if a rule fires at ``point``,
    else ``None``.  The disabled path is one global load."""
    plan = _PLAN
    if plan is None:
        return None
    return _check_armed(plan, point, attrs)


def _check_armed(plan: FaultPlan, point: str,
                 attrs: Dict[str, Any]) -> Optional[Fault]:
    fired: Optional[FaultRule] = None
    counter: Optional[obs.Counter] = None
    with _LOCK:
        if _PLAN is not plan:        # racing uninstall
            return None
        for idx, rule in enumerate(plan.rules):
            if not rule.matches(point):
                continue
            n = _HITS.get(idx, 0)
            _HITS[idx] = n + 1
            if n < rule.after:
                continue
            if rule.times is not None and _FIRED.get(idx, 0) >= rule.times:
                continue
            if not _decide(plan.seed, idx, point, n, rule.p):
                continue
            _FIRED[idx] = _FIRED.get(idx, 0) + 1
            _BY_POINT[point] = _BY_POINT.get(point, 0) + 1
            fired, counter = rule, _COUNTER
            break                    # first matching rule wins
    if fired is None:
        return None
    if counter is not None:
        counter.inc()
    sp = obs.start_span("faults.injected", point=point, kind=fired.kind,
                        rule=fired.point)
    sp.end()
    _LOG.info("injected %s at %s", fired.kind, point)
    return Fault(point=point, kind=fired.kind, rule=fired, attrs=attrs)


def hit(point: str, **attrs: Any) -> Optional[Fault]:
    """Check-and-apply: sleeps out latency, raises ``error`` kinds,
    honors ``exit`` kinds (process dies, like a kill between two
    non-atomic steps).  Site-specific kinds (``torn_write``, ``drop``,
    ``duplicate``) are returned for the caller to enact; plain latency
    returns ``None`` after the stall so callers can ignore it."""
    plan = _PLAN
    if plan is None:
        return None
    f = _check_armed(plan, point, attrs)
    if f is None:
        return None
    if f.delay_s > 0:
        time.sleep(f.delay_s)
    if f.kind == "latency":
        return None
    if f.kind == "error":
        f.raise_()
    if f.kind == "exit":
        os._exit(17)
    return f


def _arm_from_env() -> None:
    spec = os.environ.get("REPRO_FAULTS", "").strip()
    if not spec or spec == "0":
        return
    try:
        if spec.startswith("{"):
            plan = FaultPlan.from_json(spec)
        else:
            plan = FaultPlan.from_file(spec)
    except (OSError, ValueError) as e:  # a broken plan must not take
        _LOG.warning(                       # down the real service
            "ignoring REPRO_FAULTS=%r: %s", spec, e)
        return
    install(plan)


_arm_from_env()
