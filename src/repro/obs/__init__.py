"""repro.obs — flight recorder: tracing, metrics, search telemetry.

Zero-dependency observability for the DSE service and fleet:

  * ``obs.span("synth.compile", attrs=...)`` — context-var spans with
    campaign/batch/lease correlation that survives thread, process and
    fleet-HTTP boundaries (`trace.wire_context`/`trace.attach` ride the
    existing wire payloads); bounded ring + optional ``--trace`` JSONL
    sink; ``python -m repro.obs.export --chrome-trace`` for Perfetto.
  * ``obs.REGISTRY`` — per-thread-sharded counters/gauges/histograms
    behind ``GET /metrics`` (Prometheus text) and ``GET /stats``.
  * ``obs.Timeline`` — per-campaign hypervolume/front/labels series
    behind ``GET /campaigns/<id>/timeline``.

``REPRO_OBS=0`` (or ``obs.set_enabled(False)``) no-ops the span layer;
metrics stay on (they are the stats() substrate).
"""

from .logs import get_logger, parse_level, setup_logging
from .metrics import (
    REGISTRY, Counter, Gauge, Histogram, Registry, render_prometheus,
)
from .timeline import Timeline
from .trace import (
    Recorder, attach, context, current_baggage, enabled, recorder,
    set_enabled, set_sink, span, start_span, wire_context,
)

__all__ = [
    "REGISTRY", "Counter", "Gauge", "Histogram", "Recorder", "Registry",
    "Timeline", "attach", "context", "current_baggage", "enabled",
    "get_logger", "parse_level", "recorder", "render_prometheus",
    "set_enabled", "set_sink", "setup_logging", "span", "start_span",
    "wire_context",
]
