"""Optimizer + compression unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamW, clip_by_global_norm, ef_quantize


def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, warmup_steps=1)
    target = jnp.asarray(np.random.default_rng(0).standard_normal((4, 3)))
    params = {"w": jnp.zeros((4, 3))}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"] - target).max()) < 0.05


def test_adamw_weight_decay_shrinks():
    opt = AdamW(lr=0.1, weight_decay=0.5, warmup_steps=1)
    params = {"w": jnp.ones((3,)) * 10.0}
    state = opt.init(params)
    for _ in range(50):
        params, state, _ = opt.update({"w": jnp.zeros(3)}, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_adamw_bf16_moments_supported():
    opt = AdamW(lr=0.01, moment_dtype="bfloat16")
    params = {"w": jnp.ones((8,))}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    params2, state2, m = opt.update({"w": jnp.ones(8)}, state, params)
    assert state2["m"]["w"].dtype == jnp.bfloat16
    assert np.isfinite(float(m["grad_norm"]))


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((10,)) * 4.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(90 + 160), rel=1e-5)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-4)


def test_ef_quantize_error_feedback_unbiased_over_time():
    """Residual carrying: the cumulative applied gradient converges to the
    cumulative true gradient (compression error doesn't accumulate)."""
    rng = np.random.default_rng(0)
    err = jnp.zeros((64,))
    applied = np.zeros(64)
    true = np.zeros(64)
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal(64) * rng.uniform(0.1, 5.0))
        deq, err = ef_quantize(g, err)
        applied += np.asarray(deq)
        true += np.asarray(g)
    # residual bounded by one quantization step, not 50 of them
    assert np.abs(applied + np.asarray(err) - true).max() < 1e-3
    assert np.abs(applied - true).max() < np.abs(true).max() * 0.2 + 1.0
