"""Staged pipeline accelerators: N stages composed into one application.

A ``StagedPipeline`` implements the full ``Accelerator`` protocol over
the concatenation of its stages' slots, so the *flat joint-genome*
baseline runs through the existing ``run_dse`` unchanged.  Between stage
*i* and stage *i+1* a ``Coupling`` applies the application's
re-quantization (clip/shift/re-blocking) in both the behavioral domain
(numpy) and the deployment domain (jnp), mirroring how a real pipeline
re-quantizes the intermediate signal back into the next stage's input
format.

``StageView`` exposes ONE stage as a standalone accelerator for the
hierarchical per-stage campaigns: its QoR is measured *in situ* (the
pipeline runs end-to-end with every other stage exact) while its
hardware labels are the stage's own deployment cost — exactly the
per-component decomposition of autoAx-style hierarchical search, with
the composed front re-verified end-to-end afterwards (search.py).

Genome layout of a pipeline with stages A, B, ... (rank_genes=True):

    [A slot genes][B slot genes]...[A rank genes][B rank genes]...

``split_genome`` / ``assemble_genome`` convert between this layout and
the per-stage layouts ``[slot genes][rank genes]``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from ..accel.base import Accelerator, Slot
from ..core.acl.library import Circuit

__all__ = ["Coupling", "StagedPipeline", "StageView"]


@dataclass(frozen=True)
class Coupling:
    """Re-quantization hook between consecutive stages.

    ``sim``: numpy map from stage-i behavioral output to stage-(i+1)
    behavioral input.  ``deploy``: jnp map from stage-i deployment output
    to stage-(i+1) deployment *activation* (the preprocessed matmul
    operand, e.g. im2col windows or block rows).  ``name`` participates
    in the label-store fingerprint so editing a coupling re-keys labels.
    ``sim`` must be elementwise/broadcast-safe: the batched population
    path pushes intermediates with a leading genome axis through it.
    """

    name: str = "identity"
    sim: Optional[Callable] = None
    deploy: Optional[Callable] = None

    def apply_sim(self, x):
        return x if self.sim is None else self.sim(x)

    def apply_deploy(self, y):
        return y if self.deploy is None else self.deploy(y)


class StagedPipeline(Accelerator):
    """Compose stage accelerators into one application accelerator."""

    def __init__(
        self,
        name: str,
        stages: Sequence[Accelerator],
        couplings: Optional[Sequence[Coupling]] = None,
    ):
        assert len(stages) >= 1, "a pipeline needs at least one stage"
        self.name = name
        self.stages = list(stages)
        self.couplings = list(
            couplings if couplings is not None
            else [Coupling()] * (len(stages) - 1)
        )
        assert len(self.couplings) == len(self.stages) - 1, (
            "need exactly one coupling between each pair of stages"
        )
        self.slots: List[Slot] = []
        for st in self.stages:
            self.slots += [
                Slot(f"{st.name}.{s.name}", s.kind, s.weight) for s in st.slots
            ]

    @property
    def batched_sim(self) -> bool:
        """The chain handles a leading genome axis iff every stage does
        (couplings are elementwise by contract)."""
        return all(getattr(st, "batched_sim", False) for st in self.stages)

    # --- genome layout ----------------------------------------------------
    def stage_slot_counts(self) -> List[int]:
        return [len(st.slots) for st in self.stages]

    def stage_mul_counts(self) -> List[int]:
        return [len(st.mul_slot_indices()) for st in self.stages]

    def split_genome(
        self, genome: np.ndarray, *, rank_genes: bool = False
    ) -> List[np.ndarray]:
        """Pipeline genome -> per-stage genomes in each stage's layout."""
        genome = np.asarray(genome)
        out = []
        s_off, r_off = 0, len(self.slots)
        for ns, nm in zip(self.stage_slot_counts(), self.stage_mul_counts()):
            parts = [genome[s_off : s_off + ns]]
            if rank_genes:
                parts.append(genome[r_off : r_off + nm])
            out.append(np.concatenate(parts))
            s_off += ns
            r_off += nm
        return out

    def assemble_genome(
        self, stage_genomes: Sequence[np.ndarray], *, rank_genes: bool = False
    ) -> np.ndarray:
        """Per-stage genomes -> one pipeline genome (split_genome inverse)."""
        assert len(stage_genomes) == len(self.stages)
        slot_parts, rank_parts = [], []
        for st, g in zip(self.stages, stage_genomes):
            g = np.asarray(g)
            ns = len(st.slots)
            slot_parts.append(g[:ns])
            if rank_genes:
                rank_parts.append(g[ns:])
        return np.concatenate(slot_parts + rank_parts).astype(np.int64)

    def split_circuits(self, circuits: Sequence[Circuit]) -> List[Sequence[Circuit]]:
        out, off = [], 0
        for ns in self.stage_slot_counts():
            out.append(list(circuits[off : off + ns]))
            off += ns
        return out

    def split_per_mul(self, values: Sequence) -> List[List]:
        """Split a per-multiplier-slot sequence (ranks, deploy specs) into
        per-stage lists (pipeline mul order is stage-major)."""
        out, off = [], 0
        for nm in self.stage_mul_counts():
            out.append(list(values[off : off + nm]))
            off += nm
        return out

    # --- behavior ---------------------------------------------------------
    def sample_inputs(self, n: int, seed: int = 0) -> np.ndarray:
        return self.stages[0].sample_inputs(n, seed=seed)

    def stage_inputs(self, inputs: np.ndarray, index: int) -> np.ndarray:
        """Stage ``index``'s in-situ input: the pipeline input propagated
        through the preceding stages run exact."""
        x = inputs
        for i in range(index):
            x = self.couplings[i].apply_sim(self.stages[i].exact_output(x))
        return x

    def simulate_with_stage(
        self, index: int, circuits: Sequence[Circuit], inputs: np.ndarray
    ) -> np.ndarray:
        """End-to-end behavioral output with stage ``index`` under the
        given slot assignment and every OTHER stage exact."""
        x = inputs
        for i, st in enumerate(self.stages):
            y = st.simulate(circuits, x) if i == index else st.exact_output(x)
            x = self.couplings[i].apply_sim(y) if i < len(self.stages) - 1 else y
        return x

    def simulate(self, circuits: Sequence[Circuit], inputs: np.ndarray) -> np.ndarray:
        per_stage = self.split_circuits(circuits)
        x = inputs
        for i, st in enumerate(self.stages):
            y = st.simulate(per_stage[i], x)
            x = self.couplings[i].apply_sim(y) if i < len(self.stages) - 1 else y
        return x

    def split_genome_batch(
        self, genomes: np.ndarray, *, rank_genes: bool = False
    ) -> List[np.ndarray]:
        """(G, pipeline genome) -> per-stage (G, stage genome) column
        blocks (the population form of ``split_genome``)."""
        genomes = np.atleast_2d(np.asarray(genomes))
        out = []
        s_off, r_off = 0, len(self.slots)
        for ns, nm in zip(self.stage_slot_counts(), self.stage_mul_counts()):
            parts = [genomes[:, s_off : s_off + ns]]
            if rank_genes:
                parts.append(genomes[:, r_off : r_off + nm])
            out.append(np.concatenate(parts, axis=1))
            s_off += ns
            r_off += nm
        return out

    def simulate_batch(
        self,
        genomes: np.ndarray,
        library,
        inputs: np.ndarray,
        *,
        rank_genes: bool = False,
        per_genome_inputs: bool = False,
    ) -> np.ndarray:
        """Population sim of the chain: each stage evaluates the whole
        genome batch at once (vectorized where the stage supports it),
        and the per-genome intermediate stack flows through the couplings
        elementwise.

        When every stage has a fused plan and every coupling a traceable
        twin, the WHOLE chain dispatches as one XLA program; otherwise
        this body runs and each stage's own dispatch still fuses the
        fusible stages individually."""
        from ..accel import fused

        fused_out = fused.try_simulate_batch(
            self, genomes, library, inputs,
            rank_genes=rank_genes, per_genome_inputs=per_genome_inputs,
        )
        if fused_out is not None:
            return fused_out
        genomes = np.atleast_2d(np.asarray(genomes))
        stage_genomes = self.split_genome_batch(genomes, rank_genes=rank_genes)
        x, per = inputs, per_genome_inputs
        for i, st in enumerate(self.stages):
            y = st.simulate_batch(
                stage_genomes[i], library, x,
                rank_genes=rank_genes, per_genome_inputs=per,
            )
            per = True  # stage outputs always carry the genome axis
            x = self.couplings[i].apply_sim(y) if i < len(self.stages) - 1 else y
        return x

    def exact_output(self, inputs: np.ndarray) -> np.ndarray:
        x = inputs
        for i, st in enumerate(self.stages):
            y = st.exact_output(x)
            x = self.couplings[i].apply_sim(y) if i < len(self.stages) - 1 else y
        return x

    # --- deployment -------------------------------------------------------
    def mul_slot_constants(self) -> List[Optional[int]]:
        out: List[Optional[int]] = []
        for st in self.stages:
            out += st.mul_slot_constants()
        return out

    def adjusted_compute(self, circuits, ranks) -> float:
        """Dtype-aware MXU cost of the chained deployment: the sum of the
        stages' costs (the coupling re-quantization is VPU-side noise)."""
        from ..core.features.synth import _adjusted_compute

        total = 0.0
        for st, sc, sr in zip(
            self.stages, self.split_circuits(circuits), self.split_per_mul(ranks)
        ):
            total += _adjusted_compute(st, sc, sr)
        return total

    def build_deploy(self, specs: Sequence, inputs: Optional[np.ndarray] = None):
        """The chained rank-k MXU deployment: stage fns composed with the
        couplings' deploy maps; compiled cost is the application's
        hardware ground truth."""
        if inputs is None:
            inputs = self.sample_inputs(1, seed=1)
        per_stage_specs = self.split_per_mul(specs)
        fns, weights = [], []
        x = np.asarray(inputs)
        first_args = None
        for i, st in enumerate(self.stages):
            fn_i, args_i = st.build_deploy(per_stage_specs[i], inputs=x)
            fns.append(fn_i)
            weights.append(args_i[1])
            if i == 0:
                first_args = args_i
            if i < len(self.stages) - 1:
                # the NEXT stage's example input (for tracing shapes only;
                # at run time its activation comes from the chain)
                x = self.couplings[i].apply_sim(st.exact_output(x))

        couplings = self.couplings

        def fn(x0, *ws):
            y = fns[0](x0, ws[0])
            for i in range(1, len(fns)):
                y = couplings[i - 1].apply_deploy(y)
                y = fns[i](y, ws[i])
            return y

        return fn, (first_args[0],) + tuple(weights)

    def label_fingerprint(self) -> str:
        """Per-stage structure + coupling names: a stage or coupling edit
        re-keys the label store instead of serving stale labels."""
        parts = []
        for st in self.stages:
            try:
                shape: Tuple = tuple(int(v) for v in st.matmul_shape())
            except NotImplementedError:
                shape = ()
            parts.append((
                st.name, shape,
                tuple((s.name, s.kind, float(s.weight)) for s in st.slots),
                int(getattr(st, "deploy_passes", 1)),
            ))
        return repr((parts, tuple(c.name for c in self.couplings)))

    def deploy_signature(self, specs):
        """The chained deployment's structural key: per-stage signatures
        composed with the coupling names.  Classes keep the stage
        boundaries (stage A's slots never permute into stage B); within
        a stage the stage's own signature decides interchangeability.
        Any stage opting out opts the whole chain out."""
        fams, classes = [], []
        for st, sp in zip(self.stages, self.split_per_mul(specs)):
            sig = st.deploy_signature(sp)
            if sig is None:
                return None
            f, c = sig
            fams.append(tuple(f))
            classes.append(tuple(c))
        family = ("staged", tuple(c.name for c in self.couplings),
                  tuple(fams))
        return family, tuple(classes)

    # --- hierarchy --------------------------------------------------------
    def stage_views(self) -> List["StageView"]:
        return [StageView(self, i) for i in range(len(self.stages))]


class StageView(Accelerator):
    """One pipeline stage as a standalone accelerator.

    QoR runs the WHOLE pipeline with every other stage exact (the stage's
    in-situ quality contribution); hardware labels are the stage's own
    deployment (so composed candidates sum per-stage hardware).  The
    hierarchical search labels the composed winners end-to-end afterwards
    — these per-stage labels only have to rank candidates, not be exact.
    """

    def __init__(self, pipeline: StagedPipeline, index: int):
        assert 0 <= index < len(pipeline.stages)
        self.pipeline = pipeline
        self.index = index
        self.stage = pipeline.stages[index]
        self.name = f"{pipeline.name}/stage{index}"
        self.slots = list(self.stage.slots)

    @property
    def deploy_passes(self) -> int:
        return int(getattr(self.stage, "deploy_passes", 1))

    def sample_inputs(self, n: int, seed: int = 0) -> np.ndarray:
        return self.pipeline.sample_inputs(n, seed=seed)

    def simulate(self, circuits: Sequence[Circuit], inputs: np.ndarray) -> np.ndarray:
        return self.pipeline.simulate_with_stage(self.index, circuits, inputs)

    def exact_output(self, inputs: np.ndarray) -> np.ndarray:
        return self.pipeline.exact_output(inputs)

    def simulate_batch(
        self,
        genomes: np.ndarray,
        library,
        inputs: np.ndarray,
        *,
        rank_genes: bool = False,
        per_genome_inputs: bool = False,
    ) -> np.ndarray:
        """In-situ population sim: exact prefix once for the whole
        population, this stage batched, exact suffix over the per-genome
        intermediate stack."""
        if per_genome_inputs:
            # rare (a StageView nested inside another pipeline): fall
            # back to the per-genome loop
            return super().simulate_batch(
                genomes, library, inputs,
                rank_genes=rank_genes, per_genome_inputs=True,
            )
        pipe = self.pipeline
        x = pipe.stage_inputs(inputs, self.index)   # shared exact prefix
        y = self.stage.simulate_batch(
            genomes, library, x, rank_genes=rank_genes
        )
        for i in range(self.index, len(pipe.stages) - 1):
            x = pipe.couplings[i].apply_sim(y)
            y = pipe.stages[i + 1].exact_output_batch(x, per_genome_inputs=True)
        return y

    # hardware: the stage's own deployment, at its in-situ input
    def matmul_shape(self) -> Tuple[int, int, int]:
        return self.stage.matmul_shape()

    def slot_groups(self) -> List[Tuple[int, int]]:
        return self.stage.slot_groups()

    def mul_slot_constants(self):
        return self.stage.mul_slot_constants()

    def adjusted_compute(self, circuits, ranks) -> float:
        from ..core.features.synth import _adjusted_compute

        return _adjusted_compute(self.stage, circuits, ranks)

    def build_deploy(self, specs: Sequence, inputs: Optional[np.ndarray] = None):
        if inputs is None:
            inputs = self.pipeline.stage_inputs(
                self.pipeline.sample_inputs(1, seed=1), self.index
            )
        return self.stage.build_deploy(specs, inputs=np.asarray(inputs))

    def deploy_signature(self, specs):
        """The stage's own signature — a stage view whose in-situ deploy
        input matches the standalone stage's native input shape (always
        true for stage 0) compiles IDENTICAL graphs and shares the
        standalone accelerator's cache entries; deeper stages, fed a
        different intermediate shape by the chain, get a shape-prefixed
        family of their own."""
        sig = self.stage.deploy_signature(specs)
        if sig is None:
            return None
        family, classes = sig
        native = getattr(self, "_native_shape_cache", None)
        if native is None:
            native = np.shape(self.stage.sample_inputs(1, seed=1))
            self._native_shape_cache = native
        if self._insitu_shape() != native:
            family = ("stage_view", self._insitu_shape()) + tuple(family)
        return family, classes

    def _insitu_shape(self) -> Tuple[int, ...]:
        """Shape of this stage's deploy example input (the pipeline input
        propagated through the exact prefix); cached — signature lookups
        must not re-run the prefix simulation per genome."""
        shape = getattr(self, "_insitu_shape_cache", None)
        if shape is None:
            shape = np.shape(self.pipeline.stage_inputs(
                self.pipeline.sample_inputs(1, seed=1), self.index
            ))
            self._insitu_shape_cache = shape
        return shape

    def label_fingerprint(self) -> str:
        return f"stage{self.index}@{self.pipeline.label_fingerprint()}"


# whole-chain fusion: one XLA program per pipeline when every stage and
# coupling has a traceable twin (registered here, after the class exists)
from ..accel import fused as _fused  # noqa: E402

_fused._register_staged()
