"""ServingEngine: a continuous-batching request loop over one catalog.

Requests enter an admission queue (``submit`` returns a future and
holds no thread); a dedicated batcher thread drains up to ``max_batch``
requests per cycle (waiting ``max_wait_s`` for stragglers so concurrent
callers coalesce), resolves each request's SLA against the CURRENT
catalog — or the catalog version the request is pinned to — groups the
batch by resolved operating point + input shape, and executes each
group in one batched backend call (fused population sim, or jitted LM
prefill/decode).

Hot-swap: ``install`` atomically replaces the catalog between batches
(the batcher snapshots it once per cycle under the same lock), keeps
the last ``keep_catalogs`` versions for pinned requests, and
``attach``/``refresh_from`` subscribe the engine to a live
``CampaignManager`` so a campaign that improves the merged front swaps
it in mid-run without dropping a request — search while serving.

The ``serving.request`` span starts in the submitter's trace context
(trace id flows through batch formation into the group execution
attrs); counters ride the PR-7 sharded registry and surface as
``repro_serving_*`` on ``GET /metrics`` and in ``GET /serving/stats``.
"""

from __future__ import annotations

import itertools
import threading
import time
import uuid
from collections import OrderedDict, deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .. import faults, obs
from ..core.acl.library import default_library
from .backends import make_backend
from .catalog import EmptyFrontError, FrontCatalog, Selection

__all__ = ["DeadlineExceeded", "OverloadedError", "ServeRequest",
           "ServingEngine"]


class OverloadedError(RuntimeError):
    """Admission queue full — the request was rejected WITHOUT being
    enqueued.  Retriable: the caller should back off and resubmit (the
    HTTP layer maps this to 429)."""

    retriable = True


class DeadlineExceeded(TimeoutError):
    """The request's ``deadline_s`` elapsed before its group ran; it
    was dropped instead of burning backend time on an answer nobody is
    waiting for."""

_log = obs.get_logger("repro.serving")

# instruments are process-wide (the registry is a flat name->instrument
# map with replace-on-register): create once, shared by every engine;
# per-engine breakdowns live in ServingEngine.stats()
_METRICS_LOCK = threading.Lock()
_METRICS: Dict[str, object] = {}


def _metrics() -> Dict[str, object]:
    with _METRICS_LOCK:
        if not _METRICS:
            R = obs.REGISTRY
            _METRICS.update(
                requests=R.counter(
                    "repro_serving_requests_total",
                    "serving requests admitted"),
                responses=R.counter(
                    "repro_serving_responses_total",
                    "serving requests completed"),
                errors=R.counter(
                    "repro_serving_errors_total",
                    "serving requests failed"),
                batches=R.counter(
                    "repro_serving_batches_total", "serving batch cycles"),
                groups=R.counter(
                    "repro_serving_groups_total",
                    "operating-point batch groups run"),
                swaps=R.counter(
                    "repro_serving_hot_swaps_total",
                    "catalog hot-swaps installed"),
                degrades=R.counter(
                    "repro_serving_degrades_total",
                    "infeasible budgets degraded to nearest-feasible"),
                rejects=R.counter(
                    "repro_serving_rejects_total",
                    "requests rejected at admission (queue full)"),
                expired=R.counter(
                    "repro_serving_deadline_expired_total",
                    "requests dropped after their deadline elapsed"),
                depth=R.gauge(
                    "repro_serving_queue_depth", "admission queue depth"),
                latency=R.histogram(
                    "repro_serving_request_seconds",
                    "request latency (seconds)"),
            )
        return _METRICS


def _tier_counter(tier: str) -> "obs.Counter":
    name = f"repro_serving_selected_{tier}_total"
    with _METRICS_LOCK:
        ctr = obs.REGISTRY.get(name)
        if ctr is None:
            ctr = obs.REGISTRY.counter(
                name, f"requests served at the {tier} tier")
    return ctr


@dataclass
class ServeRequest:
    """One admitted request (internal; callers hold the future)."""

    id: str
    inputs: np.ndarray
    tier: Optional[str] = None
    budget: Optional[Dict[str, float]] = None
    pin_version: Optional[int] = None
    gen: Optional[int] = None            # LM: tokens to decode
    return_outputs: bool = False
    deadline: Optional[float] = None     # absolute perf_counter time
    future: Future = field(default_factory=Future)
    span: object = None                  # serving.request (submitter ctx)
    t_submit: float = field(default_factory=time.perf_counter)


class ServingEngine:
    """Continuous-batching inference over one accelerator's front."""

    def __init__(
        self,
        accel,
        library=None,
        *,
        catalog: Optional[FrontCatalog] = None,
        rank_genes: bool = False,
        max_batch: int = 16,
        max_wait_s: float = 0.005,
        keep_catalogs: int = 8,
        default_tier: str = "balanced",
        max_queue: int = 256,
    ):
        if isinstance(accel, str):
            from ..service.campaigns import make_accelerator

            accel = make_accelerator(accel)
        self.accel = accel
        self.library = library if library is not None else default_library()
        self.rank_genes = bool(rank_genes)
        self.backend = make_backend(self.accel, self.library,
                                    rank_genes=self.rank_genes)
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.keep_catalogs = max(1, int(keep_catalogs))
        self.default_tier = str(default_tier)
        self.max_queue = max(1, int(max_queue))

        self._cond = threading.Condition()
        self._queue: deque = deque()
        self._catalog: Optional[FrontCatalog] = None
        self._catalogs: "OrderedDict[int, FrontCatalog]" = OrderedDict()
        self._version = itertools.count(1)
        self._closed = False
        self._manager = None

        name = self.accel.name
        self._m = _metrics()
        # engine-local breakdowns (instruments are process-wide)
        self._n: Dict[str, int] = dict(
            requests=0, responses=0, errors=0, batches=0, groups=0,
            hot_swaps=0, degrades=0, rejects=0, expired=0,
        )
        self._tier_counts: Dict[str, int] = {}
        self._served_by_version: Dict[int, int] = {}
        _log.info("serving engine up for %s (backend=%s)",
                  name, self.backend.kind)

        if catalog is not None:
            self.install(catalog)
        self._thread = threading.Thread(
            target=self._loop, daemon=True,
            name=f"serving-{name}",
        )
        self._thread.start()

    # ------------------------------------------------------------------
    # catalog lifecycle (hot-swap)
    # ------------------------------------------------------------------
    def install(self, catalog: FrontCatalog) -> Optional[int]:
        """Atomically make ``catalog`` the serving front.  Between
        batches by construction: the batcher snapshots the catalog
        under the same lock once per cycle.  Returns the installed
        version, or None when the front content is unchanged."""
        with self._cond:
            prev = self._catalog
            if prev is not None and prev.digest == catalog.digest:
                return None
            version = next(self._version)
            catalog.version = version
            self._catalog = catalog
            self._catalogs[version] = catalog
            while len(self._catalogs) > self.keep_catalogs:
                self._catalogs.popitem(last=False)
        if prev is not None:
            self._m["swaps"].inc()
            with self._cond:
                self._n["hot_swaps"] += 1
            _log.info("hot-swap: %s front v%d -> v%d (%d -> %d points)",
                      catalog.accel, prev.version, version,
                      len(prev), len(catalog))
        return version

    def refresh_from(self, manager, objectives=None) -> Optional[int]:
        """Rebuild the catalog from the manager's merged global front;
        install it only when the front actually changed."""
        cat = FrontCatalog.from_manager(
            manager, self.accel.name, objectives or self._objectives(),
            rank_genes=self.rank_genes,
        )
        if cat.empty:
            return None
        return self.install(cat)

    def attach(self, manager) -> None:
        """Subscribe to a live CampaignManager: every campaign that
        completes for this accelerator re-derives the catalog (the
        search-while-serving loop)."""
        self._manager = manager
        manager.subscribe_front(self._on_front_update)

    def _on_front_update(self, accel_name: str) -> None:
        if accel_name != self.accel.name or self._manager is None:
            return
        try:
            self.refresh_from(self._manager)
        except Exception:  # noqa: BLE001 - a bad refresh must not kill the campaign tick
            _log.exception("front refresh failed for %s", accel_name)

    def _objectives(self):
        with self._cond:
            cat = self._catalog
        return cat.objectives if cat is not None else ("qor", "energy")

    @property
    def catalog(self) -> Optional[FrontCatalog]:
        with self._cond:
            return self._catalog

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def submit(
        self,
        inputs,
        *,
        tier: Optional[str] = None,
        budget: Optional[Dict[str, float]] = None,
        pin_version: Optional[int] = None,
        gen: Optional[int] = None,
        return_outputs: bool = False,
        deadline_s: Optional[float] = None,
    ) -> Future:
        """Admit one request; returns a Future resolving to the result
        record.  SLA errors (unknown tier, bad budget, unknown pinned
        version, empty front) surface as ValueError on the future.

        Graceful degradation: when the admission queue already holds
        ``max_queue`` requests the call raises :class:`OverloadedError`
        immediately (retriable — nothing was enqueued); a request whose
        ``deadline_s`` elapses before its group runs fails with
        :class:`DeadlineExceeded` instead of burning backend time."""
        if self._closed:
            raise RuntimeError("serving engine is closed")
        req = ServeRequest(
            id=uuid.uuid4().hex[:12],
            inputs=np.asarray(inputs),
            tier=tier,
            budget=dict(budget) if budget else None,
            pin_version=int(pin_version) if pin_version is not None else None,
            gen=gen,
            return_outputs=bool(return_outputs),
            deadline=(time.perf_counter() + float(deadline_s)
                      if deadline_s is not None else None),
        )
        # started in the SUBMITTER's trace context: the request span
        # carries the caller's trace id through batch formation and is
        # ended by the batcher with the batch/group attrs
        req.span = obs.start_span(
            "serving.request", accel=self.accel.name, request=req.id,
            tier=tier, pinned=req.pin_version,
        )
        with self._cond:
            if len(self._queue) >= self.max_queue:
                # bounded admission: reject NOW (nothing enqueued) so
                # the caller can shed load instead of queueing forever
                self._n["rejects"] += 1
                depth = len(self._queue)
            else:
                depth = None
                self._n["requests"] += 1
                self._queue.append(req)
                self._m["depth"].set(len(self._queue))
                self._cond.notify_all()
        if depth is not None:
            self._m["rejects"].inc()
            req.span.end(error="OverloadedError: queue full")
            raise OverloadedError(
                f"serving queue full ({depth}/{self.max_queue}); "
                "retry with backoff")
        self._m["requests"].inc()
        return req.future

    def serve(self, inputs, *, timeout: float = 300.0, **kw) -> Dict:
        """Blocking convenience wrapper around ``submit``."""
        return self.submit(inputs, **kw).result(timeout=timeout)

    # ------------------------------------------------------------------
    # the batch loop
    # ------------------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._closed:
                    self._cond.wait(0.25)
                if self._closed and not self._queue:
                    return
                # admission window: linger briefly so concurrent
                # submitters coalesce into one batch
                deadline = time.perf_counter() + self.max_wait_s
                while (len(self._queue) < self.max_batch
                       and not self._closed):
                    left = deadline - time.perf_counter()
                    if left <= 0:
                        break
                    self._cond.wait(left)
                batch = [
                    self._queue.popleft()
                    for _ in range(min(self.max_batch, len(self._queue)))
                ]
                self._m["depth"].set(len(self._queue))
                catalog = self._catalog
                catalogs = dict(self._catalogs)
            if batch:
                try:
                    self._run_batch(batch, catalog, catalogs)
                except Exception as exc:  # noqa: BLE001 - engine must survive
                    _log.exception("serving batch failed")
                    for req in batch:
                        self._fail(req, exc)

    def _run_batch(self, batch, catalog, catalogs) -> None:
        bid = uuid.uuid4().hex[:8]
        with obs.span("serving.batch", accel=self.accel.name,
                      batch=bid, n=len(batch)) as sp:
            self._m["batches"].inc()
            with self._cond:
                self._n["batches"] += 1
            groups: "OrderedDict[tuple, tuple]" = OrderedDict()
            for req in batch:
                if (req.deadline is not None
                        and time.perf_counter() > req.deadline):
                    self._m["expired"].inc()
                    with self._cond:
                        self._n["expired"] += 1
                    self._fail(req, DeadlineExceeded(
                        f"request {req.id} waited "
                        f"{time.perf_counter() - req.t_submit:.3f}s, "
                        "past its deadline"))
                    continue
                cat = catalog
                if req.pin_version is not None:
                    cat = catalogs.get(req.pin_version)
                    if cat is None:
                        self._fail(req, ValueError(
                            f"unknown catalog version {req.pin_version} "
                            f"(kept: {sorted(catalogs)})"))
                        continue
                if cat is None or cat.empty:
                    self._fail(req, EmptyFrontError(
                        f"no front installed for {self.accel.name!r}"))
                    continue
                try:
                    sel = cat.select(tier=req.tier, budget=req.budget)
                except ValueError as exc:
                    self._fail(req, exc)
                    continue
                key = (sel.point.genome, self.backend.group_key(req))
                groups.setdefault(
                    key, (sel, cat.version, [])
                )[2].append(req)
            sp.set(groups=len(groups))
            for (genome, _), (sel, version, reqs) in groups.items():
                self._run_group(bid, sel, version, reqs)

    def _run_group(self, bid: str, sel: Selection, version: int,
                   reqs: List[ServeRequest]) -> None:
        tier_label = sel.tier or ("degraded" if not sel.feasible
                                  else "budget")
        with obs.span("serving.group", accel=self.accel.name, batch=bid,
                      tier=tier_label, version=version, n=len(reqs)):
            self._m["groups"].inc()
            try:
                faults.hit("serving.backend", accel=self.accel.name,
                           tier=tier_label, n=len(reqs))
                results = self.backend.run(sel.point, reqs)
            except Exception as exc:  # noqa: BLE001 - group isolation
                _log.exception("group execution failed (tier=%s)",
                               tier_label)
                for req in reqs:
                    self._fail(req, exc)
                return
        now = time.perf_counter()
        with self._cond:
            self._n["groups"] += 1
            self._n["responses"] += len(reqs)
            self._tier_counts[tier_label] = (
                self._tier_counts.get(tier_label, 0) + len(reqs))
            self._served_by_version[version] = (
                self._served_by_version.get(version, 0) + len(reqs))
            if not sel.feasible:
                self._n["degrades"] += len(reqs)
        _tier_counter(tier_label).inc(len(reqs))
        if not sel.feasible:
            self._m["degrades"].inc(len(reqs))
        for req, res in zip(reqs, results):
            out = {
                "id": req.id,
                "accel": self.accel.name,
                "tier": sel.tier,
                "feasible": sel.feasible,
                "catalog_version": version,
                "genome": list(sel.point.genome),
                "labels": dict(sel.point.labels),
                "batch": bid,
                "group_size": len(reqs),
                "latency_s": now - req.t_submit,
                **res,
            }
            self._m["responses"].inc()
            self._m["latency"].observe(now - req.t_submit)
            req.span.end(tier=tier_label, batch=bid, version=version,
                         group_size=len(reqs))
            if not req.future.set_running_or_notify_cancel():
                continue
            req.future.set_result(out)

    def _fail(self, req: ServeRequest, exc: BaseException) -> None:
        self._m["errors"].inc()
        with self._cond:
            self._n["errors"] += 1
        req.span.end(error=f"{type(exc).__name__}: {exc}")
        if req.future.set_running_or_notify_cancel():
            req.future.set_exception(exc)

    # ------------------------------------------------------------------
    def stats(self) -> Dict:
        with self._cond:
            cat = self._catalog
            depth = len(self._queue)
            tiers = dict(self._tier_counts)
            by_version = dict(self._served_by_version)
            counts = dict(self._n)
        out = {
            "accel": self.accel.name,
            "backend": self.backend.kind,
            **counts,
            "queue_depth": depth,
            "tier_selections": tiers,
            "served_by_version": {str(k): v for k, v in by_version.items()},
        }
        if cat is not None:
            out["catalog"] = {
                "version": cat.version,
                "points": len(cat),
                "digest": cat.digest,
                "objectives": list(cat.objectives),
                "tiers": {
                    name: dict(cat.points[i].labels)
                    for name, i in cat.tiers.items()
                },
            }
        return out

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._thread.join(timeout=10.0)
        with self._cond:
            pending = list(self._queue)
            self._queue.clear()
        for req in pending:
            self._fail(req, RuntimeError("serving engine closed"))
