"""Pluggable ask/tell search strategies + the ``Campaign`` stage machine.

The DSE core's central seam: explorers implement ``SearchStrategy``
(``ask``/``tell``/``state``/``restore``/``done``) and register a factory
under a name; ``Campaign`` owns the paper's TRAIN -> EXPLORE -> FINAL
loop and yields labeling requests instead of calling a labeler, so the
service can step many campaigns cooperatively and resume killed ones.

Built-ins: ``nsga2`` (seed-identical to the legacy loop), ``random``,
and ``bo`` (ParEGO expected-improvement Bayesian optimization).  Add
your own with ``register_strategy`` — see examples/STRATEGIES.md.
"""

from .base import (
    STRATEGIES,
    SearchStrategy,
    available_strategies,
    make_strategy,
    register_strategy,
)
from .bo import BOStrategy
from .campaign import Campaign, LabelRequest, drive
from .nsga2 import NSGA2Strategy
from .random import RandomStrategy

__all__ = [
    "SearchStrategy",
    "STRATEGIES",
    "register_strategy",
    "make_strategy",
    "available_strategies",
    "NSGA2Strategy",
    "RandomStrategy",
    "BOStrategy",
    "Campaign",
    "LabelRequest",
    "drive",
]


def _nsga2_factory(gene_sizes, cfg, *, init=None):
    return NSGA2Strategy(gene_sizes, cfg.nsga, init=init)


def _random_factory(gene_sizes, cfg, *, init=None):
    # same evaluation budget as NSGA-II: init population + one batch per
    # generation (init, if given, is ignored — random search is the
    # uniform baseline by definition)
    n = cfg.nsga.pop_size * (cfg.nsga.n_generations + 1)
    return RandomStrategy(
        gene_sizes,
        n_total=n,
        batch_size=cfg.nsga.pop_size,
        n_parents=cfg.nsga.n_parents,
        seed=cfg.nsga.seed,
    )


def _bo_factory(gene_sizes, cfg, *, init=None):
    return BOStrategy(
        gene_sizes,
        n_rounds=cfg.nsga.n_generations,
        batch_size=cfg.nsga.pop_size,
        n_parents=cfg.nsga.n_parents,
        seed=cfg.nsga.seed,
        init=init,
    )


register_strategy("nsga2", _nsga2_factory)
register_strategy("random", _random_factory)
register_strategy("bo", _bo_factory)
