"""The paper's exploration framework applied to a language model: search
the per-projection-class approximate-circuit space of granite-8b
(QoR = logits PSNR vs the exact model; cost = v5e roofline energy of the
policy'd step).

    PYTHONPATH=src python examples/dse_on_lm.py [--arch granite-8b]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    args = ap.parse_args()
    from repro.launch import dse_lm

    sys.argv = ["dse_lm", "--arch", args.arch, "--n-train", "32",
                "--generations", "8", "--pop", "24", "--parents", "8"]
    dse_lm.main()


if __name__ == "__main__":
    main()
