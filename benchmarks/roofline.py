"""§Roofline — renders the per-(arch x shape x mesh) roofline table from
the dry-run JSON cache (launch/dryrun.py) and emits summary rows.

Also writes experiments/roofline.md (the table EXPERIMENTS.md embeds).
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

from .common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "roofline.md")

_RECOMMEND = {
    "compute": "raise arithmetic intensity (larger micro-batch, fuse "
               "rank-k corrections)",
    "memory": "cut activation traffic (fused attention kernel, chunk "
              "remat, fewer weight re-gathers per micro-batch)",
    "collective": "re-shard to cut wire bytes (kv/model placement, int8 "
                  "gradient compression, hierarchical reduction)",
}


def load_records(d: str = DRYRUN_DIR) -> List[Dict]:
    recs = []
    if not os.path.isdir(d):
        return recs
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json"):
            with open(os.path.join(d, fn)) as f:
                recs.append(json.load(f))
    return recs


def render_md(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | "
        "bottleneck | peak GiB (TPU est) | fits | useful/HLO | "
        "what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"{r.get('status','?')} | — | — | — | — |"
            )
            continue
        rt = r["roofline"]
        mem = r["memory"]["peak_tpu_estimate_bytes"] / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rt['t_compute']:.3f} | {rt['t_memory']:.3f} "
            f"| {rt['t_collective']:.3f} | {rt['bottleneck']} "
            f"| {mem:.2f} | {'Y' if r['fits_hbm'] else 'N'} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {_RECOMMEND[rt['bottleneck']]} |"
        )
    return "\n".join(lines)


def run():
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    skip = [r for r in recs if r.get("status", "").startswith("SKIP")]
    emit("roofline.cells_ok", 0.0, len(ok))
    emit("roofline.cells_skipped", 0.0, len(skip))
    if not ok:
        return

    md = render_md(recs)
    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    with open(OUT_MD, "w") as f:
        f.write("# Roofline table (single-pod 16x16 + multi-pod 2x16x16)\n\n")
        f.write(md + "\n")

    by_bneck: Dict[str, int] = {}
    for r in ok:
        b = r["roofline"]["bottleneck"]
        by_bneck[b] = by_bneck.get(b, 0) + 1
    for b, n in sorted(by_bneck.items()):
        emit(f"roofline.bottleneck.{b}", 0.0, n)

    fits = sum(r["fits_hbm"] for r in ok)
    emit("roofline.fits_16GiB", 0.0, f"{fits}/{len(ok)}")

    # the three §Perf hillclimb picks
    sp = [r for r in ok if r["mesh"] == "16x16"]
    worst_useful = min(sp, key=lambda r: r["useful_flops_ratio"])
    most_coll = max(sp, key=lambda r: r["roofline"]["t_collective"]
                    / max(r["roofline"]["t_step"], 1e-12))
    emit("roofline.worst_useful_cell", 0.0,
         f"{worst_useful['arch']}/{worst_useful['shape']}"
         f"={worst_useful['useful_flops_ratio']:.2f}")
    emit("roofline.most_collective_cell", 0.0,
         f"{most_coll['arch']}/{most_coll['shape']}")
    # overall roofline fraction: useful model flops per device vs the
    # time the dominant term implies
    import numpy as np

    fracs = []
    for r in sp:
        t_model = r["model_flops_per_device"] / 197e12
        frac = t_model / max(r["roofline"]["t_step"], 1e-12)
        fracs.append(frac)
    emit("roofline.median_roofline_fraction", 0.0,
         round(float(np.median(fracs)), 4))
    return recs
