"""Fleet wire protocol: context descriptors, label codecs, portability.

A fleet ships *descriptions*, never objects: an evaluation context
crosses the wire as the 4-tuple a fresh process can rebuild it from
(accelerator name, rank-gene setting, QoR sample count + seed) plus the
parent's context fingerprint.  The worker rebuilds the context from the
description and refuses the lease unless its fingerprint matches the
parent's bit for bit — the same PR-3 gate the process-pool labeler
uses, so a drifted worker (different library build, different jax) can
never poison the label store.

Labels cross the wire as JSON floats.  Python's ``json`` emits the
shortest round-tripping ``repr`` for every finite float, so a label
that travels orchestrator -> worker -> orchestrator is byte-identical
to one computed in-process (tests pin this end to end).
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

__all__ = [
    "PROTOCOL_VERSION",
    "ctx_descriptor",
    "build_context",
    "context_is_portable",
    "encode_labels",
    "decode_labels",
]

# bump on any incompatible wire change; register() rejects mismatches so
# an old worker fails loudly at join time instead of mid-lease
PROTOCOL_VERSION = 1


def ctx_descriptor(ctx) -> Dict:
    """The JSON-safe description of an ``EvalContext`` a worker rebuilds
    it from.  ``fingerprint`` is the parent's ground truth: the worker
    must derive the same one or reject the lease."""
    return {
        "accel": ctx.accel.name,
        "rank_genes": bool(ctx.rank_genes),
        "n_qor_samples": int(ctx.n_qor_samples),
        "qor_seed": int(ctx.qor_seed),
        "fingerprint": ctx.fingerprint,
    }


def build_context(desc: Dict, library=None):
    """Rebuild an ``EvalContext`` from a wire descriptor (builtin
    accelerator names only — a remote worker has no registry) and verify
    its fingerprint against the parent's.  Raises ValueError on unknown
    names and RuntimeError on fingerprint drift."""
    from ..core.acl.library import default_library
    from ..service.campaigns import make_accelerator
    from ..service.store import EvalContext

    ctx = EvalContext(
        make_accelerator(desc["accel"], builtin_only=True),
        library if library is not None else default_library(),
        rank_genes=bool(desc["rank_genes"]),
        n_qor_samples=int(desc["n_qor_samples"]),
        qor_seed=int(desc["qor_seed"]),
    )
    expected = desc.get("fingerprint")
    if expected and ctx.fingerprint != expected:
        raise RuntimeError(
            f"context fingerprint {ctx.fingerprint} != parent {expected} "
            f"for {desc['accel']!r}"
        )
    return ctx


def context_is_portable(ctx, library=None) -> bool:
    """True iff a fresh process, given only the context's descriptor,
    would rebuild a context with the SAME fingerprint (identical labels
    and store keys) — the dispatch gate shared by the process-pool
    labeler and the fleet orchestrator.  Ad-hoc registered pipelines,
    subset libraries and parameterized accelerators fail it and stay on
    the in-process path."""
    try:
        if not getattr(ctx.accel, "name", None):
            return False
        build_context(ctx_descriptor(ctx), library=library)
        return True
    except Exception:  # noqa: BLE001 - unresolvable name == not portable
        return False


def encode_labels(labels: Dict[str, np.ndarray]) -> Dict[str, List[float]]:
    """Label arrays -> JSON-safe lists (order-preserving)."""
    from ..service.store import LABEL_KEYS

    return {k: [float(v) for v in np.asarray(labels[k])] for k in LABEL_KEYS}


def decode_labels(obj: Dict[str, List[float]],
                  n: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Wire labels -> arrays; validates every label key is present with
    ``n`` rows, so a truncated or mangled result fails the lease instead
    of committing short labels."""
    from ..service.store import LABEL_KEYS

    out = {}
    for k in LABEL_KEYS:
        if k not in obj:
            raise ValueError(f"result is missing label key {k!r}")
        arr = np.asarray(obj[k], dtype=np.float64)
        if n is not None and arr.shape != (n,):
            raise ValueError(
                f"label {k!r} has shape {arr.shape}, expected ({n},)"
            )
        out[k] = arr
    return out
