"""Exhaustive tables, error statistics and low-rank error factorization.

This is the numerical heart of the TPU adaptation (DESIGN.md §2): for an
8-bit approximate multiplier with product table M[a,b] we factor the error
table E = M - a*b as E ~= sum_r u_r (x) v_r (SVD), so an approximate matmul
becomes  A@B + sum_r U_r[A] @ V_r[B]  — (k+1) exact MXU matmuls plus
256-entry elementwise lookups.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

AXIS_U8 = np.arange(256, dtype=np.int64)
AXIS_S8 = np.arange(-128, 128, dtype=np.int64)

__all__ = [
    "product_table_u8",
    "product_table_s8",
    "error_table",
    "ErrorStats",
    "error_stats",
    "adder_error_stats",
    "RankFactors",
    "svd_factors",
    "effective_rank",
]


def product_table_u8(fn) -> np.ndarray:
    """(256,256) int64 table of fn over the full unsigned 8-bit domain."""
    a, b = np.meshgrid(AXIS_U8, AXIS_U8, indexing="ij")
    return np.asarray(fn(a, b), dtype=np.int64)


def product_table_s8(signed_fn) -> np.ndarray:
    """(256,256) int64 table over int8 x int8; index i maps to value i-128."""
    a, b = np.meshgrid(AXIS_S8, AXIS_S8, indexing="ij")
    return np.asarray(signed_fn(a, b), dtype=np.int64)


def error_table(table: np.ndarray, *, signed: bool) -> np.ndarray:
    """E[a,b] = approx(a,b) - a*b over the matching 8-bit domain."""
    ax = AXIS_S8 if signed else AXIS_U8
    exact = np.multiply.outer(ax, ax)
    return table - exact


@dataclass(frozen=True)
class ErrorStats:
    """The error metrics the paper's QoR surrogate consumes ("mean and
    average error of the approximate circuits"), plus the standard AC
    benchmarking set (MAE/MSE/WCE/EP/MRE)."""

    me: float      # mean (signed) error — bias
    mae: float     # mean absolute error
    mse: float     # mean squared error
    wce: float     # worst-case absolute error
    ep: float      # error probability (fraction of input pairs with error)
    mre: float     # mean relative error (w.r.t. exact product, 0-safe)
    var: float     # error variance (mse - me^2)

    def as_array(self) -> np.ndarray:
        return np.array(
            [self.me, self.mae, self.mse, self.wce, self.ep, self.mre, self.var]
        )


def _stats_from_errors(err: np.ndarray, exact: np.ndarray) -> ErrorStats:
    err = err.astype(np.float64)
    me = float(err.mean())
    mae = float(np.abs(err).mean())
    mse = float((err**2).mean())
    wce = float(np.abs(err).max())
    ep = float((err != 0).mean())
    denom = np.maximum(np.abs(exact.astype(np.float64)), 1.0)
    mre = float((np.abs(err) / denom).mean())
    return ErrorStats(me=me, mae=mae, mse=mse, wce=wce, ep=ep, mre=mre, var=mse - me * me)


def error_stats(table: np.ndarray, *, signed: bool) -> ErrorStats:
    ax = AXIS_S8 if signed else AXIS_U8
    exact = np.multiply.outer(ax, ax)
    return _stats_from_errors(table - exact, exact)


def adder_error_stats(fn, *, w: int = 16, n: int = 1 << 20, seed: int = 0) -> ErrorStats:
    """Adder error metrics over a fixed uniform sample (the 2^32 pair space
    is too large to exhaust; deterministic seed keeps this reproducible)."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1 << w, size=n, dtype=np.int64)
    b = rng.integers(0, 1 << w, size=n, dtype=np.int64)
    exact = a + b
    err = np.asarray(fn(a, b), dtype=np.int64) - exact
    return _stats_from_errors(err, exact)


@dataclass(frozen=True)
class RankFactors:
    """Rank-k factorization of an error table: E ~= u @ v.T (singular
    values folded symmetrically into both factors)."""

    u: np.ndarray  # (256, k) float32
    v: np.ndarray  # (256, k) float32

    @property
    def rank(self) -> int:
        return self.u.shape[1]

    def reconstruct(self) -> np.ndarray:
        return self.u @ self.v.T


def svd_factors(etab: np.ndarray, rank: int) -> RankFactors:
    u, s, vt = np.linalg.svd(etab.astype(np.float64), full_matrices=False)
    rank = min(rank, len(s))
    sq = np.sqrt(s[:rank])
    return RankFactors(
        u=(u[:, :rank] * sq).astype(np.float32),
        v=(vt[:rank, :].T * sq).astype(np.float32),
    )


def effective_rank(etab: np.ndarray, energy: float = 0.99) -> int:
    """Smallest k such that the top-k singular values capture `energy` of
    the error table's squared Frobenius norm.  0 for an all-zero table."""
    s = np.linalg.svd(etab.astype(np.float64), compute_uv=False)
    tot = float((s**2).sum())
    if tot == 0.0:
        return 0
    c = np.cumsum(s**2) / tot
    return int(np.searchsorted(c, energy) + 1)
