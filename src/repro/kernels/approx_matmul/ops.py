"""jit'd public ops for approximate matmul deployment.

``ApproxSpec`` packages everything a deployment site needs about one
circuit choice: the rank-k factors (MXU path), the exhaustive table
(behavioral path) and the signedness.  ``grouped_matmul`` implements the
per-slot assignment semantics of the DSE: the K (contraction) axis is
partitioned into slot groups, each with its own circuit — cost is
sum_c (1 + rank_c) MXU matmuls over that group's columns (DESIGN.md §2).

Also provides symmetric int8 quantization helpers used by
``models/approx_linear.py`` to put bf16 tensors into the 8-bit circuit
domain.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .kernel import lut_matmul_pallas, rank_k_mxu

__all__ = [
    "ApproxSpec",
    "from_circuit",
    "approx_matmul",
    "grouped_matmul",
    "quantize_sym",
    "dequantize",
]


@dataclass(frozen=True)
class ApproxSpec:
    """Deployment data of one circuit at one chosen rank.

    Truncation-family circuits carry ``trunc_bits`` > 0 and rank 0: they
    deploy NATIVELY as a reduced-width integer matmul (operands masked to
    8 - trunc_bits bits) — the MXU-cheap family.  Everything else deploys
    as an int8 base matmul + ``rank`` bf16 correction matmuls."""

    name: str
    signed: bool
    rank: int
    u: np.ndarray          # (256, rank) f32
    v: np.ndarray          # (256, rank) f32
    table: Optional[np.ndarray] = None   # (256,256) i32, behavioral path
    trunc_bits: int = 0    # native reduced-width deployment

    @property
    def width(self) -> int:
        return 8 - self.trunc_bits

    @property
    def is_exact(self) -> bool:
        return self.rank == 0 and self.name.endswith("_exact")


def from_circuit(circuit, rank: Optional[int] = None) -> ApproxSpec:
    """Build an ApproxSpec from an acl.library.Circuit.

    rank=None uses the circuit's faithful deployment rank (0 for exact
    and natively-truncating circuits, the 99%-energy effective rank
    otherwise); an explicit rank is the beyond-paper DSE axis.
    """
    if circuit.kind == "add16":
        raise ValueError("adders do not deploy as matmul corrections")
    native = circuit.native_width is not None
    r = circuit.deploy_rank if rank is None else (0 if native else int(rank))
    if circuit.is_exact or native or r == 0:
        u = np.zeros((256, 0), np.float32)
        v = np.zeros((256, 0), np.float32)
    else:
        f = circuit.factors(r)
        u, v = f.u, f.v
    return ApproxSpec(
        name=circuit.name,
        signed=circuit.signed,
        rank=u.shape[1],
        u=u,
        v=v,
        table=circuit.table.astype(np.int32),
        trunc_bits=circuit.trunc_bits if native else 0,
    )


def _approx_matmul_impl(x, w, u, v, table, *, signed, path, trunc=0):
    if path == "lut":
        return ref.lut_matmul(x, w, table, signed=signed).astype(jnp.float32)
    if trunc:
        # native reduced-width deployment: the truncation IS the circuit.
        # Sign-magnitude masking matches the behavioral mul8s wrapper.
        def _mask(v):
            v = v.astype(jnp.int32)
            return jnp.sign(v) * ((jnp.abs(v) >> trunc) << trunc)
        x, w = _mask(x), _mask(w)
    return ref.rank_k_matmul(x, w, u, v, signed=signed)


# inline=True: deployment graphs call this once PER MUL SLOT inside an
# outer synthesis jit; inlining drops the per-call pjit frames from the
# trace.  XLA flattens the calls during optimization anyway, so the
# optimized HLO — and the cost-analysis labels read off it — are
# unchanged; only lowering gets cheaper.  The non-inlined variant is the
# seed engine's trace, kept for the legacy baseline (below).
_STATIC = ("signed", "path", "trunc")
_approx_matmul_jit = functools.partial(
    jax.jit, static_argnames=_STATIC, inline=True
)(_approx_matmul_impl)
_approx_matmul_jit_outlined = functools.partial(
    jax.jit, static_argnames=_STATIC
)(_approx_matmul_impl)


# The original deployment trace materialized each spec's exhaustive
# (256,256) behavioral table as a graph constant even on the MXU path,
# where it is dead (the static ``path`` branch never reads it), and
# emitted every per-slot call as an outlined pjit.  XLA removes the dead
# constants and flattens the calls before cost analysis — flops /
# bytes-accessed labels are identical either way — but lowering and
# hashing ~256KB of dead literal PER MUL SLOT dominated synthesis time
# on multi-slot accelerators.  The lean trace passes a 1x1 dummy and
# inlines the per-slot calls; flipping this switch restores the seed
# trace exactly (benchmarks use it to measure the old engine as the
# per-genome baseline).
LEGACY_EMBED_TABLES = False

_DUMMY_TABLE = np.zeros((1, 1), np.int32)


def approx_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: ApproxSpec,
    *,
    path: str = "mxu",     # "mxu" (rank-k deployment) | "lut" (behavioral)
    use_pallas: bool = False,
    interpret: bool = True,
) -> jnp.ndarray:
    """Approximate x @ w under one circuit spec.

    path="mxu": deployment semantics, f32 out.  path="lut": behavioral
    bit-exact semantics.  use_pallas selects the tiled TPU kernels (CPU
    validation runs them with interpret=True).
    """
    if use_pallas:
        if path == "lut":
            return lut_matmul_pallas(
                x, w, jnp.asarray(spec.table), signed=spec.signed,
                interpret=interpret,
            ).astype(jnp.float32)
        return rank_k_mxu(
            x, w, jnp.asarray(spec.u), jnp.asarray(spec.v),
            signed=spec.signed, interpret=interpret,
        )
    if path == "lut" or LEGACY_EMBED_TABLES:
        table = spec.table if spec.table is not None else np.zeros((256, 256), np.int32)
    else:
        table = _DUMMY_TABLE
    fn = _approx_matmul_jit_outlined if LEGACY_EMBED_TABLES else _approx_matmul_jit
    return fn(
        x, w, jnp.asarray(spec.u), jnp.asarray(spec.v), jnp.asarray(table),
        signed=spec.signed, path=path, trunc=spec.trunc_bits,
    )


def grouped_matmul(
    x: jnp.ndarray,                      # (m, k)
    w: jnp.ndarray,                      # (k, n)
    specs: Sequence[ApproxSpec],
    groups: Sequence[Tuple[int, int]],   # [start, stop) K-ranges per spec
    *,
    path: str = "mxu",
) -> jnp.ndarray:
    """Per-slot-group approximate matmul: contraction columns [s, e) of
    group g use circuit specs[g].  This is the deployment form of a DSE
    genome over a matmul accelerator; its compiled cost is
    sum_g (1 + rank_g) partial matmuls — the TPU cost model the surrogates
    learn."""
    assert len(specs) == len(groups)
    out = None
    for spec, (s, e) in zip(specs, groups):
        part = approx_matmul(x[:, s:e], w[s:e, :], spec, path=path)
        out = part if out is None else out + part
    return out


def quantize_sym(
    t: jnp.ndarray, *, axis: Optional[int] = None, bits: int = 8
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric linear quantization to signed `bits` integers.

    Returns (q, scale) with t ~= q * scale; q in [-(2^(b-1)-1), 2^(b-1)-1].
    axis=None: per-tensor scale; otherwise per-slice along `axis`.
    """
    qmax = float(2 ** (bits - 1) - 1)
    if axis is None:
        amax = jnp.max(jnp.abs(t))
    else:
        amax = jnp.max(jnp.abs(t), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(t / scale), -qmax, qmax).astype(jnp.int32)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale
