"""Shared machinery for vectorized population ("genome-batch") simulation.

The per-genome behavioral path pays a Python-level loop per slot per
genome; the batched path makes the population the unit of work:

  * multiplier slots with a constant second operand collapse to a
    per-slot 256-entry lookup column sliced out of the circuit's
    exhaustive product table — a population evaluates ALL slots of one
    kind with a single ``(G, m, slots)`` advanced index into the stacked
    ``(n_circuits, slots, 256)`` LUT,
  * adder slots (not tabulable: 2^32 pair space) group the population by
    the circuit chosen at each slot and apply each distinct behavioral
    model once to the whole sub-population.

Both paths are bit-exact versus looping ``simulate`` per genome: the LUT
is the exhaustive evaluation of the same behavioral fn, and grouping
calls the same fn on the same operand values.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from ..core.acl.library import Circuit, Library, library_fingerprint

__all__ = ["mul_lut", "lut_gather", "grouped_apply"]


# (library content digest, accel-side cache key) -> stacked LUT.  Keyed
# on CONTENT, not ``id(library)``: an id can be reused after the first
# library is collected, silently serving one library's tables for
# another.  Content-equal libraries share entries by construction.
# Entries are tiny (n_circuits x slots x 256 int64); the LRU bound keeps
# memory flat across long many-library campaigns.
_LUT_CACHE: "OrderedDict[Tuple, np.ndarray]" = OrderedDict()
_LUT_CACHE_MAX = 64
_LUT_LOCK = threading.Lock()  # scheduler worker threads share this


def mul_lut(
    library: Library,
    kind: str,
    constants: Sequence[int],
    *,
    tag: str = "",
) -> np.ndarray:
    """(n_circuits, n_slots, 256) lookup stack for constant-operand
    multiplier slots: ``lut[c, s, x] == circuits[c].fn(value(x),
    constants[s])`` where ``value(x) = x`` for mul8u and ``x - 128`` for
    mul8s (the product-table index convention)."""
    key = (
        library_fingerprint(library), kind, tag,
        tuple(int(c) for c in constants),
    )
    with _LUT_LOCK:
        hit = _LUT_CACHE.get(key)
        if hit is not None:
            _LUT_CACHE.move_to_end(key)
            return hit
    circuits = library.kind(kind)
    off = 128 if kind == "mul8s" else 0
    cols = [int(c) + off for c in constants]
    lut = np.stack([c.table[:, cols].T for c in circuits])  # (C, S, 256)
    lut.setflags(write=False)
    with _LUT_LOCK:
        _LUT_CACHE[key] = lut
        while len(_LUT_CACHE) > _LUT_CACHE_MAX:
            _LUT_CACHE.popitem(last=False)
    return lut


def lut_gather(
    lut: np.ndarray,
    genes: np.ndarray,
    x_index: np.ndarray,
    *,
    per_genome: bool,
) -> np.ndarray:
    """One advanced index for every multiplier slot of one kind.

    ``lut``: (n_circuits, S, 256); ``genes``: (G, S) circuit indices;
    ``x_index``: table indices, ``(..., S)`` shared across the population
    or ``(G, ..., S)`` per-genome.  Returns products ``(G, ..., S)``."""
    G, S = genes.shape
    if per_genome:
        flat = x_index.reshape(G, -1, S)                  # (G, M, S)
    else:
        flat = x_index.reshape(1, -1, S)                  # (1, M, S)
    out = lut[genes[:, None, :], np.arange(S)[None, None, :], flat]
    mid = x_index.shape[1:-1] if per_genome else x_index.shape[:-1]
    return out.reshape((G,) + mid + (S,))


def grouped_apply(
    fns: Sequence[Callable],
    genes_col: np.ndarray,
    a: np.ndarray,
    b: np.ndarray,
) -> np.ndarray:
    """``out[g] = fns[genes_col[g]](a[g], b[g])`` — one call per DISTINCT
    circuit over the sub-population that selected it, instead of one call
    per genome.  ``a``/``b``: (G, ...) int64 operand stacks."""
    out = np.empty_like(a)
    for c in np.unique(genes_col):
        m = genes_col == c
        out[m] = fns[int(c)](a[m], b[m])
    return out
