"""Benchmark harness — one module per paper table/figure + roofline +
kernels.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run              # all, reduced
    PYTHONPATH=src python -m benchmarks.run --only fig5
    PYTHONPATH=src python -m benchmarks.run --paper-scale  # full sizes
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (
    fig1_motivation,
    fig5_pipelines,
    fig6_models,
    fig7_generations,
    fig89_soa,
    kernels,
    lm_dse,
    roofline,
)
from .common import emit, section

BENCHES = {
    "fig1": lambda paper: fig1_motivation.run(
        n_variants=1000 if paper else 120),
    "fig5": lambda paper: fig5_pipelines.run(
        n_train=800 if paper else 80, n_test=200 if paper else 40),
    "fig6": lambda paper: fig6_models.run(
        n_train=800 if paper else 60, n_test=200 if paper else 30),
    "fig7": lambda paper: fig7_generations.run(
        generations=100 if paper else 20, pop=256 if paper else 64),
    "fig89": lambda paper: fig89_soa.run(
        budget=400 if paper else 60, generations=40 if paper else 8,
        rows=(0, 1, 2, 3) if paper else (0, 1)),
    "kernels": lambda paper: kernels.run(),
    "lm_dse": lambda paper: lm_dse.run(
        n_train=64 if paper else 24, generations=20 if paper else 6),
    "roofline": lambda paper: roofline.run(),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    ap.add_argument("--paper-scale", action="store_true",
                    help="paper-sized populations/budgets (hours)")
    args = ap.parse_args()
    names = list(BENCHES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        section(name)
        t0 = time.time()
        try:
            BENCHES[name](args.paper_scale)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            emit(f"{name}.FAILED", 0.0, repr(e))
            failures.append(name)
        section(f"{name} done in {time.time()-t0:.1f}s")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
