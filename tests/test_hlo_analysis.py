"""The trip-count-aware HLO analyzer: validated against programs with
analytically known FLOP counts (incl. the critical scan-multiplier case
that XLA's own cost_analysis gets wrong)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_analysis import analyze_hlo


def _analyze(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(compiled.as_text()), compiled


def test_single_matmul_flops_exact():
    m, k, n = 64, 128, 32
    x = jnp.ones((m, k), jnp.float32)
    w = jnp.ones((k, n), jnp.float32)
    cost, _ = _analyze(lambda a, b: a @ b, x, w)
    assert cost.flops == pytest.approx(2 * m * k * n)


def test_batched_matmul_flops():
    b, m, k, n = 4, 16, 32, 8
    x = jnp.ones((b, m, k), jnp.float32)
    w = jnp.ones((b, k, n), jnp.float32)
    cost, _ = _analyze(lambda a, b: jnp.einsum("bmk,bkn->bmn", a, b), x, w)
    assert cost.flops == pytest.approx(2 * b * m * k * n)


def test_scan_multiplies_flops_by_trip_count():
    """THE critical property: a scanned matmul counts trips times."""
    m = 32
    trips = 7
    x = jnp.ones((m, m), jnp.float32)
    ws = jnp.ones((trips, m, m), jnp.float32)

    def fn(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    cost, compiled = _analyze(fn, x, ws)
    expect = trips * 2 * m**3
    assert cost.flops == pytest.approx(expect, rel=0.01)
    # ... and XLA's own aggregate misses the multiplier
    from repro.dist.compat import compiled_cost_analysis

    xla = float(compiled_cost_analysis(compiled).get("flops", 0.0))
    assert xla < expect


def test_nested_scan_multiplies_both_levels():
    m, outer, inner = 16, 3, 5
    x = jnp.ones((m, m), jnp.float32)
    ws = jnp.ones((outer, inner, m, m), jnp.float32)

    def fn(x, ws):
        def obody(c, wgrp):
            def ibody(ci, w):
                return ci @ w, None
            c2, _ = jax.lax.scan(ibody, c, wgrp)
            return c2, None
        out, _ = jax.lax.scan(obody, x, ws)
        return out

    cost, _ = _analyze(fn, x, ws)
    assert cost.flops == pytest.approx(outer * inner * 2 * m**3, rel=0.01)


def test_hbm_bytes_scale_with_tensor_size():
    big = jnp.ones((512, 512), jnp.float32)
    small = jnp.ones((64, 64), jnp.float32)
    cb, _ = _analyze(lambda a: (a * 2 + 1).sum(), big)
    cs, _ = _analyze(lambda a: (a * 2 + 1).sum(), small)
    assert cb.hbm_bytes > cs.hbm_bytes * 20


def test_dynamic_slice_not_charged_full_operand():
    """A scan slicing a big stacked tensor must not count the full stack
    every iteration."""
    trips, m = 50, 64
    ws = jnp.ones((trips, m, m), jnp.float32)
    x = jnp.ones((m, m), jnp.float32)

    def fn(x, ws):
        def body(c, w):
            return c + w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    cost, _ = _analyze(fn, x, ws)
    full_stack = trips * m * m * 4
    # per-iteration traffic ~ 3 slices of m*m*4; total ~ trips * 3 slices
    # << trips * full_stack
    assert cost.hbm_bytes < 0.5 * trips * full_stack


def test_remat_increases_flops():
    m = 64
    x = jnp.ones((m, m), jnp.float32)
    w = jnp.ones((m, m), jnp.float32)

    def loss(w, x):
        h = jnp.tanh(x @ w)
        return (h @ w).sum()

    def loss_remat(w, x):
        def inner(w, x):
            return jnp.tanh(x @ w)
        h = jax.checkpoint(inner)(w, x)
        return (h @ w).sum()

    c_plain, _ = _analyze(jax.grad(loss), w, x)
    c_remat, _ = _analyze(jax.grad(loss_remat), w, x)
    assert c_remat.flops >= c_plain.flops
