"""Deterministic synthetic 8-bit test images for QoR evaluation.

The paper evaluates PSNR 'for a set of input signal samples'.  Offline we
generate structured images (gradients + sinusoids + blobs + texture noise)
— smooth enough that PSNR is meaningful, textured enough that truncation
errors show.
"""

from __future__ import annotations

import numpy as np

__all__ = ["sample_images"]


def sample_images(n: int, size: int = 64, seed: int = 0) -> np.ndarray:
    """(n, size, size) uint8-valued int64 array."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float64) / size
    out = np.empty((n, size, size), dtype=np.int64)
    for i in range(n):
        fx, fy = rng.uniform(1, 6, size=2)
        phase = rng.uniform(0, 2 * np.pi, size=2)
        img = (
            0.35 * (xx * rng.uniform(-1, 1) + yy * rng.uniform(-1, 1) + 1.0)
            + 0.3 * (np.sin(2 * np.pi * fx * xx + phase[0]) * 0.5 + 0.5)
            + 0.2 * (np.sin(2 * np.pi * fy * yy + phase[1]) * 0.5 + 0.5)
        )
        # blobs
        for _ in range(3):
            cx, cy = rng.uniform(0.2, 0.8, size=2)
            r = rng.uniform(0.05, 0.2)
            img += 0.3 * np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * r * r))
        img += 0.05 * rng.standard_normal((size, size))
        img = (img - img.min()) / (img.max() - img.min() + 1e-9)
        out[i] = np.clip(np.round(img * 255), 0, 255).astype(np.int64)
    return out
