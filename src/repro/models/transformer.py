"""Model assembly: parameter declaration, the scanned super-block stack,
full forward (train / prefill), single-token decode, encoder-decoder
composition, KV/SSM cache management.

Layer stacks are ``lax.scan``s over *super-blocks* (config.block_pattern)
with per-super-block remat, so compile time is O(1) in depth and
activation memory is one residual per super-block.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from .approx_linear import ApproxPolicy
from .attention import (
    attn_param_specs,
    cross_attention,
    init_kv_cache_spec,
    self_attention,
)
from .common import ParamSpec, make_rope, rms_norm
from .config import LayerKind, ModelConfig
from .moe import dense_mlp, dense_mlp_param_specs, moe_layer, moe_param_specs
from .ssm import mamba_cache_spec, mamba_layer, mamba_param_specs

__all__ = [
    "param_specs",
    "cache_specs",
    "forward",
    "decode_step",
    "encode",
]


# --------------------------------------------------------------------------
# parameter declaration
# --------------------------------------------------------------------------

def _layer_specs(cfg: ModelConfig, kind: LayerKind) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if kind.mixer == "attn":
        out["attn"] = attn_param_specs(cfg)
    else:
        out["mamba"] = mamba_param_specs(cfg)
    if kind.cross_attn:
        out["cross"] = attn_param_specs(cfg, cross=True)
    if kind.mlp == "dense":
        out["mlp"] = dense_mlp_param_specs(cfg)
    elif kind.mlp == "moe":
        out["moe"] = moe_param_specs(cfg)
    return out


def _stack_specs(specs, n: int):
    """Add a leading scan dimension to every ParamSpec leaf."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (None,) + s.logical, s.dtype,
                            s.init, s.scale),
        specs,
        is_leaf=lambda s: isinstance(s, ParamSpec),
    )


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.padded_vocab
    blk = {
        f"layer{i}": _layer_specs(cfg, kind)
        for i, kind in enumerate(cfg.block_pattern)
    }
    out: Dict[str, Any] = {
        "embed": ParamSpec((v, d), ("vocab", "embed")),
        "blocks": _stack_specs(blk, cfg.n_superblocks),
        "final_norm": ParamSpec((d,), ("norm",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))
    if cfg.is_encoder_decoder:
        enc_layer = {
            "attn": attn_param_specs(cfg),
            "mlp": dense_mlp_param_specs(cfg),
        }
        out["encoder"] = {
            "blocks": _stack_specs(enc_layer, cfg.n_enc_layers),
            "final_norm": ParamSpec((d,), ("norm",), init="zeros"),
        }
    return out


def cache_specs(
    cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0
) -> Dict[str, Any]:
    """Decode-cache declaration, stacked over super-blocks."""
    layer_caches = {}
    for i, kind in enumerate(cfg.block_pattern):
        c: Dict[str, Any] = {}
        if kind.mixer == "attn":
            c["kv"] = init_kv_cache_spec(cfg, batch, max_len)
        else:
            c["ssm_state"] = mamba_cache_spec(cfg, batch)
        if kind.cross_attn:
            hd = cfg.resolved_head_dim
            c["cross"] = {
                "k": ParamSpec((batch, cfg.n_kv_heads, enc_len, hd),
                               ("batch", "kv_heads", None, None),
                               dtype="bfloat16", init="zeros"),
                "v": ParamSpec((batch, cfg.n_kv_heads, enc_len, hd),
                               ("batch", "kv_heads", None, None),
                               dtype="bfloat16", init="zeros"),
            }
        layer_caches[f"layer{i}"] = c
    return _stack_specs(layer_caches, cfg.n_superblocks)


# --------------------------------------------------------------------------
# super-block
# --------------------------------------------------------------------------

def _superblock(
    blk: Dict[str, Any],
    x: jnp.ndarray,
    cfg: ModelConfig,
    inv_freq,
    *,
    policy: Optional[ApproxPolicy],
    causal: bool,
    caches: Optional[Dict[str, Any]] = None,
    pos: Optional[jnp.ndarray] = None,
    enc_out: Optional[jnp.ndarray] = None,
    attn_chunk: int = 1024,
    scan_chunk: int = 128,
) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]], jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}
    training = caches is None

    def ckpt(fn):
        # nested per-layer remat: the outer (superblock) checkpoint alone
        # would hold every layer's recomputed internals simultaneously
        # during backward; nesting bounds the live set to one layer
        return jax.checkpoint(fn) if training else fn

    for i, kind in enumerate(cfg.block_pattern):
        lp = blk[f"layer{i}"]
        ch = caches[f"layer{i}"] if caches is not None else None
        nch: Dict[str, Any] = {}
        if kind.mixer == "attn":
            def attn_fn(lp_, x_):
                return self_attention(
                    lp_, x_, cfg, inv_freq, policy=policy, causal=causal,
                    cache=ch["kv"] if ch is not None else None, pos=pos,
                    attn_chunk=attn_chunk,
                )
            y, kv = ckpt(attn_fn)(lp["attn"], x)
            if kv is not None:
                nch["kv"] = kv
            x = x + y
        else:
            def mamba_fn(lp_, x_):
                return mamba_layer(
                    lp_, x_, cfg, policy=policy,
                    cache=ch["ssm_state"] if ch is not None else None,
                    decode=pos is not None,
                    scan_chunk=scan_chunk,
                )
            y, sc = ckpt(mamba_fn)(lp["mamba"], x)
            if sc is not None:
                nch["ssm_state"] = sc
            x = x + y
        if kind.cross_attn:
            cached = ch["cross"] if (ch is not None and pos is not None) else None
            y, ckv = cross_attention(
                lp["cross"], x, enc_out, cfg, policy=policy, cached_kv=cached
            )
            if ch is not None:
                nch["cross"] = {
                    "k": ckv["k"].astype(jnp.bfloat16),
                    "v": ckv["v"].astype(jnp.bfloat16),
                }
            x = x + y
        if kind.mlp == "dense":
            def mlp_fn(lp_, x_):
                return dense_mlp(lp_, x_, cfg, policy=policy)
            x = x + ckpt(mlp_fn)(lp["mlp"], x)
        elif kind.mlp == "moe":
            def moe_fn(lp_, x_):
                return moe_layer(lp_, x_, cfg, policy=policy)
            y, a = ckpt(moe_fn)(lp["moe"], x)
            x = x + y
            aux = aux + a
        x = constrain(x, ("batch", "seq", "act_embed"))
        new_caches[f"layer{i}"] = nch
    return x, (new_caches if caches is not None else None), aux


# --------------------------------------------------------------------------
# full forward
# --------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
    return x


def _logits(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )
    logits = jnp.einsum(
        "bsd,dv->bsv", x.astype(jnp.bfloat16), head.astype(jnp.bfloat16)
    )
    return constrain(logits, ("batch", "seq", "vocab"))


def _scan_blocks(params, cfg, x, inv_freq, *, policy, causal, caches, pos,
                 enc_out, remat, attn_chunk, scan_chunk):
    from ..dist.sharding import constrain_cotangent

    inner_fn = functools.partial(
        _superblock, cfg=cfg, inv_freq=inv_freq, policy=policy,
        causal=causal, pos=pos, enc_out=enc_out,
        attn_chunk=attn_chunk, scan_chunk=scan_chunk,
    )
    # per-layer weight-gradient sharding: constrain cotangents inside the
    # scan body (see dist.sharding.constrain_cotangent)
    blk_specs = {
        f"layer{i}": _layer_specs(cfg, kind)
        for i, kind in enumerate(cfg.block_pattern)
    }

    def body_fn(blk, x, caches=None):
        # barrier: stops XLA hoisting per-layer weight transforms (e.g.
        # the CPU backend's bf16->f32 dot upcast) out of the loop, which
        # would materialize f32 copies of the ENTIRE stacked stack at
        # once (observed +20 GB on the 398B config)
        from ..dist.compat import opt_barrier

        blk = opt_barrier(blk)
        if remat:
            blk = jax.tree.map(
                lambda t, s: constrain_cotangent(t, s.logical),
                blk, blk_specs,
            )
        return inner_fn(blk, x, caches=caches)

    if remat:
        body_fn = jax.checkpoint(
            body_fn, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(),
        )

    if caches is None:
        def body(carry, blk):
            x, aux = carry
            x, _, a = body_fn(blk, x, caches=None)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["blocks"]
        )
        return x, None, aux

    def body(carry, inp):
        x, aux = carry
        blk, ch = inp
        x, nch, a = body_fn(blk, x, caches=ch)
        return (x, aux + a), nch

    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["blocks"], caches)
    )
    return x, new_caches, aux


def encode(
    params, cfg: ModelConfig, enc_embeds: jnp.ndarray,
    *, policy: Optional[ApproxPolicy] = None, remat: bool = True,
) -> jnp.ndarray:
    """Encoder stack (enc-dec models): full attention over embeddings."""
    inv_freq = jnp.asarray(make_rope(cfg.resolved_head_dim, cfg.rope_theta))
    enc = params["encoder"]
    x = enc_embeds.astype(jnp.bfloat16)

    def body(x, blk):
        def blk_fn(blk, x):
            y, _ = self_attention(blk["attn"], x, cfg, inv_freq,
                                  policy=policy, causal=False)
            x = x + y
            x = x + dense_mlp(blk["mlp"], x, cfg, policy=policy)
            return x
        if remat:
            blk_fn = jax.checkpoint(
                blk_fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        return blk_fn(blk, x), None

    x, _ = jax.lax.scan(body, x, enc["blocks"])
    return rms_norm(x, enc["final_norm"], cfg.rms_eps)


def forward(
    params,
    cfg: ModelConfig,
    tokens: Optional[jnp.ndarray] = None,      # (b, s_text)
    *,
    embeds: Optional[jnp.ndarray] = None,      # frontend embeddings (b,f,d)
    enc_embeds: Optional[jnp.ndarray] = None,  # enc-dec source features
    policy: Optional[ApproxPolicy] = None,
    caches: Optional[Dict[str, Any]] = None,   # prefill: filled, returned
    remat: bool = True,
    attn_chunk: int = 1024,
    scan_chunk: int = 128,
) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]], jnp.ndarray]:
    """Teacher-forcing / prefill forward.

    Returns (logits (b, s, padded_vocab), caches|None, aux_loss)."""
    inv_freq = jnp.asarray(
        make_rope(cfg.resolved_head_dim, cfg.rope_theta,
                  fraction=0.5 if cfg.rope_style == "half" else 1.0)
    )
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(jnp.bfloat16))
    if tokens is not None:
        parts.append(_embed(params, cfg, tokens))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = constrain(x, ("batch", "seq", "act_embed"))

    enc_out = None
    if cfg.is_encoder_decoder:
        assert enc_embeds is not None
        enc_out = encode(params, cfg, enc_embeds, policy=policy, remat=remat)

    x, new_caches, aux = _scan_blocks(
        params, cfg, x, inv_freq, policy=policy, causal=True,
        caches=caches, pos=None, enc_out=enc_out, remat=remat,
        attn_chunk=attn_chunk, scan_chunk=scan_chunk,
    )
    return _logits(params, cfg, x), new_caches, aux


def decode_step(
    params,
    cfg: ModelConfig,
    caches: Dict[str, Any],
    tokens: jnp.ndarray,          # (b, 1)
    pos: jnp.ndarray,             # scalar int32 — current write position
    *,
    policy: Optional[ApproxPolicy] = None,
    enc_out: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One autoregressive step against a pre-allocated cache."""
    inv_freq = jnp.asarray(
        make_rope(cfg.resolved_head_dim, cfg.rope_theta,
                  fraction=0.5 if cfg.rope_style == "half" else 1.0)
    )
    x = _embed(params, cfg, tokens)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x, new_caches, _ = _scan_blocks(
        params, cfg, x, inv_freq, policy=policy, causal=True,
        caches=caches, pos=pos, enc_out=enc_out, remat=False,
        attn_chunk=4096, scan_chunk=1,
    )
    return _logits(params, cfg, x), new_caches
