"""Accelerator abstraction: the paper's 'target application' objects.

An ``Accelerator`` exposes
  * ``slots`` — the approximable arithmetic sites (the DSE genome decodes
    one circuit per slot, optionally plus a correction-rank gene),
  * a bit-exact *behavioral* simulator (numpy, table-driven) for QoR,
  * a *deployment* builder: the rank-k MXU JAX function whose compiled
    cost_analysis provides the hardware ground truth (the Vivado
    analogue; see core/features/synth.py),
  * deterministic sample inputs.

Genome convention: genes[i] indexes ``library.kind(slots[i].kind)``.
With ``rank_genes=True`` the genome doubles: genes[n_slots + i] selects a
correction rank in RANK_CHOICES for slot i (beyond-paper axis).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.acl.library import Circuit, Library

__all__ = ["Slot", "Accelerator", "RANK_CHOICES", "decode_genome",
           "gene_sizes", "grouped_deploy_signature"]

# rank gene vocabulary (beyond-paper DSE axis); index 0 = paper-faithful
# deterministic rank (circuit.eff_rank)
RANK_CHOICES: Tuple[Optional[int], ...] = (None, 0, 1, 2, 4, 8)


@dataclass(frozen=True)
class Slot:
    name: str
    kind: str        # "mul8u" | "mul8s" | "add16"
    weight: float    # relative MAC count of this slot per output element


class Accelerator:
    """Base class; subclasses define slots + simulate() + deploy info."""

    name: str = "base"
    slots: List[Slot] = []
    # True when simulate()/exact_output() accept inputs with an arbitrary
    # leading genome axis (vectorized accelerators set this; staged
    # pipelines use it to propagate per-genome intermediates exactly)
    batched_sim: bool = False

    # --- genome ---------------------------------------------------------
    def gene_sizes(self, library: Library, *, rank_genes: bool = False) -> np.ndarray:
        return gene_sizes(self.slots, library, rank_genes=rank_genes)

    def decode(
        self, genome: np.ndarray, library: Library, *, rank_genes: bool = False
    ) -> Tuple[List[Circuit], List[Optional[int]]]:
        return decode_genome(genome, self.slots, library, rank_genes=rank_genes)

    def exact_genome(self, library: Library, *, rank_genes: bool = False) -> np.ndarray:
        g = [library.exact_index(s.kind) for s in self.slots]
        if rank_genes:
            # one rank gene per MULTIPLIER slot; index 1 => rank 0
            g = g + [1] * len(self.mul_slot_indices())
        return np.array(g, dtype=np.int64)

    # --- behavior -------------------------------------------------------
    def sample_inputs(self, n: int, seed: int = 0) -> np.ndarray:
        raise NotImplementedError

    def simulate(self, circuits: Sequence[Circuit], inputs: np.ndarray) -> np.ndarray:
        """Bit-exact behavioral output under the slot assignment."""
        raise NotImplementedError

    def exact_output(self, inputs: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    # --- population (genome-batch) behavior --------------------------------
    def simulate_batch(
        self,
        genomes: np.ndarray,
        library: Library,
        inputs: np.ndarray,
        *,
        rank_genes: bool = False,
        per_genome_inputs: bool = False,
    ) -> np.ndarray:
        """(G, ...) stacked behavioral outputs for a genome batch.

        ``per_genome_inputs=True`` means ``inputs`` carries one input set
        per genome on a leading axis (staged pipelines feed approximate
        intermediates forward).  The default loops ``simulate``;
        vectorized accelerators override with table-gather paths that are
        bit-exact versus this loop."""
        genomes = np.atleast_2d(np.asarray(genomes))
        outs = []
        for t, g in enumerate(genomes):
            circuits, _ = self.decode(g, library, rank_genes=rank_genes)
            x = inputs[t] if per_genome_inputs else inputs
            outs.append(self.simulate(circuits, x))
        return np.stack(outs)

    def exact_output_batch(
        self, inputs: np.ndarray, *, per_genome_inputs: bool = False
    ) -> np.ndarray:
        """Exact output over a (G, ...) per-genome input stack."""
        if not per_genome_inputs or self.batched_sim:
            return self.exact_output(inputs)
        return np.stack([self.exact_output(x) for x in inputs])

    def qor_batch(
        self,
        genomes: np.ndarray,
        library: Library,
        inputs: np.ndarray,
        *,
        rank_genes: bool = False,
        peak: float | None = None,
    ) -> np.ndarray:
        """Per-genome QoR vector; the exact reference is computed ONCE
        for the whole population and PSNR is vectorized across the
        genome axis.

        Integer-output accelerators with a fused plan run the whole
        (genomes, inputs) -> QoR program on-device (SSE reduction, host
        PSNR finish); others fall through here, where simulate_batch
        itself may still dispatch to the fused engine."""
        from ..core import qor as qor_mod
        from . import fused

        vals = fused.try_qor_batch(
            self, genomes, library, inputs, rank_genes=rank_genes, peak=peak
        )
        if vals is not None:
            return vals
        ref = self.exact_output(inputs)
        outs = self.simulate_batch(
            genomes, library, inputs, rank_genes=rank_genes
        )
        return qor_mod.psnr_batch(ref, outs, peak)

    # --- deployment (for XLA synthesis) ----------------------------------
    def matmul_shape(self) -> Tuple[int, int, int]:
        """(m, k, n) of the accelerator's canonical matmul deployment form
        (im2col for filters, transform matrix for DCT)."""
        raise NotImplementedError

    def deploy_signature(self, specs: Sequence) -> Optional[Tuple[tuple, tuple]]:
        """``(family, classes)`` structural key of ``build_deploy(specs)``'s
        compiled graph, for the synthesis engine's structural compile
        cache (core/features/synth.py).  Two spec lists with equal
        signatures must compile to identical HLO-level cost numbers —
        the engine VERIFIES this on each family's first collisions and
        pins divergent families back to exact identity keys, so a too-
        coarse signature costs correctness nothing, only verification
        compiles.

        ``family`` identifies the graph builder + fixed geometry (the
        unit of verification); ``classes`` the per-slot deployment
        structure.  The default is conservative: family is this
        accelerator's labeling identity (name, shapes, group widths,
        passes, fingerprint extras) and classes are the ORDERED per-slot
        (rank, truncated bits, signedness) — circuits sharing a class
        interchange, slots do not.  Accelerators whose slots are
        interchangeable (equal-width grouped matmuls) override with
        ``grouped_deploy_signature``.  Return None to opt out of
        structural keying entirely."""
        try:
            shape: Tuple = tuple(int(v) for v in self.matmul_shape())
        except NotImplementedError:
            shape = ()
        try:
            widths: Tuple = tuple(int(e - s) for s, e in self.slot_groups())
        except NotImplementedError:
            widths = ()
        if hasattr(self, "label_fingerprint"):
            extra = str(self.label_fingerprint())
        else:
            extra = repr({
                k: repr(getattr(self, k))
                for k in ("seed", "batch", "seq") if hasattr(self, k)
            })
        family = (
            "accel", type(self).__name__, self.name, shape, widths,
            int(getattr(self, "deploy_passes", 1)),
            tuple((s.name, s.kind) for s in self.slots), extra,
        )
        classes = tuple(
            (int(sp.rank), int(sp.trunc_bits), bool(sp.signed))
            for sp in specs
        )
        return family, classes

    def slot_groups(self) -> List[Tuple[int, int]]:
        """K-ranges of each *multiplier* slot in the deployment matmul."""
        raise NotImplementedError

    def mul_slot_indices(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.kind.startswith("mul8")]

    def mul_slot_constants(self) -> List[Optional[int]]:
        """Per-multiplier-slot constant second operand (None = variable).
        Constant-operand slots get column-conditional error features in the
        cheap extractor."""
        return [None] * len(self.mul_slot_indices())

    # --- QoR --------------------------------------------------------------
    def qor(
        self, circuits: Sequence[Circuit], inputs: np.ndarray, peak: float | None = None
    ) -> float:
        from ..core import qor as qor_mod

        ref = self.exact_output(inputs)
        out = self.simulate(circuits, inputs)
        return qor_mod.psnr(ref, out, peak)


def grouped_deploy_signature(accel: "Accelerator", specs: Sequence
                             ) -> Tuple[tuple, tuple]:
    """Structural signature for plain ``grouped_matmul`` deployments
    (one rank-k matmul per K-slot-group, partials summed): the graph is
    a sum of per-group subgraphs whose shapes depend only on each
    group's width and spec class, so slots with equal widths PERMUTE
    freely — classes are the sorted multiset of (width, rank, trunc,
    signed).  Family drops the accelerator's NAME on purpose: a
    pipeline's stage view at the same geometry (e.g. ``smoothed_dct/
    stage0`` vs ``gaussian3x3``) shares the standalone accelerator's
    compiles."""
    family = (
        "grouped",
        tuple(int(v) for v in accel.matmul_shape()),
        int(getattr(accel, "deploy_passes", 1)),
    )
    classes = tuple(sorted(
        (int(e - s), int(sp.rank), int(sp.trunc_bits), bool(sp.signed))
        for (s, e), sp in zip(accel.slot_groups(), specs)
    ))
    return family, classes


def gene_sizes(
    slots: Sequence[Slot], library: Library, *, rank_genes: bool = False
) -> np.ndarray:
    sizes = [len(library.kind(s.kind)) for s in slots]
    if rank_genes:
        sizes += [len(RANK_CHOICES)] * len(
            [s for s in slots if s.kind.startswith("mul8")]
        )
    return np.array(sizes, dtype=np.int64)


def decode_genome(
    genome: np.ndarray,
    slots: Sequence[Slot],
    library: Library,
    *,
    rank_genes: bool = False,
) -> Tuple[List[Circuit], List[Optional[int]]]:
    """-> (circuit per slot, correction rank per *multiplier* slot)."""
    n = len(slots)
    circuits = [library.kind(s.kind)[int(genome[i])] for i, s in enumerate(slots)]
    mul_idx = [i for i, s in enumerate(slots) if s.kind.startswith("mul8")]
    if rank_genes:
        ranks = [RANK_CHOICES[int(genome[n + j])] for j in range(len(mul_idx))]
    else:
        ranks = [None] * len(mul_idx)
    return circuits, ranks
