"""repro.serving — the Pareto front as a product.

The search tier (repro.service) finds fronts; this package *serves*
them.  A ``FrontCatalog`` materializes a campaign's composed front as
named operating tiers (``exact`` / ``balanced`` / ``budget``) plus an
SLA selector that maps a per-request latency/energy/QoR budget to a
genome (deterministic tie-breaking, nearest-feasible degrade on
infeasible budgets).  A ``ServingEngine`` runs a continuous-batching
request loop over one accelerator: admission queue -> per-operating-
point batch groups -> fused ``(genomes, inputs) -> QoR`` / LM decode
execution -> completion, with atomic catalog hot-swap between batches
("search while serving": the engine subscribes to a live
``CampaignManager`` and picks up improved fronts; requests pinned to an
old catalog version keep byte-identical results).  ``ServingHub`` keys
engines by accelerator behind ``POST /serve`` / ``GET /serving/stats``
on the service HTTP API.

See ``examples/SERVING.md``.
"""

from .backends import LMBackend, SimBackend, make_backend
from .catalog import (
    DEFAULT_TIERS,
    EmptyFrontError,
    FrontCatalog,
    NoFrontError,
    OperatingPoint,
    Selection,
)
from .engine import ServeRequest, ServingEngine
from .hub import ServingHub

__all__ = [
    "DEFAULT_TIERS",
    "EmptyFrontError",
    "FrontCatalog",
    "LMBackend",
    "NoFrontError",
    "OperatingPoint",
    "Selection",
    "ServeRequest",
    "ServingEngine",
    "ServingHub",
    "SimBackend",
    "make_backend",
]
