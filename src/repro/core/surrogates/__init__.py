from .base import Model, Standardizer, mae, pcc, r2, rmse
from .kernel import KNN, MLP, SVR, KernelRidgeRBF
from .linear import (
    OLS,
    BayesianRidge,
    ElasticNet,
    Huber,
    Lasso,
    Poly2Ridge,
    Ridge,
    SGDRegressor,
)
from .registry import REGISTRY, available, make
from .trees import CART, ExtraTrees, GradientBoosting, RandomForest

__all__ = [
    "Model", "Standardizer", "pcc", "r2", "mae", "rmse",
    "OLS", "Ridge", "Lasso", "ElasticNet", "BayesianRidge", "Huber",
    "SGDRegressor", "Poly2Ridge",
    "KernelRidgeRBF", "SVR", "KNN", "MLP",
    "CART", "RandomForest", "ExtraTrees", "GradientBoosting",
    "REGISTRY", "make", "available",
]
