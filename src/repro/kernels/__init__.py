# Pallas TPU kernels for the compute hot-spots (validated in interpret
# mode on CPU; see each package's kernel.py for the VMEM tiling):
#   approx_matmul    — the paper's technique: LUT behavioral oracle +
#                      rank-k MXU deployment
#   flash_attention  — fused blockwise attention (removes the dominant
#                      training-traffic class, §Perf)
#   selective_scan   — fused Mamba-1 scan (removes the SSM state-stream
#                      traffic, §Perf cell B)
#   population_lut   — the batched behavioral sim's population LUT
#                      gather (the fused labeling engine's inner op)
from . import approx_matmul, flash_attention, population_lut, selective_scan

__all__ = [
    "approx_matmul", "flash_attention", "population_lut", "selective_scan",
]
