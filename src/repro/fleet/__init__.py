"""Multi-host labeling fleet: orchestrator/worker tier for ground truth.

One machine's process pool is the labeling economy's ceiling; this
package splits the PR-1/PR-3 labeling service across hosts:

  * ``orchestrator`` — ``FleetCoordinator``: leases coalesced genome
    batches to workers (pull-style), requeues on lease/heartbeat expiry,
    reclaims starved chunks in-process so batches always complete,
  * ``worker``       — ``python -m repro.fleet.worker``: registers over
    HTTP, rebuilds evaluation contexts from wire descriptors behind the
    fingerprint gate, warm-starts from the shared label store + synth
    cache, labels leased chunks, streams results + heartbeats,
  * ``protocol``     — wire descriptors, label codecs, the portability
    gate shared with the process-pool labeler,
  * ``leases``       — worker/chunk/lease/batch records,
  * ``http``         — stdlib client with bounded retry, exponential
    backoff and jitter (every fleet edge and the service ``Client``).

The scheduler integration is ``EvalScheduler(backend="fleet")``: batches
go to the fleet when a live worker can serve them and degrade to the
in-process backend when the fleet is empty.  Worker failure is loss-free
by construction — labels are deterministic and content-addressed, so a
requeued chunk recomputes byte-identical records and duplicate commits
change nothing.
"""

from .http import HttpError, request_json
from .leases import Chunk, FleetBatch, Lease, WorkerRecord
from .orchestrator import FleetCoordinator, handle_fleet_request, serve_fleet
from .protocol import (
    PROTOCOL_VERSION,
    build_context,
    context_is_portable,
    ctx_descriptor,
    decode_labels,
    encode_labels,
)
# NOT imported eagerly: ``python -m repro.fleet.worker`` first imports
# the package, and an eager ``from .worker import ...`` here would leave
# a half-initialized copy of the module runpy is about to execute
# (RuntimeWarning + double-import).  Lazy attribute access keeps
# ``from repro.fleet import FleetWorker`` working for library users.


def __getattr__(name):
    if name == "FleetWorker":
        from .worker import FleetWorker

        return FleetWorker
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "PROTOCOL_VERSION",
    "HttpError",
    "request_json",
    "Chunk",
    "FleetBatch",
    "Lease",
    "WorkerRecord",
    "FleetCoordinator",
    "handle_fleet_request",
    "serve_fleet",
    "FleetWorker",
    "ctx_descriptor",
    "build_context",
    "context_is_portable",
    "encode_labels",
    "decode_labels",
]
