"""Lock-cheap counters/gauges/histograms with Prometheus-text scrape.

The scheduler/labeler/fleet hot paths increment counters from worker
threads on every request; a mutex per increment would serialize exactly
the paths the service exists to parallelize.  ``Counter`` and
``Histogram`` therefore shard per thread: each thread owns a private
accumulator (single writer, no lock on the hot path — list-item float
adds are atomic enough under the GIL because only the owning thread
writes them) and scrapes sum the shards under the registration lock.
``Gauge`` is a plain locked cell (set-dominated, never hot).

A ``Registry`` maps flat metric names to instruments and renders the
whole family as Prometheus exposition text for ``GET /metrics``.
Registration is idempotent-replace: components create their instruments
per instance (so per-instance ``stats()`` keep working and tests can
build many schedulers), and the most recently constructed instance is
the one a scrape observes — which is the live service object.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
    "render_prometheus",
]

# label→batch→synth latencies span ~100µs (store hit) to minutes (cold
# compile wave): exponential-ish seconds buckets covering that range
DEFAULT_BUCKETS = (
    0.001, 0.005, 0.02, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
    30.0, 60.0, 120.0, 300.0,
)


class Counter:
    """Monotonic counter, per-thread sharded."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._shards: Dict[int, List[float]] = {}

    def inc(self, n: float = 1.0) -> None:
        tid = threading.get_ident()
        shard = self._shards.get(tid)
        if shard is None:
            with self._lock:
                shard = self._shards.setdefault(tid, [0.0])
        shard[0] += n

    @property
    def value(self) -> float:
        with self._lock:
            shards = list(self._shards.values())
        return sum(s[0] for s in shards)

    def samples(self) -> List[Tuple[str, float]]:
        return [(self.name, self.value)]


class Gauge:
    """Last-write-wins value (queue depths, fleet size, inflight)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def samples(self) -> List[Tuple[str, float]]:
        return [(self.name, self.value)]


class Histogram:
    """Cumulative-bucket histogram, per-thread sharded like Counter.
    ``observe`` takes seconds (or any unit consistent per metric)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self._lock = threading.Lock()
        # shard layout: [count per bucket..., overflow, sum, n]
        self._shards: Dict[int, List[float]] = {}
        self._width = len(self.buckets) + 3

    def observe(self, v: float) -> None:
        tid = threading.get_ident()
        shard = self._shards.get(tid)
        if shard is None:
            with self._lock:
                shard = self._shards.setdefault(tid, [0.0] * self._width)
        i = 0
        for b in self.buckets:
            if v <= b:
                break
            i += 1
        shard[i] += 1.0
        shard[-2] += v
        shard[-1] += 1.0

    def _agg(self) -> List[float]:
        with self._lock:
            shards = [list(s) for s in self._shards.values()]
        agg = [0.0] * self._width
        for s in shards:
            for i, v in enumerate(s):
                agg[i] += v
        return agg

    @property
    def count(self) -> float:
        return self._agg()[-1]

    @property
    def sum(self) -> float:
        return self._agg()[-2]

    @property
    def value(self) -> float:  # uniform scrape surface: the mean
        agg = self._agg()
        return (agg[-2] / agg[-1]) if agg[-1] else 0.0

    def samples(self) -> List[Tuple[str, float]]:
        agg = self._agg()
        out: List[Tuple[str, float]] = []
        cum = 0.0
        for b, c in zip(self.buckets, agg):
            cum += c
            out.append((f'{self.name}_bucket{{le="{b:g}"}}', cum))
        cum += agg[len(self.buckets)]
        out.append((f'{self.name}_bucket{{le="+Inf"}}', cum))
        out.append((f"{self.name}_sum", agg[-2]))
        out.append((f"{self.name}_count", agg[-1]))
        return out


class Registry:
    """Flat name → instrument map with idempotent-replace creation."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[str, object] = {}

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter(name, help))

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge(name, help))

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram(name, help, buckets))

    def _register(self, inst):
        with self._lock:
            self._instruments[inst.name] = inst
        return inst

    def get(self, name: str) -> Optional[object]:
        with self._lock:
            return self._instruments.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self) -> Dict[str, float]:
        """name → scalar view (histograms report their mean) — the raw
        material /stats-style JSON views read."""
        with self._lock:
            insts = list(self._instruments.values())
        return {i.name: i.value for i in insts}

    def collect(self, prefix: str) -> Dict[str, float]:
        """Scalar snapshot of every instrument whose name starts with
        ``prefix`` — how the chaos drill and ``/health`` gather one
        subsystem's counters (e.g. ``repro_faults_``, ``repro_http_``)
        without enumerating names at the call site."""
        with self._lock:
            insts = [i for i in self._instruments.values()
                     if i.name.startswith(prefix)]
        return {i.name: i.value for i in insts}

    def render(self) -> str:
        """Prometheus text exposition (format version 0.0.4)."""
        with self._lock:
            insts = sorted(self._instruments.values(),
                           key=lambda i: i.name)
        lines: List[str] = []
        for inst in insts:
            if inst.help:
                h = inst.help.replace("\\", "\\\\").replace("\n", "\\n")
                lines.append(f"# HELP {inst.name} {h}")
            lines.append(f"# TYPE {inst.name} {inst.kind}")
            for name, v in inst.samples():
                lines.append(f"{name} {v:g}")
        return "\n".join(lines) + "\n"


REGISTRY = Registry()


def render_prometheus(registry: Optional[Registry] = None) -> str:
    return (registry or REGISTRY).render()
