"""Flight-recorder quickstart: trace, metrics and timeline for one DSE
campaign.

    PYTHONPATH=src python examples/obs_quickstart.py

Runs a small campaign with the span sink enabled, then shows the three
observability surfaces the service exposes:

  1. the JSONL span sink + ``python -m repro.obs.export --chrome-trace``
     -> a Perfetto-loadable trace where every scheduler tick, label
     batch and synth compile correlates to the campaign's trace id;
  2. ``GET /metrics`` — Prometheus text exposition of the scheduler/
     labeler/store/synth counters (parsed and sanity-checked here with
     a ~15-line stdlib parser);
  3. ``GET /campaigns/<id>/timeline`` — per-tick hypervolume, front
     size and label accounting sampled live while the campaign ran.

Set REPRO_SMOKE=1 for the CI-sized fast mode."""

import json
import os
import re
import sys
import tempfile
import threading
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import obs
from repro.obs.export import main as export_main
from repro.service import CampaignManager, CampaignSpec
from repro.service.api import make_server

SMOKE = bool(os.environ.get("REPRO_SMOKE"))

_SAMPLE_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [^ ]+$")


def parse_prometheus(text):
    """Tiny exposition-format parser: {sample_name: float}."""
    out = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        assert _SAMPLE_RE.match(line), f"bad prometheus line: {line!r}"
        name, _, value = line.rpartition(" ")
        out[name] = float(value)
    return out


def main():
    run_dir = os.environ.get("REPRO_OBS_DEMO_DIR")
    if run_dir:
        os.makedirs(run_dir, exist_ok=True)
    else:
        run_dir = tempfile.mkdtemp(prefix="obs_demo_")
    sink = os.path.join(run_dir, "dse.trace.jsonl")
    obs.set_sink(sink)
    obs.setup_logging("info")

    mgr = CampaignManager(eval_workers=2, campaign_workers=2)
    srv = make_server(mgr, port=0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    base = f"http://127.0.0.1:{srv.server_address[1]}"

    spec = CampaignSpec(accel="mcm2",
                        n_train=10 if SMOKE else 32, n_qor_samples=2,
                        pop_size=8 if SMOKE else 16,
                        n_parents=4 if SMOKE else 8,
                        n_generations=2 if SMOKE else 4)
    print(f"service on {base}, tracing to {sink}")
    cid = mgr.submit(spec)
    state = mgr.wait(cid, timeout=600)
    assert state == "done", state

    print(f"\n-- GET /campaigns/{cid}/timeline --")
    tl = json.load(urllib.request.urlopen(f"{base}/campaigns/{cid}/timeline"))
    assert len(tl["samples"]) >= 3, tl
    assert any("hypervolume" in s for s in tl["samples"])
    for s in tl["samples"]:
        hv = f"hv={s['hypervolume']:.3e}" if "hypervolume" in s else "hv=-"
        print(f"  t+{s['rel_s']:6.2f}s stage={s.get('stage', '-'):8s} {hv} "
              f"front={s.get('front_size', '-'):>2} "
              f"labels={s.get('labels_requested', 0):.0f}")

    print("\n-- GET /metrics (prometheus text) --")
    text = urllib.request.urlopen(f"{base}/metrics").read().decode()
    samples = parse_prometheus(text)
    assert samples["repro_sched_requests_total"] > 0
    assert samples["repro_sched_batches_total"] > 0
    for k in ("repro_sched_requests_total", "repro_sched_batches_total",
              "repro_sched_labeled_total", "repro_store_hits_total"):
        print(f"  {k} = {samples.get(k, 0):g}")
    print(f"  ({len(samples)} samples total, all parse)")

    obs.set_sink(None)
    srv.shutdown()
    mgr.shutdown()

    print("\n-- python -m repro.obs.export --chrome-trace --")
    assert export_main([sink, "--chrome-trace"]) == 0
    out = sink[: -len(".jsonl")][: -len(".trace")] + ".trace.json"
    doc = json.load(open(out))
    events = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    names = sorted({e["name"] for e in events})
    campaign_events = [e for e in events if e["args"].get("trace") == cid]
    assert campaign_events, "no spans correlated to the campaign"
    print(f"  {out}: {len(events)} slices, span kinds: {', '.join(names)}")
    print(f"  {len(campaign_events)} slices correlated to campaign {cid}")
    print("  open in https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
