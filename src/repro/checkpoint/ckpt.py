"""Sharded checkpointing: per-leaf .npy files + JSON manifest, atomic
directory rename, restore-with-resharding.

Layout:
    <dir>/step_000123.tmp/...   (written)
    <dir>/step_000123/          (atomic rename on completion)
        MANIFEST.json           {step, leaves: {path: {shape, dtype}}}
        leaf files  <flattened/key/path>.npy

Restore takes the *target* sharding tree (possibly for a different mesh
than the save — elastic resize) and device_puts each leaf accordingly;
arrays are host-staged, so a 2-pod checkpoint restores onto a 1-pod mesh
and vice versa.  On a real multi-host cluster each host would write its
addressable shards; the manifest format already records per-leaf shapes
so that extension is purely IO plumbing.
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "list_steps"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def save(directory: str, step: int, tree) -> str:
    """Write a checkpoint; returns the final path.  Atomic: a crash
    mid-write leaves only a .tmp directory that restore ignores."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fn = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][key] = {
            "file": fn,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, "MANIFEST.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like_tree, shardings=None):
    """Load a checkpoint into the structure of ``like_tree`` (a pytree of
    arrays or ShapeDtypeStructs).  ``shardings``: optional matching pytree
    of NamedShardings for elastic resharding."""
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like_tree)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, like in flat_like.items():
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(path, meta["file"]))
        assert tuple(arr.shape) == tuple(like.shape), (key, arr.shape, like.shape)
        if key in flat_sh:
            arr = jax.device_put(arr, flat_sh[key])
        out[key] = arr
    # rebuild the original tree structure
    paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    keys = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in paths
    ]
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])
