import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

"""§Perf hillclimb driver: run a named experiment (a cell + a change),
print the before/after roofline terms, and append a JSON record to
experiments/perf_log.json.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell gemma_batch_tp
    PYTHONPATH=src python -m repro.launch.hillclimb --list
"""

import argparse
import json
from typing import Callable, Dict

from .dryrun import lower_cell

PERF_LOG = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments",
    "perf_log.json",
)


def _with_chunk_remat(fn: Callable) -> Callable:
    def wrapped(**kw):
        from ..kernels.flash_attention.ref import set_chunk_remat

        set_chunk_remat(True)
        try:
            return fn(**kw)
        finally:
            set_chunk_remat(False)

    return wrapped


# name -> (hypothesis, callable -> record)
EXPERIMENTS: Dict[str, tuple] = {
    # ---- cell A: gemma-2b train (worst useful-FLOPs ratio) ----------------
    "gemma_base": (
        "baseline",
        lambda: lower_cell("gemma-2b", "train_4k"),
    ),
    "gemma_batch_tp": (
        "gemma has 8 heads < 16-way model axis, so attention replicates "
        "across TP: sharding batch over (data, model) should divide "
        "attention flops/device by ~16 at the cost of MLP-weight regathers",
        lambda: lower_cell("gemma-2b", "train_4k",
                           rules_override={"batch": ("pod", "data", "model")},
                           n_micro=1),
    ),
    "gemma_chunk_remat": (
        "attention-chunk residuals dominate HBM traffic; flash-style "
        "per-chunk recompute should cut the memory term",
        _with_chunk_remat(lambda: lower_cell("gemma-2b", "train_4k")),
    ),
    "gemma_both": (
        "compose the two wins",
        _with_chunk_remat(
            lambda: lower_cell("gemma-2b", "train_4k",
                               rules_override={"batch": ("pod", "data",
                                                         "model")},
                               n_micro=1)),
    ),
    # ---- cell B: jamba train multi-pod (memory-bound, tightest fit) ------
    "jamba_base": (
        "baseline",
        lambda: lower_cell("jamba-1.5-large-398b", "train_4k",
                           multi_pod=True),
    ),
    "jamba_micro4": (
        "per-microbatch FSDP weight regathers dominate HBM traffic at 398B "
        "(~100 GB/micro); halving the microbatch count halves weight "
        "traffic at 2x activation cost (activations are small at 1 row)",
        lambda: lower_cell("jamba-1.5-large-398b", "train_4k",
                           multi_pod=True, n_micro=4),
    ),
    "jamba_micro2": (
        "further: quarter the weight regathers",
        lambda: lower_cell("jamba-1.5-large-398b", "train_4k",
                           multi_pod=True, n_micro=2),
    ),
    "jamba_chunk_remat_micro4": (
        "compose with attention/ssm chunk remat",
        _with_chunk_remat(
            lambda: lower_cell("jamba-1.5-large-398b", "train_4k",
                               multi_pod=True, n_micro=4)),
    ),
    # ---- cell B2: qwen2-vl train (most collective-bound) ------------------
    "qwen_base": (
        "baseline (n_micro=16)",
        lambda: lower_cell("qwen2-vl-72b", "train_4k"),
    ),
    "qwen_micro4": (
        "per-micro collectives dominate (3.3 TB all-reduce + 0.7 TB weight "
        "all-gather/device-step): every microbatch re-gathers FSDP weights "
        "and reduce-scatters every layer gradient; n_micro 16->4 should "
        "cut the collective term ~4x (activation memory grows 4x but "
        "starts at ~1 row/device)",
        lambda: lower_cell("qwen2-vl-72b", "train_4k", n_micro=4),
    ),
    "qwen_micro4_bf16acc": (
        "compose: bf16 gradient accumulators halve the grad reduce bytes",
        lambda: lower_cell("qwen2-vl-72b", "train_4k", n_micro=4,
                           acc_dtype="bfloat16"),
    ),
    # ---- cell D: granite-moe prefill (worst useful ratio 0.01) ------------
    "granitemoe_prefill_base_nogroup": (
        "baseline: single routing group; GShard dispatch one-hots are "
        "(b, 32768, 48, cap~6827) -> ~57 TB/device HBM traffic",
        lambda: _with_moe_group(0, lambda: lower_cell(
            "granite-moe-3b-a800m", "prefill_32k")),
    ),
    "granitemoe_prefill_grouped": (
        "sequence grouping (4096-token routing groups) bounds capacity per "
        "group: dispatch bytes drop ~8x -> memory term should drop ~5-8x",
        lambda: _with_moe_group(4096, lambda: lower_cell(
            "granite-moe-3b-a800m", "prefill_32k")),
    ),
    # ---- cell C: the paper's technique on an LM (approx policy) ----------
    "granite_base": (
        "baseline (exact bf16)",
        lambda: lower_cell("granite-8b", "train_4k"),
    ),
    "granite_trunc4": (
        "native int4 truncation on FFN projections: FLOPs unchanged in HLO "
        "but the dtype-adjusted compute term drops 4x on the FFN share "
        "(~2/3 of block flops)",
        lambda: _approx_cell("granite-8b", "train_4k", "mul8s_trunc4"),
    ),
    "granite_drum4": (
        "rank-2 DRUM correction: HLO flops on FFN grow ~(0.5+2)/1 -> the "
        "compute term should grow ~1.7x vs baseline on the FFN share",
        lambda: _approx_cell("granite-8b", "train_4k", "mul8s_drum4"),
    ),
}


EXPERIMENTS.update({
    # ---- iteration 2 --------------------------------------------------
    "qwen_batch_tp": (
        "qwen's 3 TB all-reduce is TP partial-sum reduction of activations "
        "(invariant to n_micro).  Shard batch over (data, model) too: "
        "activations stop needing TP all-reduces; weights stay "
        "(data, model)-sharded and get per-layer all-gathers instead "
        "(72B*2/16 = 9 GB/pass << 3 TB)",
        lambda: lower_cell("qwen2-vl-72b", "train_4k",
                           rules_override={"batch": ("pod", "data",
                                                     "model")},
                           n_micro=1),
    ),
    "granitemoe_prefill_seqshard": (
        "granite-moe's 24 heads cannot shard on the 16-way model axis -> "
        "attention replicates; with heads fallen back, sharding the QUERY "
        "seq dim on model (context parallelism) divides the 40 TB of "
        "chunk-attention traffic by 16",
        lambda: _with_moe_group(4096, lambda: lower_cell(
            "granite-moe-3b-a800m", "prefill_32k",
            rules_override={"seq": "model"})),
    ),
    "gemma_prefill_seqshard": (
        "same context-parallel trick for gemma prefill (useful=0.06)",
        lambda: lower_cell("gemma-2b", "prefill_32k",
                           rules_override={"seq": "model"}),
    ),
    "jamba_ssm_bf16": (
        "jamba's memory term is dominated by the (b,L,16384,16) f32 "
        "selective-scan streams (~6 MB/token/layer, invariant to "
        "n_micro — the refuted micro hypothesis); bf16 streams halve it",
        lambda: _with_scan_dtype("bfloat16", lambda: lower_cell(
            "jamba-1.5-large-398b", "train_4k", multi_pod=True)),
    ),
    "falcon_ssm_bf16": (
        "same for the pure-SSM trainer (falcon-mamba, t_mem 72s)",
        lambda: _with_scan_dtype("bfloat16", lambda: lower_cell(
            "falcon-mamba-7b", "train_4k")),
    ),
})


EXPERIMENTS.update({
    "qwen_batch_tp_chunk_remat": (
        "compose: batch-TP killed the 3 TB activation all-reduce (81->36s) "
        "but n_micro=1 activations blew HBM (22 GiB); flash-style chunk "
        "remat should pull the attention residuals back under 16 GiB",
        _with_chunk_remat(lambda: lower_cell(
            "qwen2-vl-72b", "train_4k",
            rules_override={"batch": ("pod", "data", "model")},
            n_micro=1)),
    ),
})


def _with_scan_dtype(dt, fn):
    from ..models import ssm as _ssm

    prev = _ssm.SCAN_DTYPE
    _ssm.set_scan_dtype(dt)
    try:
        return fn()
    finally:
        _ssm.set_scan_dtype(prev)


def _with_moe_group(n, fn):
    from ..models.moe import set_moe_group

    from ..models import moe as _moe
    prev = _moe.MOE_GROUP
    set_moe_group(n)
    try:
        return fn()
    finally:
        set_moe_group(prev)


def _approx_cell(arch, shape, circuit):
    from ..models import ApproxPolicy

    pol = ApproxPolicy({"ffn_in": (circuit, None),
                        "ffn_out": (circuit, None)})
    return lower_cell(arch, shape, policy=pol)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list or args.cell is None:
        for k, (hyp, _) in EXPERIMENTS.items():
            print(f"{k:28s} {hyp[:90]}")
        return
    hyp, fn = EXPERIMENTS[args.cell]
    print(f"[hillclimb] {args.cell}: {hyp}")
    rec = fn()
    rec["experiment"] = args.cell
    rec["hypothesis"] = hyp
    log = []
    if os.path.exists(PERF_LOG):
        with open(PERF_LOG) as f:
            log = json.load(f)
    log.append(rec)
    os.makedirs(os.path.dirname(PERF_LOG), exist_ok=True)
    with open(PERF_LOG, "w") as f:
        json.dump(log, f, indent=1)
    rt = rec.get("roofline", {})
    print(json.dumps({k: rt.get(k) for k in
                      ("t_compute", "t_memory", "t_collective", "t_step",
                       "bottleneck")}, indent=1))


if __name__ == "__main__":
    main()
