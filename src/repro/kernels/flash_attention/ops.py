"""Public attention op: dispatches between the naive reference, the
chunked scan (production path on any backend) and the Pallas flash kernel
(TPU target; interpret mode on CPU)."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .kernel import flash_attention_fwd
from .ref import chunked_attention, mha_reference, repeat_kv

__all__ = ["attention"]


def attention(
    q: jnp.ndarray,          # (b, h, sq, d)
    k: jnp.ndarray,          # (b, kvh, sk, d)
    v: jnp.ndarray,          # (b, kvh, sk, d)
    *,
    causal: bool = True,
    impl: str = "chunked",   # "chunked" | "naive" | "pallas"
    chunk: int = 1024,
    q_offset: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    if impl == "naive":
        return mha_reference(q, k, v, causal=causal, q_offset=q_offset)
    if impl == "chunked":
        return chunked_attention(
            q, k, v, causal=causal, chunk=min(chunk, k.shape[2]),
            q_offset=q_offset,
        )
    if impl == "pallas":
        b, h, sq, d = q.shape
        kvh = k.shape[1]
        kr = repeat_kv(k, h // kvh)
        vr = repeat_kv(v, h // kvh)
        out = flash_attention_fwd(
            q.reshape(b * h, sq, d),
            kr.reshape(b * h, -1, d),
            vr.reshape(b * h, -1, d),
            causal=causal,
            q_offset=q_offset,
            interpret=interpret,
        )
        return out.reshape(b, h, sq, d)
    raise ValueError(f"unknown attention impl {impl!r}")
