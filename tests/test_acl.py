"""Circuit-library unit tests: behavioral model properties, exhaustive
tables, error statistics, SVD factorization."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.acl import adders, multipliers, tables
from repro.core.acl.library import default_library

LIB = default_library()


def test_library_contents():
    assert len(LIB.kind("mul8u")) >= 20
    assert len(LIB.kind("mul8s")) >= 15
    assert len(LIB.kind("add16")) >= 12
    # exactly one exact circuit per kind
    for kind in ("mul8u", "mul8s", "add16"):
        assert sum(c.is_exact for c in LIB.kind(kind)) == 1


def test_exact_circuits_are_exact():
    a, b = np.meshgrid(np.arange(256), np.arange(256), indexing="ij")
    assert np.array_equal(multipliers.mul8_exact(a, b), a * b)
    s = np.arange(-128, 128)
    sa, sb = np.meshgrid(s, s, indexing="ij")
    sf = multipliers.signed_wrap(multipliers.mul8_exact)
    assert np.array_equal(sf(sa, sb), sa * sb)
    ra = np.arange(0, 1 << 16, 257)
    assert np.array_equal(adders.add_exact(ra, ra[::-1]), ra + ra[::-1])


@pytest.mark.parametrize("k", [1, 3, 5])
def test_trunc_mean_error_closed_form(k):
    """Operand truncation has a known mean error: E[a*b - (a>>k<<k)(b>>k<<k)]
    = E[a]*E[b] - E[a_t]*E[b_t] over uniform operands."""
    c = LIB[f"mul8u_trunc{k}"]
    ax = np.arange(256)
    trunc = (ax >> k) << k
    expected = (ax.mean() ** 2) - (trunc.mean() ** 2)
    assert abs(-c.stats.me - expected) < 1e-6


def test_mitchell_error_bound():
    """Mitchell's multiplier under-approximates by at most ~11.1%."""
    c = LIB["mul8u_mitchell"]
    etab = c.etab
    ax = np.arange(256)
    exact = np.multiply.outer(ax, ax)
    rel = etab / np.maximum(exact, 1)
    assert etab.max() <= 0  # never over-approximates
    assert rel.min() > -0.12


def test_drum_unbiased():
    """DRUM is approximately unbiased: |mean error| is a small fraction of
    the mean exact product (~16256 for uniform operands)."""
    c = LIB["mul8u_drum6"]
    mean_product = (255 / 2) ** 2
    assert abs(c.stats.me) < 0.02 * mean_product


@pytest.mark.parametrize("name", ["mul8u_trunc2", "mul8u_perf3", "mul8s_drum4"])
def test_error_table_consistency(name):
    c = LIB[name]
    assert c.table.shape == (256, 256)
    st_ = c.stats
    assert st_.mse >= st_.var >= 0
    assert st_.wce >= st_.mae >= 0
    assert 0 <= st_.ep <= 1


def test_svd_reconstruction_exact_at_full_rank():
    c = LIB["mul8u_perf2"]
    f = c.factors(256)
    err = np.abs(f.reconstruct() - c.etab).max()
    assert err < 1e-3 * max(np.abs(c.etab).max(), 1)


def test_effective_rank_captures_energy():
    for name in ("mul8u_trunc3", "mul8u_bam4", "mul8u_mitchell"):
        c = LIB[name]
        k = c.eff_rank
        f = c.factors(k)
        res = np.linalg.norm(c.etab - f.reconstruct()) ** 2
        tot = np.linalg.norm(c.etab) ** 2
        assert res <= 0.011 * tot, name
        assert k <= 16, (name, k)


def test_exact_has_rank_zero():
    assert LIB["mul8u_exact"].eff_rank == 0
    assert LIB["mul8s_exact"].eff_rank == 0


@given(
    st.integers(0, 255), st.integers(0, 255),
    st.sampled_from(["mul8u_trunc2", "mul8u_perf4", "mul8u_bam6",
                     "mul8u_mitchell", "mul8u_drum4", "mul8u_kulkarni"]),
)
@settings(max_examples=200, deadline=None)
def test_table_matches_model(a, b, name):
    c = LIB[name]
    assert c.table[a, b] == int(np.asarray(c.fn(a, b)))


@given(st.integers(-128, 127), st.integers(-128, 127))
@settings(max_examples=100, deadline=None)
def test_signed_table_indexing(a, b):
    c = LIB["mul8s_trunc1"]
    assert c.table[a + 128, b + 128] == int(np.asarray(c.fn(a, b)))


@given(st.integers(0, (1 << 16) - 1), st.integers(0, (1 << 16) - 1),
       st.integers(1, 8))
@settings(max_examples=100, deadline=None)
def test_adder_bounds(a, b, k):
    """Approximate adders stay within 2^k of the exact sum (LOA/trunc)."""
    exact = a + b
    assert abs(int(np.asarray(adders.add_loa(a, b, k=k))) - exact) < (1 << (k + 1))
    assert abs(int(np.asarray(adders.add_trunc(a, b, k=k))) - exact) < (1 << (k + 1))


def test_speculative_adder_exact_on_short_carries():
    # carry chains shorter than the lookahead window are exact
    a = np.array([0x0F0F, 0x1111, 0x00FF])
    b = np.array([0x1010, 0x2222, 0x0100])
    out = adders.add_speculative(a, b, la=8)
    assert np.array_equal(out, a + b)
