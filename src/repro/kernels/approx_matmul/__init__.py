from .kernel import lut_matmul_pallas, rank_k_mxu
from .ops import (
    ApproxSpec,
    approx_matmul,
    dequantize,
    from_circuit,
    grouped_matmul,
    quantize_sym,
)
from .ref import lut_matmul, rank_k_matmul

__all__ = [
    "ApproxSpec", "from_circuit", "approx_matmul", "grouped_matmul",
    "quantize_sym", "dequantize",
    "lut_matmul", "rank_k_matmul", "lut_matmul_pallas", "rank_k_mxu",
]
