"""Surrogate-model base classes and metrics.

All models implement fit(X, y) -> self and predict(X) -> y_hat on float64
numpy arrays, are deterministic under their ``seed``, and standardize
inputs internally (the library's feature scales span ~6 decades).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

__all__ = ["Standardizer", "Model", "pcc", "r2", "mae", "rmse"]


@dataclass
class Standardizer:
    mu: Optional[np.ndarray] = None
    sd: Optional[np.ndarray] = None

    def fit(self, X: np.ndarray) -> "Standardizer":
        self.mu = X.mean(axis=0)
        self.sd = X.std(axis=0)
        self.sd = np.where(self.sd > 0, self.sd, 1.0)
        return self

    def transform(self, X: np.ndarray) -> np.ndarray:
        return (X - self.mu) / self.sd


class Model:
    """Base: handles x/y standardization around a core _fit/_predict."""

    standardize_x = True
    standardize_y = True

    def __init__(self, seed: int = 0):
        self.seed = seed
        self._xs = Standardizer()
        self._ymu = 0.0
        self._ysd = 1.0

    def fit(self, X, y) -> "Model":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64).ravel()
        if self.standardize_x:
            X = self._xs.fit(X).transform(X)
        if self.standardize_y:
            self._ymu = float(y.mean())
            self._ysd = float(y.std()) or 1.0
            y = (y - self._ymu) / self._ysd
        self._fit(X, y)
        return self

    def predict(self, X) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        if self.standardize_x:
            X = self._xs.transform(X)
        y = self._predict(X)
        if self.standardize_y:
            y = y * self._ysd + self._ymu
        return y

    # subclasses implement:
    def _fit(self, X: np.ndarray, y: np.ndarray) -> None:
        raise NotImplementedError

    def _predict(self, X: np.ndarray) -> np.ndarray:
        raise NotImplementedError


def pcc(y_true, y_pred) -> float:
    """Pearson correlation coefficient — the paper's model-quality metric."""
    a = np.asarray(y_true, dtype=np.float64).ravel()
    b = np.asarray(y_pred, dtype=np.float64).ravel()
    sa, sb = a.std(), b.std()
    if sa == 0 or sb == 0:
        return 0.0
    return float(((a - a.mean()) * (b - b.mean())).mean() / (sa * sb))


def r2(y_true, y_pred) -> float:
    a = np.asarray(y_true, dtype=np.float64).ravel()
    b = np.asarray(y_pred, dtype=np.float64).ravel()
    ss = ((a - a.mean()) ** 2).sum()
    if ss == 0:
        return 0.0
    return float(1.0 - ((a - b) ** 2).sum() / ss)


def mae(y_true, y_pred) -> float:
    return float(np.mean(np.abs(np.asarray(y_true) - np.asarray(y_pred))))


def rmse(y_true, y_pred) -> float:
    return float(np.sqrt(np.mean((np.asarray(y_true) - np.asarray(y_pred)) ** 2)))
