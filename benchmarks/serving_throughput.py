"""Serving-tier benchmark -> BENCH_serving.json.

Measures the three headline properties of the serving tier
(repro.serving):

  1. **Campaign -> front -> serving** — a real mcm2 campaign's merged
     front loaded into an engine through the manager hub, one request
     served at every named tier (exact / balanced / budget).
  2. **Continuous-batching throughput** — a mixed-tier request storm
     against a gaussian3x3 engine over a 4-point catalog: requests/sec,
     responses-per-batch-group, and MEASURED per-tier QoR (PSNR vs the
     exact output on each request's own inputs) across >= 3 distinct
     front operating points.
  3. **Hot-swap drill** — an improved front installed while the request
     stream is in flight: post-swap requests pick up the new catalog
     version, requests pinned to the old version keep byte-identical
     outputs and QoR.

Run:  PYTHONPATH=src python benchmarks/serving_throughput.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from common import emit, section  # noqa: E402

CAMPAIGN_SPEC = dict(
    accel="mcm2",
    n_train=48,
    n_qor_samples=2,
    pop_size=16,
    n_parents=8,
    n_generations=4,
    seed=0,
)
SMOKE_SPEC = dict(CAMPAIGN_SPEC, n_train=10, pop_size=8, n_parents=4,
                  n_generations=2)

TIERS = ("exact", "balanced", "budget")


def gauss_catalog(accel, lib, n_points: int = 4):
    """A catalog of genuinely distinct gaussian3x3 operating points:
    the exact genome plus progressively more-approximate variants.
    Labels are nominal (energy proxies) — the benchmark reports the
    MEASURED per-request QoR, which is the point."""
    from repro.serving import FrontCatalog

    n_mul = len(lib.kind("mul8u"))
    g = accel.exact_genome(lib)
    genomes, front = [], []
    for k in range(n_points):
        gk = g.copy()
        for i in range(min(3 * k, 9)):
            gk[i] = (gk[i] + 1 + k) % n_mul
        genomes.append(gk.tolist())
        front.append([-(100.0 - 20.0 * k), 10.0 - 2.0 * k])
    return FrontCatalog.from_front(accel.name, genomes, front)


def bench_campaign_front(spec: dict) -> dict:
    """mcm2: campaign -> merged global front -> hub engine -> one
    request per tier."""
    from repro.service import CampaignManager, CampaignSpec, make_accelerator

    mgr = CampaignManager(eval_workers=2, campaign_workers=1)
    try:
        t0 = time.perf_counter()
        cid = mgr.submit(CampaignSpec(**spec))
        state = mgr.wait(cid, timeout=1800)
        campaign_wall = time.perf_counter() - t0
        assert state == "done", mgr.status(cid).get("error")

        eng = mgr.serving.engine_for("mcm2")
        accel = make_accelerator("mcm2")
        X = accel.sample_inputs(8, seed=1)
        tiers = {}
        for tier in TIERS:
            r = eng.serve(X, tier=tier)
            tiers[tier] = {
                "genome": r["genome"],
                "labels": r["labels"],
                "measured_qor": float(r["qor"]),
            }
            emit(f"serving.campaign_tier.{tier}",
                 r["latency_s"] * 1e6, f"qor={r['qor']:.1f}")
        return {
            "campaign_wall_s": campaign_wall,
            "front_points": len(eng.catalog),
            "tiers": tiers,
        }
    finally:
        mgr.shutdown()


def bench_throughput(n_requests: int) -> dict:
    """gaussian3x3 mixed-tier storm: requests/sec + measured QoR per
    operating tier over a 4-point catalog."""
    from repro.core.acl.library import default_library
    from repro.service.campaigns import make_accelerator
    from repro.serving import ServingEngine

    lib = default_library()
    accel = make_accelerator("gaussian3x3")
    cat = gauss_catalog(accel, lib)
    eng = ServingEngine(accel, lib, catalog=cat, max_batch=16,
                        max_wait_s=0.005)
    try:
        X = accel.sample_inputs(2, seed=2)
        # warm the sim paths (fused plan compile etc.) off the clock
        for tier in TIERS:
            eng.serve(X, tier=tier)
        slas = [dict(tier=TIERS[i % 3]) if i % 4 else
                dict(budget={"energy": float(4 + (i % 7))})
                for i in range(n_requests)]
        t0 = time.perf_counter()
        futs = [eng.submit(X, **sla) for sla in slas]
        results = [f.result(timeout=600) for f in futs]
        wall = time.perf_counter() - t0

        st = eng.stats()
        by_tier: dict = {}
        for r in results:
            key = r["tier"] or "budget"
            by_tier.setdefault(key, []).append(float(r["qor"]))
        per_tier_qor = {
            k: {"n": len(v), "mean_qor": float(np.mean(v)),
                "min_qor": float(np.min(v)), "max_qor": float(np.max(v))}
            for k, v in sorted(by_tier.items())
        }
        distinct_points = len({tuple(r["genome"]) for r in results})
        rps = n_requests / max(wall, 1e-9)
        emit("serving.throughput", wall / n_requests * 1e6,
             f"{rps:.1f} req/s")
        emit("serving.batching", float(st["groups"]),
             f"{n_requests / max(st['groups'], 1):.1f} req/group")
        return {
            "n_requests": n_requests,
            "wall_s": wall,
            "requests_per_s": rps,
            "batches": st["batches"],
            "groups": st["groups"],
            "mean_group_size": n_requests / max(st["groups"], 1),
            "front_points": len(cat),
            "distinct_points_served": distinct_points,
            "per_tier_qor": per_tier_qor,
        }
    finally:
        eng.close()


def bench_hot_swap(n_requests: int) -> dict:
    """Improved front installed mid-stream: the in-flight workload
    picks it up; requests pinned to the old version stay
    byte-identical."""
    from repro.core.acl.library import default_library
    from repro.service.campaigns import make_accelerator
    from repro.serving import FrontCatalog, ServingEngine

    lib = default_library()
    accel = make_accelerator("gaussian3x3")
    cat1 = gauss_catalog(accel, lib, n_points=4)
    eng = ServingEngine(accel, lib, catalog=cat1, max_batch=8,
                        max_wait_s=0.002)
    try:
        X = accel.sample_inputs(2, seed=3)
        baseline = eng.serve(X, tier="budget", return_outputs=True)
        assert baseline["catalog_version"] == 1

        # the "improved" front: drop the most aggressive point, so the
        # budget tier moves to a higher-QoR genome
        keep = cat1.points[:-1]
        cat2 = FrontCatalog(
            accel.name,
            keep,
            cat1.objectives,
        )
        half = n_requests // 2
        futs = [eng.submit(X, tier="budget") for _ in range(half)]
        v2 = eng.install(cat2)
        futs += [eng.submit(X, tier="budget") for _ in range(half)]
        results = [f.result(timeout=600) for f in futs]
        versions = sorted({r["catalog_version"] for r in results})

        # pinned to the pre-swap catalog: byte-identical output + QoR
        pinned = eng.serve(X, tier="budget", pin_version=1,
                           return_outputs=True)
        byte_identical = (
            pinned["genome"] == baseline["genome"]
            and pinned["qor"] == baseline["qor"]
            and np.array_equal(np.asarray(pinned["outputs"]),
                               np.asarray(baseline["outputs"]))
        )
        post = eng.serve(X, tier="budget")
        st = eng.stats()
        emit("serving.hot_swap", float(st["hot_swaps"]),
             f"pinned_byte_identical={byte_identical}")
        assert v2 == 2 and post["catalog_version"] == 2
        assert byte_identical, "pinned request diverged across hot-swap"
        return {
            "installed_version": v2,
            "versions_served_in_stream": versions,
            "old_budget_genome": baseline["genome"],
            "new_budget_genome": post["genome"],
            "old_qor": float(baseline["qor"]),
            "new_qor": float(post["qor"]),
            "pinned_byte_identical": bool(byte_identical),
            "hot_swaps": st["hot_swaps"],
            "served_by_version": st["served_by_version"],
        }
    finally:
        eng.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing: small campaign, short storm")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serving.json"))
    args = ap.parse_args()

    spec = SMOKE_SPEC if args.smoke else CAMPAIGN_SPEC
    n_storm = 24 if args.smoke else 200
    report = {"smoke": bool(args.smoke)}

    section("campaign -> front -> serving (mcm2)")
    report["campaign"] = bench_campaign_front(spec)

    section("continuous-batching throughput (gaussian3x3)")
    report["throughput"] = bench_throughput(n_storm)
    tq = report["throughput"]["per_tier_qor"]
    assert len(tq) >= 3, f"expected >=3 tiers, got {sorted(tq)}"
    # exact must measurably beat the budget tier on real QoR
    assert tq["exact"]["mean_qor"] > tq["budget"]["mean_qor"], tq

    section("hot-swap drill (improved front mid-stream)")
    report["hot_swap"] = bench_hot_swap(n_storm // 2)

    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
