"""Pareto-front utilities (minimization convention throughout).

Objectives are (n, m) float arrays; smaller is better on every axis.
QoR-style "bigger is better" objectives are negated by the caller.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

__all__ = [
    "dominates",
    "non_dominated_mask",
    "fast_non_dominated_sort",
    "crowding_distance",
    "pareto_front",
    "hypervolume_2d",
]


def dominates(a: np.ndarray, b: np.ndarray) -> bool:
    """True iff a <= b on all axes and a < b on at least one."""
    return bool(np.all(a <= b) and np.any(a < b))


def non_dominated_mask(obj: np.ndarray) -> np.ndarray:
    """Boolean mask of the non-dominated points of `obj` (n, m).

    O(n^2) vectorized pairwise check — fine for n up to a few 10^4.
    """
    obj = np.asarray(obj, dtype=np.float64)
    n = obj.shape[0]
    if n == 0:
        return np.zeros(0, dtype=bool)
    # le[i, j] = obj[i] <= obj[j] on all axes; lt = strictly on some axis
    le = np.all(obj[:, None, :] <= obj[None, :, :], axis=-1)
    lt = np.any(obj[:, None, :] < obj[None, :, :], axis=-1)
    dom = le & lt  # dom[i, j]: i dominates j
    return ~dom.any(axis=0)


def fast_non_dominated_sort(obj: np.ndarray) -> List[np.ndarray]:
    """NSGA-II fast non-dominated sort: list of index arrays, front 0 first."""
    obj = np.asarray(obj, dtype=np.float64)
    n = obj.shape[0]
    le = np.all(obj[:, None, :] <= obj[None, :, :], axis=-1)
    lt = np.any(obj[:, None, :] < obj[None, :, :], axis=-1)
    dom = le & lt                       # dom[i, j]: i dominates j
    n_dom = dom.sum(axis=0).astype(np.int64)  # how many dominate j
    fronts: List[np.ndarray] = []
    current = np.flatnonzero(n_dom == 0)
    assigned = np.zeros(n, dtype=bool)
    while current.size:
        fronts.append(current)
        assigned[current] = True
        # remove the current front's domination counts
        n_dom = n_dom - dom[current].sum(axis=0)
        nxt = np.flatnonzero((n_dom == 0) & ~assigned)
        current = nxt
    return fronts


def crowding_distance(obj: np.ndarray) -> np.ndarray:
    """NSGA-II crowding distance for one front (n, m); boundary points inf."""
    obj = np.asarray(obj, dtype=np.float64)
    n, m = obj.shape
    if n <= 2:
        return np.full(n, np.inf)
    dist = np.zeros(n)
    for k in range(m):
        order = np.argsort(obj[:, k], kind="stable")
        vals = obj[order, k]
        span = vals[-1] - vals[0]
        dist[order[0]] = np.inf
        dist[order[-1]] = np.inf
        if span > 0:
            dist[order[1:-1]] += (vals[2:] - vals[:-2]) / span
    return dist


def pareto_front(obj: np.ndarray) -> np.ndarray:
    """Indices of the non-dominated points, sorted by first objective."""
    idx = np.flatnonzero(non_dominated_mask(obj))
    return idx[np.argsort(np.asarray(obj)[idx, 0], kind="stable")]


def hypervolume_2d(obj: np.ndarray, ref: Sequence[float]) -> float:
    """Exact 2-D hypervolume (minimization) w.r.t. reference point `ref`.

    Used by tests and by the Fig. 7 generation-quality benchmark.
    """
    obj = np.asarray(obj, dtype=np.float64)
    assert obj.shape[1] == 2, "hypervolume_2d is 2-D only"
    ref = np.asarray(ref, dtype=np.float64)
    pts = obj[non_dominated_mask(obj)]
    pts = pts[np.all(pts < ref, axis=1)]
    if pts.shape[0] == 0:
        return 0.0
    pts = pts[np.argsort(pts[:, 0], kind="stable")]
    hv = 0.0
    prev_y = ref[1]
    for x, y in pts:
        hv += (ref[0] - x) * (prev_y - y)
        prev_y = y
    return float(hv)
