from . import adders, multipliers, tables
from .library import ADD16, MUL8S, MUL8U, Circuit, Library, default_library

__all__ = [
    "adders", "multipliers", "tables",
    "Circuit", "Library", "default_library", "MUL8U", "MUL8S", "ADD16",
]
