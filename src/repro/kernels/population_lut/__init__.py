"""Population LUT-gather: the batched behavioral sim's inner gather as a
tiled Pallas TPU kernel, an XLA gather (the CPU fused-engine path) and a
numpy reference.

``out[g, m, s] = lut[genes[g, s], s, cols[m, s]]`` — one gathered
product per (genome, input element, multiplier slot), the population
analogue of ``accel._batchsim.lut_gather``.
"""

from .kernel import population_lut_gather_pallas
from .ops import gather_xla, population_lut_gather
from .ref import population_lut_gather_ref

__all__ = [
    "population_lut_gather",
    "population_lut_gather_pallas",
    "population_lut_gather_ref",
    "gather_xla",
]
