"""Explorer quality: hypervolume-per-ground-truth-label for every
registered search strategy -> BENCH_strategies.json.

The ask/tell ``SearchStrategy`` seam makes the explorer a measurable
axis: each strategy runs the SAME three-stage campaign on gaussian3x3
(same training budget, same per-round evaluation budget derived from
the NSGA-II knobs), so the only difference is how EXPLORE proposes
genomes.  Headline per strategy:

  * hv          — 2-D hypervolume of the TRUE (re-labeled) front,
                  against a shared reference point,
  * labels      — ground-truth labels paid (train + final, deduped),
  * hv_per_label— the efficiency headline,
  * sur_evals   — surrogate evaluations the explorer spent.

All strategies share one synthesis cache, so ground truth for a genome
is paid once across the whole benchmark (labels are counted per
strategy anyway — the count is of unique genomes it asked for).

Run:  PYTHONPATH=src python benchmarks/strategy_quality.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from common import emit, section  # noqa: E402

FULL = dict(n_train=60, n_qor_samples=2, pop_size=24, n_parents=12,
            n_generations=8)
SMOKE = dict(n_train=16, n_qor_samples=2, pop_size=10, n_parents=5,
             n_generations=3)


def run_one(strategy: str, accel, lib, sizes_kw, shared_cache) -> dict:
    from repro.core.dse import DSEConfig, default_labeler, run_dse
    from repro.core.nsga2 import NSGA2Config

    cfg = DSEConfig(
        strategy=strategy,
        n_train=sizes_kw["n_train"],
        n_qor_samples=sizes_kw["n_qor_samples"],
        nsga=NSGA2Config(
            pop_size=sizes_kw["pop_size"],
            n_parents=sizes_kw["n_parents"],
            n_generations=sizes_kw["n_generations"],
            seed=0,
        ),
        seed=0,
    )
    labeled = set()
    base = default_labeler(accel, lib, n_qor_samples=cfg.n_qor_samples,
                           cache=shared_cache)

    def counting_labeler(genomes):
        for g in np.atleast_2d(genomes):
            labeled.add(np.asarray(g, dtype=np.int64).tobytes())
        return base(genomes)

    t0 = time.perf_counter()
    res = run_dse(accel, lib, cfg, labeler=counting_labeler)
    wall = time.perf_counter() - t0
    return {
        "wall_s": wall,
        "labels": len(labeled),
        "sur_evals": int(res.search.n_evaluated),
        "front": res.front_objectives.tolist(),
        "front_size": int(res.front_mask.sum()),
        "val_pcc": res.val_pcc,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized budgets")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_strategies.json"))
    ap.add_argument("--strategies", default="nsga2,bo,random")
    args = ap.parse_args()

    from repro.accel import GaussianFilter
    from repro.core.acl.library import default_library
    from repro.core.pareto import hypervolume_2d

    sizes_kw = SMOKE if args.smoke else FULL
    accel = GaussianFilter()
    lib = default_library()
    shared_cache: dict = {}
    strategies = [s for s in args.strategies.split(",") if s]

    results = {}
    for name in strategies:
        section(f"strategy {name}")
        results[name] = run_one(name, accel, lib, sizes_kw, shared_cache)

    # shared reference point over the union of fronts (a shared frame is
    # the only way per-strategy hypervolumes are comparable)
    union = np.concatenate([np.array(r["front"]) for r in results.values()])
    ref = union.max(axis=0) + 0.05 * np.maximum(
        union.max(axis=0) - union.min(axis=0), 1e-9)
    for name, r in results.items():
        hv = hypervolume_2d(np.array(r["front"]), ref)
        r["hv"] = float(hv)
        r["hv_per_label"] = float(hv / max(r["labels"], 1))
        emit(f"strategy_quality/{name}", r["wall_s"] * 1e6,
             f"hv_per_label={r['hv_per_label']:.4g}")

    # sanity: every strategy finds a non-trivial front; the guided
    # explorers should not lose to random on the shared-frame hv
    for name, r in results.items():
        assert r["front_size"] > 0, f"{name}: empty front"
    if "nsga2" in results and "random" in results and not args.smoke:
        assert results["nsga2"]["hv"] >= 0.9 * results["random"]["hv"], \
            "nsga2 lost >10% hypervolume to random search"

    out = {
        "accel": "gaussian3x3",
        "mode": "smoke" if args.smoke else "full",
        "budgets": sizes_kw,
        "ref_point": ref.tolist(),
        "strategies": results,
        "methodology": (
            "identical three-stage campaign per strategy (same training "
            "set, same per-round eval budget from the NSGA-II knobs); "
            "hv is true-front 2-D hypervolume against the shared "
            "reference point; labels = unique genomes ground-truthed "
            "(train + final)."
        ),
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nwrote {os.path.abspath(args.out)}")
    for name, r in results.items():
        print(f"  {name:8s} hv={r['hv']:.4g}  labels={r['labels']}  "
              f"hv/label={r['hv_per_label']:.4g}  "
              f"sur_evals={r['sur_evals']}  wall={r['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
