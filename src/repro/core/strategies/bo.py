"""Batch Bayesian optimization as an ask/tell strategy (AMG-style,
arXiv:2310.15495: BO replacing evolutionary search for approximate
multiplier selection).

Multi-objective handling is ParEGO-style: each round draws a random
weight vector, scalarizes the normalized observed objectives with the
augmented Chebyshev norm, fits a probabilistic model from the existing
surrogate registry (default ``bayesian_ridge``, whose posterior
``predict_std`` gives calibrated uncertainty; models without a std are
wrapped with a constant residual estimate), and picks the batch by
closed-form expected improvement over a candidate pool of random
genomes plus mutations of the current non-dominated set.

The strategy is deliberately a *different* explorer, not NSGA-II in a
hat: no crossover, no elitist selection — every proposal is
acquisition-driven.  It exists to prove the ask/tell seam carries a
genuinely different search, and to be compared on
hypervolume-per-evaluation in ``benchmarks/strategy_quality.py``.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional

import numpy as np

from ..nsga2 import GenerationLog, NSGA2Result, _select_parents
from ..pareto import non_dominated_mask
from ..surrogates import make as make_surrogate
from .base import SearchStrategy, decode_array, encode_array

__all__ = ["BOStrategy"]

_erf = np.frompyfunc(math.erf, 1, 1)


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + _erf(z / math.sqrt(2.0)).astype(np.float64))


def _norm_pdf(z: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


def _row_keys(genomes: np.ndarray, width: int) -> np.ndarray:
    """(n, width) int64 genomes -> (n,) void row keys: each key's bytes
    equal ``row.tobytes()``, but the whole batch is encoded in one C
    view instead of a per-row Python loop."""
    a = np.ascontiguousarray(
        np.atleast_2d(np.asarray(genomes, dtype=np.int64))
    ).reshape(-1, width)
    return a.view(np.dtype((np.void, a.dtype.itemsize * width))).reshape(-1)


def _first_occurrence(keys: np.ndarray) -> np.ndarray:
    """Indices of each key's first occurrence, in original order (the
    vectorized equivalent of the seen-set dedup loop)."""
    _, first = np.unique(keys, return_index=True)
    return np.sort(first)


class BOStrategy(SearchStrategy):
    name = "bo"

    def __init__(
        self,
        gene_sizes,
        *,
        n_rounds: int = 10,
        batch_size: int = 16,
        n_parents: Optional[int] = None,
        model: str = "bayesian_ridge",
        pool_size: Optional[int] = None,
        mutation_prob: float = 0.15,
        seed: int = 0,
        init: Optional[np.ndarray] = None,
        keep_history: bool = True,
    ):
        self.gene_sizes = np.asarray(gene_sizes, dtype=np.int64)
        self.n_rounds = int(n_rounds)
        self.batch_size = int(batch_size)
        self.n_parents = n_parents
        self.model = model
        self.pool_size = int(pool_size) if pool_size else 8 * self.batch_size
        self.mutation_prob = float(mutation_prob)
        self.seed = int(seed)
        self.keep_history = keep_history
        self._rng = np.random.default_rng(self.seed)
        self._init = None if init is None else np.asarray(init, dtype=np.int64)
        self._round = 0
        self._pending: Optional[np.ndarray] = None
        self._obs_g: List[np.ndarray] = []
        self._obs_o: List[np.ndarray] = []
        self._seen_keys = _row_keys(
            np.empty((0, len(self.gene_sizes)), dtype=np.int64),
            len(self.gene_sizes),
        )
        self.n_evaluated = 0
        self.history: List[GenerationLog] = []

    # ------------------------------------------------------------------
    @property
    def done(self) -> bool:
        # round 0 is the initial design, then n_rounds acquisition rounds
        return self._round > self.n_rounds and self._pending is None

    def _encode(self, genomes: np.ndarray) -> np.ndarray:
        """Genomes -> [0, 1] floats (the BO model's input space)."""
        span = np.maximum(self.gene_sizes - 1, 1).astype(np.float64)
        return genomes.astype(np.float64) / span[None, :]

    def _observed(self):
        return np.concatenate(self._obs_g), np.concatenate(self._obs_o)

    def _candidate_pool(self) -> np.ndarray:
        """Random genomes + mutations of the current non-dominated set,
        deduped against everything already observed.  Dedup is fully
        vectorized (void-view row keys + np.unique/np.isin), so growing
        the pool no longer grows a per-row Python loop."""
        g = len(self.gene_sizes)
        n_rand = self.pool_size // 2
        pool = [self._rng.integers(0, self.gene_sizes[None, :],
                                   size=(n_rand, g))]
        G, O = self._observed()
        elite = G[non_dominated_mask(O)]
        n_mut = self.pool_size - n_rand
        base = elite[self._rng.integers(0, len(elite), size=n_mut)]
        mut = self._rng.random(base.shape) < self.mutation_prob
        resets = self._rng.integers(0, self.gene_sizes[None, :],
                                    size=base.shape)
        pool.append(np.where(mut, resets, base))
        cand = np.concatenate(pool).astype(np.int64)
        keys = _row_keys(cand, g)
        first = _first_occurrence(keys)
        keep = first[~np.isin(keys[first], self._seen_keys)]
        return cand[keep] if len(keep) else cand[:0]

    def _acquire(self) -> np.ndarray:
        """One ParEGO round: scalarize, fit, maximize EI over the pool."""
        G, O = self._observed()
        lo, hi = O.min(axis=0), O.max(axis=0)
        Z = (O - lo) / np.where(hi > lo, hi - lo, 1.0)
        w = self._rng.random(O.shape[1])
        w = w / w.sum()
        y = (w * Z).max(axis=1) + 0.05 * (w * Z).sum(axis=1)
        m = make_surrogate(self.model, seed=self.seed).fit(self._encode(G), y)
        cand = self._candidate_pool()
        if len(cand) == 0:
            # space exhausted: fall back to fresh uniform draws
            return self._rng.integers(
                0, self.gene_sizes[None, :],
                size=(self.batch_size, len(self.gene_sizes)),
            )
        Xc = self._encode(cand)
        mu = np.asarray(m.predict(Xc), dtype=np.float64)
        if hasattr(m, "predict_std"):
            sd = np.asarray(m.predict_std(Xc), dtype=np.float64)
        else:
            resid = y - np.asarray(m.predict(self._encode(G)))
            sd = np.full(len(cand), float(resid.std()) or 1e-6)
        sd = np.maximum(sd, 1e-9)
        imp = float(y.min()) - mu              # minimization EI
        z = imp / sd
        ei = imp * _norm_cdf(z) + sd * _norm_pdf(z)
        order = np.argsort(-ei, kind="stable")
        return cand[order[: min(self.batch_size, len(cand))]]

    def ask(self) -> np.ndarray:
        if self.done:
            raise RuntimeError("strategy is done; ask() has no next batch")
        if self._pending is None:
            if self._round == 0:
                if self._init is not None:
                    batch = self._init
                else:
                    batch = self._rng.integers(
                        0, self.gene_sizes[None, :],
                        size=(self.batch_size, len(self.gene_sizes)),
                    )
                # dedup the initial design against itself (vectorized
                # first-occurrence, original order preserved)
                batch = np.asarray(batch, dtype=np.int64)
                batch = batch[_first_occurrence(
                    _row_keys(batch, len(self.gene_sizes))
                )]
            else:
                batch = self._acquire()
            self._pending = np.asarray(batch, dtype=np.int64)
        return self._pending

    def tell(self, genomes, objectives) -> Optional[GenerationLog]:
        genomes = self._check_tell(self._pending, genomes)
        objectives = np.asarray(objectives, dtype=np.float64)
        self._obs_g.append(np.array(genomes))
        self._obs_o.append(objectives)
        self._seen_keys = np.concatenate([
            self._seen_keys, _row_keys(genomes, len(self.gene_sizes)),
        ])
        self.n_evaluated += len(genomes)
        log = GenerationLog(self._round, np.array(genomes), objectives,
                            self.n_evaluated)
        if self.keep_history:
            self.history.append(log)
        self._round += 1
        self._pending = None
        return log

    def result(self) -> NSGA2Result:
        if not self._obs_g:
            raise RuntimeError("no population evaluated yet")
        G, O = self._observed()
        if self.n_parents is not None and self.n_parents < len(G):
            G, O, _ = _select_parents(G, O, self.n_parents)
        return NSGA2Result(
            genomes=G,
            objectives=O,
            front_mask=non_dominated_mask(O),
            history=self.history,
            n_evaluated=self.n_evaluated,
        )

    def progress(self) -> Dict:
        return {
            "strategy": self.name,
            "generation": int(self._round),
            "n_generations": int(self.n_rounds) + 1,
            "surrogate_evals": int(self.n_evaluated),
            "done": bool(self.done),
        }

    # ------------------------------------------------------------------
    def state(self) -> Dict:
        return {
            "name": self.name,
            "gene_sizes": encode_array(self.gene_sizes),
            "n_rounds": self.n_rounds,
            "batch_size": self.batch_size,
            "n_parents": self.n_parents,
            "model": self.model,
            "pool_size": self.pool_size,
            "mutation_prob": self.mutation_prob,
            "seed": self.seed,
            "rng": self._rng.bit_generator.state,
            "init": encode_array(self._init),
            "round": self._round,
            "pending": encode_array(self._pending),
            "obs_g": [encode_array(a) for a in self._obs_g],
            "obs_o": [encode_array(a) for a in self._obs_o],
            "n_evaluated": self.n_evaluated,
        }

    def restore(self, state: Dict) -> "BOStrategy":
        self.gene_sizes = decode_array(state["gene_sizes"])
        g = len(self.gene_sizes)
        for k in ("n_rounds", "batch_size", "n_parents", "model",
                  "pool_size", "mutation_prob", "seed"):
            setattr(self, k, state[k])
        self._rng = np.random.default_rng()
        self._rng.bit_generator.state = state["rng"]
        self._init = decode_array(state["init"], width=g)
        self._round = state["round"]
        self._pending = decode_array(state["pending"], width=g)
        self._obs_g = [decode_array(a, width=g) for a in state["obs_g"]]
        self._obs_o = [decode_array(a, dtype=np.float64)
                       for a in state["obs_o"]]
        self._seen_keys = _row_keys(
            np.concatenate(self._obs_g) if self._obs_g
            else np.empty((0, g), dtype=np.int64), g,
        )
        self.n_evaluated = state["n_evaluated"]
        self.history = []
        return self
    # NOTE: history is not round-tripped (it can be large and the result
    # front does not depend on it); a resumed strategy's history covers
    # post-restore rounds only.
