"""Segmented, CRC-framed, crash-safe append-log machinery.

One big JSONL file was fine for one host; a fleet needs durability the
replay loop can *prove*.  A :class:`SegmentedLog` is a directory of
fixed-size segments::

    root/
      MANIFEST.json      # sealed-segment catalog + generation counter
      active.jsonl       # current append segment (CRC-framed lines)
      seg-000001.jsonl   # sealed, immutable
      seg-000001.idx     # optional key sidecar (O(1) warm start)
      quarantine/        # corrupt segments end up here, not in a stack
      .lock              # cross-process flock sidecar

Every record line is ``<crc32:08x> <compact json>\\n`` — a torn write,
a bit flip, or a merged line fails the checksum and is *quarantined and
counted* instead of silently skipped or fatally raised.  Sealing renames
``active.jsonl`` to ``seg-NNNNNN.jsonl`` (atomic), writes a key sidecar,
then updates the manifest; a crash between those steps leaves an orphan
segment that the next open adopts back into the manifest.  All mutation
runs under one advisory ``flock`` so concurrent writer *processes*
(the fleet case) interleave safely, exactly like the single-file
``JsonlLabelStore`` did — but a reader warm-starts from the manifest +
sidecars without parsing a single record body.

Owners (``SegmentedLabelStore``, ``SegmentedSynthCache``) drive the log
under its lock: ``sync_locked`` reconciles with foreign writers,
``append_locked`` frames + appends + seals.  The log knows framing and
files; it never interprets records beyond the optional ``index_field``
used to build sidecars.
"""

from __future__ import annotations

import contextlib
import json
import os
import re
import threading
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None

from . import faults, obs

__all__ = ["SegmentedLog", "frame_record", "parse_line"]

_SEG_RE = re.compile(r"^seg-(\d{6})\.jsonl$")
ACTIVE = "active.jsonl"
MANIFEST = "MANIFEST.json"


def frame_record(obj: Any) -> str:
    payload = json.dumps(obj, sort_keys=True, separators=(",", ":"))
    crc = zlib.crc32(payload.encode()) & 0xFFFFFFFF
    return f"{crc:08x} {payload}\n"


def parse_line(line: str) -> Optional[Any]:
    """CRC-checked parse of one framed line (no trailing newline).
    Returns None for anything damaged — torn, merged, flipped."""
    if len(line) < 10 or line[8] != " ":
        return None
    try:
        crc = int(line[:8], 16)
    except ValueError:
        return None
    payload = line[9:]
    if zlib.crc32(payload.encode()) & 0xFFFFFFFF != crc:
        return None
    try:
        return json.loads(payload)
    except json.JSONDecodeError:
        return None


class SegmentedLog:
    """Files, framing, manifest, locking — no record semantics."""

    def __init__(self, root: str, *, segment_records: int = 4096,
                 retention_segments: Optional[int] = None,
                 index_field: Optional[str] = None, name: str = "store"):
        if segment_records < 1:
            raise ValueError("segment_records must be >= 1")
        if retention_segments is not None and retention_segments < 1:
            raise ValueError("retention_segments must be >= 1")
        self.root = str(root)
        self.segment_records = int(segment_records)
        self.retention_segments = retention_segments
        self.index_field = index_field
        self.name = name
        self.log = obs.get_logger(f"segments.{name}")
        # durability accounting (exposed via owner stats())
        self.quarantined_records = 0
        self.quarantined_segments = 0
        self.repaired_tails = 0
        self.seals = 0
        # active-segment replay cursor (same tail-seek discipline as the
        # single-file store: refresh is O(new bytes))
        self._offset = 0
        self._records = 0          # good records replayed/appended
        self._damage = 0           # quarantined lines still in the file
        self._keys: List[str] = []  # index_field values in the active seg
        self._ino: Optional[int] = None
        self._fh = None
        self._thread_lock = threading.Lock()
        os.makedirs(self.root, exist_ok=True)

    # -- paths ----------------------------------------------------------
    def _p(self, *parts: str) -> str:
        return os.path.join(self.root, *parts)

    @property
    def active_path(self) -> str:
        return self._p(ACTIVE)

    # -- cross-process lock --------------------------------------------
    @contextlib.contextmanager
    def lock(self):
        """Advisory cross-process lock (plus an in-process mutex so the
        flock's per-process semantics never bite threads)."""
        faults.hit("store.lock", root=self.root)
        with self._thread_lock:
            if fcntl is None:  # pragma: no cover - non-POSIX
                yield
                return
            with open(self._p(".lock"), "a+") as lk:
                fcntl.flock(lk.fileno(), fcntl.LOCK_EX)
                try:
                    yield
                finally:
                    fcntl.flock(lk.fileno(), fcntl.LOCK_UN)

    # -- manifest -------------------------------------------------------
    def manifest(self) -> Dict[str, Any]:
        try:
            with open(self._p(MANIFEST)) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError):
            return {"version": 1, "gen": 0, "seq": 0, "sealed": []}

    def _write_manifest_locked(self, m: Dict[str, Any]) -> None:
        m["gen"] = int(m.get("gen", 0)) + 1
        tmp = self._p(MANIFEST + ".tmp")
        with open(tmp, "w") as f:
            json.dump(m, f, sort_keys=True, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._p(MANIFEST))

    # -- segment IO -----------------------------------------------------
    def read_segment(self, seg_name: str) -> Tuple[List[Any], int]:
        """Parse a sealed segment; returns (records, damaged lines).
        Raises OSError only if the file itself cannot be read."""
        recs: List[Any] = []
        bad = 0
        # errors="replace": bit-rot can make bytes undecodable; a mangled
        # line must fail its CRC and count as damage, not crash the read
        with open(self._p(seg_name), errors="replace") as f:
            for line in f:
                if not line.endswith("\n"):
                    bad += 1  # sealed segments must not have torn tails
                    continue
                obj = parse_line(line[:-1])
                if obj is None:
                    bad += 1
                else:
                    recs.append(obj)
        return recs, bad

    def read_index(self, seg_name: str) -> Optional[List[str]]:
        """Key sidecar for a sealed segment (None if absent/corrupt)."""
        idx = self._p(seg_name[:-len(".jsonl")] + ".idx")
        try:
            with open(idx, errors="replace") as f:
                line = f.readline()
        except OSError:
            return None
        obj = parse_line(line.rstrip("\n"))
        if not isinstance(obj, dict) or "keys" not in obj:
            return None
        return list(obj["keys"])

    def _write_index_locked(self, seg_name: str, keys: List[str]) -> None:
        idx = self._p(seg_name[:-len(".jsonl")] + ".idx")
        tmp = idx + ".tmp"
        with open(tmp, "w") as f:
            f.write(frame_record({"keys": keys}))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, idx)

    def quarantine_locked(self, seg_name: str, reason: str) -> None:
        """Move a damaged segment aside and drop it from the manifest —
        the store keeps serving; the evidence keeps existing."""
        qdir = self._p("quarantine")
        os.makedirs(qdir, exist_ok=True)
        for suffix in (".jsonl", ".idx"):
            src = self._p(seg_name[:-len(".jsonl")] + suffix)
            if os.path.exists(src):
                os.replace(src, os.path.join(
                    qdir, os.path.basename(src)))
        m = self.manifest()
        m["sealed"] = [e for e in m["sealed"] if e["name"] != seg_name]
        self._write_manifest_locked(m)
        self.quarantined_segments += 1
        self.log.warning("quarantined segment %s (%s)", seg_name, reason)

    # -- reconcile with foreign writers --------------------------------
    def sync_locked(self) -> Tuple[Dict[str, Any], List[Any]]:
        """Adopt orphan segments (a sealer died between rename and
        manifest write), then replay the active tail.  Returns the
        manifest and the newly visible tail records; the owner diffs the
        manifest's sealed list against what it already indexed."""
        m = self._adopt_orphans_locked()
        tail = self._read_tail_locked()
        return m, tail

    def _adopt_orphans_locked(self) -> Dict[str, Any]:
        m = self.manifest()
        known = {e["name"] for e in m["sealed"]}
        orphans = sorted(
            n for n in os.listdir(self.root)
            if _SEG_RE.match(n) and n not in known)
        if not orphans:
            return m
        for name in orphans:
            recs, bad = self.read_segment(name)
            self.quarantined_records += bad
            keys: List[str] = []
            if self.index_field is not None:
                keys = [r[self.index_field] for r in recs
                        if isinstance(r, dict) and self.index_field in r]
                self._write_index_locked(name, keys)
            m["sealed"].append({"name": name, "records": len(recs)})
            m["seq"] = max(int(m.get("seq", 0)),
                           int(_SEG_RE.match(name).group(1)))
            self.log.warning("adopted orphan segment %s (%d records)",
                             name, len(recs))
        m["sealed"].sort(key=lambda e: e["name"])
        self._write_manifest_locked(m)
        return self.manifest()

    def _read_tail_locked(self) -> List[Any]:
        path = self.active_path
        try:
            f = open(path, errors="replace")
        except OSError:
            # active was sealed away by another process; start fresh
            self._reset_active_locked()
            return []
        out: List[Any] = []
        with f:
            ino = os.fstat(f.fileno()).st_ino
            if self._ino is not None and ino != self._ino:
                self._reset_active_locked()
            self._ino = ino
            f.seek(self._offset)
            while True:
                pos = f.tell()
                line = f.readline()
                if not line or not line.endswith("\n"):
                    # EOF or torn tail from a live foreign writer: leave
                    # the cursor so the bytes are re-read next time (or
                    # repaired before our next append)
                    self._offset = pos
                    break
                obj = parse_line(line[:-1])
                if obj is None:
                    self.quarantined_records += 1
                    self._damage += 1
                    self.log.warning(
                        "quarantined damaged record in %s @%d", ACTIVE, pos)
                else:
                    out.append(obj)
                    self._records += 1
                    if (self.index_field is not None
                            and isinstance(obj, dict)
                            and self.index_field in obj):
                        self._keys.append(obj[self.index_field])
        return out

    def _reset_active_locked(self) -> None:
        self._offset = 0
        self._records = 0
        self._damage = 0
        self._keys = []
        self._ino = None
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- append + seal --------------------------------------------------
    def append_locked(self, objs: List[Any]) -> Dict[str, Any]:
        """Frame and append records to the active segment (repairing any
        torn tail first), sealing as the size threshold crosses.
        Returns {"dropped_keys": [...]} when retention evicted sealed
        segments."""
        f = faults.check("store.append", n=len(objs))
        if f is not None:
            if f.kind == "torn_write":
                # simulate a writer that died mid-append: a partial,
                # newline-less record lands ahead of ours.  Written via
                # a separate handle so OUR replay cursor stays put — the
                # repair below must see it as a foreign torn tail
                garbage = frame_record(
                    {"k": "__torn__", "chaos": True})[:-1]
                cut = max(int(len(garbage) * f.fraction), 1)
                with open(self.active_path, "a") as gf:
                    gf.write(garbage[:cut])
            elif f.kind == "error":
                f.raise_()
            elif f.delay_s > 0:
                time.sleep(f.delay_s)
        self._repair_tail_locked()
        dropped: List[str] = []
        i = 0
        while i < len(objs):
            # fill the active segment to its fixed size, then seal —
            # a big batch becomes several uniform segments, not one blob
            room = max(self.segment_records - self._records, 1)
            chunk = objs[i:i + room]
            i += len(chunk)
            self._append_raw("".join(frame_record(o) for o in chunk))
            self._records += len(chunk)
            if self.index_field is not None:
                self._keys.extend(
                    o[self.index_field] for o in chunk
                    if isinstance(o, dict) and self.index_field in o)
            if self._records >= self.segment_records:
                dropped.extend(self._seal_locked())
        return {"dropped_keys": dropped}

    def _append_raw(self, text: str) -> None:
        if self._fh is None:
            self._fh = open(self.active_path, "a")
            self._ino = os.fstat(self._fh.fileno()).st_ino
        self._fh.write(text)
        self._fh.flush()
        self._offset = self._fh.tell()

    def _repair_tail_locked(self) -> None:
        """A torn tail left by a dead writer would otherwise merge with
        our first record and silently destroy BOTH — terminate it with a
        newline so it fails CRC as its own quarantined line instead."""
        try:
            size = os.path.getsize(self.active_path)
        except OSError:
            return
        if size <= self._offset:
            return
        torn = size - self._offset
        self._append_raw("\n")
        self.quarantined_records += 1
        self.repaired_tails += 1
        self._damage += 1
        self.log.warning(
            "repaired torn tail in %s (%d bytes quarantined)",
            ACTIVE, torn)

    def _seal_locked(self) -> List[str]:
        """active.jsonl -> seg-NNNNNN.jsonl + idx + manifest; returns
        keys dropped by retention (for the owner's index)."""
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._fh.close()
            self._fh = None
        m = self.manifest()
        seq = int(m.get("seq", 0)) + 1
        name = f"seg-{seq:06d}.jsonl"
        records, keys = self._records, list(self._keys)
        if self._damage:
            # quarantined (CRC-failing) lines must not fossilize into an
            # immutable sealed segment — every future load would re-flag
            # the whole segment as damaged.  Scrub them now, atomically.
            with open(self.active_path, errors="replace") as f:
                good = [ln for ln in f.read().splitlines()
                        if parse_line(ln) is not None]
            tmp = self.active_path + ".tmp"
            with open(tmp, "w") as f:
                f.write("".join(ln + "\n" for ln in good))
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.active_path)
            self._damage = 0
        os.replace(self.active_path, self._p(name))
        # a kill here leaves an orphan segment; sync_locked adopts it
        faults.hit("store.seal", segment=name)
        if self.index_field is not None:
            self._write_index_locked(name, keys)
        m["sealed"].append({"name": name, "records": records})
        m["seq"] = seq
        dropped_keys: List[str] = []
        if (self.retention_segments is not None
                and len(m["sealed"]) > self.retention_segments):
            n_drop = len(m["sealed"]) - self.retention_segments
            for entry in m["sealed"][:n_drop]:
                dropped_keys.extend(self.read_index(entry["name"]) or [])
                for suffix in (".jsonl", ".idx"):
                    p = self._p(entry["name"][:-len(".jsonl")] + suffix)
                    with contextlib.suppress(OSError):
                        os.remove(p)
            m["sealed"] = m["sealed"][n_drop:]
        self._write_manifest_locked(m)
        self._reset_active_locked()
        self.seals += 1
        with obs.span("store.seal", segment=name, records=records):
            pass
        return dropped_keys

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        m = self.manifest()
        return {
            "segments": len(m["sealed"]),
            "active_records": self._records,
            "seals": self.seals,
            "quarantined": self.quarantined_records,
            "quarantined_segments": self.quarantined_segments,
            "repaired_tails": self.repaired_tails,
        }

    def close(self) -> None:
        with self._thread_lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None
