"""Shared model building blocks: parameter specs, RMSNorm, RoPE variants,
activations.

Parameters are plain nested dicts of jnp arrays.  Their shapes/logical
axes are declared once via ``ParamSpec``; ``init_tree`` materializes real
arrays (smoke tests / examples) and ``abstract_tree`` materializes
ShapeDtypeStructs with NamedShardings (dry-run) from the same declaration.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ParamSpec",
    "init_tree",
    "abstract_tree",
    "rms_norm",
    "make_rope",
    "apply_rope",
    "act_fn",
]


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: str = "float32"
    init: str = "normal"      # normal | zeros | ones | conv
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_tree(specs, key: jax.Array):
    """Materialize a ParamSpec tree into real arrays (deterministic)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, spec.dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, spec.dtype)
        else:
            arr = (
                jax.random.normal(k, spec.shape, jnp.float32) * spec.scale
            ).astype(spec.dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def abstract_tree(specs, mesh, rules=None):
    """ParamSpec tree -> ShapeDtypeStruct tree with resolved shardings."""
    from ..dist.sharding import sharding_for

    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=sharding_for(s.logical, s.shape, mesh, rules)
        ),
        specs,
        is_leaf=_is_spec,
    )


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def make_rope(head_dim: int, theta: float = 10000.0,
              fraction: float = 1.0) -> np.ndarray:
    """Inverse-frequency vector (rot_dim//2,).  cos/sin are computed on
    the fly from positions (no O(max_len) table — a 512k-position table
    would be a 268 MB baked constant).

    fraction < 1 rotates only the first ``fraction*head_dim`` dims
    (ChatGLM-style 2d/partial RoPE)."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    return (1.0 / (theta ** (np.arange(0, rot, 2) / rot))).astype(np.float32)


def apply_rope(
    x: jnp.ndarray,                            # (b, h, s, d)
    inv_freq: jnp.ndarray,                     # (rot//2,)
    positions: Optional[jnp.ndarray] = None,   # (s,) or (b, s); None=arange
) -> jnp.ndarray:
    b, h, s, d = x.shape
    rot2 = inv_freq.shape[0]
    if positions is None:
        positions = jnp.arange(s)
    ang = positions[..., :, None].astype(jnp.float32) * inv_freq  # (s|b,s, r2)
    c, sn = jnp.cos(ang), jnp.sin(ang)
    if c.ndim == 2:
        c, sn = c[None, None], sn[None, None]
    else:
        c, sn = c[:, None], sn[:, None]
    xr = x[..., : 2 * rot2].astype(jnp.float32).reshape(b, h, s, rot2, 2)
    x1, x2 = xr[..., 0], xr[..., 1]
    rotated = jnp.stack([x1 * c - x2 * sn, x1 * sn + x2 * c], axis=-1)
    rotated = rotated.reshape(b, h, s, 2 * rot2).astype(x.dtype)
    return jnp.concatenate([rotated, x[..., 2 * rot2 :]], axis=-1)
