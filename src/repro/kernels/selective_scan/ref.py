"""Pure-jnp oracle for the Mamba-1 selective scan.

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t
    y_t = <h_t, C_t> + D * x_t        (the D term is applied by the caller)

Shapes: x/dt (b, s, di), A (di, n), B/C (b, s, n), h (b, di, n).
Sequential-scan reference — the ground truth for both the chunked
associative implementation (models/ssm.py) and the Pallas kernel.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["selective_scan_reference"]


def selective_scan_reference(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    h0: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (b, s, di), h_final (b, di, n)); f32 math."""
    b, s, di = x.shape
    n = A.shape[1]
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                       # (b, di), (b, di), (b, n)
        a = jnp.exp(dtt[..., None] * A[None])       # (b, di, n)
        h = a * h + (dtt * xt)[..., None] * Bt[:, None, :]
        y = jnp.einsum("bin,bn->bi", h, Ct)
        return h, y

    hT, ys = jax.lax.scan(
        step, h0,
        (x.transpose(1, 0, 2), dt.transpose(1, 0, 2),
         B.transpose(1, 0, 2), C.transpose(1, 0, 2)),
    )
    return ys.transpose(1, 0, 2), hT
