import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell and both production meshes
(single-pod 16x16, multi-pod 2x16x16), lower + compile the appropriate
step function against abstract inputs (ShapeDtypeStructs, no allocation),
then record:

  * memory_analysis()  — proves the cell fits 16 GB/chip,
  * cost_analysis()    — per-device HLO FLOPs / bytes,
  * collective bytes   — parsed from the partitioned HLO text,
  * the three roofline terms (core/hw.py).

Results are cached as JSON under experiments/dryrun/ — the §Roofline
benchmark and EXPERIMENTS.md tables read from there.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch jamba --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod ...
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..core import hlo_analysis, hw
from ..configs import ARCHS, get_config
from ..models import ApproxPolicy
from ..optim.adamw import AdamW
from ..train.serve import make_decode_step, make_prefill_step
from ..train.step import init_state, make_train_step
from .mesh import make_production_mesh
from .shapes import SHAPES, input_specs, n_microbatches, runnable

DEFAULT_OUT = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"
)


def _abstract_opt_state(cfg, params_abs, mesh, rules):
    """Abstract AdamW state matching init_state(): m, v in moment dtype."""

    def mom(p):
        return jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype, sharding=p.sharding)

    return {
        "m": jax.tree.map(mom, params_abs),
        "v": jax.tree.map(mom, params_abs),
        "step": jax.ShapeDtypeStruct((), np.int32),
    }


def lower_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool = False,
    policy: Optional[ApproxPolicy] = None,
    rules_override: Optional[dict] = None,
    n_micro: Optional[int] = None,
    acc_dtype: Optional[str] = None,
    verbose: bool = True,
) -> Dict[str, Any]:
    """Lower + compile one cell; returns the §Dry-run/§Roofline record."""
    cfg = get_config(arch)
    ok, reason = runnable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "approx_policy": sorted(policy.assignments) if policy else None,
    }
    if not ok:
        rec["status"] = reason
        return rec

    from ..dist import sharding as shd

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    spec = input_specs(cfg, shape, mesh, rules_override=rules_override)
    rules = spec["rules"]
    rules_ctx = shd.rule_overrides(rules)  # reach the model's constrain()s

    def sh(tree):
        # pin output shardings to the input shardings: without this XLA
        # leaves e.g. FSDP gradients REPLICATED on output (47 GB/device
        # observed on jamba) instead of reduce-scattering them
        return jax.tree.map(lambda s: s.sharding, tree)

    from ..dist.compat import mesh_context

    t0 = time.perf_counter()
    with mesh_context(mesh), rules_ctx:
        if spec["kind"] == "train":
            nm = n_micro or n_microbatches(cfg, mesh)
            rec["n_micro"] = nm
            opt = AdamW(moment_dtype=cfg.moment_dtype)
            step = make_train_step(cfg, opt, n_micro=nm, policy=policy,
                                   acc_dtype=acc_dtype)
            state_abs = {
                "params": spec["params"],
                "opt": _abstract_opt_state(cfg, spec["params"], mesh, rules),
            }
            lowered = jax.jit(
                step, donate_argnums=(0,),
                out_shardings=(sh(state_abs), None),
            ).lower(state_abs, spec["batch"])
        elif spec["kind"] == "prefill":
            step = make_prefill_step(cfg, policy=policy)
            out_sh = (
                (None, sh(spec["caches"]), None)
                if cfg.is_encoder_decoder
                else (None, sh(spec["caches"]))
            )
            lowered = jax.jit(
                step, donate_argnums=(2,), out_shardings=out_sh,
            ).lower(spec["params"], spec["batch"], spec["caches"])
        else:  # decode
            step = make_decode_step(cfg, policy=policy)
            args = [spec["params"], spec["caches"], spec["tokens"], spec["pos"]]
            kw = {}
            if "enc_out" in spec:
                kw["enc_out"] = spec["enc_out"]
            lowered = jax.jit(
                step, donate_argnums=(1,),
                out_shardings=(None, None, sh(spec["caches"])),
            ).lower(*args, **kw)
        t_lower = time.perf_counter() - t0

        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    from ..dist.compat import compiled_cost_analysis

    mem = compiled.memory_analysis()
    ca = compiled_cost_analysis(compiled)
    hlo = compiled.as_text()
    # Trip-count-aware analysis of the partitioned HLO (XLA's aggregate
    # cost_analysis counts while bodies once — useless for scanned stacks).
    hc = hlo_analysis.analyze_hlo(hlo)

    flops = hc.flops
    byts = hc.hbm_bytes
    rt = hw.roofline(flops, byts, hc.collective_bytes)

    cell = SHAPES[shape]
    tokens = cell.global_batch * (
        cell.seq_len if cell.kind in ("train", "prefill") else 1
    )
    n_active = cfg.active_param_count()
    model_flops = (6 if cell.kind == "train" else 2) * n_active * tokens
    rec.update(
        status="ok",
        n_devices=n_dev,
        t_lower_s=round(t_lower, 2),
        t_compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_cpu_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
            # TPU-corrected temp: the CPU backend upcasts every bf16 dot
            # operand/result to f32 (it has no bf16 ALU), so big temps are
            # f32 shadows of bf16 tensors that a TPU would never allocate;
            # argument/output state sizes are exact.  Documented in
            # EXPERIMENTS.md §Dry-run.
            "peak_tpu_estimate_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes / 2
            - mem.alias_size_in_bytes,
        },
        fits_hbm=bool(
            mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes / 2 - mem.alias_size_in_bytes
            < hw.V5E.hbm_bytes
        ),
        flops_per_device=flops,
        hbm_bytes_per_device=byts,
        collective_bytes_per_device={
            **hc.collective_detail, "total": hc.collective_bytes
        },
        xla_reported={  # XLA aggregate (loop bodies counted once) — ref only
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        roofline=rt.as_dict(),
        model_flops_total=model_flops,
        model_flops_per_device=model_flops / n_dev,
        useful_flops_ratio=(model_flops / n_dev) / flops if flops else 0.0,
    )
    if verbose:
        mb = rec["memory"]["peak_tpu_estimate_bytes"] / 2**30
        mb_cpu = rec["memory"]["peak_cpu_bytes"] / 2**30
        print(
            f"[dryrun] {arch:24s} {shape:12s} {rec['mesh']:8s} "
            f"compile={t_compile:6.1f}s peak={mb:6.2f}GiB(tpu-est"
            f"|cpu {mb_cpu:.1f}) "
            f"bottleneck={rt.bottleneck:10s} t_step={rt.t_step*1e3:9.3f}ms "
            f"useful={rec['useful_flops_ratio']:.2f}",
            flush=True,
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or prefix (default: all)")
    ap.add_argument("--shape", default=None, choices=[None, *SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--approx", default=None,
                    help="circuit name to apply to ffn projections "
                         "(paper-technique cell), e.g. mul8s_trunc2")
    args = ap.parse_args()

    archs = ARCHS if args.arch is None else [
        a for a in ARCHS if a.startswith(args.arch)
    ]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    policy = None
    if args.approx:
        policy = ApproxPolicy({
            "ffn_in": (args.approx, None),
            "ffn_out": (args.approx, None),
        })

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
                if policy:
                    tag += f"__approx_{args.approx}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rec = lower_cell(arch, shape, multi_pod=mp, policy=policy)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": f"FAIL: {e}"}
                    failures.append(tag)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nall requested cells lowered + compiled OK")


if __name__ == "__main__":
    main()
