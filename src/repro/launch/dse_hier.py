"""Hierarchical DSE driver: staged-pipeline search via per-stage
campaigns, composition and end-to-end verification (repro.hierarchy).

    PYTHONPATH=src python -m repro.launch.dse_hier --accel smoothed_dct \
        --n-train 36 --generations 6 --pop 24 --store labels.jsonl

Prints per-stage campaign stats, the composition summary and the
verified application-level Pareto front, plus the ground-truth-call
count against the flat joint-genome space size.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from .. import obs
from ..core.acl.library import default_library
from ..hierarchy.search import HierarchicalConfig, run_hierarchical
from ..service.campaigns import CampaignManager, make_accelerator

__all__ = ["main"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--accel", default="smoothed_dct",
                    help="a staged pipeline accelerator name")
    ap.add_argument("--n-train", type=int, default=36)
    ap.add_argument("--generations", type=int, default=6)
    ap.add_argument("--pop", type=int, default=24)
    ap.add_argument("--parents", type=int, default=12)
    ap.add_argument("--pipeline", default="D", choices=list("BCDEF"))
    from ..core.strategies import available_strategies

    ap.add_argument("--strategy", default="nsga2",
                    choices=available_strategies(),
                    help="explorer for every stage campaign")
    ap.add_argument("--qor-samples", type=int, default=2)
    ap.add_argument("--k-per-stage", type=int, default=12)
    ap.add_argument("--max-candidates", type=int, default=64)
    ap.add_argument("--rank-genes", action="store_true")
    ap.add_argument("--store", default=None,
                    help="persistent JSONL label store shared by the "
                         "stage campaigns AND the final verification")
    ap.add_argument("--synth-cache", default=None,
                    help="persistent JSONL structural compile cache "
                         "shared by the stage campaigns (stage 0 rides "
                         "the standalone accelerator's compiles) and the "
                         "end-to-end verification")
    ap.add_argument("--eval-workers", type=int, default=2)
    ap.add_argument("--eval-backend", choices=("thread", "process", "fleet"),
                    default="thread",
                    help="ground-truth backend for every stage campaign: "
                         "threads, a process pool, or a multi-host fleet "
                         "(an orchestrator HTTP listener is started and "
                         "remote 'python -m repro.fleet.worker' processes "
                         "may join mid-search)")
    ap.add_argument("--fleet-port", type=int, default=0,
                    help="orchestrator port for --eval-backend fleet "
                         "(0 = ephemeral)")
    ap.add_argument("--campaign-workers", type=int, default=0,
                    help="0 = one worker per stage")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="append finished spans (campaign ticks, label "
                         "batches, synth compiles, fleet leases) as JSON "
                         "lines; export with 'python -m repro.obs.export "
                         "PATH --chrome-trace'")
    ap.add_argument("--out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.trace:
        obs.set_sink(args.trace)
        print(f"[dse-hier] tracing to {args.trace}")

    pipeline = make_accelerator(args.accel)
    if not hasattr(pipeline, "stage_views"):
        raise SystemExit(f"{args.accel!r} is not a staged pipeline")
    library = default_library()
    cfg = HierarchicalConfig(
        pipeline=args.pipeline,
        strategy=args.strategy,
        n_train=args.n_train,
        n_qor_samples=args.qor_samples,
        rank_genes=args.rank_genes,
        pop_size=args.pop,
        n_parents=args.parents,
        n_generations=args.generations,
        k_per_stage=args.k_per_stage,
        max_candidates=args.max_candidates,
        seed=args.seed,
    )

    store = None
    mgr_kw = dict(
        eval_workers=args.eval_workers,
        eval_backend=args.eval_backend,
        campaign_workers=args.campaign_workers or len(pipeline.stages),
        synth_cache=args.synth_cache or None,
    )
    if args.store:
        from ..service.store import open_label_store

        store = open_label_store(args.store)
        print(f"[dse-hier] label store {args.store}: {len(store)} entries")
    manager = CampaignManager(store, **mgr_kw)
    if manager.synth_cache is not None:
        print(f"[dse-hier] synth cache {args.synth_cache}: "
              f"{len(manager.synth_cache)} compiled structures")
    fleet_srv = None
    if args.eval_backend == "fleet":
        from ..fleet import serve_fleet

        fleet_srv = serve_fleet(manager.scheduler.fleet,
                                host="0.0.0.0", port=args.fleet_port)
        port = fleet_srv.server_address[1]
        print(f"[dse-hier] fleet orchestrator on :{port} — join workers "
              f"with: python -m repro.fleet.worker --orchestrator "
              f"http://<this-host>:{port}"
              + (f" --store {args.store}" if args.store else ""))
    try:
        res = run_hierarchical(pipeline, library, cfg,
                               manager=manager, verbose=True)
    finally:
        manager.shutdown()
        if fleet_srv is not None:
            fleet_srv.shutdown()
        if store is not None:
            store.close()

    print(f"\n[dse-hier] {pipeline.name}: "
          f"{len(pipeline.stages)} stages, flat space "
          f"{res.flat_space_size:.2e}")
    print(f"  per-stage campaigns: "
          + ", ".join(f"stage{i}={res.timings[f'stage{i}']:.1f}s"
                      for i in range(len(pipeline.stages)))
          + f" (max {res.max_concurrent_stages} in flight)")
    cs = res.compose_stats
    print(f"  composition: fronts {cs.stage_sizes} -> truncated "
          f"{cs.truncated_sizes} -> {cs.pairs_evaluated} pairs -> "
          f"{cs.survivors} survivors")
    gt = res.ground_truth_calls
    print(f"  ground truth: {gt['stage_campaigns']} stage + {gt['final']} "
          f"final = {gt['total']} calls")
    front = res.front_objectives
    order = np.argsort(front[:, 0])
    print(f"  verified front ({len(front)} designs) [PSNR dB, energy J]:")
    for i in order[:12]:
        print(f"    psnr={-front[i, 0]:7.2f}  energy={front[i, 1]:.3e}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump({
                "accel": args.accel,
                "timings": res.timings,
                "ground_truth_calls": gt,
                "flat_space_size": res.flat_space_size,
                "max_concurrent_stages": res.max_concurrent_stages,
                "front": front.tolist(),
                "front_genomes": res.front_genomes.tolist(),
                "val_pcc": res.val_pcc,
            }, f, indent=1)


if __name__ == "__main__":
    main()
