"""Serving example: batched greedy decoding from the attention-free
falcon-mamba backbone (O(1) decode state — the long_500k family), with
the approximation policy drawn from a stored Pareto front.

    PYTHONPATH=src python examples/serve_mamba.py
    PYTHONPATH=src python examples/serve_mamba.py --front front.json \
        --tier budget
    PYTHONPATH=src python examples/serve_mamba.py --demo-front /tmp/f.json

``--front`` loads a front JSON (the service's ``GET /front`` payload
shape) and serves the chosen tier's genome as an ``ApproxPolicy``.
``--demo-front`` writes a small synthetic front for this arch first
(exact genome + two perturbed points) so the front->policy->decode path
is exercisable without running an LM campaign — that is what CI does.

REPRO_SMOKE=1 shrinks the workload for CI.
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.launch.serve import policy_from_front, serve_batch
from repro.models import reduced

SMOKE = os.environ.get("REPRO_SMOKE", "") not in ("", "0")


def write_demo_front(cfg, path: str) -> None:
    """A synthetic 3-point front for ``lm:<arch>``: the exact genome plus
    two perturbed genomes with fabricated labels, in the minimization
    convention front JSONs carry (qor negated).  Stands in for a real LM
    campaign's front in smoke tests."""
    from repro.accel.lm import LMAccelerator
    from repro.core.acl.library import default_library

    accel = LMAccelerator(cfg, use_reduced=False)
    lib = default_library()
    g0 = accel.exact_genome(lib)
    n = len(lib.kind("mul8s"))
    g1, g2 = g0.copy(), g0.copy()
    g1[0] = (g1[0] + 1) % n
    g2[:2] = (g2[:2] + 2) % n
    front = {
        "accel": accel.name,
        "objectives": ["qor", "energy"],
        "genomes": [g0.tolist(), g1.tolist(), g2.tolist()],
        # [-qor, energy]: exact = capped PSNR at full cost
        "front": [[-100.0, 10.0], [-72.0, 7.0], [-48.0, 4.0]],
    }
    with open(path, "w") as f:
        json.dump(front, f, indent=1)
    print(f"wrote demo front for {accel.name} -> {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--front", default=None,
                    help="front JSON to draw the serving policy from")
    ap.add_argument("--tier", default="balanced",
                    choices=("exact", "balanced", "budget"))
    ap.add_argument("--demo-front", default=None, metavar="PATH",
                    help="write a synthetic front for this arch to PATH "
                         "(if missing) and serve from it")
    args = ap.parse_args()

    cfg = reduced(get_config("falcon-mamba-7b"))
    print(f"serving {cfg.name}: layers={cfg.n_layers} d={cfg.d_model} "
          f"(attention-free: decode state is O(1) in context length)")

    front_path = args.front
    if args.demo_front:
        front_path = args.demo_front
        if not os.path.exists(front_path):
            write_demo_front(cfg, front_path)
    policy = None
    if front_path:
        policy, sel = policy_from_front(cfg, front_path, args.tier)
        labels = " ".join(
            f"{k}={v:.3g}" for k, v in sel.point.labels.items())
        print(f"tier={args.tier}: genome={list(sel.point.genome)} "
              f"({labels}) -> {len(policy.assignments)} approximated "
              f"projection classes")

    batch, prompt_len, gen = (2, 16, 8) if SMOKE else (4, 32, 24)
    tokens, tps = serve_batch(
        cfg, batch=batch, prompt_len=prompt_len, gen=gen, policy=policy)
    print(f"generated {tokens.shape[0]}x{tokens.shape[1]} tokens "
          f"@ {tps:.1f} tok/s (CPU, reduced config)")
    print("sample:", tokens[0, -gen:].tolist())


if __name__ == "__main__":
    main()
