"""repro.faults — deterministic, seeded fault injection.

The chaos harness for the DSE service, fleet, store and serving tiers:

  * :class:`FaultPlan` / :class:`FaultRule` — named injection points
    with per-point probability / latency / error schedules, decided by
    a pure function of ``(seed, rule, point, hit index)`` so storms
    replay bit-identically.
  * ``REPRO_FAULTS=plan.json`` env (inherited by worker subprocesses)
    or programmatic :func:`install` / :func:`uninstall`.
  * Zero overhead when disarmed — :func:`check`/:func:`hit` are a
    single global load, the same no-op discipline as ``REPRO_OBS=0``.
  * Every firing: ``repro_faults_injected_total`` + a
    ``faults.injected`` span + per-point tallies in :func:`stats`.

See ``examples/RESILIENCE.md`` and ``benchmarks/chaos_drill.py``.
"""

from .inject import (
    Fault, FaultInjected, active, check, hit, install, installed, reset,
    stats, uninstall,
)
from .plan import KINDS, POINTS, FaultPlan, FaultRule

__all__ = [
    "Fault", "FaultInjected", "FaultPlan", "FaultRule", "KINDS",
    "POINTS", "active", "check", "hit", "install", "installed", "reset",
    "stats", "uninstall",
]
