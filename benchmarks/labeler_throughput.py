"""Labeling-engine throughput benchmark -> BENCH_labeler.json.

Ground-truth labeling (XLA synthesis + behavioral simulation) is the
hot path of every DSE campaign.  This benchmark measures labels/sec of
three engine configurations on the same random populations:

  * ``per_genome_thread`` — the SEED engine as the baseline: one
    ground-truth call per genome fanned out to 2 worker threads, with
    the original deployment trace (dead behavioral tables embedded,
    outlined per-slot pjits) and default XLA codegen.  Threads buy
    nothing: the sim is GIL-bound and XLA tracing holds the GIL, so
    this backend can never use more than ~1 core.
  * ``batched_thread``   — the batched engine in-process: ONE
    ground-truth call for the population (vectorized ``qor_batch`` LUT
    simulation, lean inlined deployment trace, guarded label-invariant
    fast codegen).
  * ``batched_process``  — the batched engine fanned out in chunks to a
    warm spawn-safe process pool (``repro.service.workers``), the only
    backend whose throughput scales with real cores.

Labels (and the Pareto fronts induced by them) must be byte-identical
across all three — the engines differ in speed only.

Methodology: backends are measured INTERLEAVED over several rounds
(fresh genomes per round, so no synthesis-cache hits) and the median
per-label wall is reported — shared hosts drift by +-40% between runs.
Aggregate CPU-seconds per label (parent + workers, /proc-based) and a
measured machine parallelism ceiling are recorded alongside, so the
wall-clock ratios can be read against what the host actually provides:
on a full 2-core machine the process backend's projected throughput is
``n_cores / cpu_s_per_label``.

Run:  PYTHONPATH=src python benchmarks/labeler_throughput.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from common import emit, section  # noqa: E402

WORKERS = 2
DET_KEYS = ("qor", "latency", "energy", "flops", "hbm_bytes")


# --------------------------------------------------------------------------
# cpu accounting: parent + live worker processes (RUSAGE_CHILDREN only
# counts reaped children, so read /proc/<pid>/stat directly)
def _proc_cpu_s(pid: int) -> float:
    try:
        with open(f"/proc/{pid}/stat") as f:
            parts = f.read().rsplit(") ", 1)[1].split()
        utime, stime = int(parts[11]), int(parts[12])
        return (utime + stime) / os.sysconf("SC_CLK_TCK")
    except Exception:  # noqa: BLE001 - non-linux or reaped pid
        return 0.0


def _cpu_snapshot(worker_pids) -> float:
    return _proc_cpu_s(os.getpid()) + sum(_proc_cpu_s(p) for p in worker_pids)


def _parallel_ceiling() -> float:
    """Measured aggregate speedup of 2 CPU-bound processes vs 1 (shared
    hosts often deliver far less than os.cpu_count() cores)."""
    import multiprocessing as mp
    from concurrent.futures import ProcessPoolExecutor

    n = 8_000_000
    t0 = time.perf_counter()
    _burn(n)
    t1 = time.perf_counter() - t0
    with ProcessPoolExecutor(2, mp_context=mp.get_context("spawn")) as pool:
        list(pool.map(_burn, [n // 8, n // 8]))           # spawn warmup
        t0 = time.perf_counter()
        list(pool.map(_burn, [n, n]))
        t2 = time.perf_counter() - t0
    return 2.0 * t1 / t2


def _burn(n):
    s = 0
    for i in range(n):
        s += i * i
    return s


# --------------------------------------------------------------------------
def _population(accel, library, n, seed):
    rng = np.random.default_rng(seed)
    sizes = accel.gene_sizes(library)
    return rng.integers(0, sizes[None, :], size=(n, len(sizes)))


def _front(labels):
    from repro.core.dse import _objective_matrix
    from repro.core.pareto import non_dominated_mask

    obj = _objective_matrix(labels, ("qor", "energy"))
    return obj[non_dominated_mask(obj)]


def _fresh_ctx(name, n_qor):
    from repro.core.acl.library import default_library
    from repro.service import EvalContext, make_accelerator

    return EvalContext(
        make_accelerator(name), default_library(), n_qor_samples=n_qor
    )


def bench_per_genome_thread(name, genomes, n_qor):
    """Seed-engine baseline: per-genome ground truth on thread workers.
    Structural compile keying and the shared compile cache are disabled
    (and the engine reset) so the baseline pays exactly what the seed
    engine paid — without this, the new engine's process-wide cache
    would answer for compiles another backend already did."""
    import repro.core.features.synth as synth
    import repro.kernels.approx_matmul.ops as ops

    ctx = _fresh_ctx(name, n_qor)
    ops.LEGACY_EMBED_TABLES, fast = True, synth.FAST_CODEGEN
    struct = synth.STRUCTURAL_KEYS
    synth.FAST_CODEGEN = False
    synth.STRUCTURAL_KEYS = False
    synth.reset_fast_codegen()
    try:
        with ThreadPoolExecutor(WORKERS) as pool:
            t0 = time.perf_counter()
            outs = list(pool.map(lambda g: ctx.ground_truth(g[None]), genomes))
            wall = time.perf_counter() - t0
    finally:
        ops.LEGACY_EMBED_TABLES = False
        synth.FAST_CODEGEN = fast
        synth.STRUCTURAL_KEYS = struct
    labels = {k: np.concatenate([o[k] for o in outs]) for k in DET_KEYS}
    return labels, wall


def bench_batched_thread(name, genomes, n_qor):
    """Batched engine, in-process: one ground-truth call for the batch
    (cold shared compile cache — backends must not feed each other)."""
    import repro.core.features.synth as synth

    synth.reset_fast_codegen()
    ctx = _fresh_ctx(name, n_qor)
    t0 = time.perf_counter()
    labels = ctx.ground_truth(genomes)
    return labels, time.perf_counter() - t0


def bench_batched_process(name, genomes, n_qor, pool):
    """Batched engine on the warm process pool (chunked fan-out)."""
    ctx = _fresh_ctx(name, n_qor)
    assert pool.can_label(ctx), f"{name} should be process-safe"
    t0 = time.perf_counter()
    labels = pool.label(ctx, genomes)
    return labels, time.perf_counter() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny population, one round (CI: exercise all "
                         "three backends, don't trust the ratios)")
    ap.add_argument("-n", type=int, default=None,
                    help="population size per round")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_labeler.json"))
    args = ap.parse_args()

    from repro.core.acl.library import default_library
    from repro.service.workers import ProcessPoolLabeler, warm_library

    G = args.n or (4 if args.smoke else 8)
    rounds = args.rounds or (1 if args.smoke else 3)
    n_qor = 2 if args.smoke else 4
    library = default_library()
    # steady-state measurement for EVERY backend: per-circuit caches
    # (tables, error SVDs) are warm, as in a long-lived service
    warm_library(library)

    section("machine parallelism probe")
    ceiling = _parallel_ceiling()
    emit("labeler.parallel_ceiling", 0.0, f"{ceiling:.2f}x")

    section(f"warming process pool ({WORKERS} spawn workers)")
    pool = ProcessPoolLabeler(WORKERS)
    t0 = time.perf_counter()
    for name in ("gaussian3x3", "smoothed_dct"):
        wctx = _fresh_ctx(name, n_qor)
        pool.label(wctx, _population(wctx.accel, library, 2 * WORKERS,
                                     seed=777))
    emit("labeler.pool_warmup", (time.perf_counter() - t0) * 1e6, WORKERS)
    worker_pids = list(getattr(pool._pool, "_processes", {}) or [])

    backends = ("per_genome_thread", "batched_thread", "batched_process")
    report = {
        "population": G, "rounds": rounds, "n_qor_samples": n_qor,
        "workers": WORKERS, "smoke": bool(args.smoke),
        "machine": {"os_cpu_count": os.cpu_count(),
                    "measured_parallel_ceiling_x": ceiling},
        "workloads": {},
    }
    for name in ("gaussian3x3", "smoothed_dct"):
        section(f"{name}: {rounds} rounds x {G} genomes x 3 backends")
        ctx0 = _fresh_ctx(name, n_qor)
        walls = {b: [] for b in backends}
        cpus = {b: [] for b in backends}
        identical = front_identical = True
        front_size = 0
        for rnd in range(rounds):
            genomes = _population(ctx0.accel, library, G, seed=rnd)
            labels = {}
            for backend, fn in (
                ("per_genome_thread",
                 lambda: bench_per_genome_thread(name, genomes, n_qor)),
                ("batched_thread",
                 lambda: bench_batched_thread(name, genomes, n_qor)),
                ("batched_process",
                 lambda: bench_batched_process(name, genomes, n_qor, pool)),
            ):
                c0 = _cpu_snapshot(worker_pids)
                lab, wall = fn()
                cpus[backend].append((_cpu_snapshot(worker_pids) - c0) / G)
                walls[backend].append(wall / G)
                labels[backend] = {k: np.asarray(lab[k]) for k in DET_KEYS}
            base = labels["per_genome_thread"]
            identical &= all(
                np.array_equal(base[k], labels[b][k])
                for b in backends[1:] for k in DET_KEYS
            )
            fronts = {b: _front(labels[b]) for b in backends}
            front_identical &= all(
                np.array_equal(fronts[backends[0]], fronts[b])
                for b in backends[1:]
            )
            front_size = int(len(fronts[backends[0]]))

        results = {}
        for b in backends:
            wall = float(np.median(walls[b]))
            results[b] = {
                "s_per_label": wall,
                "labels_per_sec": 1.0 / wall,
                "cpu_s_per_label": float(np.median(cpus[b])),
            }
            emit(f"labeler.{name}.{b}", wall * 1e6,
                 f"{1.0 / wall:.2f}/s")
        speedups = {
            b: (results[b]["labels_per_sec"]
                / results["per_genome_thread"]["labels_per_sec"])
            for b in backends[1:]
        }
        # the process backend parallelizes across real cores; the seed
        # per-genome thread backend cannot (GIL).  Project both onto a
        # machine that actually provides WORKERS cores:
        proj = {
            "per_genome_thread":
                1.0 / results["per_genome_thread"]["cpu_s_per_label"],
            "batched_process":
                WORKERS / results["batched_process"]["cpu_s_per_label"],
        }
        proj["speedup"] = proj["batched_process"] / proj["per_genome_thread"]
        emit(f"labeler.{name}.process_speedup", 0.0,
             f"{speedups['batched_process']:.2f}x")
        emit(f"labeler.{name}.process_speedup_projected_{WORKERS}core", 0.0,
             f"{proj['speedup']:.2f}x")
        report["workloads"][name] = {
            "backends": results,
            "speedup_vs_per_genome_thread": speedups,
            "projected_full_parallel": proj,
            "labels_identical": bool(identical),
            "front_identical": bool(front_identical),
            "front_size": front_size,
        }
        assert identical, f"{name}: backend labels diverged"
        assert front_identical, f"{name}: backend fronts diverged"

    pool.shutdown()
    wl = report["workloads"]["smoothed_dct"]
    measured = wl["speedup_vs_per_genome_thread"]["batched_process"]
    projected = wl["projected_full_parallel"]["speedup"]
    if not args.smoke and measured < 3.0 and projected < 3.0:
        print(f"WARNING: smoothed_dct batched-process speedup "
              f"{measured:.2f}x measured / {projected:.2f}x projected < 3x",
              file=sys.stderr)

    out_path = os.path.abspath(args.out)
    if args.smoke:
        print(f"smoke mode: not writing {out_path}", file=sys.stderr)
        return
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
