"""Process-pool ground-truth labeler.

Behavioral simulation is numpy (GIL-bound) and XLA synthesis holds the
GIL through tracing — thread workers give ZERO labeling parallelism (the
scheduler's thread pool only overlaps I/O).  This module fans whole
coalesced label batches out to a pool of **spawned worker processes**,
each of which initializes once (library + exhaustive product tables
warmed at startup, accelerators and evaluation contexts cached per
fingerprint) and then labels genome chunks with the same batched
``EvalContext.ground_truth`` path the thread backend uses.

Labels are a pure function of the evaluation context fingerprint and the
genome, so process-backend labels are byte-identical to thread-backend
labels (tests pin this).

Nothing heavyweight is pickled: workers rebuild the accelerator from its
NAME via ``make_accelerator`` and the default library from scratch.  A
context is process-safe exactly when a fresh process would derive the
SAME context fingerprint from the name — ``can_label`` checks that in
the parent (resolving the name with the registry bypassed, since
``register_accelerator`` entries don't exist in a spawned child) and the
scheduler falls back to in-process labeling when it fails (ad-hoc
registered pipelines, subset libraries, parameterized accelerators).
"""

from __future__ import annotations

import math
import multiprocessing as mp
import os
import threading
import time
from concurrent.futures import ProcessPoolExecutor
from typing import Dict, Optional

import numpy as np

from .. import obs
from .store import LABEL_KEYS, EvalContext

__all__ = ["ProcessPoolLabeler", "WORKER_XLA_FLAGS", "warm_library"]

# Appended to XLA_FLAGS in each worker BEFORE jax loads: one compile's
# parallel LLVM codegen would fight the other workers for cores, so each
# worker compiles single-threaded and the pool supplies the parallelism.
# Codegen splitting only parallelizes backend code emission — HLO-level
# cost analysis (the labels) is unaffected.
WORKER_XLA_FLAGS = "--xla_cpu_parallel_codegen_split_count=1"

# per-worker-process state: the warm library and the contexts built so far
_WORKER_STATE: Dict = {}


def warm_library(lib) -> None:
    """Build every multiplier circuit's labeling-side caches: the
    exhaustive product table (the batched sim's LUT source), the error
    table, its effective rank and the deployment-rank SVD factors.  A
    cold labeler pays these lazily INSIDE its first batches (one
    256x256 SVD per circuit); warming them once up front keeps them out
    of the steady-state label stream."""
    for kind in ("mul8u", "mul8s"):
        for c in lib.kind(kind):
            c.table
            c.etab
            r = c.deploy_rank
            if r > 0:
                c.factors(r)


def _init_worker(xla_flags: str = "", synth_cache_path: str = "") -> None:
    """Run once per spawned process: pin down XLA's threading before jax
    is imported, then build the library and warm the per-circuit
    labeling caches so the first labeled chunk doesn't pay them.  With a
    ``synth_cache_path`` the worker joins the pool-wide persistent
    compile cache (one JSONL file appended by every worker AND the
    parent), so no structure ever compiles twice across the pool."""
    if xla_flags:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + " " + xla_flags
        ).strip()
    from ..core.acl.library import default_library
    from ..core.features import synth

    if synth_cache_path:
        # non-migrating open: the parent already owns (and may have
        # migrated) this path; replicas must never rename it
        synth.set_shared_synth_cache(
            synth.open_synth_cache(synth_cache_path))
    lib = default_library()
    warm_library(lib)
    # pre-build (and probe-verify) the fused sim engine's adder twins so
    # the first labeled chunk only pays its own shape's XLA compile —
    # structurally identical contexts then land in warm jit buckets
    from ..accel import fused

    fused.warm(lib)
    _WORKER_STATE["library"] = lib
    _WORKER_STATE["ctxs"] = {}


def _worker_label(
    accel_name: str,
    rank_genes: bool,
    n_qor_samples: int,
    qor_seed: int,
    expected_fp: str,
    genomes: np.ndarray,
    wire: Optional[Dict] = None,
) -> Dict[str, np.ndarray]:
    """Label one genome chunk inside a worker process."""
    if "library" not in _WORKER_STATE:  # fork-start or initializer skipped
        _init_worker()
    from ..core.features import synth
    from .campaigns import make_accelerator

    key = (accel_name, bool(rank_genes), int(n_qor_samples), int(qor_seed))
    ctx = _WORKER_STATE["ctxs"].get(key)
    if ctx is None:
        ctx = EvalContext(
            make_accelerator(accel_name, builtin_only=True),
            _WORKER_STATE["library"],
            rank_genes=rank_genes,
            n_qor_samples=n_qor_samples,
            qor_seed=qor_seed,
        )
        _WORKER_STATE["ctxs"][key] = ctx
    if ctx.fingerprint != expected_fp:
        # the parent's safety check should make this unreachable; guard
        # anyway so a drifted worker can never poison the store
        raise RuntimeError(
            f"worker context fingerprint {ctx.fingerprint} != parent "
            f"{expected_fp} for {accel_name!r}"
        )
    scache = synth.shared_synth_cache()
    if hasattr(scache, "refresh"):
        # pick up compiles that sibling workers / the parent appended
        scache.refresh()
    # adopt the parent's trace context so this chunk's spans (and the
    # synth.compile spans under it) link to the submitting campaign;
    # the worker handles one chunk at a time, so the ring holds exactly
    # this chunk's spans between clear() and snapshot()
    rec = obs.recorder()
    rec.clear()
    with obs.attach(wire, worker=f"pool-{os.getpid()}"):
        with obs.span("labeler.chunk", n=int(len(genomes)),
                      accel=accel_name):
            labels = ctx.ground_truth(np.asarray(genomes, dtype=np.int64))
    out = {k: np.asarray(labels[k]) for k in LABEL_KEYS}
    # piggyback this worker's cumulative synth counters AND the chunk's
    # finished spans on the result so the parent can aggregate/ingest
    # them without an extra round trip
    out["_synth_stats"] = {"pid": os.getpid(), **scache.stats()}
    from ..accel import fused

    out["_sim_stats"] = {"pid": os.getpid(), **fused.stats()}
    out["_spans"] = rec.snapshot()
    rec.clear()
    return out


class ProcessPoolLabeler:
    """Chunked batch fan-out to spawn-safe worker processes.

    ``label`` splits a genome batch into ~``2 x n_workers`` chunks (or
    fixed ``chunk_size`` rows) and reassembles the per-chunk label dicts
    in order.  ``can_label`` gates which contexts may cross the process
    boundary; callers fall back to in-process labeling otherwise."""

    def __init__(
        self,
        n_workers: int = 2,
        *,
        chunk_size: Optional[int] = None,
        mp_context: str = "spawn",
        xla_flags: str = WORKER_XLA_FLAGS,
        synth_cache_path: Optional[str] = None,
    ):
        self.n_workers = max(1, int(n_workers))
        self.chunk_size = None if chunk_size is None else max(1, int(chunk_size))
        self.synth_cache_path = synth_cache_path
        self._pool = ProcessPoolExecutor(
            self.n_workers,
            mp_context=mp.get_context(mp_context),
            initializer=_init_worker,
            initargs=(xla_flags, synth_cache_path or ""),
        )
        self._lock = threading.Lock()
        self._safe_fps: Dict[str, bool] = {}   # ctx fingerprint -> verdict
        self._worker_synth: Dict[int, Dict] = {}  # pid -> latest counters
        self._worker_sim: Dict[int, Dict] = {}    # pid -> latest fused-sim counters
        self.n_chunks = obs.REGISTRY.counter(
            "repro_labeler_chunks_total", "chunks sent to worker processes")
        self.n_labeled = obs.REGISTRY.counter(
            "repro_labeler_labeled_total",
            "genomes labeled by the process pool")
        self.batch_seconds = obs.REGISTRY.histogram(
            "repro_labeler_batch_seconds",
            "wall seconds per process-pool batch fan-out")

    # ------------------------------------------------------------------
    def can_label(self, ctx: EvalContext) -> bool:
        """True iff a fresh process, given only ``ctx.accel.name``, would
        rebuild a context with the SAME fingerprint (identical labels and
        store keys).  Cached per fingerprint.  The check itself is the
        fleet's portability gate — one rule decides what may cross a
        process OR host boundary."""
        fp = ctx.fingerprint
        with self._lock:
            if fp in self._safe_fps:
                return self._safe_fps[fp]
        from ..fleet.protocol import context_is_portable

        verdict = context_is_portable(ctx)
        with self._lock:
            self._safe_fps[fp] = verdict
        return verdict

    def _chunks(self, n: int) -> int:
        if self.chunk_size is not None:
            return max(1, math.ceil(n / self.chunk_size))
        # ~2 chunks per worker: keeps the pool busy when chunk costs are
        # uneven without shredding the batched-sim vectorization
        return min(n, 2 * self.n_workers)

    def label(self, ctx: EvalContext, genomes: np.ndarray) -> Dict[str, np.ndarray]:
        """Label a genome batch across the pool (caller must have
        checked ``can_label``)."""
        genomes = np.atleast_2d(np.asarray(genomes, dtype=np.int64))
        parts = [
            c for c in np.array_split(genomes, self._chunks(len(genomes)))
            if len(c)
        ]
        t0 = time.perf_counter()
        with obs.span("labeler.batch", n=int(len(genomes)),
                      chunks=len(parts)):
            wire = obs.wire_context()
            futures = [
                self._pool.submit(
                    _worker_label,
                    ctx.accel.name, ctx.rank_genes, ctx.n_qor_samples,
                    ctx.qor_seed, ctx.fingerprint, chunk, wire,
                )
                for chunk in parts
            ]
            results = [f.result() for f in futures]
        self.batch_seconds.observe(time.perf_counter() - t0)
        self.n_chunks.inc(len(parts))
        self.n_labeled.inc(len(genomes))
        rec = obs.recorder()
        with self._lock:
            for r in results:
                ws = r.get("_synth_stats")
                if ws:   # counters are cumulative: latest-per-pid wins
                    self._worker_synth[ws["pid"]] = ws
                sim = r.get("_sim_stats")
                if sim:
                    self._worker_sim[sim["pid"]] = sim
        for r in results:
            rec.ingest(r.get("_spans") or ())
        return {
            k: np.concatenate([r[k] for r in results]) for k in LABEL_KEYS
        }

    def stats(self) -> Dict[str, int]:
        """Pool counters + the aggregated synthesis-engine counters of
        every worker process (compiles, identity/structural cache hits,
        verification compiles, pinned families)."""
        with self._lock:
            per_worker = list(self._worker_synth.values())
        synth_agg = {k: sum(int(w.get(k, 0)) for w in per_worker)
                     for k in ("compiles", "verify_compiles",
                               "identity_hits", "structural_hits",
                               "pinned_families")}
        # cache sizes are shared state when the pool rides one cache
        # file: report the widest view, not the (double-counting) sum
        for k in ("entries", "structures"):
            synth_agg[k] = max((int(w.get(k, 0)) for w in per_worker),
                               default=0)
        served = synth_agg["identity_hits"] + synth_agg["structural_hits"]
        total = served + synth_agg["compiles"]
        synth_agg["hit_rate"] = (served / total) if total else 0.0
        synth_agg["workers_reporting"] = len(per_worker)
        with self._lock:
            per_worker_sim = list(self._worker_sim.values())
        sim_agg = {k: sum(int(w.get(k, 0)) for w in per_worker_sim)
                   for k in ("fused_calls", "fused_qor_calls", "compiles",
                             "bucket_hits", "verify_calls", "pins",
                             "fallback_calls")}
        sim_agg["workers_reporting"] = len(per_worker_sim)
        return {
            "workers": self.n_workers,
            "chunks": int(self.n_chunks.value),
            "labeled": int(self.n_labeled.value),
            "synth_cache_path": self.synth_cache_path,
            "synth": synth_agg,
            "sim": sim_agg,
        }

    def shutdown(self, *, wait: bool = True) -> None:
        self._pool.shutdown(wait=wait)
