"""Training driver — runnable end-to-end on CPU at reduced scale, and the
same code path the dry-run lowers at production scale.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
        --reduced --steps 50 --batch 8 --seq 64

Features: deterministic data pipeline, AdamW, microbatch accumulation,
periodic checkpointing + restart-from-latest (fault tolerance), optional
int8 error-feedback gradient compression, optional approximation policy
(the paper's technique applied to the LM).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import ckpt
from ..configs import get_config
from ..data.pipeline import TokenPipeline
from ..models import ApproxPolicy, reduced
from ..models.common import init_tree
from ..models.transformer import param_specs
from ..optim.adamw import AdamW
from ..train.step import init_state, make_train_step

__all__ = ["train_loop", "main"]


def train_loop(
    cfg,
    *,
    steps: int = 50,
    batch: int = 8,
    seq: int = 64,
    n_micro: int = 1,
    lr: float = 1e-3,
    ckpt_dir: str | None = None,
    ckpt_every: int = 25,
    compress: bool = False,
    policy: ApproxPolicy | None = None,
    seed: int = 0,
    log_every: int = 10,
    attn_chunk: int = 64,
    scan_chunk: int = 16,
):
    pipe = TokenPipeline(cfg.vocab_size, batch, seq, seed=seed)
    opt = AdamW(lr=lr, warmup_steps=max(steps // 10, 1),
                moment_dtype=cfg.moment_dtype)
    step_fn = jax.jit(make_train_step(
        cfg, opt, n_micro=n_micro, policy=policy, compress=compress,
        attn_chunk=attn_chunk, scan_chunk=scan_chunk,
    ), donate_argnums=(0,))

    start = 0
    state = None
    if ckpt_dir is not None:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            like = init_state(
                init_tree(param_specs(cfg), jax.random.PRNGKey(seed)), opt,
                compress=compress,
            )
            state = ckpt.restore(ckpt_dir, latest, like)
            start = latest
            print(f"[train] restored checkpoint @ step {latest}")
    if state is None:
        params = init_tree(param_specs(cfg), jax.random.PRNGKey(seed))
        state = init_state(params, opt, compress=compress)

    losses = []
    t0 = time.perf_counter()
    for step in range(start, steps):
        b = pipe.batch_at(step)
        batch_dev = {
            "tokens": jnp.asarray(b["tokens"]),
            "labels": jnp.asarray(b["labels"]),
        }
        if cfg.is_encoder_decoder:
            batch_dev["enc_embeds"] = jax.random.normal(
                jax.random.PRNGKey(step), (batch, seq, cfg.d_model),
                jnp.float32) * 0.1
        if cfg.frontend == "vision":
            batch_dev["embeds"] = jax.random.normal(
                jax.random.PRNGKey(step),
                (batch, cfg.frontend_len, cfg.d_model), jnp.float32) * 0.1
        state, metrics = step_fn(state, batch_dev)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            dt = time.perf_counter() - t0
            print(f"[train] step {step:5d} loss={loss:8.4f} "
                  f"ce={float(metrics['ce']):8.4f} "
                  f"gnorm={float(metrics['grad_norm']):7.3f} ({dt:5.1f}s)",
                  flush=True)
        if ckpt_dir is not None and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, state)
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--approx", default=None,
                    help="apply a circuit to ffn projections, e.g. mul8s_trunc2")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    policy = None
    if args.approx:
        policy = ApproxPolicy({
            "ffn_in": (args.approx, None), "ffn_out": (args.approx, None),
        })
    _, losses = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        n_micro=args.n_micro, lr=args.lr, ckpt_dir=args.ckpt_dir,
        compress=args.compress, policy=policy,
    )
    print(f"[train] first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
