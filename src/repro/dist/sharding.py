"""Logical-axis sharding rules (GSPMD "logical axis annotation" idiom).

Model code names array dimensions *logically* — ``("batch", "seq",
"act_embed")`` — and never mentions mesh axes.  This module owns the
mapping from logical names to mesh axes:

  * ``DEFAULT_RULES`` — the global defaults (FSDP weights over "data",
    tensor-parallel weights/activations over "model", batch over
    ("pod", "data"), decode KV sequence over "model"),
  * ``rule_overrides`` — a (thread-local, re-entrant) context manager
    that layers per-cell / per-arch overrides on top; ``active_rules()``
    returns the currently layered overrides,
  * ``spec_for`` — rule resolution to a ``PartitionSpec`` with the two
    safety properties every caller relies on: an axis is never used for
    two dimensions of one array, and a dimension that is not divisible
    by its shard count falls back toward replication (tuple rules apply
    the longest divisible *prefix*),
  * ``sharding_for`` — ``NamedSharding`` built from ``spec_for``,
  * ``constrain`` — ``with_sharding_constraint`` against the ambient
    mesh (a no-op outside any mesh context: single-device tests and the
    behavioral simulators never pay for it),
  * ``constrain_cotangent`` — identity forward, constrains the
    *cotangent* in the backward pass (weight-gradient sharding inside
    scanned/remat'd blocks, where the fwd constraint alone does not
    reach the grads).
"""

from __future__ import annotations

import functools
import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

AxisSpec = Union[None, str, Tuple[str, ...]]
AxisRules = Dict[str, AxisSpec]

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "active_rules",
    "rule_overrides",
    "spec_for",
    "sharding_for",
    "constrain",
    "constrain_cotangent",
]

# Logical-name -> mesh-axis defaults.  Weight axes: FSDP on "data",
# tensor parallel on "model".  Activation ("act_*") axes mirror their
# weight counterparts; "batch" spreads over every data-parallel axis.
DEFAULT_RULES: AxisRules = {
    # weight axes
    "embed": "data",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert_mlp": "model",
    "experts": "data",
    "norm": None,
    "state": None,
    "conv": None,
    "dt": None,
    # activation axes
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": "model",
    "act_embed": None,
    "act_mlp": "model",
    "act_heads": "model",
    "act_experts": "data",
}


_local = threading.local()


def _stack() -> list:
    if not hasattr(_local, "stack"):
        _local.stack = []
    return _local.stack


def active_rules() -> AxisRules:
    """The merged override layers currently in effect (NOT including
    DEFAULT_RULES — resolution merges defaults underneath)."""
    merged: AxisRules = {}
    for layer in _stack():
        merged.update(layer)
    return merged


@contextmanager
def rule_overrides(rules: Optional[AxisRules]):
    """Layer ``rules`` over the active overrides for the duration of the
    context.  Later layers win; a value of ``None`` un-shards the axis."""
    _stack().append(dict(rules or {}))
    try:
        yield
    finally:
        _stack().pop()


def _mesh_shape(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def spec_for(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh,
    rules: Optional[AxisRules] = None,
):
    """Resolve logical axis names to a PartitionSpec on ``mesh``.

    Guarantees: (a) each mesh axis is used at most once per array,
    (b) a dimension keeps only the longest prefix of its rule's axes
    whose cumulative shard count divides the dimension (single-axis
    rules therefore fall back to replication when non-divisible)."""
    from jax.sharding import PartitionSpec

    merged: AxisRules = {**DEFAULT_RULES, **active_rules(), **(rules or {})}
    sizes = _mesh_shape(mesh)
    used: set = set()
    entries = []
    for name, dim in zip(logical, shape):
        rule = merged.get(name) if name is not None else None
        if rule is None:
            entries.append(None)
            continue
        axes = (rule,) if isinstance(rule, str) else tuple(rule)
        picked = []
        shards = 1
        for a in axes:
            n = int(sizes.get(a, 1))
            if a in used or n <= 1 or dim % (shards * n) != 0:
                break
            picked.append(a)
            shards *= n
        used.update(picked)
        if not picked:
            entries.append(None)
        elif isinstance(rule, str):
            entries.append(picked[0])
        else:
            entries.append(tuple(picked))
    return PartitionSpec(*entries)


def sharding_for(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh,
    rules: Optional[AxisRules] = None,
):
    from jax.sharding import NamedSharding

    return NamedSharding(mesh, spec_for(logical, shape, mesh, rules))


def _ambient_mesh():
    """The physical mesh of the enclosing mesh context, or None.

    Works with the legacy ``with mesh:`` context (jax <= 0.4.x, what
    ``dist.compat.mesh_context`` uses there) and with ``jax.set_mesh``
    on newer jax."""
    import jax

    try:  # newer jax: ambient (possibly abstract) mesh from set_mesh
        m = jax.sharding.get_abstract_mesh()  # type: ignore[attr-defined]
        if m is not None and not m.empty:
            return m
    except AttributeError:
        pass
    try:
        m = jax.interpreters.pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except AttributeError:
        pass
    return None


def constrain(x, logical: Sequence[Optional[str]]):
    """``with_sharding_constraint(x, <resolved spec>)`` against the
    ambient mesh; identity when no mesh context is active."""
    import jax

    mesh = _ambient_mesh()
    if mesh is None:
        return x
    spec = spec_for(logical, x.shape, mesh)
    try:
        from jax.sharding import NamedSharding

        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except (TypeError, ValueError):
        # abstract mesh (set_mesh) path: bare PartitionSpec is accepted
        return jax.lax.with_sharding_constraint(x, spec)


@functools.lru_cache(maxsize=1)
def _build_cc():
    import jax

    @functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
    def cc(logical, x):
        return x

    def fwd(logical, x):
        return x, None

    def bwd(logical, _res, g):
        return (constrain(g, logical),)

    cc.defvjp(fwd, bwd)
    return cc


def constrain_cotangent(x, logical: Sequence[Optional[str]]):
    """Identity on the forward value; applies ``constrain`` to the
    cotangent on the backward pass.  Used inside scanned transformer
    blocks so per-layer weight *gradients* land sharded."""
    return _build_cc()(tuple(logical), x)
