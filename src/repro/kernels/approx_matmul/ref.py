"""Pure-jnp oracles for approximate integer matmul.

Two reference semantics:

1. ``lut_matmul`` — the *behavioral* oracle: every scalar product is an
   exhaustive (256x256) product-table lookup, accumulation is exact.  This
   is the TPU analogue of the paper's "DSP blocks disabled" mapping: all
   arithmetic realized in malleable logic (here: gathers), no MXU.  It is
   bit-exact w.r.t. the numpy behavioral circuit models.

2. ``rank_k_matmul`` — the *deployment* oracle: the DESIGN.md §2
   factorization  approx(A@B) = A@B + sum_r U_r[A] @ V_r[B],  i.e. (k+1)
   exact matmuls plus 256-entry elementwise lookups.  At full rank this
   reconstructs the behavioral table exactly (up to f32 rounding of the
   SVD factors); at the DSE-selected rank it matches to the truncated
   error energy.

Index convention: unsigned circuits index the table with the raw 8-bit
value; signed circuits with value+128 (see acl.tables.AXIS_S8).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["lut_matmul", "rank_k_matmul", "to_index"]


def to_index(x: jnp.ndarray, signed: bool) -> jnp.ndarray:
    """Map int8/uint8-valued ints to table row/col indices."""
    x = x.astype(jnp.int32)
    return x + 128 if signed else x


def lut_matmul(
    x: jnp.ndarray,       # (m, k) int values in the 8-bit domain
    w: jnp.ndarray,       # (k, n) int values in the 8-bit domain
    table: jnp.ndarray,   # (256, 256) int32 product table
    *,
    signed: bool = False,
) -> jnp.ndarray:
    """Behavioral approximate matmul: out[i,j] = sum_k T[x[i,k], w[k,j]].

    O(m*k*n) gathers — the bit-exact oracle, not a performance path.
    """
    xi = to_index(x, signed)      # (m, k)
    wi = to_index(w, signed)      # (k, n)
    flat = table.reshape(-1)      # (65536,)
    idx = xi[:, :, None] * 256 + wi[None, :, :]  # (m, k, n)
    prods = jnp.take(flat, idx, axis=0)
    # int32 accumulation: |product| <= 65025, safe for k up to ~3.3e4.
    return prods.sum(axis=1, dtype=jnp.int32)


def rank_k_matmul(
    x: jnp.ndarray,   # (m, k) int values
    w: jnp.ndarray,   # (k, n) int values
    u: jnp.ndarray,   # (256, r) f32 error row-factors
    v: jnp.ndarray,   # (256, r) f32 error col-factors
    *,
    signed: bool = False,
    compute_dtype=jnp.float32,
) -> jnp.ndarray:
    """Deployment-form approximate matmul (r+1 MXU matmuls).

    out = x @ w + sum_r u_r[x] @ v_r[w], computed in `compute_dtype`.
    """
    xi = to_index(x, signed)
    wi = to_index(w, signed)
    xf = x.astype(compute_dtype)
    wf = w.astype(compute_dtype)
    out = xf @ wf
    if u.shape[1]:
        ux = jnp.take(u.astype(compute_dtype), xi, axis=0)   # (m, k, r)
        vw = jnp.take(v.astype(compute_dtype), wi, axis=0)   # (k, n, r)
        # sum_r (m,k)@(k,n) — batch the rank dim through one einsum
        out = out + jnp.einsum("mkr,knr->mn", ux, vw)
    return out
