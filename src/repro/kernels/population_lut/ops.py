"""Dispatch layer for the population LUT gather.

``gather_xla`` is the traceable building block the fused engine inlines
into its per-accelerator XLA programs (CPU and TPU alike — on CPU a
Pallas interpret round-trip would cost more than the gather saves);
``population_lut_gather`` is the standalone op with backend selection,
mirroring ``approx_matmul.ops``: real Pallas kernel on TPU, interpret
mode for validation, numpy reference otherwise.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import population_lut_gather_pallas
from .ref import population_lut_gather_ref

__all__ = ["gather_xla", "population_lut_gather", "on_tpu"]


def on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def gather_xla(
    flat_lut: jnp.ndarray,   # (C*S*256,) flattened (C, S, 256) stack
    genes: jnp.ndarray,      # (G, S) int32
    cols: jnp.ndarray,       # (M, S) or (G, M, S) int32 table indices
    *,
    nslots: int,
    per_genome: bool = False,
) -> jnp.ndarray:
    """Traceable ``out[g, m, s] = lut[genes[g, s], s, cols[.., m, s]]``
    as one flat XLA gather; fuses into the surrounding jit."""
    sidx = jnp.arange(nslots, dtype=jnp.int32)[None, None, :]
    base = (genes[:, None, :] * nslots + sidx) * 256
    idx = base + (cols if per_genome else cols[None])
    return jnp.take(flat_lut, idx.reshape(-1), axis=0).reshape(idx.shape)


def population_lut_gather(
    lut: np.ndarray,
    genes: np.ndarray,
    cols: np.ndarray,
    *,
    per_genome: bool = False,
    backend: Optional[str] = None,
) -> np.ndarray:
    """(G, M, S) gathered products; ``backend``: "pallas",
    "pallas_interpret", "xla", "ref" or None (auto: pallas on TPU, xla
    elsewhere)."""
    if backend is None:
        backend = "pallas" if on_tpu() else "xla"
    if backend == "ref":
        return population_lut_gather_ref(lut, genes, cols, per_genome=per_genome)
    lut32 = np.asarray(lut, dtype=np.int32)
    genes32 = np.asarray(genes, dtype=np.int32)
    cols32 = np.asarray(cols, dtype=np.int32)
    if backend in ("pallas", "pallas_interpret"):
        G, S = genes32.shape
        M = cols32.shape[-2]
        bg = _block(G, 8)
        bm = _block(M, 256)
        out = population_lut_gather_pallas(
            jnp.asarray(lut32), jnp.asarray(genes32), jnp.asarray(cols32),
            per_genome=per_genome, bg=bg, bm=bm,
            interpret=(backend == "pallas_interpret"),
        )
        return np.asarray(out)
    if backend == "xla":
        out = jax.jit(gather_xla, static_argnames=("nslots", "per_genome"))(
            jnp.asarray(lut32).reshape(-1), jnp.asarray(genes32),
            jnp.asarray(cols32), nslots=lut32.shape[1],
            per_genome=per_genome,
        )
        return np.asarray(out)
    raise ValueError(f"unknown backend {backend!r}")


def _block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= target (tile size picker)."""
    b = min(n, target)
    while n % b:
        b -= 1
    return b
