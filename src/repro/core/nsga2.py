"""NSGA-II [24] over integer genomes, as used by the paper's exploration
stage (Section II-B): population 1000, elite parent set 200, 1000
generations (with the paper's own Fig. 7 observation that ~10x fewer
generations suffice — exposed as a knob).

A genome is an integer vector; gene i takes values in [0, gene_sizes[i]).
For accelerator DSE, genes are (circuit index per slot) and optionally
(correction rank per slot).  ``evaluate`` maps a (n, g) genome batch to a
(n, m) objective batch, minimization convention.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import numpy as np

from .pareto import crowding_distance, fast_non_dominated_sort

__all__ = ["NSGA2Config", "GenerationLog", "NSGA2Result", "nsga2"]


@dataclass(frozen=True)
class NSGA2Config:
    pop_size: int = 1000          # paper: 1000 variants per generation
    n_parents: int = 200          # paper: 200 best kept as parents
    n_generations: int = 100      # paper: 1000; Fig. 7 shows ~100 suffices
    crossover_prob: float = 0.9
    mutation_prob: float = 0.05   # per gene: random reset
    seed: int = 0
    dedup: bool = True            # never re-evaluate an identical genome


@dataclass
class GenerationLog:
    generation: int
    genomes: np.ndarray      # (pop, g) the evaluated population
    objectives: np.ndarray   # (pop, m)
    n_evaluated: int         # surrogate calls so far (cumulative)


@dataclass
class NSGA2Result:
    genomes: np.ndarray        # final parent set (n_parents, g)
    objectives: np.ndarray     # (n_parents, m)
    front_mask: np.ndarray     # non-dominated mask within the parent set
    history: List[GenerationLog] = field(default_factory=list)
    n_evaluated: int = 0

    @property
    def front_genomes(self) -> np.ndarray:
        return self.genomes[self.front_mask]

    @property
    def front_objectives(self) -> np.ndarray:
        return self.objectives[self.front_mask]


def _select_parents(
    genomes: np.ndarray, obj: np.ndarray, k: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Elitist environmental selection: fill k slots front-by-front, break
    the last front by crowding distance.  Returns (genomes, obj, rank)."""
    fronts = fast_non_dominated_sort(obj)
    chosen: List[int] = []
    rank = np.zeros(len(obj), dtype=np.int64)
    for fi, front in enumerate(fronts):
        rank[front] = fi
        if len(chosen) + len(front) <= k:
            chosen.extend(front.tolist())
        else:
            cd = crowding_distance(obj[front])
            order = np.argsort(-cd, kind="stable")
            chosen.extend(front[order[: k - len(chosen)]].tolist())
            break
    idx = np.array(chosen, dtype=np.int64)
    return genomes[idx], obj[idx], rank[idx]


def _tournament(
    rng: np.random.Generator, rank: np.ndarray, cd: np.ndarray, n: int
) -> np.ndarray:
    """Binary tournament with the crowded-comparison operator."""
    a = rng.integers(0, len(rank), size=n)
    b = rng.integers(0, len(rank), size=n)
    a_wins = (rank[a] < rank[b]) | ((rank[a] == rank[b]) & (cd[a] > cd[b]))
    return np.where(a_wins, a, b)


def _offspring(
    rng: np.random.Generator,
    parents: np.ndarray,
    rank: np.ndarray,
    cd: np.ndarray,
    gene_sizes: np.ndarray,
    n: int,
    cfg: NSGA2Config,
) -> np.ndarray:
    i = _tournament(rng, rank, cd, n)
    j = _tournament(rng, rank, cd, n)
    pa, pb = parents[i], parents[j]
    # uniform crossover
    cross = rng.random((n, 1)) < cfg.crossover_prob
    take_b = rng.random(pa.shape) < 0.5
    child = np.where(cross & take_b, pb, pa)
    # per-gene random-reset mutation
    mut = rng.random(child.shape) < cfg.mutation_prob
    resets = rng.integers(0, gene_sizes[None, :], size=child.shape)
    return np.where(mut, resets, child)


def nsga2(
    gene_sizes,
    evaluate: Callable[[np.ndarray], np.ndarray],
    cfg: Optional[NSGA2Config] = None,
    *,
    init: Optional[np.ndarray] = None,
    callback: Optional[Callable[[GenerationLog], None]] = None,
    keep_history: bool = True,
) -> NSGA2Result:
    """Run NSGA-II to completion.  ``evaluate`` is called on full
    generations (vectorized surrogate evaluation is the whole point of
    the paper).

    This is now a thin drive-to-completion loop over the ask/tell
    ``strategies.NSGA2Strategy`` — interruptible callers (the campaign
    service) step the strategy themselves and snapshot between rounds."""
    from .strategies.nsga2 import NSGA2Strategy

    cfg = cfg if cfg is not None else NSGA2Config()
    strat = NSGA2Strategy(gene_sizes, cfg, init=init,
                          keep_history=keep_history or callback is not None)
    while not strat.done:
        genomes = strat.ask()
        if len(genomes):
            obj = np.asarray(evaluate(genomes), dtype=np.float64)
        else:
            # every candidate is cached: tell() rebuilds the generation
            # from its cache and never reads the (empty) objectives
            obj = np.zeros((0, 0))
        log = strat.tell(genomes, obj)
        if callback is not None and log is not None:
            callback(log)
    res = strat.result()
    if not keep_history:
        res.history = []
    return res
