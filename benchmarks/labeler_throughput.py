"""Labeling-engine throughput benchmark -> BENCH_labeler.json.

Ground-truth labeling (XLA synthesis + behavioral simulation) is the
hot path of every DSE campaign.  This benchmark measures labels/sec of
three engine configurations on the same random populations:

  * ``per_genome_thread`` — the SEED engine as the baseline: one
    ground-truth call per genome fanned out to 2 worker threads, with
    the original deployment trace (dead behavioral tables embedded,
    outlined per-slot pjits) and default XLA codegen.  Threads buy
    nothing: the sim is GIL-bound and XLA tracing holds the GIL, so
    this backend can never use more than ~1 core.
  * ``batched_thread``   — the batched engine in-process: ONE
    ground-truth call for the population (vectorized ``qor_batch`` LUT
    simulation, lean inlined deployment trace, guarded label-invariant
    fast codegen).
  * ``batched_process``  — the batched engine fanned out in chunks to a
    warm spawn-safe process pool (``repro.service.workers``), the only
    backend whose throughput scales with real cores.

Labels (and the Pareto fronts induced by them) must be byte-identical
across all three — the engines differ in speed only.

Methodology: backends are measured INTERLEAVED over several rounds
(fresh genomes per round, so no synthesis-cache hits) and the median
per-label wall is reported — shared hosts drift by +-40% between runs.
Aggregate CPU-seconds per label (parent + workers, /proc-based) and a
measured machine parallelism ceiling are recorded alongside, so the
wall-clock ratios can be read against what the host actually provides:
on a full 2-core machine the process backend's projected throughput is
``n_cores / cpu_s_per_label``.

``--obs`` measures the flight recorder's overhead guardrail instead and
writes ``BENCH_obs.json``: span-machinery cost on vs off (microbench),
then labels/sec through the real scheduler path with tracing enabled vs
disabled (sink off, interleaved + order-alternated rounds).  Target:
tracing costs <3% labels/sec — a warning, not an assert, because shared
hosts drift more than that between runs.

``--fused`` measures the steady-state labeling regime instead — warm
synthesis caches across generations, where behavioral simulation
dominates the label — comparing the numpy batched engine (fused kill
switch thrown), the fused XLA engine, and the warm process pool on the
same per-round populations.  Labels and fronts must be byte-identical
across all three, and the fused engine must add ZERO XLA recompiles
across the timed generations (population bucketing).  Results merge
into BENCH_labeler.json under the ``fused`` key.

``--fleet`` benchmarks the multi-host labeling fleet instead and writes
``BENCH_fleet.json``: labels/sec of one vs two local fleet workers on
gaussian3x3 (measured, plus a CPU-seconds projection onto a machine
that actually provides 2 cores), then a kill -9 drill — one worker is
killed while holding a lease mid-batch and the batch must still
complete with labels byte-identical to the in-process engine.

Run:  PYTHONPATH=src python benchmarks/labeler_throughput.py [--smoke]
      PYTHONPATH=src python benchmarks/labeler_throughput.py --fused [--smoke]
      PYTHONPATH=src python benchmarks/labeler_throughput.py --fleet [--smoke]
      PYTHONPATH=src python benchmarks/labeler_throughput.py --obs [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from common import emit, section  # noqa: E402

WORKERS = 2
DET_KEYS = ("qor", "latency", "energy", "flops", "hbm_bytes")


# --------------------------------------------------------------------------
# cpu accounting: parent + live worker processes (RUSAGE_CHILDREN only
# counts reaped children, so read /proc/<pid>/stat directly)
def _proc_cpu_s(pid: int) -> float:
    try:
        with open(f"/proc/{pid}/stat") as f:
            parts = f.read().rsplit(") ", 1)[1].split()
        utime, stime = int(parts[11]), int(parts[12])
        return (utime + stime) / os.sysconf("SC_CLK_TCK")
    except Exception:  # noqa: BLE001 - non-linux or reaped pid
        return 0.0


def _cpu_snapshot(worker_pids) -> float:
    return _proc_cpu_s(os.getpid()) + sum(_proc_cpu_s(p) for p in worker_pids)


def _parallel_ceiling() -> float:
    """Measured aggregate speedup of 2 CPU-bound processes vs 1 (shared
    hosts often deliver far less than os.cpu_count() cores)."""
    import multiprocessing as mp
    from concurrent.futures import ProcessPoolExecutor

    n = 8_000_000
    t0 = time.perf_counter()
    _burn(n)
    t1 = time.perf_counter() - t0
    with ProcessPoolExecutor(2, mp_context=mp.get_context("spawn")) as pool:
        list(pool.map(_burn, [n // 8, n // 8]))           # spawn warmup
        t0 = time.perf_counter()
        list(pool.map(_burn, [n, n]))
        t2 = time.perf_counter() - t0
    return 2.0 * t1 / t2


def _burn(n):
    s = 0
    for i in range(n):
        s += i * i
    return s


# --------------------------------------------------------------------------
def _population(accel, library, n, seed):
    rng = np.random.default_rng(seed)
    sizes = accel.gene_sizes(library)
    return rng.integers(0, sizes[None, :], size=(n, len(sizes)))


def _front(labels):
    from repro.core.dse import _objective_matrix
    from repro.core.pareto import non_dominated_mask

    obj = _objective_matrix(labels, ("qor", "energy"))
    return obj[non_dominated_mask(obj)]


def _fresh_ctx(name, n_qor):
    from repro.core.acl.library import default_library
    from repro.service import EvalContext, make_accelerator

    return EvalContext(
        make_accelerator(name), default_library(), n_qor_samples=n_qor
    )


def bench_per_genome_thread(name, genomes, n_qor):
    """Seed-engine baseline: per-genome ground truth on thread workers.
    Structural compile keying and the shared compile cache are disabled
    (and the engine reset) so the baseline pays exactly what the seed
    engine paid — without this, the new engine's process-wide cache
    would answer for compiles another backend already did."""
    import repro.core.features.synth as synth
    import repro.kernels.approx_matmul.ops as ops

    ctx = _fresh_ctx(name, n_qor)
    ops.LEGACY_EMBED_TABLES, fast = True, synth.FAST_CODEGEN
    struct = synth.STRUCTURAL_KEYS
    synth.FAST_CODEGEN = False
    synth.STRUCTURAL_KEYS = False
    synth.reset_fast_codegen()
    try:
        with ThreadPoolExecutor(WORKERS) as pool:
            t0 = time.perf_counter()
            outs = list(pool.map(lambda g: ctx.ground_truth(g[None]), genomes))
            wall = time.perf_counter() - t0
    finally:
        ops.LEGACY_EMBED_TABLES = False
        synth.FAST_CODEGEN = fast
        synth.STRUCTURAL_KEYS = struct
    labels = {k: np.concatenate([o[k] for o in outs]) for k in DET_KEYS}
    return labels, wall


def bench_batched_thread(name, genomes, n_qor):
    """Batched engine, in-process: one ground-truth call for the batch
    (cold shared compile cache — backends must not feed each other)."""
    import repro.core.features.synth as synth

    synth.reset_fast_codegen()
    ctx = _fresh_ctx(name, n_qor)
    t0 = time.perf_counter()
    labels = ctx.ground_truth(genomes)
    return labels, time.perf_counter() - t0


def bench_batched_process(name, genomes, n_qor, pool):
    """Batched engine on the warm process pool (chunked fan-out)."""
    ctx = _fresh_ctx(name, n_qor)
    assert pool.can_label(ctx), f"{name} should be process-safe"
    t0 = time.perf_counter()
    labels = pool.label(ctx, genomes)
    return labels, time.perf_counter() - t0


# --------------------------------------------------------------------------
# fleet mode
def _wait_until(pred, timeout, what):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if pred():
            return
        time.sleep(0.05)
    raise TimeoutError(f"timed out waiting for {what}")


def _spawn_fleet_worker(base, wid):
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    return subprocess.Popen(
        [sys.executable, "-m", "repro.fleet.worker",
         "--orchestrator", base, "--id", wid, "--max-idle-s", "600"],
        env={**os.environ, "PYTHONPATH": src},
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
    )


def run_fleet_bench(args):
    """1 vs 2 local fleet workers on gaussian3x3 + a kill -9 drill.

    Real ``python -m repro.fleet.worker`` subprocesses join an in-parent
    ``FleetCoordinator`` over HTTP.  Each phase gets a warmup batch first
    so both phases measure the steady state of long-lived workers
    (per-circuit tables and structural compile caches warm); rounds use
    fresh genomes so the label store never answers.  The drill kills one
    of two workers with SIGKILL while it holds a lease mid-batch: the
    batch must still complete (heartbeat expiry requeues the dead
    worker's chunks) with labels byte-identical to the in-process
    engine on the same genomes.
    """
    from repro.core.acl.library import default_library
    from repro.fleet import FleetCoordinator, serve_fleet
    from repro.service.workers import warm_library

    name = "gaussian3x3"
    G = args.n or (4 if args.smoke else 24)
    rounds = args.rounds or (1 if args.smoke else 3)
    n_qor = 2 if args.smoke else 4
    library = default_library()
    warm_library(library)

    section("machine parallelism probe")
    ceiling = _parallel_ceiling()
    emit("fleet.parallel_ceiling", 0.0, f"{ceiling:.2f}x")

    coord = FleetCoordinator(lease_ttl_s=60.0, heartbeat_ttl_s=10.0)
    srv = serve_fleet(coord, port=0)
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    ctx0 = _fresh_ctx(name, n_qor)
    procs = {}

    def phase(pids, seed0):
        walls, cpus = [], []
        for rnd in range(rounds):
            genomes = _population(ctx0.accel, library, G, seed=seed0 + rnd)
            c0 = _cpu_snapshot(pids)
            t0 = time.perf_counter()
            coord.label(_fresh_ctx(name, n_qor), genomes)
            walls.append((time.perf_counter() - t0) / G)
            cpus.append((_cpu_snapshot(pids) - c0) / G)
        wall = float(np.median(walls))
        return {"s_per_label": wall, "labels_per_sec": 1.0 / wall,
                "cpu_s_per_label": float(np.median(cpus))}

    try:
        section("fleet: worker bench-w0 joining (register + warm)")
        procs["bench-w0"] = _spawn_fleet_worker(base, "bench-w0")
        _wait_until(lambda: coord.stats()["live"] >= 1, 300,
                    "bench-w0 to register")

        # warmup batch doubles as the byte-identity check against the
        # in-process engine
        genomes = _population(ctx0.accel, library, G, seed=999)
        ref = _fresh_ctx(name, n_qor).ground_truth(genomes)
        lab = coord.label(_fresh_ctx(name, n_qor), genomes)
        identical = all(np.array_equal(np.asarray(ref[k]),
                                       np.asarray(lab[k]))
                        for k in DET_KEYS)
        front_identical = bool(np.array_equal(_front(ref), _front(lab)))
        emit("fleet.labels_identical", 0.0, identical)

        section(f"fleet 1 worker: {rounds} rounds x {G} genomes")
        one = phase([procs["bench-w0"].pid], seed0=100)
        emit("fleet.gaussian3x3.1_worker", one["s_per_label"] * 1e6,
             f"{one['labels_per_sec']:.2f}/s")

        section("fleet: worker bench-w1 joining (elastic, mid-campaign ok)")
        procs["bench-w1"] = _spawn_fleet_worker(base, "bench-w1")
        _wait_until(lambda: coord.stats()["live"] >= 2, 300,
                    "bench-w1 to register")
        coord.label(_fresh_ctx(name, n_qor),
                    _population(ctx0.accel, library, G, seed=998))  # warm w1

        section(f"fleet 2 workers: {rounds} rounds x {G} genomes")
        pids = [p.pid for p in procs.values()]
        two = phase(pids, seed0=200)
        emit("fleet.gaussian3x3.2_workers", two["s_per_label"] * 1e6,
             f"{two['labels_per_sec']:.2f}/s")

        measured = two["labels_per_sec"] / one["labels_per_sec"]
        # one worker is one process; projected onto a machine that
        # actually provides 2 cores the fleet runs both workers at
        # full speed:
        proj_1 = 1.0 / one["cpu_s_per_label"]
        proj_2 = 2.0 / two["cpu_s_per_label"]
        projected = proj_2 / proj_1
        emit("fleet.gaussian3x3.scaling", 0.0, f"{measured:.2f}x")
        emit("fleet.gaussian3x3.scaling_projected_2core", 0.0,
             f"{projected:.2f}x")

        section("fleet: kill -9 drill (bench-w0 dies holding a lease)")
        kd_genomes = _population(ctx0.accel, library, max(2 * G, 8),
                                 seed=4242)
        kd_ref = _fresh_ctx(name, n_qor).ground_truth(kd_genomes)
        out = {}
        th = threading.Thread(
            target=lambda: out.update(
                labels=coord.label(_fresh_ctx(name, n_qor), kd_genomes)),
            daemon=True)
        th.start()

        def _victim_leased():
            with coord._cv:
                return any(l.worker == "bench-w0"
                           for l in coord._leases.values())

        _wait_until(_victim_leased, 120, "bench-w0 to hold a lease")
        procs["bench-w0"].send_signal(signal.SIGKILL)
        th.join(timeout=600)
        assert "labels" in out, "kill drill batch never completed"
        kd = out["labels"]
        kd_identical = all(np.array_equal(np.asarray(kd_ref[k]),
                                          np.asarray(kd[k]))
                           for k in DET_KEYS)
        kd_front = bool(np.array_equal(_front(kd_ref), _front(kd)))
        stats = coord.stats()
        emit("fleet.kill_drill.labels_identical", 0.0, kd_identical)
        emit("fleet.kill_drill.requeues", 0.0, stats["requeues"])
    finally:
        for p in procs.values():
            if p.poll() is None:
                p.kill()
        coord.shutdown()
        srv.shutdown()

    report = {
        "mode": "fleet", "workload": name,
        "population": G, "rounds": rounds, "n_qor_samples": n_qor,
        "smoke": bool(args.smoke),
        "machine": {"os_cpu_count": os.cpu_count(),
                    "measured_parallel_ceiling_x": ceiling},
        "labels_identical": bool(identical),
        "front_identical": front_identical,
        "backends": {"fleet_1_worker": one, "fleet_2_workers": two},
        "scaling": {
            "measured_x": measured,
            "projected_2core_x": projected,
            "projected_1_worker_labels_per_sec": proj_1,
            "projected_2_worker_labels_per_sec": proj_2,
        },
        "kill_drill": {
            "completed": True,
            "labels_identical": bool(kd_identical),
            "front_identical": kd_front,
            "requeues": stats["requeues"],
            "expired_leases": stats["expired_leases"],
            "dead_workers": stats["dead_workers"],
            "duplicate_results": stats["duplicate_results"],
            "local_fallback_chunks": stats["local_fallback_chunks"],
            "remote_labels": stats["remote_labels"],
            "local_labels": stats["local_labels"],
        },
    }
    assert identical, "fleet labels diverged from in-process engine"
    assert kd_identical, "kill drill labels diverged"
    if not args.smoke and measured < 1.5 and projected < 1.5:
        print(f"WARNING: fleet 2-worker scaling {measured:.2f}x measured "
              f"/ {projected:.2f}x projected < 1.5x", file=sys.stderr)

    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}", file=sys.stderr)


def run_fused_bench(args):
    """Steady-state (warm-synth-cache) engine comparison -> the
    ``fused`` section of BENCH_labeler.json.

    A long-lived campaign's label stream runs with warm per-circuit
    tables and a warm structural synthesis cache — the regime where the
    numpy behavioral sim is the dominant cost.  Each round draws fresh
    genomes, pre-pays their synthesis once (untimed warm pass, so every
    arm sees the same cached-synthesis work), then times three engines
    in alternating order:

      * ``numpy_batched_thread`` — the batched numpy engine, fused
        dispatch disabled via the REPRO_SIM_FUSED=0 kill switch
      * ``fused_thread``         — the fused XLA engine in-process
      * ``batched_process``      — the warm spawn pool (production
        default; its workers fuse too, the delta is IPC + chunking)

    Asserted: labels and Pareto fronts byte-identical across all three
    engines every round, and zero fused-engine recompiles across the
    timed generations (population bucketing holds)."""
    from repro.accel import fused
    from repro.core.acl.library import default_library
    from repro.service.workers import ProcessPoolLabeler, warm_library

    G = args.n or (4 if args.smoke else 16)
    rounds = args.rounds or (1 if args.smoke else 5)
    n_qor = 2 if args.smoke else 4
    library = default_library()
    warm_library(library)
    fused.warm(library)

    section(f"warming process pool ({WORKERS} spawn workers)")
    pool = ProcessPoolLabeler(WORKERS)
    for name in ("gaussian3x3", "smoothed_dct"):
        wctx = _fresh_ctx(name, n_qor)
        pool.label(wctx, _population(wctx.accel, library, G, seed=777))
    worker_pids = list(getattr(pool._pool, "_processes", {}) or [])

    backends = ("numpy_batched_thread", "fused_thread", "batched_process")
    fused_report = {
        "population": G, "rounds": rounds, "n_qor_samples": n_qor,
        "workers": WORKERS, "smoke": bool(args.smoke),
        "workloads": {},
    }

    def run_numpy(ctx, genomes):
        os.environ["REPRO_SIM_FUSED"] = "0"
        try:
            t0 = time.perf_counter()
            labels = ctx.ground_truth(genomes)
            return labels, time.perf_counter() - t0
        finally:
            del os.environ["REPRO_SIM_FUSED"]

    def run_fused(ctx, genomes):
        t0 = time.perf_counter()
        labels = ctx.ground_truth(genomes)
        return labels, time.perf_counter() - t0

    def run_process(ctx, genomes):
        t0 = time.perf_counter()
        labels = pool.label(ctx, genomes)
        return labels, time.perf_counter() - t0

    for name in ("gaussian3x3", "smoothed_dct"):
        section(f"{name} steady-state: {rounds} rounds x {G} genomes "
                f"x 3 engines")
        ctx = _fresh_ctx(name, n_qor)
        # engine warmup: exhausts the fused verification budget and
        # compiles the population bucket; 2 calls per switch state so
        # both arms start steady
        for seed in (888, 889):
            warm_genomes = _population(ctx.accel, library, G, seed=seed)
            run_fused(ctx, warm_genomes)
            run_numpy(ctx, warm_genomes)
            run_process(ctx, warm_genomes)
        compiles_baseline = fused.stats()["compiles"]
        assert fused.stats()["pins"] == 0, "fused engine pinned during warmup"

        walls = {b: [] for b in backends}
        cpus = {b: [] for b in backends}
        identical = front_identical = True
        for rnd in range(rounds):
            genomes = _population(ctx.accel, library, G, seed=1000 + rnd)
            # pre-pay this round's synthesis once IN EVERY ARM'S CACHE
            # DOMAIN (parent and worker processes) so every arm measures
            # the warm-cache regime, not who-went-first
            run_fused(ctx, genomes)
            run_process(ctx, genomes)
            arms = [("numpy_batched_thread", run_numpy),
                    ("fused_thread", run_fused),
                    ("batched_process", run_process)]
            if rnd % 2:
                arms.reverse()
            labels = {}
            for backend, fn in arms:
                c0 = _cpu_snapshot(worker_pids)
                lab, wall = fn(ctx, genomes)
                cpus[backend].append((_cpu_snapshot(worker_pids) - c0) / G)
                walls[backend].append(wall / G)
                labels[backend] = {k: np.asarray(lab[k]) for k in DET_KEYS}
            base = labels["numpy_batched_thread"]
            identical &= all(
                np.array_equal(base[k], labels[b][k])
                for b in backends[1:] for k in DET_KEYS
            )
            fronts = {b: _front(labels[b]) for b in backends}
            front_identical &= all(
                np.array_equal(fronts[backends[0]], fronts[b])
                for b in backends[1:]
            )
        recompiles = fused.stats()["compiles"] - compiles_baseline

        results = {}
        for b in backends:
            wall = float(np.median(walls[b]))
            results[b] = {
                "s_per_label": wall,
                "labels_per_sec": 1.0 / wall,
                "cpu_s_per_label": float(np.median(cpus[b])),
            }
            emit(f"labeler.fused.{name}.{b}", wall * 1e6,
                 f"{1.0 / wall:.2f}/s")
        speed_vs_numpy = (results["fused_thread"]["labels_per_sec"]
                          / results["numpy_batched_thread"]["labels_per_sec"])
        speed_vs_process = (results["fused_thread"]["labels_per_sec"]
                            / results["batched_process"]["labels_per_sec"])
        emit(f"labeler.fused.{name}.speedup_vs_numpy", 0.0,
             f"{speed_vs_numpy:.2f}x")
        emit(f"labeler.fused.{name}.speedup_vs_process", 0.0,
             f"{speed_vs_process:.2f}x")
        emit(f"labeler.fused.{name}.steady_state_recompiles", 0.0,
             recompiles)
        fused_report["workloads"][name] = {
            "backends": results,
            "fused_speedup_vs_numpy_batched": speed_vs_numpy,
            "fused_speedup_vs_batched_process": speed_vs_process,
            "labels_identical": bool(identical),
            "front_identical": bool(front_identical),
            "steady_state_recompiles": int(recompiles),
            "engine_stats": fused.stats(),
        }
        assert identical, f"{name}: engine labels diverged"
        assert front_identical, f"{name}: engine fronts diverged"
        assert recompiles == 0, (
            f"{name}: {recompiles} steady-state recompiles (bucketing "
            f"failed to absorb the generations)"
        )

    pool.shutdown()
    best = max(
        wl["fused_speedup_vs_batched_process"]
        for wl in fused_report["workloads"].values()
    )
    if not args.smoke and best < 1.5:
        print(f"WARNING: best fused-vs-process speedup {best:.2f}x < 1.5x",
              file=sys.stderr)

    out_path = os.path.abspath(args.out)
    if args.smoke:
        print(f"smoke mode: not writing {out_path}", file=sys.stderr)
        return
    # merge into the existing default-mode report instead of clobbering it
    report = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            report = json.load(f)
    report["fused"] = fused_report
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}", file=sys.stderr)


def run_obs_bench(args):
    """Flight-recorder overhead guardrail -> BENCH_obs.json.

    Two measurements, both with the JSONL sink DISABLED (the sink is
    opt-in and pays I/O by design; the guardrail is about the always-on
    span machinery):

      * span microbench — enter/exit cost of one instrumented region
        with tracing on (ring append) vs off (null span), isolated from
        the workload.
      * labels/sec — the real scheduler path (submit -> coalesce ->
        batched ground truth -> resolve) with tracing enabled vs
        disabled, interleaved rounds with alternating order and fresh
        genomes per arm (no store/synth-cache cross-feeding), median
        per-label wall.

    Target: <3% labels/sec overhead.  Reported, and warned about when
    exceeded — not asserted, because shared-host wall clocks drift by
    more than 3% between back-to-back identical runs."""
    from repro import obs
    from repro.core.acl.library import default_library
    from repro.service import EvalScheduler, InMemoryLabelStore
    from repro.service.workers import warm_library

    name = "gaussian3x3"
    G = args.n or (4 if args.smoke else 16)
    rounds = args.rounds or (2 if args.smoke else 5)
    n_qor = 2 if args.smoke else 4
    library = default_library()
    warm_library(library)
    obs.set_sink(None)

    section("span machinery microbench (sink disabled)")
    N = 5_000 if args.smoke else 50_000
    span_cost = {}
    for arm, enabled in (("on", True), ("off", False)):
        obs.set_enabled(enabled)
        t0 = time.perf_counter()
        for _ in range(N):
            with obs.span("bench.noop", k=1):
                pass
        span_cost[arm] = (time.perf_counter() - t0) / N
        emit(f"obs.span_{arm}", span_cost[arm] * 1e6,
             f"{span_cost[arm] * 1e9:.0f}ns")
    obs.set_enabled(True)

    section(f"scheduler labels/sec, tracing on vs off: "
            f"{rounds} rounds x {G} genomes x 2 arms")
    walls = {"on": [], "off": []}
    seed = 0
    # warm the per-circuit caches once so both arms measure steady state
    wctx = _fresh_ctx(name, n_qor)
    wctx.ground_truth(_population(wctx.accel, library, 2, seed=777))
    for rnd in range(rounds):
        order = ("on", "off") if rnd % 2 == 0 else ("off", "on")
        for arm in order:
            obs.set_enabled(arm == "on")
            sched = EvalScheduler(InMemoryLabelStore(), n_workers=1,
                                  max_batch=G, max_wait_s=0.001)
            ctx = _fresh_ctx(name, n_qor)
            genomes = _population(ctx.accel, library, G, seed=seed)
            seed += 1
            t0 = time.perf_counter()
            for fut in sched.submit(ctx, genomes):
                fut.result(timeout=600)
            walls[arm].append((time.perf_counter() - t0) / G)
            sched.shutdown()
    obs.set_enabled(True)

    on = float(np.median(walls["on"]))
    off = float(np.median(walls["off"]))
    overhead_pct = (on - off) / off * 100.0
    emit("obs.labels_per_sec.on", on * 1e6, f"{1.0 / on:.2f}/s")
    emit("obs.labels_per_sec.off", off * 1e6, f"{1.0 / off:.2f}/s")
    emit("obs.overhead_pct", 0.0, f"{overhead_pct:+.2f}%")
    if overhead_pct > 3.0:
        print(f"WARNING: tracing overhead {overhead_pct:+.2f}% > 3% "
              f"target (shared-host noise is +-40%; rerun before "
              f"trusting)", file=sys.stderr)

    report = {
        "mode": "obs", "workload": name,
        "population": G, "rounds": rounds, "n_qor_samples": n_qor,
        "smoke": bool(args.smoke),
        "machine": {"os_cpu_count": os.cpu_count()},
        "span_cost_s": {"on": span_cost["on"], "off": span_cost["off"]},
        "labels": {
            "on_s_per_label": on, "off_s_per_label": off,
            "on_labels_per_sec": 1.0 / on,
            "off_labels_per_sec": 1.0 / off,
            "overhead_pct": overhead_pct,
        },
        "target_overhead_pct": 3.0,
        "within_target": bool(overhead_pct <= 3.0),
    }
    out_path = os.path.abspath(args.out)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}", file=sys.stderr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny population, one round (CI: exercise all "
                         "three backends, don't trust the ratios)")
    ap.add_argument("--fused", action="store_true",
                    help="steady-state engine comparison (numpy batched "
                         "vs fused XLA vs process pool, warm synth "
                         "caches) merged into BENCH_labeler.json under "
                         "the 'fused' key")
    ap.add_argument("--fleet", action="store_true",
                    help="benchmark the multi-host labeling fleet "
                         "(1 vs 2 local workers + kill -9 drill) and "
                         "write BENCH_fleet.json instead")
    ap.add_argument("--obs", action="store_true",
                    help="measure flight-recorder overhead (tracing on "
                         "vs off, sink disabled) and write "
                         "BENCH_obs.json instead")
    ap.add_argument("-n", type=int, default=None,
                    help="population size per round")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    root = os.path.join(os.path.dirname(__file__), "..")
    args.out = args.out or os.path.join(
        root, "BENCH_obs.json" if args.obs
        else "BENCH_fleet.json" if args.fleet else "BENCH_labeler.json")
    if args.obs:
        return run_obs_bench(args)
    if args.fleet:
        return run_fleet_bench(args)
    if args.fused:
        return run_fused_bench(args)

    from repro.core.acl.library import default_library
    from repro.service.workers import ProcessPoolLabeler, warm_library

    G = args.n or (4 if args.smoke else 8)
    rounds = args.rounds or (1 if args.smoke else 3)
    n_qor = 2 if args.smoke else 4
    library = default_library()
    # steady-state measurement for EVERY backend: per-circuit caches
    # (tables, error SVDs) are warm, as in a long-lived service
    warm_library(library)

    section("machine parallelism probe")
    ceiling = _parallel_ceiling()
    emit("labeler.parallel_ceiling", 0.0, f"{ceiling:.2f}x")

    section(f"warming process pool ({WORKERS} spawn workers)")
    pool = ProcessPoolLabeler(WORKERS)
    t0 = time.perf_counter()
    for name in ("gaussian3x3", "smoothed_dct"):
        wctx = _fresh_ctx(name, n_qor)
        pool.label(wctx, _population(wctx.accel, library, 2 * WORKERS,
                                     seed=777))
    emit("labeler.pool_warmup", (time.perf_counter() - t0) * 1e6, WORKERS)
    worker_pids = list(getattr(pool._pool, "_processes", {}) or [])

    backends = ("per_genome_thread", "batched_thread", "batched_process")
    report = {
        "population": G, "rounds": rounds, "n_qor_samples": n_qor,
        "workers": WORKERS, "smoke": bool(args.smoke),
        "machine": {"os_cpu_count": os.cpu_count(),
                    "measured_parallel_ceiling_x": ceiling},
        "workloads": {},
    }
    for name in ("gaussian3x3", "smoothed_dct"):
        section(f"{name}: {rounds} rounds x {G} genomes x 3 backends")
        ctx0 = _fresh_ctx(name, n_qor)
        walls = {b: [] for b in backends}
        cpus = {b: [] for b in backends}
        identical = front_identical = True
        front_size = 0
        for rnd in range(rounds):
            genomes = _population(ctx0.accel, library, G, seed=rnd)
            labels = {}
            for backend, fn in (
                ("per_genome_thread",
                 lambda: bench_per_genome_thread(name, genomes, n_qor)),
                ("batched_thread",
                 lambda: bench_batched_thread(name, genomes, n_qor)),
                ("batched_process",
                 lambda: bench_batched_process(name, genomes, n_qor, pool)),
            ):
                c0 = _cpu_snapshot(worker_pids)
                lab, wall = fn()
                cpus[backend].append((_cpu_snapshot(worker_pids) - c0) / G)
                walls[backend].append(wall / G)
                labels[backend] = {k: np.asarray(lab[k]) for k in DET_KEYS}
            base = labels["per_genome_thread"]
            identical &= all(
                np.array_equal(base[k], labels[b][k])
                for b in backends[1:] for k in DET_KEYS
            )
            fronts = {b: _front(labels[b]) for b in backends}
            front_identical &= all(
                np.array_equal(fronts[backends[0]], fronts[b])
                for b in backends[1:]
            )
            front_size = int(len(fronts[backends[0]]))

        results = {}
        for b in backends:
            wall = float(np.median(walls[b]))
            results[b] = {
                "s_per_label": wall,
                "labels_per_sec": 1.0 / wall,
                "cpu_s_per_label": float(np.median(cpus[b])),
            }
            emit(f"labeler.{name}.{b}", wall * 1e6,
                 f"{1.0 / wall:.2f}/s")
        speedups = {
            b: (results[b]["labels_per_sec"]
                / results["per_genome_thread"]["labels_per_sec"])
            for b in backends[1:]
        }
        # the process backend parallelizes across real cores; the seed
        # per-genome thread backend cannot (GIL).  Project both onto a
        # machine that actually provides WORKERS cores:
        proj = {
            "per_genome_thread":
                1.0 / results["per_genome_thread"]["cpu_s_per_label"],
            "batched_process":
                WORKERS / results["batched_process"]["cpu_s_per_label"],
        }
        proj["speedup"] = proj["batched_process"] / proj["per_genome_thread"]
        emit(f"labeler.{name}.process_speedup", 0.0,
             f"{speedups['batched_process']:.2f}x")
        emit(f"labeler.{name}.process_speedup_projected_{WORKERS}core", 0.0,
             f"{proj['speedup']:.2f}x")
        report["workloads"][name] = {
            "backends": results,
            "speedup_vs_per_genome_thread": speedups,
            "projected_full_parallel": proj,
            "labels_identical": bool(identical),
            "front_identical": bool(front_identical),
            "front_size": front_size,
        }
        assert identical, f"{name}: backend labels diverged"
        assert front_identical, f"{name}: backend fronts diverged"

    pool.shutdown()
    wl = report["workloads"]["smoothed_dct"]
    measured = wl["speedup_vs_per_genome_thread"]["batched_process"]
    projected = wl["projected_full_parallel"]["speedup"]
    if not args.smoke and measured < 3.0 and projected < 3.0:
        print(f"WARNING: smoothed_dct batched-process speedup "
              f"{measured:.2f}x measured / {projected:.2f}x projected < 3x",
              file=sys.stderr)

    out_path = os.path.abspath(args.out)
    if args.smoke:
        print(f"smoke mode: not writing {out_path}", file=sys.stderr)
        return
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
