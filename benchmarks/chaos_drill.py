"""Chaos drill -> BENCH_chaos.json.

A REAL DSE campaign (service ``CampaignManager``, fleet eval backend,
``python -m repro.fleet.worker`` subprocesses over HTTP) runs to
completion under a seeded fault storm while a fault-free twin runs the
same spec first.  The acceptance bar is the robustness north star:

  * byte-identical Pareto front vs the fault-free twin,
  * labels-lost = 0 (every label the storm campaign paid for is still
    readable from a FRESH store opened on the post-storm files),
  * the segmented store warm-starts without replaying sealed segments
    and quarantines a deliberately corrupted segment while continuing
    to serve (and accept) everything else.

The storm is deterministic under ``--seed`` (``repro.faults`` keys its
coin flips on seed x injection-point x occurrence, never on wall
clock):

  parent plan   store.append torn writes under the store's own writer,
                fleet.lease grant drops (TTL-expiry requeue),
                fleet.result drop + duplicate (requeue / dedup)
  worker plan   injected 503 bursts on every outbound HTTP call,
                heartbeat drops, slow synthesis (synth.compile
                latency) — shipped via the ``REPRO_FAULTS`` env var
  plus          kill -9 of a worker while it holds a lease

Recovery latencies (kill -> dead-worker detection, kill -> campaign
done) are recorded alongside fleet/storm counters.

Run:  PYTHONPATH=src python benchmarks/chaos_drill.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import threading
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from common import emit, section  # noqa: E402

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _wait_until(pred, timeout, what):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if pred():
            return
        time.sleep(0.05)
    raise TimeoutError(f"timed out waiting for {what}")


def _spawn_worker(base, wid, plan_path, log_path):
    return subprocess.Popen(
        [sys.executable, "-m", "repro.fleet.worker",
         "--orchestrator", base, "--id", wid, "--max-idle-s", "600",
         "--log-level", "info"],
        env={**os.environ, "PYTHONPATH": SRC, "REPRO_FAULTS": plan_path},
        stdout=subprocess.DEVNULL, stderr=open(log_path, "w"),
    )


def _spec(args):
    from repro.service import CampaignSpec

    if args.smoke:
        size = dict(n_train=8, n_qor_samples=2, pop_size=8, n_parents=4,
                    n_generations=2)
    else:
        size = dict(n_train=16, n_qor_samples=3, pop_size=12, n_parents=6,
                    n_generations=3)
    return CampaignSpec(accel="gaussian3x3", seed=args.seed, **size)


def _run_twin(args, root):
    """Fault-free twin: same spec, thread backend, clean store."""
    from repro.service import CampaignManager
    from repro.service.store import open_label_store

    store = open_label_store(os.path.join(root, "twin.segd"),
                             segment_records=8)
    mgr = CampaignManager(store, eval_workers=2, campaign_workers=1)
    try:
        t0 = time.perf_counter()
        cid = mgr.submit(_spec(args))
        assert mgr.wait(cid, timeout=1200) == "done", "twin failed"
        wall = time.perf_counter() - t0
        front = mgr.result(cid).front_objectives.copy()
        keys = set(store._data)
    finally:
        mgr.shutdown()
        store.close()
    return front, keys, wall


def _worker_plan(args, root):
    from repro.faults import FaultPlan

    plan = (
        FaultPlan(seed=args.seed, name="chaos-worker")
        # 503 burst early (registration/first leases retry through it),
        # then a sprinkle for the rest of the campaign
        .add("http.request", "error", status=503, after=2, times=4)
        .add("http.request", "error", status=503, p=0.05)
        .add("fleet.heartbeat", "drop", p=0.10)
        .add("synth.compile", "latency", delay_s=0.05, times=20)
    )
    return plan.save(os.path.join(root, "worker_plan.json"))


def _parent_plan(args):
    from repro.faults import FaultPlan

    return (
        FaultPlan(seed=args.seed + 1, name="chaos-parent")
        .add("store.append", "torn_write", times=3, fraction=0.5)
        .add("fleet.lease", "drop", times=2)
        .add("fleet.result", "drop", times=1)
        .add("fleet.result", "duplicate", times=1)
    )


def _run_storm(args, root):
    from repro import faults
    from repro.service import CampaignManager
    from repro.service.api import make_server
    from repro.service.store import open_label_store

    store = open_label_store(os.path.join(root, "storm.segd"),
                             segment_records=8)
    mgr = CampaignManager(
        store, eval_workers=2, campaign_workers=1,
        eval_backend="fleet", fleet_fallback="thread",
        lease_ttl_s=8.0, heartbeat_ttl_s=5.0,
    )
    srv = make_server(mgr, port=0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    coord = mgr.scheduler.fleet
    plan_path = _worker_plan(args, root)
    procs = {}
    report = {}
    try:
        section("storm: 2 workers joining through an injected 503 burst")
        for wid in ("chaos-w0", "chaos-w1"):
            procs[wid] = _spawn_worker(
                base, wid, plan_path, os.path.join(root, f"{wid}.log"))
        _wait_until(lambda: coord.stats()["live"] >= 2, 600,
                    "both workers to register")

        section("storm: campaign under parent + worker fault plans")
        faults.install(_parent_plan(args))
        t0 = time.perf_counter()
        cid = mgr.submit(_spec(args))

        def _victim():
            with coord._cv:
                for lease in coord._leases.values():
                    if lease.worker in procs:
                        return lease.worker
            return None

        # kill -9 a worker the moment it holds a lease mid-campaign
        victim = None
        kill_deadline = time.time() + 600
        while victim is None and time.time() < kill_deadline:
            if mgr.status(cid)["state"] in ("done", "failed"):
                break
            victim = _victim()
            time.sleep(0.02)
        t_kill = time.perf_counter()
        if victim is not None:
            section(f"storm: kill -9 {victim} (holding a lease)")
            procs[victim].send_signal(signal.SIGKILL)
            dead0 = coord.stats()["dead_workers"]
            _wait_until(lambda: coord.stats()["dead_workers"] > dead0,
                        120, "dead-worker detection")
            report["kill_to_dead_s"] = time.perf_counter() - t_kill

        state = mgr.wait(cid, timeout=1800)
        wall = time.perf_counter() - t0
        assert state == "done", f"storm campaign ended {state!r}"
        report.update(
            wall_s=wall,
            kill_to_done_s=(time.perf_counter() - t_kill
                            if victim is not None else None),
            victim=victim,
            parent_faults=faults.stats(),
            fleet={k: v for k, v in coord.stats().items()
                   if k != "workers"},
            store=store.stats(),
        )
        front = mgr.result(cid).front_objectives.copy()
        keys = set(store._data)
    finally:
        faults.uninstall()
        for p in procs.values():
            if p.poll() is None:
                p.kill()
        srv.shutdown()
        mgr.shutdown()
        store.close()
    # worker-side proof the storm reached the subprocesses: every
    # firing logs "injected <kind> at <point>" in the worker's stderr
    report["worker_injections"] = sum(
        open(os.path.join(root, f"{wid}.log")).read().count("injected")
        for wid in procs)
    return front, keys, report


def _durability(root, storm_keys):
    """Crash-consistency view: everything the storm campaign paid for
    must be readable from a FRESH store on the post-storm files, the
    open must not replay sealed segments, and a corrupted segment must
    quarantine without taking the store down."""
    from repro.service.store import LABEL_KEYS, open_label_store

    path = os.path.join(root, "storm.segd")

    t0 = time.perf_counter()
    fresh = open_label_store(path, segment_records=8)
    open_s = time.perf_counter() - t0
    lazy = fresh.stats()["segments_loaded"] == 0
    lost = [k for k in storm_keys if fresh.get(k) is None]
    n_total = len(fresh)
    fresh.close()

    # bit-rot one sealed segment -> quarantine-and-continue
    segs = sorted(f for f in os.listdir(path)
                  if f.startswith("seg-") and f.endswith(".jsonl"))
    quarantine = {"checked": False}
    if segs:
        seg = os.path.join(path, segs[0])
        data = bytearray(open(seg, "rb").read())
        data[len(data) // 2] ^= 0xFF
        open(seg, "wb").write(data)
        q = open_label_store(path, segment_records=8)
        survivors = sum(1 for k in storm_keys if q.get(k) is not None)
        st = q.stats()
        q.put("chaos:drill:probe", {k: 1.0 for k in LABEL_KEYS})
        still_writes = q.get("chaos:drill:probe") is not None
        q.close()
        quarantine = {
            "checked": True,
            "quarantined_segments": int(st["quarantined_segments"]),
            "records_dropped": n_total - survivors,
            "survivors": survivors,
            "still_writable": bool(still_writes),
        }
        assert st["quarantined_segments"] >= 1, "corruption not detected"
        assert still_writes, "store stopped accepting writes"
    return {
        "reopen_s": open_s,
        "lazy_warm_start": bool(lazy),
        "labels_lost": len(lost),
        "entries": n_total,
        "quarantine": quarantine,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny campaign (CI: exercise every fault path, "
                         "don't trust the latencies)")
    ap.add_argument("--seed", type=int, default=0,
                    help="storm seed (fault plans + campaign)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--keep", action="store_true",
                    help="keep the scratch dir (worker logs, stores)")
    args = ap.parse_args()
    out_path = os.path.abspath(args.out or os.path.join(
        os.path.dirname(__file__), "..", "BENCH_chaos.json"))
    root = tempfile.mkdtemp(prefix="chaos_drill_")

    from repro.service.workers import warm_library  # noqa: E402
    from repro.core.acl.library import default_library  # noqa: E402

    warm_library(default_library())
    try:
        section("fault-free twin")
        twin_front, twin_keys, twin_wall = _run_twin(args, root)
        emit("chaos.twin", twin_wall * 1e6, f"{len(twin_keys)} labels")

        section("seeded storm")
        storm_front, storm_keys, storm = _run_storm(args, root)
        emit("chaos.storm", storm["wall_s"] * 1e6,
             f"{len(storm_keys)} labels")

        front_identical = bool(np.array_equal(twin_front, storm_front))
        emit("chaos.front_identical", 0.0, front_identical)
        if storm.get("kill_to_dead_s") is not None:
            emit("chaos.kill_to_dead", storm["kill_to_dead_s"] * 1e6,
                 storm["victim"])

        section("durability: fresh reopen + corrupted-segment drill")
        dur = _durability(root, storm_keys)
        emit("chaos.labels_lost", 0.0, dur["labels_lost"])
        emit("chaos.quarantine_continue", 0.0,
             dur["quarantine"].get("still_writable", "n/a"))

        report = {
            "mode": "chaos", "smoke": bool(args.smoke), "seed": args.seed,
            "front_identical": front_identical,
            "twin": {"wall_s": twin_wall, "n_labels": len(twin_keys)},
            "storm": storm,
            "durability": dur,
        }
        assert front_identical, "storm front diverged from twin"
        assert dur["labels_lost"] == 0, (
            f"{dur['labels_lost']} labels lost in the storm")
        with open(out_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {out_path}", file=sys.stderr)
    finally:
        if args.keep:
            print(f"scratch kept at {root}", file=sys.stderr)
        else:
            shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
