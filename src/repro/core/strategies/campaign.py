"""``Campaign`` — the paper's Fig. 2 loop (train surrogates -> explore ->
final evaluation) as an interruptible state machine.

The legacy ``run_dse`` was one blocking call that owned its labeler for
its whole life; a ``Campaign`` instead *yields* labeling requests and is
stepped from outside:

    campaign = Campaign(accel, library, cfg)
    while not campaign.done:
        req = campaign.step()                 # advance one tick
        if req is not None:                   # ground truth needed
            campaign.deliver(req, labeler(req.genomes))
    res = campaign.result()                   # a DSEResult

One ``step()`` is one cooperative tick: the TRAIN tick returns the
training-set label request, each EXPLORE tick runs exactly one strategy
round (ask -> surrogate evaluation -> tell), the FINAL tick returns the
survivor-set request.  Between ticks the full campaign state — stage,
training data, strategy internals — is capturable with ``state()`` and
re-installable with ``restore()``, which is what makes service
campaigns multiplexable over a small worker pool and resumable after a
kill (surrogates are refit deterministically from the snapshotted
training set; ground truth re-requested on resume is answered by the
label store).

``drive()`` runs a campaign to completion against a blocking labeler —
``run_dse`` is now that one-liner.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from ... import obs
from ..nsga2 import NSGA2Result
from ..pareto import non_dominated_mask
from ..surrogates import make as make_surrogate
from ..surrogates import pcc
from .base import (
    SearchStrategy,
    decode_array,
    encode_array,
    make_strategy,
)

__all__ = ["LabelRequest", "Campaign", "drive"]

CAMPAIGN_STATE_VERSION = 1


@dataclass
class LabelRequest:
    """A batch of UNIQUE genomes whose ground truth the campaign needs.

    ``genomes`` is ``np.unique``-sorted — byte-identical to what the
    legacy ``label_unique`` handed the labeler — so store keys, batch
    contents and cache behavior are unchanged.  ``deliver`` scatters the
    unique labels back over the requesting batch via ``inverse``."""

    stage: str                      # "train" | "explore" | "final"
    genomes: np.ndarray             # (u, g) unique rows
    inverse: np.ndarray = field(repr=False, default=None)
    issued_at: float = field(default_factory=time.perf_counter, repr=False)


def _unique_request(stage: str, genomes: np.ndarray) -> LabelRequest:
    genomes = np.atleast_2d(np.asarray(genomes, dtype=np.int64))
    uniq, inverse = np.unique(genomes, axis=0, return_inverse=True)
    return LabelRequest(stage=stage, genomes=uniq, inverse=inverse)


class Campaign:
    """Stage machine TRAIN -> EXPLORE -> FINAL -> DONE over a pluggable
    ``SearchStrategy``.

    ``strategy`` may be a registry name, a ``SearchStrategy`` *factory*
    ``(gene_sizes, cfg, *, init=None) -> strategy``, or None (use
    ``cfg.strategy``).  ``surrogate_provider`` is the run_dse seam
    unchanged.  With ``ground_truth_explore=True`` the TRAIN and FINAL
    stages are skipped and every EXPLORE round is labeled with ground
    truth directly (how ``random_search`` rides the protocol)."""

    def __init__(
        self,
        accel,
        library=None,
        cfg=None,
        *,
        strategy=None,
        surrogate_provider=None,
        ground_truth_explore: bool = False,
        objectives: Optional[tuple] = None,
        verbose: bool = False,
        keep_history: bool = True,
    ):
        from ..acl.library import default_library
        from ..dse import DSEConfig

        self.accel = accel
        self.library = library or default_library()
        self.cfg = cfg if cfg is not None else DSEConfig()
        self.objectives = tuple(objectives or self.cfg.objectives)
        self.verbose = verbose
        self.keep_history = keep_history
        self.ground_truth_explore = bool(ground_truth_explore)
        self._strategy_arg = strategy
        self.strategy_name = (
            strategy if isinstance(strategy, str) else
            getattr(self.cfg, "strategy", "nsga2")
        )
        if surrogate_provider is None:
            def surrogate_provider(obj, name, X, y):
                return make_surrogate(name, seed=self.cfg.seed).fit(X, y)
        self._provider = surrogate_provider

        self.gene_sizes = accel.gene_sizes(self.library,
                                           rank_genes=self.cfg.rank_genes)
        self._rng = np.random.default_rng(self.cfg.seed)
        self.stage = "explore" if self.ground_truth_explore else "train"
        self.strategy: Optional[SearchStrategy] = None
        self.timings: Dict[str, float] = {}
        self.val_pcc: Dict[str, float] = {}
        self.labels_requested = 0
        # stage artifacts
        self.train_genomes: Optional[np.ndarray] = None
        self.train_labels: Optional[Dict[str, np.ndarray]] = None
        self._extractor = None
        self._models: Optional[Dict] = None
        self._search: Optional[NSGA2Result] = None
        self._gt_labels: List[Dict[str, np.ndarray]] = []  # gt-explore mode
        self._req: Optional[LabelRequest] = None
        self._result = None
        if self.ground_truth_explore:
            self._make_strategy(init=None)

    # ------------------------------------------------------------------
    @property
    def done(self) -> bool:
        return self.stage == "done"

    def _make_strategy(self, init: Optional[np.ndarray]) -> None:
        s = self._strategy_arg
        if isinstance(s, SearchStrategy):
            self.strategy = s
            self.strategy_name = s.name
        elif callable(s) and not isinstance(s, str):
            self.strategy = s(self.gene_sizes, self.cfg, init=init)
            self.strategy_name = getattr(self.strategy, "name",
                                         self.strategy_name)
        else:
            name = s if isinstance(s, str) else getattr(self.cfg, "strategy",
                                                        "nsga2")
            self.strategy = make_strategy(name, self.gene_sizes, self.cfg,
                                          init=init)
            self.strategy_name = name
        if not self.keep_history:
            self.strategy.keep_history = False

    # ------------------------------------------------------------------
    def step(self) -> Optional[LabelRequest]:
        """Advance one tick.  Returns a ``LabelRequest`` when ground
        truth is needed (the campaign then blocks until ``deliver``);
        None after a self-contained tick (an EXPLORE round, or nothing
        left to do).  Idempotent while a request is outstanding."""
        if self._req is not None:
            return self._req
        if self.stage == "train":
            if self.train_genomes is None:
                self.train_genomes = self._rng.integers(
                    0, self.gene_sizes[None, :],
                    size=(self.cfg.n_train, len(self.gene_sizes)),
                )
                # the exact reference design anchors surrogates and front
                self.train_genomes[0] = self.accel.exact_genome(
                    self.library, rank_genes=self.cfg.rank_genes
                )
            self._req = _unique_request("train", self.train_genomes)
            return self._req
        if self.stage == "explore":
            if self.strategy.done:
                self._finish_explore()
                return self.step() if self.stage == "final" else None
            genomes = self.strategy.ask()
            if self.ground_truth_explore:
                if len(genomes) == 0:
                    self.strategy.tell(genomes, np.zeros(
                        (0, len(self.objectives))))
                    return None
                self._req = _unique_request("explore", genomes)
                return self._req
            t0 = time.perf_counter()
            with obs.span("campaign.round", stage="explore",
                          strategy=self.strategy_name, n=int(len(genomes))):
                obj = (self._evaluate(genomes) if len(genomes)
                       else np.zeros((0, len(self.objectives))))
                self.strategy.tell(genomes, obj)
            self.timings["explore"] = (
                self.timings.get("explore", 0.0) + time.perf_counter() - t0
            )
            if self.strategy.done:
                self._finish_explore()
            return None
        if self.stage == "final":
            self._req = _unique_request("final", self._search.genomes)
            return self._req
        return None

    def deliver(self, req: LabelRequest, labels: Dict[str, np.ndarray]
                ) -> None:
        """Hand the ground truth for ``req.genomes`` back; advances the
        stage machine.  ``labels`` maps label name -> (u,) array aligned
        with the request's unique genomes."""
        if req is not self._req:
            raise ValueError("deliver() got a request that is not pending")
        with obs.span("campaign.deliver", stage=req.stage,
                      n=int(len(req.genomes))):
            full = {k: np.asarray(v)[req.inverse] for k, v in labels.items()}
            # counted on delivery, not issue: a request outstanding at
            # snapshot time is re-issued on resume and must not count twice
            self.labels_requested += len(req.genomes)
            self._req = None
            if req.stage == "train":
                self.timings["label"] = (
                    self.timings.get("label", 0.0)
                    + time.perf_counter() - req.issued_at
                )
                self.train_labels = full
                self._fit_surrogates()
            elif req.stage == "explore":
                from ..dse import _objective_matrix

                self._gt_labels.append(full)
                self.strategy.tell(
                    self.strategy.ask(),
                    _objective_matrix(full, self.objectives),
                )
                if self.strategy.done:
                    self._finish_explore()
            elif req.stage == "final":
                self.timings["final_eval"] = (
                    self.timings.get("final_eval", 0.0)
                    + time.perf_counter() - req.issued_at
                )
                self._finalize(full)

    # ------------------------------------------------------------------
    def _fit_surrogates(self) -> None:
        """Stage-1 tail: features, validation PCC, provider refit, then
        warm-start init + strategy construction (moves to EXPLORE)."""
        from ..features.pipelines import build_extractor

        t0 = time.perf_counter()
        cfg = self.cfg
        self._extractor = build_extractor(
            cfg.pipeline, self.accel, self.library, rank_genes=cfg.rank_genes
        )
        X = self._extractor(self.train_genomes)
        n_val = max(cfg.n_train // 5, 1)
        tr, va = slice(n_val, None), slice(0, n_val)
        models = {}
        for obj in self.objectives:
            name = cfg.qor_model if obj == "qor" else cfg.hw_model
            m = make_surrogate(name, seed=cfg.seed).fit(
                X[tr], self.train_labels[obj][tr])
            models[obj] = m
            self.val_pcc[obj] = pcc(self.train_labels[obj][va],
                                    m.predict(X[va]))
        # refit on everything via the provider (warm surrogate registry)
        for obj in self.objectives:
            name = cfg.qor_model if obj == "qor" else cfg.hw_model
            models[obj] = self._provider(obj, name, X,
                                         self.train_labels[obj])
        self._models = models
        self.timings["train"] = (
            self.timings.get("train", 0.0) + time.perf_counter() - t0
        )
        if self.verbose:
            print(f"[dse:{self.accel.name}] val PCC: "
                  + ", ".join(f"{k}={v:.3f}"
                              for k, v in self.val_pcc.items()))
        init = self.train_genomes[: cfg.nsga.pop_size].copy()
        if cfg.warm_start and len(init) >= 4:
            from ...accel.approxfpgas import circuit_level_front

            half = len(init) // 2
            per_slot_choices = []
            for slot in self.accel.slots:
                front = circuit_level_front(self.library, slot.kind)
                per_slot_choices.append(
                    [self.library.index(slot.kind, c.name) for c in front]
                )
            for t in range(half):
                for j, choices in enumerate(per_slot_choices):
                    init[t, j] = choices[self._rng.integers(0, len(choices))]
        self._make_strategy(init=init)
        self.stage = "explore"

    def _evaluate(self, genomes: np.ndarray) -> np.ndarray:
        from ..dse import _objective_matrix

        Xg = self._extractor(genomes)
        labels = {obj: self._models[obj].predict(Xg)
                  for obj in self.objectives}
        return _objective_matrix(labels, self.objectives)

    def _finish_explore(self) -> None:
        self._search = self.strategy.result()
        if self.ground_truth_explore:
            # objectives ARE ground truth: assemble the result directly
            labels = {
                k: np.concatenate([d[k] for d in self._gt_labels])
                for k in self._gt_labels[0]
            } if self._gt_labels else {}
            self._finalize_gt(labels)
        else:
            self.stage = "final"

    def _finalize(self, final_labels: Dict[str, np.ndarray]) -> None:
        from ..dse import DSEResult, _objective_matrix

        cfg = self.cfg
        search = self._search
        all_genomes = np.concatenate([search.genomes, self.train_genomes])
        all_labels = {
            k: np.concatenate([final_labels[k], self.train_labels[k]])
            for k in final_labels
        }
        true_obj = _objective_matrix(all_labels, self.objectives)
        mask = non_dominated_mask(true_obj)
        self._result = DSEResult(
            accel_name=self.accel.name,
            config=cfg,
            train_genomes=self.train_genomes,
            train_labels=self.train_labels,
            val_pcc=self.val_pcc,
            search=NSGA2Result(
                genomes=all_genomes,
                objectives=np.concatenate(
                    [search.objectives,
                     _objective_matrix(self.train_labels, self.objectives)]
                ),
                front_mask=mask,
                history=search.history,
                n_evaluated=search.n_evaluated,
            ),
            est_objectives=search.objectives,
            final_labels=all_labels,
            true_objectives=true_obj,
            front_mask=mask,
            timings=self.timings,
        )
        self.stage = "done"

    def _finalize_gt(self, labels: Dict[str, np.ndarray]) -> None:
        from ..dse import _objective_matrix

        obs_g = np.concatenate(
            [h.genomes for h in self.strategy.history]
        ) if self.strategy.history else self._search.genomes
        true_obj = _objective_matrix(labels, self.objectives)
        self._result = (obs_g, true_obj, non_dominated_mask(true_obj),
                        labels)
        self.stage = "done"

    def result(self):
        if self._result is None:
            raise RuntimeError(f"campaign not finished (stage={self.stage})")
        return self._result

    def front_estimate(self) -> Optional[np.ndarray]:
        """The strategy's current survivor-set objective matrix (est.),
        or None before the first evaluated population.  Cheap enough to
        sample at every tick — the service's telemetry timeline derives
        live hypervolume/front-size from it."""
        if self.strategy is None:
            return None
        try:
            res = self.strategy.result()
        except Exception:  # noqa: BLE001 - no population evaluated yet
            return None
        return np.asarray(res.objectives, dtype=np.float64)

    # ------------------------------------------------------------------
    def progress(self) -> Dict:
        """JSON-safe live progress for the service's status endpoint."""
        out = {
            "stage": self.stage,
            "strategy": self.strategy_name,
            "labels_requested": int(self.labels_requested),
        }
        if self.val_pcc:
            out["val_pcc"] = dict(self.val_pcc)
        if self.strategy is not None:
            out.update(self.strategy.progress())
        return out

    # ------------------------------------------------------------------
    def state(self) -> Dict:
        """JSON-serializable snapshot at a tick boundary.  Surrogates and
        the feature extractor are NOT serialized: they are refit
        deterministically from the snapshotted training set on restore
        (note: a provider in 'accumulate' mode may refit on a larger
        pool — resume reproducibility holds for 'reuse'/'off')."""
        from dataclasses import asdict

        return {
            "version": CAMPAIGN_STATE_VERSION,
            "stage": self.stage,
            "cfg": asdict(self.cfg),
            "objectives": list(self.objectives),
            "strategy_name": self.strategy_name,
            "ground_truth_explore": self.ground_truth_explore,
            "rng": self._rng.bit_generator.state,
            "train_genomes": encode_array(self.train_genomes),
            "train_labels": (
                None if self.train_labels is None else
                {k: encode_array(np.asarray(v))
                 for k, v in self.train_labels.items()}
            ),
            "gt_labels": [
                {k: encode_array(np.asarray(v)) for k, v in d.items()}
                for d in self._gt_labels
            ],
            "labels_requested": int(self.labels_requested),
            "timings": dict(self.timings),
            "strategy": (self.strategy.state()
                         if self.strategy is not None else None),
        }

    def restore(self, state: Dict) -> "Campaign":
        """Re-install a snapshot onto a freshly constructed campaign for
        the SAME accelerator/library/config.  An outstanding label
        request at snapshot time is simply re-issued by the next
        ``step()`` (the label store makes the re-ask cheap)."""
        if state.get("version") != CAMPAIGN_STATE_VERSION:
            raise ValueError(
                f"campaign snapshot version {state.get('version')!r} "
                f"unsupported (want {CAMPAIGN_STATE_VERSION})"
            )
        g = len(self.gene_sizes)
        self.stage = state["stage"]
        self.objectives = tuple(state["objectives"])
        self.ground_truth_explore = state["ground_truth_explore"]
        self._rng = np.random.default_rng()
        self._rng.bit_generator.state = state["rng"]
        self.train_genomes = decode_array(state["train_genomes"], width=g)
        self.train_labels = (
            None if state["train_labels"] is None else
            {k: decode_array(v, dtype=np.float64)
             for k, v in state["train_labels"].items()}
        )
        self._gt_labels = [
            {k: decode_array(v, dtype=np.float64) for k, v in d.items()}
            for d in state["gt_labels"]
        ]
        self.labels_requested = state["labels_requested"]
        self._req = None
        self._result = None
        strat_state = state["strategy"]
        if self.stage in ("explore", "final") or (
                self.ground_truth_explore and strat_state is not None):
            if not self.ground_truth_explore:
                # replay the deterministic stage-1 tail (fits + warm
                # start init + strategy construction), then overwrite
                # the strategy's loop state with the snapshot
                rng_save = self._rng
                self._rng = np.random.default_rng()  # consumed by replay
                self._fit_surrogates()
                self._rng = rng_save
                self.stage = state["stage"]
            self.strategy.restore(strat_state)
            if self.stage == "final":
                self._search = self.strategy.result()
        # reinstate AFTER the replay so the refit's wall time does not
        # double-count into the snapshotted "train" entry
        self.timings = dict(state["timings"])
        if self.stage == "done":
            raise ValueError("refusing to restore a finished campaign "
                             "(its result was not serialized)")
        return self

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Campaign({self.accel.name}, strategy="
                f"{self.strategy_name}, stage={self.stage})")


def drive(campaign: Campaign, labeler) -> object:
    """Run a campaign to completion against a blocking labeler
    (genomes -> label dict).  The legacy one-shot entry points are thin
    wrappers over this."""
    while not campaign.done:
        req = campaign.step()
        if req is not None:
            campaign.deliver(req, labeler(req.genomes))
    return campaign.result()
