"""Pareto-as-a-service: DSE campaigns as a long-lived service.

The one-shot ``run_dse`` pays the full ground-truth bill (XLA synthesis +
behavioral simulation per variant) on every invocation and discards the
labels at exit.  This package makes exploration a *service*:

  * ``store``      — persistent, content-addressed ground-truth label
                     store; labels from any campaign's stage 1/3 are
                     reused by every later campaign (cross-process),
  * ``scheduler``  — continuous-batching evaluation scheduler: coalesces
                     label requests from concurrent campaigns, dedupes
                     identical genomes in flight, fans batches out to a
                     worker pool,
  * ``campaigns``  — campaign manager + surrogate registry (warm fitted
                     surrogates keyed by (accel, pipeline, model)),
  * ``api``        — stdlib HTTP front end (``python -m repro.service``)
                     with submit/status/result and Pareto-front queries.

Ground truth runs on one of three scheduler backends: ``thread`` (in
process), ``process`` (spawn-safe pool, one host), or ``fleet`` — the
multi-host orchestrator/worker tier in ``repro.fleet``, where remote
``python -m repro.fleet.worker`` processes lease coalesced genome
chunks over HTTP and the service degrades to the in-process backend
whenever the fleet is empty.
"""

from .store import (
    EvalContext,
    InMemoryLabelStore,
    JsonlLabelStore,
    LabelStore,
    label_key,
)
from .scheduler import EvalScheduler
from .workers import ProcessPoolLabeler
from .campaigns import (
    CampaignManager,
    CampaignSpec,
    HierarchicalSpec,
    make_accelerator,
    register_accelerator,
    unregister_accelerator,
)

__all__ = [
    "EvalContext",
    "LabelStore",
    "InMemoryLabelStore",
    "JsonlLabelStore",
    "label_key",
    "EvalScheduler",
    "ProcessPoolLabeler",
    "CampaignManager",
    "CampaignSpec",
    "HierarchicalSpec",
    "make_accelerator",
    "register_accelerator",
    "unregister_accelerator",
]
