"""Stdlib HTTP client with bounded retry, exponential backoff + jitter.

Every HTTP edge in the fleet (worker registration, lease polling, result
streaming, heartbeats) and the service ``Client`` rides this one helper
instead of growing its own ad-hoc ``urllib`` code.  Retries cover the
transient failures a fleet actually sees — connection refused while the
orchestrator restarts, a dropped socket, a 502/503/504 from a proxy —
with exponential backoff and full jitter so a rejoining fleet does not
synchronize into a thundering herd.

Retrying a POST is safe here because every fleet POST is idempotent by
construction: registration and heartbeats are upserts, a duplicated
lease request just creates an extra lease that expires and requeues,
and a duplicated result commits content-addressed labels that dedupe to
zero bytes.  Callers with genuinely non-idempotent POSTs (e.g. campaign
submission) pass ``retries=0``.
"""

from __future__ import annotations

import json
import random
import time
import urllib.error
import urllib.request
from typing import Dict, Optional

__all__ = ["HttpError", "request_json"]

# HTTP statuses worth retrying: the server (or a proxy in front of it)
# says "not right now", not "you are wrong"
RETRY_STATUSES = (429, 502, 503, 504)


class HttpError(urllib.error.HTTPError):
    """A non-retryable (or retries-exhausted) HTTP failure.

    Subclasses ``urllib.error.HTTPError`` so callers written against the
    raw urllib wrapper (``except urllib.error.HTTPError as e: e.code``)
    keep working unchanged.  ``code``/``status`` is ``None`` for pure
    transport failures (connection refused, timeout) where no HTTP
    response ever arrived; ``detail`` carries the server's decoded JSON
    ``error`` field when it sent one."""

    def __init__(self, url: str, status: Optional[int], detail: str):
        super().__init__(url, status, detail, None, None)
        self.url = url
        self.detail = detail

    def __str__(self):
        if self.code is None:
            return f"{self.url}: {self.detail}"
        return f"{self.url}: HTTP {self.code}: {self.detail}"


def request_json(
    url: str,
    payload: Optional[Dict] = None,
    *,
    method: Optional[str] = None,
    timeout: float = 30.0,
    retries: int = 4,
    backoff_s: float = 0.25,
    backoff_max_s: float = 4.0,
    jitter: float = 1.0,
    rng: Optional[random.Random] = None,
) -> Dict:
    """GET (``payload is None``) or POST ``payload`` as JSON and return
    the decoded JSON response.

    Transient failures (connection errors, timeouts, ``RETRY_STATUSES``)
    are retried up to ``retries`` times with exponential backoff capped
    at ``backoff_max_s``; each sleep is scaled by a uniform random
    factor in ``[1 - jitter/2, 1 + jitter/2]`` (full-jitter style).  Any
    other HTTP error raises ``HttpError`` immediately with the decoded
    error body when the server sent one."""
    if method is None:
        method = "GET" if payload is None else "POST"
    rng = rng or random
    last: Optional[Exception] = None
    for attempt in range(retries + 1):
        if attempt:
            delay = min(backoff_s * (2.0 ** (attempt - 1)), backoff_max_s)
            if jitter > 0:
                delay *= 1.0 + jitter * (rng.random() - 0.5)
            time.sleep(max(delay, 0.0))
        try:
            data = None if payload is None else json.dumps(payload).encode()
            req = urllib.request.Request(
                url, data=data, method=method,
                headers={"Content-Type": "application/json"},
            )
            with urllib.request.urlopen(req, timeout=timeout) as resp:
                return json.loads(resp.read() or b"{}")
        except urllib.error.HTTPError as exc:
            body = exc.read()
            try:
                detail = json.loads(body).get("error", body.decode())
            except Exception:  # noqa: BLE001 - non-JSON error body
                detail = body.decode(errors="replace")
            if exc.code not in RETRY_STATUSES:
                raise HttpError(url, exc.code, detail) from exc
            last = HttpError(url, exc.code, detail)
        except (urllib.error.URLError, ConnectionError, TimeoutError,
                OSError) as exc:
            last = exc
    if isinstance(last, HttpError):
        raise last
    raise HttpError(url, None, f"retries exhausted: {last}") from last
