"""Gradient compression for cross-pod reduction.

Two pieces:

* ``ef_quantize`` — int8 error-feedback quantization (1-bit-SGD-style
  residual carrying): the train step can compress gradients before the
  optimizer and carry the quantization residual in the train state, so
  compression error does not accumulate as bias.

* ``compressed_psum`` — a shard_map building block that all-reduces a
  tensor across a mesh axis in int8 (4x fewer wire bytes than f32): local
  scale = global max |x| (one scalar f32 all-reduce), quantize, integer
  psum, dequantize.  Used by the pod-compressed training variant and the
  collective benchmarks.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["ef_quantize", "compressed_psum"]


def ef_quantize(g: jnp.ndarray, err: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 quantization of one gradient tensor.

    Returns (dequantized gradient, new residual).  err has g's shape and
    f32 dtype; pass zeros at step 0."""
    x = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    deq = q * scale
    return deq.astype(g.dtype), x - deq


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8 all-reduce over `axis_name` (inside shard_map).

    Wire cost: 1 byte/elem for the payload + one f32 scalar, vs 4
    bytes/elem for an f32 psum."""
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    # accumulate in int32 (n_pods * 127 stays well inside int32)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale
