# The paper's primary contribution: surrogate-guided NSGA-II design-space
# exploration of approximate accelerators, retargeted from FPGA to TPU.
# Subpackages: acl (circuit library), features (cheap/synth extraction,
# pipelines A-F), surrogates (~20 regression models), nsga2/pareto/dse
# (the search), hw (v5e roofline), qor (PSNR metrics).
#
# NOTE: dse/features are imported lazily (import repro.core.dse) to avoid
# a circular import with repro.accel, which depends on repro.core.acl.
from . import hw, pareto, qor
from .nsga2 import NSGA2Config, nsga2

__all__ = ["hw", "pareto", "qor", "NSGA2Config", "nsga2"]
