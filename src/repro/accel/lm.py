"""LMAccelerator — the paper's DSE applied to the transformer stack.

The 'accelerator' is a (reduced-config) language model; the *slots* are
its projection classes (qkv / attn_out / ffn_in / ffn_out / experts / ssm
/ lm_head), each deployable as an int8 rank-k-corrected approximate
matmul (models/approx_linear).  The genome assigns one mul8s circuit per
class — exactly the accelerator-variant semantics of the paper, with

  QoR        = logits-PSNR of the approximate model vs the exact model
               (behavioral simulation at reduced scale),
  hw labels  = XLA-compile of the policy'd forward step -> roofline
               energy/latency (synthesis at reduced scale; relative cost
               transfers to the full config since every class's FLOP
               share is architecture-determined).

This makes run_dse / the surrogates / NSGA-II / the Figs. 5-9 benchmarks
reusable verbatim on LM architectures (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.acl.library import Circuit
from ..models import ApproxPolicy
from ..models.config import ModelConfig, reduced
from .base import Accelerator, Slot

__all__ = ["LMAccelerator", "proj_classes_for"]


def proj_classes_for(cfg: ModelConfig) -> List[Tuple[str, float]]:
    """[(projection class, relative FLOP share)] for this family."""
    d, ff, hd = cfg.d_model, max(cfg.d_ff, 1), cfg.resolved_head_dim
    qkv = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
    attn_out = d * hd * cfg.n_heads
    head = d * cfg.padded_vocab / max(cfg.n_layers, 1)
    out: List[Tuple[str, float]] = []
    has_attn = any(k.mixer == "attn" for k in cfg.block_pattern)
    if has_attn:
        out += [("qkv", qkv), ("attn_out", attn_out)]
    if any(k.mlp == "dense" for k in cfg.block_pattern):
        out += [("ffn_in", 2.0 * d * ff), ("ffn_out", d * ff)]
    if cfg.n_experts:
        act = cfg.n_experts_active
        out += [("expert_in", 2.0 * d * ff * act), ("expert_out", d * ff * act)]
    if any(k.mixer == "mamba" for k in cfg.block_pattern):
        di = cfg.d_inner
        out += [("ssm_in", 2.0 * d * di), ("ssm_out", di * d)]
    out += [("lm_head", head)]
    total = sum(w for _, w in out)
    return [(c, w / total) for c, w in out]


class LMAccelerator(Accelerator):
    def __init__(
        self,
        cfg: ModelConfig,
        *,
        use_reduced: bool = True,
        batch: int = 2,
        seq: int = 32,
        seed: int = 0,
    ):
        self.full_cfg = cfg
        self.cfg = reduced(cfg) if use_reduced else cfg
        self.name = f"lm:{cfg.name}"
        self.classes = proj_classes_for(self.cfg)
        self.slots = [Slot(c, "mul8s", w) for c, w in self.classes]
        self.batch, self.seq, self.seed = batch, seq, seed
        self._params = None
        self._logits_cache: Dict[bytes, np.ndarray] = {}

    # -- lazy shared weights -------------------------------------------------
    def _ensure_params(self):
        if self._params is None:
            import jax

            from ..models.common import init_tree
            from ..models.transformer import param_specs

            self._params = init_tree(
                param_specs(self.cfg), jax.random.PRNGKey(self.seed)
            )
        return self._params

    def sample_inputs(self, n: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        return rng.integers(
            0, self.cfg.vocab_size, size=(n, self.batch, self.seq)
        ).astype(np.int32)

    # -- policy plumbing ------------------------------------------------------
    def _policy(self, circuits: Sequence[Circuit],
                ranks: Optional[Sequence[Optional[int]]] = None) -> ApproxPolicy:
        ranks = ranks or [None] * len(circuits)
        assignments = {}
        for slot, c, r in zip(self.slots, circuits, ranks):
            if not c.is_exact:
                assignments[slot.name] = (c.name, r)
        return ApproxPolicy(assignments)

    def policy_for_genome(
        self,
        genome,
        library=None,
        *,
        rank_genes: bool = False,
    ) -> ApproxPolicy:
        """Decode one front genome to the ``ApproxPolicy`` the serving
        tier (and ``launch.serve --front``) feeds into the jitted
        prefill/decode steps.  This is the bridge from a stored Pareto
        point to a runnable model configuration."""
        if library is None:
            from ..core.acl.library import default_library

            library = default_library()
        genome = np.asarray(genome, dtype=np.int64).reshape(-1)
        width = len(self.slots) + (
            len(self.mul_slot_indices()) if rank_genes else 0
        )
        if len(genome) != width:
            raise ValueError(
                f"genome has {len(genome)} genes; {self.name} expects "
                f"{width} (rank_genes={rank_genes})"
            )
        circuits, ranks = self.decode(genome, library, rank_genes=rank_genes)
        return self._policy(circuits, ranks)

    def _forward(self, policy: Optional[ApproxPolicy], inputs: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp

        from ..models.transformer import forward

        params = self._ensure_params()
        outs = []
        for tok in inputs:
            kwargs = {}
            if self.cfg.is_encoder_decoder:
                rng = np.random.default_rng(self.seed)
                kwargs["enc_embeds"] = jnp.asarray(
                    rng.standard_normal((self.batch, 16, self.cfg.d_model))
                    .astype(np.float32) * 0.1)
            logits, _, _ = forward(
                params, self.cfg, jnp.asarray(tok), policy=policy,
                remat=False, attn_chunk=self.seq, scan_chunk=8, **kwargs,
            )
            outs.append(np.asarray(logits.astype(jnp.float32)))
        return np.stack(outs)

    # -- Accelerator interface ------------------------------------------------
    def simulate(self, circuits: Sequence[Circuit], inputs: np.ndarray) -> np.ndarray:
        return self._forward(self._policy(circuits), inputs)

    def exact_output(self, inputs: np.ndarray) -> np.ndarray:
        key = inputs.tobytes()
        if key not in self._logits_cache:
            self._logits_cache[key] = self._forward(None, inputs)
        return self._logits_cache[key]

    def qor_batch(
        self,
        genomes: np.ndarray,
        library,
        inputs: np.ndarray,
        *,
        rank_genes: bool = False,
        peak: float | None = None,
    ) -> np.ndarray:
        """Population path for the LM: the exact forward runs once for
        the whole batch (cached logits), distinct policies run once each
        (NSGA-II survivor sets repeat genomes heavily), and the per-
        genome logits are scored immediately instead of stacking the
        whole population's logits in memory."""
        from ..core import qor as qor_mod

        genomes = np.atleast_2d(np.asarray(genomes))
        ref = self.exact_output(inputs)
        uniq, inverse = np.unique(genomes, axis=0, return_inverse=True)
        vals = np.empty(len(uniq), dtype=np.float64)
        for i, g in enumerate(uniq):
            circuits, _ = self.decode(g, library, rank_genes=rank_genes)
            vals[i] = qor_mod.psnr(ref, self.simulate(circuits, inputs), peak)
        return vals[inverse]

    def build_deploy(self, specs: Sequence, inputs: Optional[np.ndarray] = None):
        """Deployment = the policy'd forward step of the reduced config;
        the compile's cost_analysis carries the (1 + rank)-matmul cost
        model for every approximated class."""
        import jax.numpy as jnp

        from ..models.transformer import forward

        policy = ApproxPolicy({
            slot.name: (spec.name, spec.rank)
            for slot, spec in zip(self.slots, specs)
            if not spec.is_exact
        })
        params = self._ensure_params()
        tok = jnp.asarray(self.sample_inputs(1, seed=1)[0])

        def fn(params, tok):
            kwargs = {}
            if self.cfg.is_encoder_decoder:
                kwargs["enc_embeds"] = jnp.zeros(
                    (self.batch, 16, self.cfg.d_model), jnp.bfloat16)
            logits, _, _ = forward(params, self.cfg, tok, policy=policy,
                                   remat=False, attn_chunk=self.seq,
                                   scan_chunk=8, **kwargs)
            return logits

        return fn, (params, tok)

    def mul_slot_constants(self):
        return [None] * len(self.slots)

    def adjusted_compute(self, circuits, ranks) -> float:
        """Dtype-aware MXU cost of one forward step of the reduced model:
        per projection class, (2 * N_class * tokens) MACs scaled by the
        circuit's deployment cost factor (unapproximated work — attention
        cores, norms — rides along at bf16 cost 1.0)."""
        from ..core import hw

        tokens = self.batch * self.seq
        n_active = self.cfg.active_param_count()
        total = 0.0
        for (cls, share), c, r in zip(self.classes, circuits, ranks):
            base = hw.V5E.dtype_cost_factor(c.deploy_width)
            rank = c.deploy_rank if r is None else (
                0 if c.native_width is not None else int(r)
            )
            total += 2.0 * n_active * share * tokens * (base + rank)
        return total


# The LM is not a LUT workload: its qor path is a deduped bf16 forward
# per distinct genome, not a table-driven population sim.  Opt it out of
# the fused population engine explicitly (counted as a pin-by-design).
from . import fused as _fused  # noqa: E402

_fused.register_unfused(LMAccelerator)
