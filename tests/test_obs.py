"""Flight recorder: span nesting + wire propagation (thread, process,
fleet-HTTP boundaries), race-free metrics under a hammered ``stats()``,
Prometheus rendering, Chrome-trace export, and the per-campaign
telemetry timeline."""

import json
import os
import re
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.accel import MCMAccelerator
from repro.core.acl.library import default_library
from repro.obs.export import load_jsonl, main as export_main, to_chrome_trace
from repro.obs.metrics import Registry
from repro.service import (
    CampaignManager,
    CampaignSpec,
    EvalContext,
    EvalScheduler,
    InMemoryLabelStore,
)
from repro.service.store import LABEL_KEYS

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SMALL = dict(n_train=10, n_qor_samples=2, pop_size=8, n_parents=4,
             n_generations=2)


@pytest.fixture(autouse=True)
def _obs_state():
    """Tracing is process-global: restore it whatever a test does."""
    yield
    obs.set_enabled(True)
    obs.set_sink(None)


def _wait_for(pred, timeout=60.0, every=0.01, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(every)
    raise AssertionError(f"timed out waiting for {what}")


# ---------------------------------------------------------------------------
# trace core
# ---------------------------------------------------------------------------

def test_span_nesting_parents_and_baggage():
    rec = obs.recorder()
    rec.clear()
    with obs.context(campaign="c-unit", trace_id="c-unit", stage="train"):
        with obs.span("outer.op", n=3) as outer:
            with obs.span("inner.op"):
                pass
            outer_id = outer.span_id
    spans = {s["name"]: s for s in rec.snapshot()}
    assert spans["inner.op"]["parent"] == outer_id
    assert spans["inner.op"]["trace"] == "c-unit"
    assert spans["outer.op"]["trace"] == "c-unit"
    # baggage lands in every span's attrs
    assert spans["outer.op"]["attrs"]["campaign"] == "c-unit"
    assert spans["inner.op"]["attrs"]["stage"] == "train"
    assert spans["outer.op"]["attrs"]["n"] == 3
    assert spans["outer.op"]["dur"] >= 0.0


def test_wire_context_roundtrips_through_json():
    """The wire codec is what rides fleet lease responses: it must
    survive a JSON round trip and re-parent spans on the far side."""
    rec = obs.recorder()
    rec.clear()
    with obs.context(campaign="c-wire", trace_id="c-wire"):
        with obs.span("parent.op") as parent:
            wire = obs.wire_context()
            parent_id = parent.span_id
    wire = json.loads(json.dumps(wire))  # over the wire and back
    with obs.attach(wire, worker="w9", lease="L1"):
        with obs.span("remote.op"):
            pass
    remote = [s for s in rec.snapshot() if s["name"] == "remote.op"][0]
    assert remote["trace"] == "c-wire"
    assert remote["parent"] == parent_id
    assert remote["attrs"]["campaign"] == "c-wire"
    assert remote["attrs"]["worker"] == "w9"
    assert remote["attrs"]["lease"] == "L1"
    # garbage wire still labels worker-local spans
    with obs.attach(None, worker="w9"):
        with obs.span("orphan.op"):
            pass
    orphan = [s for s in rec.snapshot() if s["name"] == "orphan.op"][0]
    assert orphan["attrs"]["worker"] == "w9"


def test_disabled_tracing_noops():
    rec = obs.recorder()
    rec.clear()
    obs.set_enabled(False)
    assert obs.wire_context() is None
    with obs.context(campaign="nope"):
        with obs.span("invisible.op") as sp:
            sp.set(k=1)  # null span: must not raise
    assert rec.snapshot() == []
    obs.set_enabled(True)


def test_recorder_ring_bound_and_ingest():
    rec = obs.Recorder(ring=4)
    for i in range(10):
        rec.emit({"name": f"s{i}", "t0": 0.0, "dur": 0.0})
    assert len(rec.snapshot()) == 4
    assert rec.stats()["spans"] == 10
    rec.ingest([{"name": "far", "t0": 0.0}, {"bogus": 1}, "junk"])
    assert rec.stats()["ingested"] == 1
    assert rec.snapshot()[-1]["name"] == "far"


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [^ ]+$"
)


def _parse_prometheus(text):
    """Tiny exposition-format checker: every non-comment line must be a
    valid sample; returns {name_with_labels: float}."""
    out = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        assert _SAMPLE_RE.match(line), f"bad prometheus line: {line!r}"
        name, _, value = line.rpartition(" ")
        out[name] = float(value)
    return out


def test_prometheus_render_parses():
    reg = Registry()
    c = reg.counter("t_requests_total", "requests")
    g = reg.gauge("t_depth", "queue depth")
    h = reg.histogram("t_seconds", "latency", buckets=(0.1, 1.0))
    c.inc()
    c.inc(2)
    g.set(5)
    h.observe(0.05)
    h.observe(0.5)
    h.observe(99.0)
    text = reg.render()
    assert "# HELP t_requests_total requests" in text
    assert "# TYPE t_seconds histogram" in text
    samples = _parse_prometheus(text)
    assert samples["t_requests_total"] == 3.0
    assert samples["t_depth"] == 5.0
    assert samples['t_seconds_bucket{le="0.1"}'] == 1.0
    assert samples['t_seconds_bucket{le="1"}'] == 2.0
    assert samples['t_seconds_bucket{le="+Inf"}'] == 3.0
    assert samples["t_seconds_count"] == 3.0
    assert samples["t_seconds_sum"] == pytest.approx(99.55)


def test_counter_concurrent_increments_exact():
    """Per-thread shards: N threads incrementing concurrently must lose
    nothing (the old dict counters could)."""
    reg = Registry()
    c = reg.counter("t_conc_total", "x")
    h = reg.histogram("t_conc_seconds", "x", buckets=(1.0,))
    N, K = 8, 5000

    def work():
        for _ in range(K):
            c.inc()
            h.observe(0.5)

    threads = [threading.Thread(target=work) for _ in range(N)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == N * K
    assert h.count == N * K
    assert h.sum == pytest.approx(0.5 * N * K)


class _SlowCtx:
    """EvalContext stand-in with a slow, observable ground truth."""

    def __init__(self, delay=0.003):
        self.fingerprint = "obs-testctx"
        self.delay = delay

    def key(self, genome):
        return "g" + "-".join(str(int(v)) for v in np.atleast_1d(genome))

    def ground_truth(self, genomes):
        genomes = np.atleast_2d(genomes)
        time.sleep(self.delay)
        val = genomes.sum(axis=1).astype(float)
        return {k: val.copy() for k in LABEL_KEYS}


def test_scheduler_stats_race_regression():
    """Hammer ``stats()`` from several threads while batches run on the
    thread backend: reads must never raise, never go backwards, and end
    exactly consistent with the submitted work."""
    sched = EvalScheduler(InMemoryLabelStore(), n_workers=2,
                          max_batch=8, max_wait_s=0.002)
    ctx = _SlowCtx()
    stop = threading.Event()
    errors = []

    def hammer():
        # monotonicity is a per-reader property: each thread tracks the
        # highest values IT has seen
        req = lab = 0
        try:
            while not stop.is_set():
                s = sched.stats()
                assert s["requests"] >= req
                assert s["labeled"] >= lab
                req, lab = s["requests"], s["labeled"]
        except Exception as exc:  # noqa: BLE001 - reported below
            errors.append(exc)

    hammers = [threading.Thread(target=hammer) for _ in range(4)]
    for t in hammers:
        t.start()
    try:
        total = 0
        for rnd in range(6):
            genomes = np.arange(rnd * 32, rnd * 32 + 16).reshape(8, 2)
            sched.label(ctx, genomes, campaign=f"c{rnd % 2}")
            total += 8
    finally:
        stop.set()
        for t in hammers:
            t.join()
        sched.shutdown()
    assert not errors, errors
    s = sched.stats()
    assert s["requests"] == total
    assert (s["labeled"] + s["store_hits"]
            + s["inflight_dedup_hits"]) == total


# ---------------------------------------------------------------------------
# chrome trace export
# ---------------------------------------------------------------------------

def test_chrome_trace_export_valid_and_nested(tmp_path):
    sink = str(tmp_path / "dse.trace.jsonl")
    obs.set_sink(sink)
    try:
        with obs.context(campaign="c-exp", trace_id="c-exp"):
            with obs.span("sched.batch", n=4) as outer:
                outer_id = outer.span_id
                with obs.span("synth.compile", kind="structural"):
                    time.sleep(0.002)
    finally:
        obs.set_sink(None)
    # a torn tail must be skipped, not fatal
    with open(sink, "a") as f:
        f.write('{"name": "torn.span", "t0": 1.0, "dur"')
    assert export_main([sink, "--chrome-trace"]) == 0
    out = tmp_path / "dse.trace.json"
    doc = json.loads(out.read_text())
    events = doc["traceEvents"]
    slices = {e["name"]: e for e in events if e["ph"] == "X"}
    assert "torn.span" not in slices
    assert slices["synth.compile"]["args"]["parent"] == outer_id
    assert slices["synth.compile"]["args"]["trace"] == "c-exp"
    assert slices["synth.compile"]["cat"] == "synth"
    assert slices["sched.batch"]["args"]["campaign"] == "c-exp"
    # complete events with µs timestamps and a nonzero floor
    for e in slices.values():
        assert e["ts"] > 1e15 and e["dur"] >= 1.0
    assert any(e["ph"] == "M" and e["name"] == "process_name"
               for e in events)
    spans, skipped = load_jsonl(sink)
    assert len(spans) == 2 and skipped == 1


def test_export_labels_fleet_worker_processes():
    doc = to_chrome_trace([
        {"name": "worker.serve", "t0": 1.0, "dur": 0.1, "pid": 41,
         "tid": 1, "attrs": {"worker": "w0"}},
        {"name": "sched.batch", "t0": 1.0, "dur": 0.2, "pid": 42, "tid": 1},
    ])
    meta = {e["pid"]: e["args"]["name"] for e in doc["traceEvents"]
            if e["ph"] == "M"}
    assert meta[41] == "fleet worker w0 (pid 41)"
    assert meta[42] == "pid 42"


# ---------------------------------------------------------------------------
# timeline
# ---------------------------------------------------------------------------

def test_timeline_hypervolume_monotone_and_frozen_ref():
    tl = obs.Timeline(maxlen=8)
    r1 = tl.sample("c", objectives=np.array([[1.0, 1.0], [0.8, 1.2]]),
                   stage="explore", labels_requested=10)
    ref = tl.reference("c")
    assert ref is not None
    # a strictly better front against the FROZEN reference grows volume
    r2 = tl.sample("c", objectives=np.array([[0.5, 0.5], [0.4, 0.9]]))
    assert tl.reference("c") == ref
    assert r2["hypervolume"] > r1["hypervolume"]
    assert r1["front_size"] == 2
    assert r1["stage"] == "explore" and r1["labels_requested"] == 10.0
    series = tl.series("c")
    assert [s["rel_s"] for s in series] == sorted(s["rel_s"] for s in series)
    # non-finite rows are dropped; a non-2D front adds no hv fields
    r3 = tl.sample("c", objectives=np.array([[np.nan, 1.0]]))
    assert "hypervolume" not in r3
    # ring is bounded
    for _ in range(20):
        tl.sample("c", labels_requested=1)
    assert len(tl.series("c")) == 8
    tl.forget("c")
    assert tl.series("c") == [] and tl.reference("c") is None


# ---------------------------------------------------------------------------
# fleet: trace context across the worker subprocess boundary
# ---------------------------------------------------------------------------

def _spawn_worker(base, wid):
    return subprocess.Popen(
        [sys.executable, "-m", "repro.fleet.worker",
         "--orchestrator", base, "--id", wid, "--no-warm",
         "--max-idle-s", "120"],
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
        env={**os.environ, "PYTHONPATH": SRC},
    )


def test_fleet_spans_survive_worker_subprocess_roundtrip():
    """The satellite acceptance check: a fleet batch's spans — recorded
    inside a real ``python -m repro.fleet.worker`` subprocess — come
    back on the result payload with the campaign trace id and lease id
    intact, and the lease lifecycle span closes with outcome=ok."""
    from repro.fleet import FleetCoordinator, serve_fleet

    lib = default_library()
    coord = FleetCoordinator(lease_ttl_s=60.0, heartbeat_ttl_s=30.0)
    srv = serve_fleet(coord, port=0)
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    rec = obs.recorder()
    proc = _spawn_worker(base, "obs-w0")
    try:
        _wait_for(lambda: coord.stats()["live"] >= 1, timeout=300,
                  what="fleet worker to register")
        rec.clear()
        ctx = EvalContext(MCMAccelerator(1), lib, n_qor_samples=2)
        rng = np.random.default_rng(7)
        sizes = ctx.accel.gene_sizes(lib)
        genomes = rng.integers(0, sizes[None, :], size=(6, len(sizes)))
        with obs.context(campaign="c-fleet", trace_id="c-fleet"):
            labels = coord.label(ctx, genomes)
        assert set(LABEL_KEYS) <= set(labels)

        spans = rec.snapshot()
        serve = [s for s in spans if s["name"] == "worker.serve"]
        assert serve, sorted({s["name"] for s in spans})
        for s in serve:
            assert s["trace"] == "c-fleet"          # across HTTP + process
            assert s["attrs"]["campaign"] == "c-fleet"
            assert s["attrs"]["worker"] == "obs-w0"
            assert s["attrs"]["lease"]
            assert s["pid"] != os.getpid()          # recorded on the far side
        leases = [s for s in spans if s["name"] == "fleet.lease"]
        assert leases and all(s["trace"] == "c-fleet" for s in leases)
        assert any(s["attrs"].get("outcome") == "ok" for s in leases)
        batch = [s for s in spans if s["name"] == "fleet.batch"]
        assert len(batch) == 1 and batch[0]["trace"] == "c-fleet"
        # worker spans were ingested, not recorded locally
        assert rec.stats()["ingested"] >= len(serve)
    finally:
        if proc.poll() is None:
            proc.kill()
        coord.shutdown()
        srv.shutdown()


# ---------------------------------------------------------------------------
# service end to end: tick spans, /metrics, /campaigns/<id>/timeline
# ---------------------------------------------------------------------------

def test_campaign_timeline_and_metrics_endpoints(tmp_path):
    import urllib.request

    from repro.service.api import make_server

    sink = str(tmp_path / "svc.trace.jsonl")
    obs.set_sink(sink)
    mgr = CampaignManager(eval_workers=2, campaign_workers=2)
    srv = make_server(mgr, port=0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    try:
        cid = mgr.submit(CampaignSpec(accel="mcm2", **SMALL))
        assert mgr.wait(cid, timeout=600) == "done"

        tl = json.load(urllib.request.urlopen(
            f"{base}/campaigns/{cid}/timeline"))
        assert tl["id"] == cid and tl["state"] == "done"
        samples = tl["samples"]
        assert len(samples) >= 3
        stages = [s.get("stage") for s in samples]
        assert "train" in stages and "done" in stages
        assert any("hypervolume" in s for s in samples)
        assert samples[-1]["labels_requested"] > 0
        assert "hv_reference" in tl

        text = urllib.request.urlopen(f"{base}/metrics").read().decode()
        samples_m = _parse_prometheus(text)
        assert samples_m["repro_sched_requests_total"] > 0
        assert samples_m["repro_sched_batches_total"] > 0
        assert any(k.startswith("repro_synth_") for k in samples_m)

        stats = json.load(urllib.request.urlopen(f"{base}/stats"))
        assert stats["obs"]["recorder"]["spans"] > 0
        assert stats["obs"]["timeline_campaigns"] >= 1

        # unknown campaign -> 404, same contract as the other GETs
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{base}/campaigns/nope/timeline")
        assert ei.value.code == 404
    finally:
        obs.set_sink(None)
        srv.shutdown()
        mgr.shutdown()

    # the sink holds the correlated spans of the whole campaign
    spans, skipped = load_jsonl(sink)
    assert skipped == 0
    by_name = {}
    for s in spans:
        by_name.setdefault(s["name"], []).append(s)
    for required in ("campaign.tick", "campaign.deliver", "sched.batch"):
        assert required in by_name, sorted(by_name)
    assert {s["trace"] for s in by_name["campaign.tick"]} == {cid}
    assert all(s["attrs"].get("campaign") == cid
               for s in by_name["sched.batch"])
