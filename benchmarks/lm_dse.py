"""The paper's DSE applied to an assigned LM architecture (the framework
as a first-class training/serving feature): surrogate PCC + front quality
+ exploration timing on granite-8b's projection classes."""

from __future__ import annotations

import numpy as np

from repro.accel.lm import LMAccelerator
from repro.configs import get_config
from repro.core.acl.library import default_library
from repro.core.dse import DSEConfig, run_dse
from repro.core.nsga2 import NSGA2Config

from .common import emit


def run(arch: str = "granite-8b", n_train: int = 24, generations: int = 6,
        seed: int = 0):
    accel = LMAccelerator(get_config(arch), seq=16)
    lib = default_library()
    cfg = DSEConfig(
        n_train=n_train, n_qor_samples=1,
        nsga=NSGA2Config(pop_size=24, n_parents=8,
                         n_generations=generations, seed=seed),
        seed=seed,
    )
    res = run_dse(accel, lib, cfg)
    emit(f"lm_dse.{arch}.pcc_qor", 0.0, round(res.val_pcc["qor"], 3))
    emit(f"lm_dse.{arch}.pcc_energy", 0.0, round(res.val_pcc["energy"], 3))
    emit(f"lm_dse.{arch}.front_size", 0.0, int(res.front_mask.sum()))
    emit(f"lm_dse.{arch}.surrogate_evals", 0.0, res.search.n_evaluated)
    emit(f"lm_dse.{arch}.explore_s",
         res.timings["explore"] * 1e6 / max(res.search.n_evaluated, 1),
         round(res.timings["explore"], 2))
    emit(f"lm_dse.{arch}.label_s", 0.0, round(res.timings["label"], 2))
    best_psnr = -res.true_objectives[:, 0].max()
    emit(f"lm_dse.{arch}.best_front_psnr", 0.0,
         round(float(-res.true_objectives[:, 0].min()), 2))
    return res
