"""ModelConfig — one dataclass covering all assigned architecture families
(dense / MoE / enc-dec / SSM / hybrid / VLM-audio-backbone).

Layers are organized as repeated *super-blocks* so heterogeneous stacks
(Jamba's 1-attention-per-8-layers, alternating MoE) scan with lax.scan:
``block_pattern`` describes the layers inside one super-block; the stack
is ``n_layers / len(block_pattern)`` scanned super-blocks.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

__all__ = ["LayerKind", "ModelConfig", "reduced"]


@dataclass(frozen=True)
class LayerKind:
    mixer: str = "attn"        # "attn" | "mamba"
    mlp: str = "dense"         # "dense" | "moe" | "none"
    cross_attn: bool = False   # decoder cross-attention (enc-dec)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | encdec | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None            # default d_model // n_heads
    # --- normalization / activations ---
    mlp_act: str = "silu"                     # silu->SwiGLU, gelu->GeGLU
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- rope ---
    rope_theta: float = 10000.0
    rope_style: str = "standard"              # standard | half (chatglm 2d) | mrope
    # --- MoE ---
    n_experts: int = 0
    n_experts_active: int = 0
    moe_period: int = 1                       # MoE every `moe_period` layers
    capacity_factor: float = 1.25
    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0                          # default ceil(d_model/16)
    attn_period: int = 0                      # hybrid: 1 attn per N layers
    attn_offset: int = 4                      # position of attn in the block
    # --- enc-dec ---
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    # --- frontend stub ---
    frontend: str = "none"                    # none | audio | vision
    frontend_len: int = 0                     # embeddings prepended (vlm)
    # --- numerics / training ---
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"              # master weights
    moment_dtype: str = "float32"             # Adam moments
    # --- sharding rule overrides (tuple-of-pairs; see dist.sharding) ---
    sharding_overrides: Tuple[Tuple[str, object], ...] = ()
    # --- notes carried into DESIGN/EXPERIMENTS ---
    notes: str = ""

    @property
    def sharding_rules(self) -> Dict[str, object]:
        return dict(self.sharding_overrides)

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // 256) * 256

    @property
    def padded_experts(self) -> int:
        """Experts padded to 16-way EP divisibility (e.g. granite-moe
        40 -> 48; padded experts are masked out of routing).  Small expert
        counts (<=16) are left unpadded and replicate under the fallback
        rule when they don't divide the model axis."""
        if self.n_experts > 16:
            return -(-self.n_experts // 16) * 16
        return self.n_experts

    @property
    def block_pattern(self) -> Tuple[LayerKind, ...]:
        """Layer kinds inside one super-block."""
        if self.family == "ssm":
            return (LayerKind(mixer="mamba", mlp="none"),)
        if self.family == "hybrid":
            period = self.attn_period or 8
            kinds = []
            for i in range(period):
                mixer = "attn" if i == (self.attn_offset % period) else "mamba"
                mlp = (
                    "moe"
                    if self.n_experts and i % self.moe_period == self.moe_period - 1
                    else "dense"
                )
                kinds.append(LayerKind(mixer=mixer, mlp=mlp))
            return tuple(kinds)
        mlp = "moe" if self.n_experts else "dense"
        xattn = self.is_encoder_decoder
        if self.n_experts and self.moe_period > 1:
            kinds = [
                LayerKind(
                    mlp="moe" if i % self.moe_period else "dense",
                    cross_attn=xattn,
                )
                for i in range(self.moe_period)
            ]
            return tuple(kinds)
        return (LayerKind(mlp=mlp, cross_attn=xattn),)

    @property
    def n_superblocks(self) -> int:
        p = len(self.block_pattern)
        assert self.n_layers % p == 0, (self.name, self.n_layers, p)
        return self.n_layers // p

    @property
    def is_attention_free(self) -> bool:
        return all(k.mixer != "attn" for k in self.block_pattern)

    @property
    def supports_long_context(self) -> bool:
        """long_500k runs only for SSM/hybrid archs (assignment brief)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate dense parameter count (embeddings included)."""
        d, ff, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        total = v * d * (1 if self.tie_embeddings else 2)
        for kind in self.block_pattern:
            n = self.n_superblocks
            if kind.mixer == "attn":
                total += n * d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
            else:
                di, st, dtr = self.d_inner, self.ssm_state, self.resolved_dt_rank
                total += n * (
                    d * 2 * di + di * self.ssm_conv + di * (dtr + 2 * st)
                    + dtr * di + di * st + di + di * d
                )
            if kind.cross_attn:
                total += n * d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
            if kind.mlp == "dense":
                total += n * 3 * d * ff
            elif kind.mlp == "moe":
                total += n * (self.n_experts * 3 * d * ff + d * self.n_experts)
        if self.is_encoder_decoder:
            # encoder layers mirror the decoder's self-attn + mlp
            total += self.n_enc_layers * (
                d * hd * (self.n_heads * 2 + self.n_kv_heads * 2) + 3 * d * ff
            )
        return int(total)

    def active_param_count(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6*N_active*D)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(
            1 for k in self.block_pattern if k.mlp == "moe"
        ) * self.n_superblocks
        inactive = (
            moe_layers
            * (self.n_experts - self.n_experts_active)
            * 3 * self.d_model * self.d_ff
        )
        return int(full - inactive)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A small same-family config for CPU smoke tests."""
    pattern = len(cfg.block_pattern)
    defaults = dict(
        n_layers=pattern * (2 if pattern > 1 else 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        n_experts_active=min(cfg.n_experts_active, 2) if cfg.n_experts else 0,
        n_enc_layers=2 if cfg.is_encoder_decoder else 0,
        dt_rank=8 if cfg.family in ("ssm", "hybrid") else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        frontend_len=min(cfg.frontend_len, 8) if cfg.frontend_len else 0,
        name=cfg.name + "-smoke",
    )
    defaults.update(overrides)
    return replace(cfg, **defaults)
