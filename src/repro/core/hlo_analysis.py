"""Trip-count-aware cost analysis of post-optimization HLO text.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE, so
any scanned model (layers / microbatches / KV chunks) is undercounted by
orders of magnitude.  This module re-derives the §Roofline inputs from
``compiled.as_text()`` — the partitioned, optimized module, whose shapes
are already per-device — using the ``known_trip_count`` backend_config
XLA attaches to its while ops:

  * FLOPs        — 2*MNK for every dot (incl. batch dims), 2*out*k for
                   convolutions, multiplied through the call graph
                   (while bodies x trip count; fusion/call/cond x 1).
  * HBM bytes    — per *top-level* op (= kernel-launch granularity):
                   result + operand bytes.  Ops inside fusion
                   subcomputations contribute no traffic (they live in
                   registers/VMEM); tuple/GTE/bitcast/parameter are free.
  * collective bytes — ring-model accounting per op class (same
                   conventions as core.hw.collective_bytes_from_hlo),
                   with loop multipliers applied.

This is an approximation (elementwise FLOPs ignored; buffer reuse within
a kernel ignored) but is exact for the matmul-dominated workloads here
and, unlike XLA's aggregate, correct across loops.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .hw import DTYPE_BYTES

__all__ = ["HloCost", "analyze_hlo"]

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$"
)
# computation headers start at column 0: "%name (params...) -> type {"
# (params may contain nested parens, so match only the leading name)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_FREE_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "after-all", "opt-barrier", "partition-id", "replica-id", "iota",
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
}


def _shape_info(type_str: str) -> Tuple[int, int]:
    """-> (total elements, total bytes) over possibly-tuple type."""
    elems = 0
    byts = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in DTYPE_BYTES and dtype != "pred":
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * DTYPE_BYTES.get(dtype, 4)
    return elems, byts


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str            # everything after the opening paren
    result_bytes: int = 0
    result_elems: int = 0


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # op name -> type


@dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_detail: Dict[str, float] = field(default_factory=dict)
    n_while: int = 0
    dot_flops_by_site: Dict[str, float] = field(default_factory=dict)
    hbm_by_site: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.n_while += other.n_while
        for k, v in other.collective_detail.items():
            self.collective_detail[k] = (
                self.collective_detail.get(k, 0.0) + v * mult
            )
        for k, v in other.dot_flops_by_site.items():
            self.dot_flops_by_site[k] = (
                self.dot_flops_by_site.get(k, 0.0) + v * mult
            )
        for k, v in other.hbm_by_site.items():
            self.hbm_by_site[k] = self.hbm_by_site.get(k, 0.0) + v * mult


def _parse(text: str) -> Tuple[Dict[str, _Computation], Optional[str], Set[str]]:
    comps: Dict[str, _Computation] = {}
    entry: Optional[str] = None
    fusion_called: Set[str] = set()
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = _Computation(m.group(2))
                if m.group(1):
                    entry = cur.name
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        op = _Op(m.group(1), m.group(2), m.group(3), m.group(4))
        op.result_elems, op.result_bytes = _shape_info(op.type_str)
        cur.ops.append(op)
        cur.symbols[op.name] = op.type_str
        if op.opcode == "fusion":
            cm = _CALLS_RE.search(op.rest)
            if cm:
                fusion_called.add(cm.group(1))
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry, fusion_called


def _dot_flops(op: _Op, symbols: Dict[str, str]) -> float:
    # contraction size from the lhs operand's shape
    cm = _CONTRACT_RE.search(op.rest)
    operands = _OPERAND_RE.findall(op.rest.split(")", 1)[0])
    k = 1
    if cm and operands:
        lhs_type = symbols.get(operands[0], "")
        sm = _SHAPE_RE.search(lhs_type)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for ci in cm.group(1).split(","):
                if ci != "" and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * op.result_elems * k


def _conv_flops(op: _Op, symbols: Dict[str, str]) -> float:
    # 2 * out_elems * (kernel elems / output features): approximate via
    # rhs (kernel) size / out_features
    operands = _OPERAND_RE.findall(op.rest.split(")", 1)[0])
    if len(operands) < 2:
        return 0.0
    rhs_type = symbols.get(operands[1], "")
    k_elems, _ = _shape_info(rhs_type)
    out_feat = 1
    sm = _SHAPE_RE.search(op.type_str)
    if sm:
        dims = [int(d) for d in sm.group(2).split(",") if d]
        if dims:
            out_feat = dims[-1]
    spatial = max(k_elems // max(out_feat, 1), 1)
    return 2.0 * op.result_elems * spatial


def _operand_names(op: _Op) -> List[str]:
    return _OPERAND_RE.findall(op.rest.split(")", 1)[0])


def _operand_bytes(op: _Op, symbols: Dict[str, str]) -> int:
    total = 0
    for name in _operand_names(op):
        t = symbols.get(name)
        if t:
            total += _shape_info(t)[1]
    return total


_SLICING_OPS = {"dynamic-slice", "slice", "gather"}
_UPDATE_OPS = {"dynamic-update-slice", "scatter"}


def _op_traffic(op: _Op, symbols: Dict[str, str],
                comps: Optional[Dict[str, "_Computation"]] = None) -> float:
    """HBM bytes touched by one top-level op.

    Slicing ops read only the slice, not the whole operand (a scan's
    per-iteration dynamic-slice on the stacked weights would otherwise
    count the full stack every iteration — a ~100x overcount).  In-place
    update ops touch ~2x the update region.  Fusions are charged per
    *parameter usage*: parameters consumed only by slicing/update ops
    inside the fusion are charged at slice granularity.
    """
    oc = op.opcode
    if oc in _SLICING_OPS:
        return 2.0 * op.result_bytes
    if oc in _UPDATE_OPS:
        ops_names = _operand_names(op)
        upd = symbols.get(ops_names[1], "") if len(ops_names) > 1 else ""
        ub = _shape_info(upd)[1] if upd else op.result_bytes
        return 2.0 * ub
    if oc == "fusion" and comps is not None:
        cm = _CALLS_RE.search(op.rest)
        child = comps.get(cm.group(1)) if cm else None
        if child is not None:
            # positional parameter map
            par_names: Dict[int, str] = {}
            for cop in child.ops:
                if cop.opcode == "parameter":
                    idx_str = cop.rest.split(")", 1)[0]
                    try:
                        par_names[int(idx_str)] = cop.name
                    except ValueError:
                        pass
            operands = _operand_names(op)
            total = 0.0
            for i, name in enumerate(operands):
                t = symbols.get(name)
                full = _shape_info(t)[1] if t else 0
                pname = par_names.get(i)
                if pname is None:
                    total += full
                    continue
                users = [
                    u for u in child.ops
                    if pname in _operand_names(u) and u.opcode != "parameter"
                ]
                if users and all(
                    u.opcode in _SLICING_OPS
                    or (u.opcode in _UPDATE_OPS
                        and _operand_names(u)[0] == pname)
                    for u in users
                ):
                    sliced = 0.0
                    for u in users:
                        if u.opcode in _SLICING_OPS:
                            sliced += u.result_bytes
                        else:
                            unames = _operand_names(u)
                            ut = child.symbols.get(unames[1], "") if len(unames) > 1 else ""
                            sliced += _shape_info(ut)[1] if ut else u.result_bytes
                    total += min(sliced, full)
                else:
                    total += full
            # fusion result: in-place DUS root writes only the update
            root = child.ops[-1] if child.ops else None
            if root is not None and root.opcode in _UPDATE_OPS:
                unames = _operand_names(root)
                ut = child.symbols.get(unames[1], "") if len(unames) > 1 else ""
                total += _shape_info(ut)[1] if ut else op.result_bytes
            else:
                total += op.result_bytes
            return total
    return float(op.result_bytes + _operand_bytes(op, symbols))


def _collective_bytes(op: _Op, symbols: Dict[str, str]) -> Tuple[str, float]:
    kind = op.opcode.replace("-start", "").replace("-done", "")
    if op.opcode.endswith("-done"):
        return kind, 0.0  # counted at -start
    if kind == "all-reduce":
        return kind, 2.0 * op.result_bytes
    if kind == "all-gather":
        return kind, float(op.result_bytes)
    # reduce-scatter / all-to-all / collective-permute: operand size
    return kind, float(_operand_bytes(op, symbols) or op.result_bytes)


def analyze_hlo(text: str) -> HloCost:
    comps, entry, fusion_called = _parse(text)
    memo: Dict[Tuple[str, bool], HloCost] = {}

    def evaluate(name: str, traffic: bool) -> HloCost:
        key = (name, traffic)
        if key in memo:
            return memo[key]
        memo[key] = HloCost()  # cycle guard (HLO is acyclic, but be safe)
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        cost = HloCost()
        for op in comp.ops:
            oc = op.opcode
            base = oc.replace("-start", "").replace("-done", "")
            if oc == "dot":
                f = _dot_flops(op, comp.symbols)
                cost.flops += f
                site = name
                cost.dot_flops_by_site[site] = (
                    cost.dot_flops_by_site.get(site, 0.0) + f
                )
            elif oc == "convolution":
                cost.flops += _conv_flops(op, comp.symbols)
            if base in _COLLECTIVES:
                kind, b = _collective_bytes(op, comp.symbols)
                cost.collective_bytes += b
                cost.collective_detail[kind] = (
                    cost.collective_detail.get(kind, 0.0) + b
                )
            # traffic accounting at kernel-launch granularity
            if traffic and oc not in _FREE_OPS and oc != "while":
                b = _op_traffic(op, comp.symbols, comps)
                cost.hbm_bytes += b
                site = f"{name}::{oc}"
                cost.hbm_by_site[site] = cost.hbm_by_site.get(site, 0.0) + b
            # recurse into called computations
            if oc == "while":
                cost.n_while += 1
                trip = 1
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trip = int(tm.group(1))
                bm = _CALLS_RE.search(op.rest)
                cm2 = _COND_RE.search(op.rest)
                if bm:
                    cost.add(evaluate(bm.group(1), traffic), trip)
                if cm2:
                    cost.add(evaluate(cm2.group(1), traffic), trip + 1)
            elif oc == "conditional":
                brm = _BRANCH_RE.search(op.rest)
                if brm:
                    branches = _OPERAND_RE.findall(brm.group(1))
                    # worst case: the most expensive branch
                    subs = [evaluate(b, traffic) for b in branches]
                    if subs:
                        worst = max(subs, key=lambda c: c.flops + c.hbm_bytes)
                        cost.add(worst)
            else:
                cm3 = _CALLS_RE.search(op.rest)
                if cm3 and cm3.group(1) in comps:
                    child = cm3.group(1)
                    # fusion internals: no HBM traffic, flops still count
                    cost.add(evaluate(child, traffic and child not in fusion_called))
        memo[key] = cost
        return cost

    if entry is None:
        return HloCost()
    return evaluate(entry, True)
