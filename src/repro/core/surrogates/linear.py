"""Linear-family surrogates: OLS, Ridge, Lasso, ElasticNet, Bayesian Ridge
(evidence maximization), Huber, SGD, and degree-2 polynomial ridge.

Bayesian Ridge is one of the paper's two production models (best power
estimator, Fig. 6)."""

from __future__ import annotations

import numpy as np

from .base import Model

__all__ = [
    "OLS",
    "Ridge",
    "Lasso",
    "ElasticNet",
    "BayesianRidge",
    "Huber",
    "SGDRegressor",
    "Poly2Ridge",
]


def _add_bias(X: np.ndarray) -> np.ndarray:
    return np.concatenate([X, np.ones((X.shape[0], 1))], axis=1)


class OLS(Model):
    def _fit(self, X, y):
        Xb = _add_bias(X)
        self.w, *_ = np.linalg.lstsq(Xb, y, rcond=None)

    def _predict(self, X):
        return _add_bias(X) @ self.w


class Ridge(Model):
    def __init__(self, alpha: float = 1.0, seed: int = 0):
        super().__init__(seed)
        self.alpha = alpha

    def _fit(self, X, y):
        Xb = _add_bias(X)
        d = Xb.shape[1]
        reg = self.alpha * np.eye(d)
        reg[-1, -1] = 0.0  # don't penalize the bias
        self.w = np.linalg.solve(Xb.T @ Xb + reg, Xb.T @ y)

    def _predict(self, X):
        return _add_bias(X) @ self.w


class Lasso(Model):
    """Coordinate descent on standardized features."""

    def __init__(self, alpha: float = 0.01, n_iter: int = 200, seed: int = 0):
        super().__init__(seed)
        self.alpha = alpha
        self.n_iter = n_iter

    def _fit(self, X, y):
        n, d = X.shape
        w = np.zeros(d)
        b = y.mean()
        col_sq = (X**2).sum(axis=0) + 1e-12
        r = y - b - X @ w
        lam = self.alpha * n
        for _ in range(self.n_iter):
            for j in range(d):
                r = r + X[:, j] * w[j]
                rho = X[:, j] @ r
                w[j] = np.sign(rho) * max(abs(rho) - lam, 0.0) / col_sq[j]
                r = r - X[:, j] * w[j]
            b_new = b + r.mean()
            r = r - (b_new - b)
            b = b_new
        self.w, self.b = w, b

    def _predict(self, X):
        return X @ self.w + self.b


class ElasticNet(Lasso):
    def __init__(self, alpha: float = 0.01, l1_ratio: float = 0.5, n_iter: int = 200, seed: int = 0):
        super().__init__(alpha, n_iter, seed)
        self.l1_ratio = l1_ratio

    def _fit(self, X, y):
        n, d = X.shape
        w = np.zeros(d)
        b = y.mean()
        lam1 = self.alpha * self.l1_ratio * n
        lam2 = self.alpha * (1 - self.l1_ratio) * n
        col_sq = (X**2).sum(axis=0) + lam2 + 1e-12
        r = y - b - X @ w
        for _ in range(self.n_iter):
            for j in range(d):
                r = r + X[:, j] * w[j]
                rho = X[:, j] @ r
                w[j] = np.sign(rho) * max(abs(rho) - lam1, 0.0) / col_sq[j]
                r = r - X[:, j] * w[j]
            b_new = b + r.mean()
            r = r - (b_new - b)
            b = b_new
        self.w, self.b = w, b


class BayesianRidge(Model):
    """Type-II maximum likelihood (evidence maximization) over the weight
    prior precision `alpha` and the noise precision `beta` — the classic
    MacKay iteration, matching sklearn's BayesianRidge behaviour."""

    def __init__(self, n_iter: int = 300, tol: float = 1e-4, seed: int = 0):
        super().__init__(seed)
        self.n_iter = n_iter
        self.tol = tol

    def _fit(self, X, y):
        n, d = X.shape
        alpha, beta = 1.0, 1.0 / (y.var() + 1e-9)
        XtX = X.T @ X
        Xty = X.T @ y
        eigs = np.linalg.eigvalsh(XtX)
        m = np.zeros(d)
        for _ in range(self.n_iter):
            A = alpha * np.eye(d) + beta * XtX
            m_new = beta * np.linalg.solve(A, Xty)
            lam = beta * eigs
            gamma = float((lam / (lam + alpha)).sum())
            alpha = gamma / float(m_new @ m_new + 1e-12)
            resid = y - X @ m_new
            beta = max(n - gamma, 1e-9) / float(resid @ resid + 1e-12)
            if np.max(np.abs(m_new - m)) < self.tol:
                m = m_new
                break
            m = m_new
        self.w = m
        self.alpha_, self.beta_ = alpha, beta
        self.Sigma = np.linalg.inv(alpha * np.eye(d) + beta * XtX)

    def _predict(self, X):
        return X @ self.w

    def predict_std(self, X) -> np.ndarray:
        """Posterior predictive std — available for acquisition heuristics."""
        X = self._xs.transform(np.asarray(X, dtype=np.float64))
        var = 1.0 / self.beta_ + np.einsum("nd,de,ne->n", X, self.Sigma, X)
        return np.sqrt(np.maximum(var, 0)) * self._ysd


class Huber(Model):
    """IRLS Huber regression (robust linear)."""

    def __init__(self, delta: float = 1.0, n_iter: int = 50, seed: int = 0):
        super().__init__(seed)
        self.delta = delta
        self.n_iter = n_iter

    def _fit(self, X, y):
        Xb = _add_bias(X)
        w = np.linalg.lstsq(Xb, y, rcond=None)[0]
        for _ in range(self.n_iter):
            r = y - Xb @ w
            a = np.abs(r)
            wt = np.where(a <= self.delta, 1.0, self.delta / np.maximum(a, 1e-12))
            W = Xb * wt[:, None]
            w = np.linalg.solve(W.T @ Xb + 1e-8 * np.eye(Xb.shape[1]), W.T @ y)
        self.w = w

    def _predict(self, X):
        return _add_bias(X) @ self.w


class SGDRegressor(Model):
    """Plain minibatch SGD on squared loss (the paper cites SGD as one of
    the weaker alternatives evaluated by [15])."""

    def __init__(self, lr: float = 0.01, epochs: int = 100, batch: int = 32, seed: int = 0):
        super().__init__(seed)
        self.lr, self.epochs, self.batch = lr, epochs, batch

    def _fit(self, X, y):
        rng = np.random.default_rng(self.seed)
        n, d = X.shape
        w = np.zeros(d)
        b = 0.0
        for _ in range(self.epochs):
            order = rng.permutation(n)
            for s in range(0, n, self.batch):
                idx = order[s : s + self.batch]
                err = X[idx] @ w + b - y[idx]
                w -= self.lr * (X[idx].T @ err) / len(idx)
                b -= self.lr * err.mean()
        self.w, self.b = w, b

    def _predict(self, X):
        return X @ self.w + self.b


class Poly2Ridge(Ridge):
    """Ridge on degree-2 polynomial features (pairwise products)."""

    def _expand(self, X):
        n, d = X.shape
        cols = [X]
        for i in range(d):
            cols.append(X[:, i : i + 1] * X[:, i:])
        return np.concatenate(cols, axis=1)

    def _fit(self, X, y):
        super()._fit(self._expand(X), y)

    def _predict(self, X):
        return super()._predict(self._expand(X))
