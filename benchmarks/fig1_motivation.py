"""Fig. 1 — motivational analysis: platform retargeting changes the
Pareto-optimal set.

The paper shows ASIC-Pareto approximate accelerators are not FPGA-Pareto.
Our retarget shows the analogous (and stronger) effect for the TPU: the
circuit ranking under an ASIC-style cost proxy (partial-product array
size — smaller logic = cheaper) inverts under the MXU deployment cost
(natively-truncating circuits cheap, exotic logic circuits cost MORE than
exact because of their correction rank).

Derived metric: fraction of ASIC-Pareto variants that are NOT TPU-Pareto.
"""

from __future__ import annotations

import numpy as np

from repro.accel import GaussianFilter
from repro.core.acl.library import default_library
from repro.core.features import synth
from repro.core.pareto import non_dominated_mask

from .common import emit, time_fn


def asic_cost_proxy(accel, circuits) -> float:
    """ASIC-style area proxy: total partial-product rows + carry cells
    (smaller approximate logic = cheaper on ASIC)."""
    cost = 0.0
    for c in circuits:
        if c.kind == "add16":
            cost += c.carry_window
        else:
            cost += c.pp_rows * 8
    return cost


def run(n_variants: int = 120, seed: int = 0, qor_samples: int = 2):
    lib = default_library()
    accel = GaussianFilter()
    rng = np.random.default_rng(seed)
    sizes = accel.gene_sizes(lib)
    genomes = rng.integers(0, sizes[None, :], size=(n_variants, len(sizes)))
    inputs = accel.sample_inputs(qor_samples, seed=123)

    qor = np.zeros(n_variants)
    asic = np.zeros(n_variants)
    tpu = np.zeros(n_variants)
    cache: dict = {}

    def label_all():
        # QoR rides the batched population path (one vectorized sim)
        qor[:] = accel.qor_batch(genomes, lib, inputs)
        for t, g in enumerate(genomes):
            circuits, ranks = accel.decode(g, lib)
            asic[t] = asic_cost_proxy(accel, circuits)
            tpu[t] = synth.synthesize_variant(accel, circuits, ranks,
                                              cache=cache)["energy"]

    us = time_fn(label_all, repeat=1, warmup=0)

    asic_front = non_dominated_mask(np.stack([-qor, asic], axis=1))
    tpu_front = non_dominated_mask(np.stack([-qor, tpu], axis=1))
    asic_idx = set(np.flatnonzero(asic_front).tolist())
    tpu_idx = set(np.flatnonzero(tpu_front).tolist())
    mismatch = len(asic_idx - tpu_idx) / max(len(asic_idx), 1)

    emit("fig1.variants_labeled", us / n_variants, n_variants)
    emit("fig1.asic_front_size", 0.0, len(asic_idx))
    emit("fig1.tpu_front_size", 0.0, len(tpu_idx))
    emit("fig1.pareto_mismatch_fraction", 0.0, round(mismatch, 3))
    return mismatch
