"""Stdlib HTTP client with bounded retry, backoff, breaker + deadline.

Every HTTP edge in the fleet (worker registration, lease polling, result
streaming, heartbeats) and the service ``Client`` rides this one helper
instead of growing its own ad-hoc ``urllib`` code.  Retries cover the
transient failures a fleet actually sees — connection refused while the
orchestrator restarts, a dropped socket, a 502/503/504 from a proxy —
with exponential backoff and full jitter so a rejoining fleet does not
synchronize into a thundering herd.

Two graceful-degradation guards bound the worst case:

  * ``total_deadline_s`` caps the WHOLE call — attempts plus backoff
    sleeps — so a caller with its own SLA (a heartbeat loop, a serving
    request) can never be wedged by a slow storm of retries.
  * a :class:`CircuitBreaker` (optional, shared by a caller across its
    calls) fails fast while a peer is melting down: after ``threshold``
    consecutive failures the circuit opens and calls raise immediately
    (``HttpError`` with ``circuit_open`` detail) until ``reset_s`` has
    passed, then one probe call half-opens it.

Retrying a POST is safe here because every fleet POST is idempotent by
construction: registration and heartbeats are upserts, a duplicated
lease request just creates an extra lease that expires and requeues,
and a duplicated result commits content-addressed labels that dedupe to
zero bytes.  Callers with genuinely non-idempotent POSTs (e.g. campaign
submission) pass ``retries=0``.

The ``http.request`` fault point fires once per *attempt*, so an
injected 503 burst exercises exactly the retry/backoff/breaker path a
real storm would.
"""

from __future__ import annotations

import json
import random
import threading
import time
import urllib.error
import urllib.request
from typing import Dict, Optional

from .. import faults, obs

__all__ = ["CircuitBreaker", "HttpError", "request_json"]

# HTTP statuses worth retrying: the server (or a proxy in front of it)
# says "not right now", not "you are wrong"
RETRY_STATUSES = (429, 502, 503, 504)


class HttpError(urllib.error.HTTPError):
    """A non-retryable (or retries-exhausted) HTTP failure.

    Subclasses ``urllib.error.HTTPError`` so callers written against the
    raw urllib wrapper (``except urllib.error.HTTPError as e: e.code``)
    keep working unchanged.  ``code``/``status`` is ``None`` for pure
    transport failures (connection refused, timeout) where no HTTP
    response ever arrived; ``detail`` carries the server's decoded JSON
    ``error`` field when it sent one."""

    def __init__(self, url: str, status: Optional[int], detail: str):
        super().__init__(url, status, detail, None, None)
        self.url = url
        self.detail = detail

    def __str__(self):
        if self.code is None:
            return f"{self.url}: {self.detail}"
        return f"{self.url}: HTTP {self.code}: {self.detail}"


class CircuitBreaker:
    """Consecutive-failure circuit: closed → open → half-open.

    Thread-safe and deliberately simple: ``threshold`` consecutive
    failures open the circuit for ``reset_s`` seconds, during which
    :meth:`allow` is False (callers fail fast instead of queueing up
    behind timeouts).  After ``reset_s`` ONE caller is admitted as the
    half-open probe; its success closes the circuit, its failure
    re-opens the clock."""

    def __init__(self, *, threshold: int = 5, reset_s: float = 10.0,
                 name: str = ""):
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        self.threshold = int(threshold)
        self.reset_s = float(reset_s)
        self.name = name
        self._lock = threading.Lock()
        self._failures = 0
        self._opened_at: Optional[float] = None
        self._probing = False
        self.opens = obs.REGISTRY.counter(
            "repro_http_breaker_opens_total",
            "circuit breaker transitions to open")
        self.fast_fails = obs.REGISTRY.counter(
            "repro_http_breaker_fast_fails_total",
            "calls refused while the circuit was open")

    @property
    def state(self) -> str:
        with self._lock:
            if self._opened_at is None:
                return "closed"
            if time.monotonic() - self._opened_at >= self.reset_s:
                return "half_open"
            return "open"

    def allow(self) -> bool:
        with self._lock:
            if self._opened_at is None:
                return True
            if time.monotonic() - self._opened_at < self.reset_s:
                self.fast_fails.inc()
                return False
            if self._probing:  # one probe at a time in half-open
                self.fast_fails.inc()
                return False
            self._probing = True
            return True

    def record_success(self) -> None:
        with self._lock:
            self._failures = 0
            self._opened_at = None
            self._probing = False

    def record_failure(self) -> None:
        with self._lock:
            self._failures += 1
            self._probing = False
            if self._opened_at is not None:
                # failed half-open probe: restart the open window
                self._opened_at = time.monotonic()
            elif self._failures >= self.threshold:
                self._opened_at = time.monotonic()
                self.opens.inc()

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {
                "state": ("closed" if self._opened_at is None else
                          ("half_open" if time.monotonic() - self._opened_at
                           >= self.reset_s else "open")),
                "failures": self._failures,
                "opens": int(self.opens.value),
                "fast_fails": int(self.fast_fails.value),
            }


def request_json(
    url: str,
    payload: Optional[Dict] = None,
    *,
    method: Optional[str] = None,
    timeout: float = 30.0,
    retries: int = 4,
    backoff_s: float = 0.25,
    backoff_max_s: float = 4.0,
    jitter: float = 1.0,
    rng: Optional[random.Random] = None,
    total_deadline_s: Optional[float] = None,
    breaker: Optional[CircuitBreaker] = None,
) -> Dict:
    """GET (``payload is None``) or POST ``payload`` as JSON and return
    the decoded JSON response.

    Transient failures (connection errors, timeouts, ``RETRY_STATUSES``)
    are retried up to ``retries`` times with exponential backoff capped
    at ``backoff_max_s``; each sleep is scaled by a uniform random
    factor in ``[1 - jitter/2, 1 + jitter/2]`` (full-jitter style).  Any
    other HTTP error raises ``HttpError`` immediately with the decoded
    error body when the server sent one.

    ``total_deadline_s`` bounds attempts + backoff wall-clock; when the
    budget would be exceeded the call raises instead of sleeping.
    ``breaker`` (optional) fail-fasts while its circuit is open and is
    fed success/failure per call."""
    if method is None:
        method = "GET" if payload is None else "POST"
    rng = rng or random
    t0 = time.monotonic()
    if breaker is not None and not breaker.allow():
        raise HttpError(
            url, None, f"circuit_open: breaker {breaker.name or 'http'} "
            f"open after {breaker.threshold} consecutive failures")
    last: Optional[Exception] = None
    for attempt in range(retries + 1):
        if attempt:
            delay = min(backoff_s * (2.0 ** (attempt - 1)),
                        backoff_max_s)
            if jitter > 0:
                delay *= 1.0 + jitter * (rng.random() - 0.5)
            delay = max(delay, 0.0)
            if total_deadline_s is not None and (
                    time.monotonic() - t0 + delay > total_deadline_s):
                break  # sleeping would blow the budget: give up now
            time.sleep(delay)
        try:
            f = faults.check("http.request", url=url, method=method,
                             attempt=attempt)
            if f is not None:
                if f.delay_s > 0:
                    time.sleep(f.delay_s)
                if f.kind == "error":
                    if f.status is not None:
                        # styled as a server response so the retry/
                        # breaker path sees a real status code
                        raise urllib.error.HTTPError(
                            url, f.status, "injected", None, None)
                    raise urllib.error.URLError("injected fault")
            data = (None if payload is None
                    else json.dumps(payload).encode())
            req = urllib.request.Request(
                url, data=data, method=method,
                headers={"Content-Type": "application/json"},
            )
            att_timeout = timeout
            if total_deadline_s is not None:
                remaining = total_deadline_s - (time.monotonic() - t0)
                if remaining <= 0:
                    break
                att_timeout = min(timeout, remaining)
            with urllib.request.urlopen(
                    req, timeout=att_timeout) as resp:
                out = json.loads(resp.read() or b"{}")
            if breaker is not None:
                breaker.record_success()
            return out
        except urllib.error.HTTPError as exc:
            body = exc.read() if exc.fp is not None else b""
            try:
                detail = json.loads(body).get("error", body.decode())
            except Exception:  # noqa: BLE001 - non-JSON error body
                detail = body.decode(errors="replace")
            if exc.code not in RETRY_STATUSES:
                raise HttpError(url, exc.code, detail) from exc
            last = HttpError(url, exc.code, detail)
        except (urllib.error.URLError, ConnectionError, TimeoutError,
                OSError) as exc:
            last = exc
    # exhausted retries / blown deadline: that is peer-health signal.
    # (Non-retryable 4xx raised above is the CALLER's bug and must not
    # open the circuit for healthy traffic.)
    if breaker is not None:
        breaker.record_failure()
    if (total_deadline_s is not None
            and time.monotonic() - t0 >= total_deadline_s
            and last is None):
        raise HttpError(url, None,
                        f"total deadline {total_deadline_s}s exceeded")
    if isinstance(last, HttpError):
        raise last
    raise HttpError(url, None, f"retries exhausted: {last}") from last
