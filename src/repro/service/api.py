"""HTTP front end for the campaign service (stdlib-only).

Endpoints (JSON in/out):

    POST /campaigns              {spec fields}        -> {"id": ...}
                                 {"strategy": "bo"} picks the explorer
                                 (any core.strategies registry name);
                                 with {"hierarchical": true, "accel":
                                 <staged pipeline>, "stages": [...]} the
                                 job runs the hierarchical search (one
                                 concurrent campaign per stage, composed
                                 + end-to-end verified front)
    POST /campaigns/<id>/cancel  -> stop at the next tick boundary
                                    (snapshot kept)
    POST /campaigns/<id>/resume  -> continue a cancelled/failed/killed
                                    campaign from its latest snapshot
    GET  /campaigns              -> [{id, state, accel, strategy}, ...]
    GET  /campaigns/<id>         -> status record; running campaigns
                                    carry live "progress" (stage,
                                    strategy, generation, labels spent)
    GET  /campaigns/<id>/result  -> summary (val_pcc, timings, front size)
    GET  /campaigns/<id>/front   -> the campaign's true Pareto front
    GET  /campaigns/<id>/timeline-> per-tick search telemetry (live
                                    hypervolume vs a frozen reference,
                                    front size, labels requested/served,
                                    store reuse rate, stage)
    GET  /front?accel=<name>     -> merged non-dominated front over every
                                    completed campaign for that accelerator
    GET  /strategies             -> registered explorer names
    GET  /stats                  -> the labeling economy in one blob:
                                    label-store hits, in-flight dedup
                                    hits, coalesced batches, per-backend
                                    labeler counters (incl. process-pool
                                    worker synthesis counters), synth-
                                    cache hit rate + verification state,
                                    surrogate registry counters, and —
                                    under the fleet backend — the fleet:
                                    registered workers, last-heartbeat
                                    ages, leases in flight, requeues,
                                    per-worker labels/sec
    GET  /metrics                -> Prometheus text exposition of the
                                    same counters /stats renders as JSON
                                    (scheduler, labeler, store, synth,
                                    fleet, worker instruments)
    POST /serve                  {"accel": <name>, "inputs": [...],
                                  "tier": "exact|balanced|budget" |
                                  "budget": {"energy": <=x, "qor": >=y} |
                                  "pin_version": <n>, "gen": <lm tokens>}
                                 -> one inference through the serving
                                    tier: the accelerator's engine picks
                                    the operating point off the merged
                                    front (409 until some campaign has
                                    produced one), batches concurrent
                                    requests per point, and returns the
                                    result + genome/labels/catalog
                                    version it served at
    GET  /serving/stats          -> per-engine serving counters
                                    (requests, tier selections, hot
                                    swaps, queue depth, catalog tiers)
    GET  /healthz                -> {"ok": true}

With ``--eval-backend fleet`` the embedded orchestrator's worker
protocol is mounted too (``repro.fleet``; 404 otherwise):

    POST /fleet/register         -> join/rejoin the labeling fleet
    POST /fleet/heartbeat        -> keep-alive (+ verified fingerprints)
    POST /fleet/lease            -> pull one leased genome chunk
    POST /fleet/result           -> stream a chunk's labels back

Run it with ``python -m repro.service`` (see __main__.py).  ``Client``
is a matching urllib convenience wrapper used by the examples/tests.
"""

from __future__ import annotations

import json
import re
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional, Tuple

from .. import obs
from .campaigns import CampaignManager, CampaignSpec, HierarchicalSpec

__all__ = ["make_server", "serve", "Client"]

_log = obs.get_logger("repro.service")


def _campaign_summary(mgr: CampaignManager, cid: str) -> Dict:
    status = mgr.status(cid)
    if status["state"] != "done":
        return status
    res = mgr.result(cid)
    status["front"] = res.front_objectives.tolist()
    # compacted results keep only the front but remember the true count
    status["n_designs"] = int(getattr(res, "n_designs",
                                      len(res.true_objectives)))
    return status


class _Handler(BaseHTTPRequestHandler):
    # set by make_server:
    manager: CampaignManager = None
    quiet: bool = True

    def log_message(self, fmt, *args):  # noqa: A003 - BaseHTTPRequestHandler API
        if not self.quiet:
            super().log_message(fmt, *args)

    # ------------------------------------------------------------------
    def _send(self, obj, code: int = 200) -> None:
        body = json.dumps(obj, default=float).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _error(self, code: int, msg: str) -> None:
        self._send({"error": msg}, code)

    def _route(self) -> Tuple[str, Dict[str, str]]:
        path, _, query = self.path.partition("?")
        params = {k: v[0] for k, v in urllib.parse.parse_qs(query).items()}
        return path.rstrip("/") or "/", params

    # ------------------------------------------------------------------
    def do_GET(self):  # noqa: N802 - BaseHTTPRequestHandler API
        mgr = self.manager
        path, params = self._route()
        try:
            if path == "/healthz":
                return self._send({"ok": True})
            if path == "/health":
                h = mgr.health()
                return self._send(h, 200 if h.get("ok") else 503)
            if path == "/metrics":
                body = obs.render_prometheus().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
                return None
            if path == "/strategies":
                from ..core.strategies import available_strategies

                return self._send({"strategies": available_strategies()})
            if path == "/stats":
                return self._send(mgr.stats())
            if path == "/serving/stats":
                return self._send(mgr.serving_stats())
            if path == "/fleet/stats":
                fleet = getattr(mgr.scheduler, "fleet", None)
                if fleet is None:
                    return self._error(404, "fleet backend not enabled "
                                            "(start with --eval-backend fleet)")
                return self._send(fleet.stats())
            if path == "/campaigns":
                return self._send(mgr.list_campaigns())
            if path == "/front":
                accel = params.get("accel")
                if not accel:
                    return self._error(400, "missing ?accel=<name>")
                objectives = tuple(
                    params["objectives"].split(",")
                ) if params.get("objectives") else ("qor", "energy")
                return self._send(mgr.global_front(accel, objectives))
            m = re.fullmatch(r"/campaigns/([\w-]+)"
                             r"(/result|/front|/timeline)?", path)
            if m:
                cid, sub = m.group(1), m.group(2)
                if sub == "/front":
                    return self._send(mgr.front(cid))
                if sub == "/result":
                    return self._send(_campaign_summary(mgr, cid))
                if sub == "/timeline":
                    return self._send(mgr.campaign_timeline(cid))
                return self._send(mgr.status(cid))
            return self._error(404, f"no route {path}")
        except KeyError:
            return self._error(404, "unknown campaign")
        except RuntimeError as exc:
            return self._error(409, str(exc))
        except Exception as exc:  # noqa: BLE001 - JSON 500 over a torn socket
            return self._error(500, f"{type(exc).__name__}: {exc}")

    def do_POST(self):  # noqa: N802 - BaseHTTPRequestHandler API
        path, _ = self._route()
        m = re.fullmatch(r"/fleet/(register|heartbeat|lease|result)", path)
        if m:
            from ..fleet.orchestrator import handle_fleet_request

            try:
                n = int(self.headers.get("Content-Length", 0))
                payload = json.loads(self.rfile.read(n) or b"{}")
                if not isinstance(payload, dict):
                    raise ValueError("fleet payload must be a JSON object")
                fleet = getattr(self.manager.scheduler, "fleet", None)
                code, obj = handle_fleet_request(fleet, m.group(1), payload)
                return self._send(obj, code)
            except (json.JSONDecodeError, TypeError, ValueError) as exc:
                return self._error(400, f"bad fleet payload: {exc}")
            except Exception as exc:  # noqa: BLE001 - JSON 500
                return self._error(500, f"{type(exc).__name__}: {exc}")
        if path == "/serve":
            try:
                n = int(self.headers.get("Content-Length", 0))
                payload = json.loads(self.rfile.read(n) or b"{}")
                if not isinstance(payload, dict):
                    raise ValueError("serve payload must be a JSON object")
                accel = payload.get("accel")
                if not accel:
                    raise ValueError('missing "accel"')
                if "inputs" not in payload:
                    raise ValueError('missing "inputs"')
                import numpy as np

                from ..serving import EmptyFrontError, NoFrontError
                from ..serving.engine import (DeadlineExceeded,
                                              OverloadedError)

                objectives = (tuple(payload["objectives"])
                              if payload.get("objectives") else None)
                try:
                    with obs.span("serving.http", accel=accel):
                        eng = self.manager.serving.engine_for(
                            accel, objectives,
                            rank_genes=bool(payload.get("rank_genes")),
                        )
                        result = eng.serve(
                            np.asarray(payload["inputs"]),
                            tier=payload.get("tier"),
                            budget=payload.get("budget"),
                            pin_version=payload.get("pin_version"),
                            gen=payload.get("gen"),
                            return_outputs=bool(
                                payload.get("return_outputs")),
                            deadline_s=payload.get("deadline_s"),
                        )
                except (NoFrontError, EmptyFrontError) as exc:
                    # no completed campaign has produced a front yet:
                    # a state conflict, not a malformed request
                    return self._error(409, str(exc))
                except OverloadedError as exc:
                    # bounded-queue backpressure: retriable — the
                    # fleet http client retries 429 with backoff
                    return self._error(429, str(exc))
                except DeadlineExceeded as exc:
                    return self._error(504, str(exc))
                return self._send(result)
            except (json.JSONDecodeError, TypeError, ValueError) as exc:
                return self._error(400, f"bad serve request: {exc}")
            except Exception as exc:  # noqa: BLE001 - JSON 500
                return self._error(500, f"{type(exc).__name__}: {exc}")
        m = re.fullmatch(r"/campaigns/([\w-]+)/(cancel|resume)", path)
        if m:
            cid, action = m.group(1), m.group(2)
            try:
                if action == "cancel":
                    self.manager.cancel(cid)
                    return self._send({"id": cid, "state": "cancelling"})
                self.manager.resume(cid)
                return self._send({"id": cid, "state": "queued"}, 202)
            except KeyError:
                return self._error(404, "unknown campaign")
            except RuntimeError as exc:
                return self._error(409, str(exc))
            except Exception as exc:  # noqa: BLE001 - JSON 500
                return self._error(500, f"{type(exc).__name__}: {exc}")
        if path != "/campaigns":
            return self._error(404, f"no route {path}")
        try:
            n = int(self.headers.get("Content-Length", 0))
            payload = json.loads(self.rfile.read(n) or b"{}")
            if not isinstance(payload, dict):
                raise ValueError("campaign spec must be a JSON object")
            # submit() validates the spec (unknown accelerator, malformed
            # sizes) and raises ValueError -> 400 here, instead of the
            # campaign failing asynchronously in a worker thread
            if payload.get("hierarchical"):
                spec = HierarchicalSpec.from_dict(payload)
                cid = self.manager.submit_hierarchical(spec)
            else:
                spec = CampaignSpec.from_dict(payload)
                cid = self.manager.submit(spec)
        except (json.JSONDecodeError, TypeError, ValueError) as exc:
            return self._error(400, f"bad campaign spec: {exc}")
        except Exception as exc:  # noqa: BLE001 - JSON 500 over a torn socket
            return self._error(500, f"{type(exc).__name__}: {exc}")
        self._send({"id": cid, "state": "queued"}, 202)


def make_server(
    manager: CampaignManager,
    host: str = "127.0.0.1",
    port: int = 8177,
    *,
    quiet: bool = True,
) -> ThreadingHTTPServer:
    handler = type("Handler", (_Handler,), {"manager": manager, "quiet": quiet})
    return ThreadingHTTPServer((host, port), handler)


def serve(manager, host="127.0.0.1", port=8177, *, quiet=False) -> None:
    if not obs.get_logger().handlers:  # CLI sets its own level first
        obs.setup_logging("info")
    srv = make_server(manager, host, port, quiet=quiet)
    _log.info("listening on http://%s:%s", host, srv.server_address[1])
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        _log.info("shutting down")
    finally:
        srv.server_close()
        manager.shutdown()


class Client:
    """Minimal stdlib client for the service API.

    Rides ``repro.fleet.http.request_json``: GETs retry transient
    transport errors and 429/5xx with exponential backoff + jitter;
    POSTs are NOT retried (``retries=0``) because campaign submission
    is not idempotent — a retried submit after a torn response would
    start a second campaign."""

    def __init__(self, base: str, *, timeout: float = 600.0, retries: int = 4):
        self.base = base.rstrip("/")
        self.timeout = float(timeout)
        self.retries = int(retries)

    def _req(self, path: str, payload: Optional[Dict] = None):
        from ..fleet.http import request_json

        return request_json(
            self.base + path, payload, timeout=self.timeout,
            retries=self.retries if payload is None else 0,
        )

    def submit(self, **spec) -> str:
        return self._req("/campaigns", spec)["id"]

    def submit_hierarchical(self, **spec) -> str:
        return self._req("/campaigns", {**spec, "hierarchical": True})["id"]

    def status(self, cid: str) -> Dict:
        return self._req(f"/campaigns/{cid}")

    def cancel(self, cid: str) -> Dict:
        return self._req(f"/campaigns/{cid}/cancel", {})

    def resume(self, cid: str) -> Dict:
        return self._req(f"/campaigns/{cid}/resume", {})

    def strategies(self) -> list:
        return self._req("/strategies")["strategies"]

    def result(self, cid: str) -> Dict:
        return self._req(f"/campaigns/{cid}/result")

    def front(self, cid: str) -> Dict:
        return self._req(f"/campaigns/{cid}/front")

    def timeline(self, cid: str) -> Dict:
        return self._req(f"/campaigns/{cid}/timeline")

    def metrics(self) -> str:
        """Raw Prometheus text from GET /metrics."""
        import urllib.request

        with urllib.request.urlopen(self.base + "/metrics",
                                    timeout=self.timeout) as resp:
            return resp.read().decode()

    def global_front(self, accel: str,
                     objectives: Optional[Tuple[str, ...]] = None) -> Dict:
        q = f"/front?accel={accel}"
        if objectives:
            q += "&objectives=" + ",".join(objectives)
        return self._req(q)

    def stats(self) -> Dict:
        return self._req("/stats")

    def health(self) -> Dict:
        """GET /health: readiness blob with ``ok``.  A degraded service
        answers 503 with the same body — returned, not raised, so a
        probe loop can inspect WHAT is unhealthy."""
        from ..fleet.http import HttpError, request_json

        try:
            # no retries: a liveness probe wants the answer NOW
            return request_json(self.base + "/health",
                                timeout=self.timeout, retries=0)
        except HttpError as exc:
            if exc.code == 503 and "ok" in (exc.detail or ""):
                import json as _json

                try:
                    return _json.loads(exc.detail)
                except ValueError:
                    pass
            raise

    def serve(self, accel: str, inputs, **kw) -> Dict:
        """One inference through the serving tier.  ``inputs`` is a
        batch of accelerator inputs (or an LM prompt token list);
        keywords pass through: tier=, budget=, pin_version=, gen=,
        return_outputs=, objectives=, rank_genes=."""
        import numpy as np

        if isinstance(inputs, np.ndarray):
            inputs = inputs.tolist()
        return self._req("/serve", {"accel": accel, "inputs": inputs, **kw})

    def serving_stats(self) -> Dict:
        return self._req("/serving/stats")

    def wait(self, cid: str, timeout: float = 600.0, poll: float = 0.25) -> Dict:
        import time

        t0 = time.time()
        while True:
            st = self.status(cid)
            if st["state"] in ("done", "failed") or time.time() - t0 > timeout:
                return st
            time.sleep(poll)
