"""Pallas TPU selective-scan kernel — the SSM memory-wall fix.

The XLA-composed chunked scan (models/ssm.py) materializes the
(b, L, d_inner, N) decay/update streams in HBM: ~6 MB per token per layer
at jamba/falcon widths — the dominant memory-roofline term of every SSM
training cell (§Perf, refuted-by-CPU-measurement bf16 experiment).  This
kernel keeps the state expansion entirely in VMEM:

  grid (b, d_inner/bd, s/L)  — TPU grid iterates sequentially, so the
  running state h (bd, N) lives in VMEM scratch across the chunk axis
  (same carry pattern as the matmul accumulator kernels).  Per chunk the
  kernel loads x/dt (L, bd) and B/C (L, N) tiles, runs the recurrence
  with a fori_loop over the L positions (vectorized (bd, N) VPU ops), and
  writes only y (L, bd) back.

HBM traffic per token per layer: 3*di*4B (x, dt, y) + 2*N*4B vs the
composed form's ~2*di*N*4B stream — a ~(2N/3 ≈ 10x) reduction at N=16.

Validated against ref.selective_scan_reference with interpret=True
(tests/test_kernels_scan.py); block shapes default to bd=512 lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["selective_scan_pallas"]


def _scan_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, h0_ref,
                 y_ref, hT_ref, h_ref, *, nchunks, L):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = h0_ref[0]

    A = A_ref[...]                                   # (bd, n)
    x = x_ref[0].astype(jnp.float32)                 # (L, bd)
    dt = dt_ref[0].astype(jnp.float32)               # (L, bd)
    B = B_ref[0].astype(jnp.float32)                 # (L, n)
    C = C_ref[0].astype(jnp.float32)                 # (L, n)

    def step(t, h):
        a = jnp.exp(dt[t][:, None] * A)              # (bd, n)
        h = a * h + (dt[t] * x[t])[:, None] * B[t][None, :]
        y_ref[0, t, :] = (h * C[t][None, :]).sum(axis=1)
        return h

    h = jax.lax.fori_loop(0, L, step, h_ref[...])
    h_ref[...] = h

    @pl.when(c == nchunks - 1)
    def _done():
        hT_ref[0] = h_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bd", "chunk", "interpret")
)
def selective_scan_pallas(
    x: jnp.ndarray,     # (b, s, di)
    dt: jnp.ndarray,    # (b, s, di)
    A: jnp.ndarray,     # (di, n)
    B: jnp.ndarray,     # (b, s, n)
    C: jnp.ndarray,     # (b, s, n)
    h0: jnp.ndarray,    # (b, di, n)
    *,
    bd: int = 512,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y (b, s, di) f32, h_final (b, di, n) f32)."""
    b, s, di = x.shape
    n = A.shape[1]
    bd = min(bd, di)
    chunk = min(chunk, s)
    assert di % bd == 0 and s % chunk == 0, (di, bd, s, chunk)
    nchunks = s // chunk
    grid = (b, di // bd, nchunks)
    kernel = functools.partial(_scan_kernel, nchunks=nchunks, L=chunk)
    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda bi, i, c: (bi, c, i)),
            pl.BlockSpec((1, chunk, bd), lambda bi, i, c: (bi, c, i)),
            pl.BlockSpec((bd, n), lambda bi, i, c: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, i, c: (bi, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, i, c: (bi, c, 0)),
            pl.BlockSpec((1, bd, n), lambda bi, i, c: (bi, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda bi, i, c: (bi, c, i)),
            pl.BlockSpec((1, bd, n), lambda bi, i, c: (bi, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, di), jnp.float32),
            jax.ShapeDtypeStruct((b, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(
        x.astype(jnp.float32), dt.astype(jnp.float32),
        A.astype(jnp.float32), B.astype(jnp.float32),
        C.astype(jnp.float32), h0.astype(jnp.float32),
    )
    return y, hT
