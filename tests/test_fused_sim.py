"""Fused population engine: the three coexisting engines (per-genome
loop, numpy batched, fused XLA) must be provably identical — bit-exact
outputs and QoR — across every registered LUT accelerator, including
staged pipelines and their in-situ stage views; plus the engine's
operational contract (kill switch, verify-then-pin, bucketing, caches).
"""

import numpy as np
import pytest

from repro.accel import GaussianFilter, HEVCDct, MCMAccelerator
from repro.accel import fused
from repro.accel.base import RANK_CHOICES, Accelerator
from repro.accel.smoothed_dct import SmoothedDct
from repro.core.acl.library import default_library, library_fingerprint

LIB = default_library()


def _pop(accel, G, seed=0, rank_genes=False):
    """Random population; row 0 is the all-exact genome."""
    rng = np.random.default_rng(seed)
    cols = [
        rng.integers(0, len(LIB.kind(s.kind)), size=G) for s in accel.slots
    ]
    g = np.stack(cols, axis=1).astype(np.int64)
    for i, s in enumerate(accel.slots):
        g[0, i] = LIB.exact_index(s.kind)
    if rank_genes:
        nm = len(accel.mul_slot_indices())
        ranks = rng.integers(0, len(RANK_CHOICES), size=(G, nm))
        g = np.concatenate([g, ranks], axis=1)
    return g


def _numpy_sim(accel, g, x, **kw):
    return fused._numpy_reference("sim", accel, g, LIB, x, rank_genes=kw.pop(
        "rank_genes", False), **kw)


def _numpy_qor(accel, g, x, *, rank_genes=False):
    return fused._numpy_reference("qor", accel, g, LIB, x,
                                  rank_genes=rank_genes)


FUSIBLE = [GaussianFilter, lambda: MCMAccelerator(0),
           lambda: MCMAccelerator(2), HEVCDct, SmoothedDct]


@pytest.mark.parametrize("make", FUSIBLE)
def test_three_engines_bit_identical(make):
    accel = make()
    g = _pop(accel, 10, seed=3)
    x = accel.sample_inputs(2, seed=1)

    fused_out = accel.simulate_batch(g, LIB, x)
    numpy_out = _numpy_sim(accel, g, x)
    loop_out = Accelerator.simulate_batch(accel, g, LIB, x)

    assert fused.stats()["fused_calls"] + fused.stats()["verify_calls"] > 0
    assert fused_out.shape == numpy_out.shape
    assert fused_out.dtype == numpy_out.dtype
    assert np.array_equal(fused_out, numpy_out)
    assert np.array_equal(
        np.asarray(numpy_out, np.float64), np.asarray(loop_out, np.float64)
    )


@pytest.mark.parametrize("make", FUSIBLE)
def test_qor_batch_bit_identical(make):
    accel = make()
    g = _pop(accel, 8, seed=5)
    x = accel.sample_inputs(2, seed=2)
    got = accel.qor_batch(g, LIB, x)
    want = _numpy_qor(accel, g, x)
    assert np.array_equal(got, want)
    assert got[0] == 100.0  # row 0 is the exact genome
    assert fused.stats()["pins"] == 0


def test_rank_genes_columns_ignored_identically():
    accel = SmoothedDct()
    g = _pop(accel, 6, seed=9, rank_genes=True)
    x = accel.sample_inputs(2, seed=0)
    got = accel.simulate_batch(g, LIB, x, rank_genes=True)
    want = _numpy_sim(accel, g, x, rank_genes=True)
    assert np.array_equal(got, want)


def test_per_genome_inputs_path():
    accel = GaussianFilter()
    G = 5
    g = _pop(accel, G, seed=2)
    x = accel.sample_inputs(2, seed=4)
    rng = np.random.default_rng(0)
    xg = np.clip(
        np.repeat(x[None], G, axis=0) + rng.integers(0, 2, (G,) + x.shape),
        0, 255,
    ).astype(x.dtype)
    got = accel.simulate_batch(g, LIB, xg, per_genome_inputs=True)
    want = _numpy_sim(accel, g, xg, per_genome_inputs=True)
    assert np.array_equal(got, want)


def test_stage_views_in_situ_qor():
    pipe = SmoothedDct()
    x = pipe.sample_inputs(2, seed=1)
    for sv in pipe.stage_views():
        g = _pop(sv, 6, seed=sv.index)
        got = sv.qor_batch(g, LIB, x)
        want = _numpy_qor(sv, g, x)
        assert np.array_equal(got, want), sv.name


def test_whole_pipeline_fuses_as_one_program():
    pipe = SmoothedDct()
    g = _pop(pipe, 6, seed=1)
    x = pipe.sample_inputs(2, seed=1)
    pipe.simulate_batch(g, LIB, x)
    pipe.simulate_batch(g, LIB, x)
    pipe.simulate_batch(g, LIB, x)  # past the verification budget
    st = fused.stats()
    # one compiled program for the chain — not one per stage
    assert st["compiles"] == 1
    assert st["fused_calls"] >= 1


def test_kill_switch(monkeypatch):
    accel = GaussianFilter()
    g = _pop(accel, 4)
    x = accel.sample_inputs(1, seed=0)
    monkeypatch.setenv("REPRO_SIM_FUSED", "0")
    out = accel.simulate_batch(g, LIB, x)
    assert fused.stats()["fused_calls"] == 0
    assert fused.stats()["compiles"] == 0
    monkeypatch.delenv("REPRO_SIM_FUSED")
    assert np.array_equal(accel.simulate_batch(g, LIB, x), out)


def test_divergent_plan_pins_to_numpy():
    accel = GaussianFilter()
    g = _pop(accel, 4, seed=7)
    x = accel.sample_inputs(1, seed=0)
    plan = fused._plan_for(accel, LIB)
    orig = plan.post
    plan.post = lambda raw, inputs, per_genome: orig(raw, inputs, per_genome) + 1
    out = accel.simulate_batch(g, LIB, x)  # verification catches the lie
    st = fused.stats()
    assert st["pins"] == 1 and plan.key in fused._PINNED
    # the caller still got the CORRECT (numpy) result
    assert np.array_equal(out, _numpy_sim(accel, g, x))
    # and the family stays pinned: no further fused calls
    accel.simulate_batch(g, LIB, x)
    assert fused.stats()["fused_calls"] == 0


def test_lm_is_registered_unfused():
    from repro.accel.lm import LMAccelerator

    assert fused._BUILDERS[LMAccelerator] is None


def test_bucketing_zero_steady_state_recompiles():
    accel = GaussianFilter()
    x = accel.sample_inputs(2, seed=0)
    for G in (9, 16, 12, 11, 16, 13):  # drifting survivor counts
        accel.qor_batch(_pop(accel, G, seed=G), LIB, x)
    st = fused.stats()
    assert st["compiles"] == 1  # all Gs land in the 16-bucket
    assert st["bucket_hits"] >= 5


def test_adder_twins_probe_verified_per_library():
    eng = fused._engine_for(LIB)
    assert eng is not None and len(eng.twins) == len(LIB.kind("add16"))
    # exhaustive-ish check on an independent operand set
    rng = np.random.default_rng(99)
    a = rng.integers(0, 1 << 16, size=4096, dtype=np.int64)
    b = rng.integers(0, 1 << 16, size=4096, dtype=np.int64)
    sh = fused._shared(a, b)
    for c, tw in zip(LIB.kind("add16"), eng.twins):
        assert np.array_equal(
            np.asarray(tw(sh), np.int64), np.asarray(c.fn(a, b), np.int64)
        ), c.name


def test_unknown_adder_model_unfuses_library():
    from repro.core.acl.library import Circuit, Library

    weird = Circuit("add16_weird", "add16", lambda a, b: (a + b) ^ 1)
    lib2 = Library(list(LIB.circuits) + [weird])
    assert fused._engine_for(lib2) is None
    accel = GaussianFilter()
    g = _pop(accel, 4)
    # population indices must stay valid for the base library's kinds
    x = accel.sample_inputs(1, seed=0)
    out = accel.simulate_batch(g, LIB, x)  # base library still fuses
    assert np.array_equal(out, _numpy_sim(accel, g, x))


def test_pallas_interpret_kernel_matches_ref():
    from repro.kernels.population_lut import (
        population_lut_gather, population_lut_gather_ref,
    )

    rng = np.random.default_rng(3)
    C, S, G, M = 5, 9, 8, 512
    lut = rng.integers(0, 1 << 15, size=(C, S, 256), dtype=np.int64)
    genes = rng.integers(0, C, size=(G, S), dtype=np.int64)
    cols = rng.integers(0, 256, size=(M, S), dtype=np.int64)
    want = population_lut_gather_ref(lut, genes, cols)
    for backend in ("xla", "pallas_interpret"):
        got = population_lut_gather(lut, genes, cols, backend=backend)
        assert np.array_equal(np.asarray(got, np.int64), want), backend
    # per-genome column stacks
    colsg = rng.integers(0, 256, size=(G, M, S), dtype=np.int64)
    want = population_lut_gather_ref(lut, genes, colsg, per_genome=True)
    got = population_lut_gather(lut, genes, colsg, backend="pallas_interpret",
                                per_genome=True)
    assert np.array_equal(np.asarray(got, np.int64), want)


# --- satellite regressions: content-keyed caches ---------------------------

def test_lut_cache_keyed_on_content_not_identity():
    from repro.accel import _batchsim
    from repro.core.acl.library import Library

    # two distinct-but-content-equal libraries share one entry
    lib_a = LIB.subset([c.name for c in LIB.circuits])
    lib_b = LIB.subset([c.name for c in LIB.circuits])
    assert lib_a is not lib_b
    assert library_fingerprint(lib_a) == library_fingerprint(lib_b)
    consts = np.array([1, 2, 3], dtype=np.int64)
    with _batchsim._LUT_LOCK:
        _batchsim._LUT_CACHE.clear()
    lut_a = _batchsim.mul_lut(lib_a, "mul8u", consts, tag="t")
    lut_b = _batchsim.mul_lut(lib_b, "mul8u", consts, tag="t")
    assert lut_a is lut_b
    assert len(_batchsim._LUT_CACHE) == 1

    # content-DIFFERENT library with the same tag must not alias
    names = [c.name for c in LIB.circuits if c.kind != "mul8u"]
    names += [c.name for c in LIB.kind("mul8u")[:3]]
    lib_c = LIB.subset(names)
    lut_c = _batchsim.mul_lut(lib_c, "mul8u", consts, tag="t")
    assert lut_c.shape[0] == 3 and lut_c is not lut_a


def test_lut_cache_bounded_lru():
    from repro.accel import _batchsim

    with _batchsim._LUT_LOCK:
        _batchsim._LUT_CACHE.clear()
    for i in range(_batchsim._LUT_CACHE_MAX + 5):
        consts = np.array([1, 2, i + 1], dtype=np.int64)
        _batchsim.mul_lut(LIB, "mul8u", consts, tag=f"bound{i}")
    assert len(_batchsim._LUT_CACHE) == _batchsim._LUT_CACHE_MAX


def test_im2col_cache_bounded_lru():
    from repro.accel import gaussian

    with gaussian._IM2COL_LOCK:
        gaussian._IM2COL_CACHE.clear()
    for i in range(gaussian._IM2COL_CACHE_MAX + 4):
        imgs = np.full((1, 8, 8), i, dtype=np.uint8)
        gaussian._im2col_cached(imgs)
    assert len(gaussian._IM2COL_CACHE) == gaussian._IM2COL_CACHE_MAX
    # a repeated hit refreshes recency instead of growing the cache
    imgs = np.full((1, 8, 8), 0, dtype=np.uint8)
    a = gaussian._im2col_cached(imgs)
    b = gaussian._im2col_cached(imgs)
    assert a is b


def test_stats_shape():
    st = fused.stats()
    for key in ("compiles", "bucket_hits", "pins", "verify_calls",
                "fused_calls", "fused_qor_calls", "pinned_plans",
                "compiled_programs"):
        assert key in st
