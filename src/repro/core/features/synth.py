"""XLA 'synthesis' — our Vivado tool-chain analogue (ground-truth labels).

The paper's ground truth for one accelerator variant is a full Vivado
synthesis run (minutes/design): LUTs, power, delay.  Ours is a full XLA
lower+compile of the variant's rank-k MXU deployment (seconds/design):
``cost_analysis()`` FLOPs and bytes, turned into roofline latency and
energy on TPU v5e constants (core/hw.py).  The QoR ground truth is the
bit-exact behavioral simulation (accel.simulate).

Both are deliberately the *slow* path; the whole point of the paper is to
call them O(n_train + n_final) times instead of O(|space|).
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import
    from ...accel.base import Accelerator
from ...core.acl.library import Circuit, Library
from .. import hw

__all__ = [
    "SynthResult",
    "synthesize_variant",
    "circuit_features_synth",
    "label_variants",
    "LABEL_KEYS",
    "DEFAULT_QOR_SEED",
    "SYNTH_AC_DIM",
]

SYNTH_AC_DIM = 6

# the per-genome record label_variants produces (the service label
# store persists exactly these keys — keep the two in sync by import)
LABEL_KEYS = ("qor", "latency", "energy", "flops", "hbm_bytes",
              "synth_time", "sim_time")

# default seed for the QoR evaluation inputs: shared by the in-process
# default labeler (core/dse.py) and the service EvalContext so both
# paths label identically (and derive identical store keys)
DEFAULT_QOR_SEED = 1234


class SynthResult(dict):
    """{'flops', 'hbm_bytes', 'latency', 'energy', 'wall_time'}"""


# --- guarded fast codegen ---------------------------------------------------
# Ground-truth labels read HLO-level quantities (flops, bytes accessed)
# off compiled_cost_analysis; most of the compile wall is backend code
# GENERATION, which does not enter them.  FAST_CODEGEN compiles
# synthesis probes at LLVM opt level 0, without expensive LLVM passes,
# on the non-thunk runtime (~2x faster on multi-slot deploys) — but the
# options are only trusted per GRAPH FAMILY after verification: the
# first compile of each ``fast_key`` runs BOTH ways and compares the
# cost-analysis keys the labels read.  Families where any option leaks
# into HLO-level cost (e.g. the LM forward under the non-thunk runtime)
# are pinned to default codegen, keeping labels byte-identical to the
# seed engine by construction.  REPRO_SYNTH_FAST=0 disables the whole
# mechanism; unknown options degrade to a default compile.
FAST_CODEGEN = os.environ.get("REPRO_SYNTH_FAST", "1") != "0"
_FAST_COMPILER_OPTIONS = {
    "xla_backend_optimization_level": 0,
    "xla_llvm_disable_expensive_passes": True,
    "xla_cpu_use_thunk_runtime": False,
    "xla_cpu_copy_insertion_use_region_analysis": False,
}
_COST_KEYS = ("flops", "bytes accessed")
# The verdict is per graph FAMILY (one accelerator's build_deploy /
# one circuit kind's canonical probe), verified on the family's first
# few distinct graphs rather than every graph — per-graph verification
# would double-compile everything and erase the speedup.  Family-level
# sampling is sound because option leakage into HLO-level cost is
# driven by op-type coverage (e.g. the thunk runtime rewrites
# control-flow ops, which is why the LM forward diverges and is pinned
# to default codegen on its very first compile), and graphs within one
# family share op types, differing only in per-slot rank/width counts.
# Residual risk is bounded by REPRO_SYNTH_FAST=0.
_FAST_VERIFY_SAMPLES = 2
# fast_key -> remaining verifications (int countdown) | False (diverged)
_FAST_VERDICT: Dict[str, object] = {}


def _cost_numbers(compiled) -> Dict[str, float]:
    from ...dist.compat import compiled_cost_analysis

    ca = compiled_cost_analysis(compiled)
    return {k: float(ca.get(k, 0.0)) for k in _COST_KEYS}


def _compile_cost(fn, args, *, fast_key: Optional[str] = None) -> Dict[str, float]:
    import jax

    t0 = time.perf_counter()
    lowered = jax.jit(fn).lower(*args)
    compiled = None
    if FAST_CODEGEN and fast_key is not None:
        verdict = _FAST_VERDICT.get(fast_key, _FAST_VERIFY_SAMPLES)
        if verdict is not False and verdict > 0:
            # verification compile: both ways, compare what labels read
            ref = lowered.compile()
            try:
                fast = lowered.compile(dict(_FAST_COMPILER_OPTIONS))
                ok = _cost_numbers(fast) == _cost_numbers(ref)
            except Exception:  # noqa: BLE001 - unknown option / old jax
                ok = False
            _FAST_VERDICT[fast_key] = (verdict - 1) if ok else False
            compiled = ref
        elif verdict is not False:
            try:
                compiled = lowered.compile(dict(_FAST_COMPILER_OPTIONS))
            except Exception:  # noqa: BLE001
                compiled = None
    if compiled is None:
        compiled = lowered.compile()
    wall = time.perf_counter() - t0
    ca = _cost_numbers(compiled)
    flops = ca["flops"]
    byts = ca["bytes accessed"]
    rt = hw.roofline(flops, byts, 0.0)
    return {
        "flops": flops,
        "hbm_bytes": byts,
        "latency": rt.t_serial,
        "energy": rt.energy,
        "wall_time": wall,
    }


def _adjusted_compute(accel, circuits, ranks) -> float:
    """Dtype-aware MXU cost (bf16-MAC equivalents) of the variant's
    faithful deployment: per slot, 2*m*width*n * (dtype_factor +
    rank) — truncation circuits deploy natively at narrow width (cheap),
    exotic circuits pay int8 base + bf16 corrections (DESIGN.md §2)."""
    if hasattr(accel, "adjusted_compute"):
        return accel.adjusted_compute(circuits, ranks)
    mul_idx = accel.mul_slot_indices()
    m, ktot, n = accel.matmul_shape()
    groups = accel.slot_groups()
    passes = getattr(accel, "deploy_passes", 1)
    total = 0.0
    for (s0, e0), i, r in zip(groups, mul_idx, ranks):
        c = circuits[i]
        base = hw.V5E.dtype_cost_factor(c.deploy_width)
        rank = c.deploy_rank if r is None else (
            0 if c.native_width is not None else int(r)
        )
        total += 2.0 * m * (e0 - s0) * n * (base + rank)
    return total * passes


def synthesize_variant(
    accel: Accelerator,
    circuits: Sequence[Circuit],
    ranks: Sequence[Optional[int]],
    *,
    cache: Optional[dict] = None,
) -> SynthResult:
    """Ground-truth hardware labels for one variant (XLA compile of its
    deployment).  Cost is shape-determined, so an optional cache keyed on
    (circuit, rank) per mul slot avoids recompiling duplicates.

    The compute term is dtype-adjusted (the CPU compile runs everything
    in f32; the v5e MXU runs int4/int8/bf16 at different rates)."""
    from ...kernels.approx_matmul import from_circuit

    mul_idx = accel.mul_slot_indices()
    mul_circuits = [circuits[i] for i in mul_idx]
    specs = [from_circuit(c, r) for c, r in zip(mul_circuits, ranks)]
    key = (accel.name,) + tuple(
        (s.name, s.rank, s.trunc_bits) for s in specs
    )
    if cache is not None and key in cache:
        out = SynthResult(cache[key])
        out["wall_time"] = 0.0
        out["cache_hit"] = True
        return out
    fn, args = accel.build_deploy(specs)
    out = SynthResult(_compile_cost(fn, args, fast_key=f"accel:{accel.name}"))
    adj = _adjusted_compute(accel, circuits, ranks)
    out["mxu_flops_adjusted"] = adj
    rt = hw.roofline(adj, out["hbm_bytes"], 0.0)
    out["latency"] = rt.t_serial
    # energy = the MARGINAL arithmetic energy of the variant (MXU MACs at
    # their dtype rate + the rank-k lookup-table traffic).  Input/output
    # streaming bytes are identical across variants of one accelerator
    # (board-level cost in the paper's terms) and would flatten the
    # objective to a ~0.2% spread on the small MCM matmuls.
    lut_bytes = sum(256.0 * 4 * 2 * sp.rank for sp in specs)
    out["energy"] = adj * hw.V5E.e_flop + lut_bytes * hw.V5E.e_hbm_byte
    out["cache_hit"] = False
    if cache is not None:
        cache[key] = dict(out)
    return out


def circuit_features_synth(
    c: Circuit, *, rank: Optional[int] = None, m: int = 256, n: int = 128
) -> np.ndarray:
    """Per-AC synthesis features — XLA-compile a canonical (m,256)@(256,n)
    deployment of this single circuit (Vivado-on-AC analogue, pipeline
    B/E).  Returns [flops, log10 bytes, latency, energy, rank, wall_time].
    Adders deploy as an elementwise segmented add (cost-flat by design)."""
    import jax.numpy as jnp

    from ...kernels.approx_matmul import approx_matmul, from_circuit

    if c.kind == "add16":
        # elementwise behavioral map: fixed small cost; use error stats row
        return np.array([256.0 * n, np.log10(256.0 * n * 8), 0.0, 0.0, 0.0, 0.0])
    spec = from_circuit(c, rank)
    rng = np.random.default_rng(0)
    lo, hi = (-128, 128) if c.signed else (0, 256)
    x = jnp.asarray(rng.integers(lo, hi, (m, 256)))
    w = jnp.asarray(rng.integers(lo, hi, (256, n)))

    def fn(x, w):
        return approx_matmul(x, w, spec)

    cost = _compile_cost(fn, (x, w), fast_key=f"circuit:{c.kind}")
    # dtype-aware adjustment (see synthesize_variant)
    adj = 2.0 * m * 256 * n * c.deploy_cost_factor()
    rt = hw.roofline(adj, cost["hbm_bytes"], 0.0)
    cost["flops"] = adj
    cost["latency"] = rt.t_serial
    cost["energy"] = adj * hw.V5E.e_flop         + 256.0 * 4 * 2 * c.deploy_rank * hw.V5E.e_hbm_byte
    return np.array(
        [
            cost["flops"],
            np.log10(1.0 + cost["hbm_bytes"]),
            cost["latency"],
            cost["energy"],
            float(spec.rank),
            cost["wall_time"],
        ]
    )


def label_variants(
    accel: Accelerator,
    genomes: np.ndarray,
    library: Library,
    *,
    rank_genes: bool = False,
    qor_inputs: Optional[np.ndarray] = None,
    cache: Optional[dict] = None,
    progress: Optional[callable] = None,
) -> Dict[str, np.ndarray]:
    """Ground-truth labels for a genome batch: hardware via XLA synthesis,
    QoR via BATCHED behavioral simulation (the population is the unit of
    evaluation — one vectorized ``qor_batch`` call instead of a sim per
    genome; values are bit-exact versus the per-genome loop).  Returns
    arrays keyed
    {'qor','latency','energy','flops','hbm_bytes','synth_time','sim_time'}.
    ``sim_time`` is the batch's wall clock amortized evenly per genome."""
    genomes = np.atleast_2d(genomes)
    n = len(genomes)
    if qor_inputs is None:
        qor_inputs = accel.sample_inputs(4, seed=DEFAULT_QOR_SEED)
    out = {k: np.zeros(n) for k in LABEL_KEYS}
    t0 = time.perf_counter()
    out["qor"][:] = accel.qor_batch(
        genomes, library, qor_inputs, rank_genes=rank_genes
    )
    out["sim_time"][:] = (time.perf_counter() - t0) / max(n, 1)
    for t, g in enumerate(genomes):
        circuits, ranks = accel.decode(g, library, rank_genes=rank_genes)
        sr = synthesize_variant(accel, circuits, ranks, cache=cache)
        out["latency"][t] = sr["latency"]
        out["energy"][t] = sr["energy"]
        out["flops"][t] = sr["flops"]
        out["hbm_bytes"][t] = sr["hbm_bytes"]
        out["synth_time"][t] = sr["wall_time"]
        if progress is not None:
            progress(t, n)
    return out
