"""Model-execution backends for the serving engine.

A backend executes ONE batch group — requests that resolved to the same
operating point (genome) and compatible input shapes — in a single
batched call:

  * ``SimBackend`` — table-driven accelerators (gaussian3x3, the HEVC
    DCTs, staged pipelines): one ``simulate_batch(..., per_genome_
    inputs=True)`` over the stacked request inputs, which dispatches to
    the fused ``(genomes, inputs)`` XLA engine where a plan exists
    (repro.accel.fused), plus the exact reference batch — each request
    gets its output and its *measured* QoR (PSNR vs exact on ITS
    inputs, bit-identical for identical genome+inputs, which is what
    the hot-swap pinning drill asserts).
  * ``LMBackend``  — ``lm:<arch>`` accelerators: the genome decodes to
    an ``ApproxPolicy`` and the group runs batched greedy decoding
    through the jitted prefill/decode pair (``repro.train.serve.
    Generator`` — the resurrected seed serving steps), with generators
    cached per genome so steady-state requests never re-jit.

Backends are pure executors: selection, batching and hot-swap live in
``engine.py``.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, List, Sequence, Tuple

import numpy as np

from ..core import qor as qor_mod
from .catalog import OperatingPoint

__all__ = ["SimBackend", "LMBackend", "make_backend"]


class SimBackend:
    """Batched behavioral execution + per-request measured QoR.

    A request's ``inputs`` is a BATCH of accelerator inputs — the shape
    ``accel.sample_inputs(n)`` returns (``(n, H, W)`` images for
    gaussian3x3 / the DCTs, ``(n, 4)`` operand rows for the MCM blocks)
    — so the stacked group forms the ``(G, n, ...)`` per-genome stack
    ``simulate_batch(..., per_genome_inputs=True)`` consumes.  Inputs
    arriving over the wire (JSON) are coerced to the accelerator's
    native dtype: integral floats cast silently, non-integral values
    for an integer-operand accelerator are a ``ValueError`` (HTTP
    400)."""

    kind = "sim"

    def __init__(self, accel, library, *, rank_genes: bool = False):
        self.accel = accel
        self.library = library
        self.rank_genes = bool(rank_genes)
        self._in_dtype = None

    def group_key(self, req) -> Tuple:
        return (tuple(np.shape(req.inputs)),)

    def _coerce(self, inputs) -> np.ndarray:
        arr = np.asarray(inputs)
        if self._in_dtype is None:
            self._in_dtype = np.asarray(
                self.accel.sample_inputs(1, 0)).dtype
        dt = self._in_dtype
        if arr.dtype == dt:
            return arr
        if np.issubdtype(dt, np.integer) and \
                not np.issubdtype(arr.dtype, np.integer):
            if arr.size and (not np.all(np.isfinite(arr))
                             or np.any(np.mod(arr, 1) != 0)):
                raise ValueError(
                    f"{self.accel.name} takes integer operands; got "
                    f"non-integral inputs (dtype {arr.dtype})")
        return arr.astype(dt)

    def run(self, point: OperatingPoint, reqs: Sequence) -> List[Dict]:
        X = np.stack([self._coerce(r.inputs) for r in reqs])
        G = np.tile(point.genome_array()[None, :], (len(reqs), 1))
        outs = self.accel.simulate_batch(
            G, self.library, X,
            rank_genes=self.rank_genes, per_genome_inputs=True,
        )
        refs = self.accel.exact_output_batch(X, per_genome_inputs=True)
        results = []
        for i, r in enumerate(reqs):
            res = {"qor": qor_mod.psnr(refs[i], outs[i])}
            if r.return_outputs:
                res["outputs"] = np.asarray(outs[i]).tolist()
            results.append(res)
        return results


class LMBackend:
    """Continuous-batching greedy decode through an ApproxPolicy'd
    model: one jitted prefill + per-token decode per batch group."""

    kind = "lm"

    def __init__(self, accel, library, *, rank_genes: bool = False,
                 max_generators: int = 8):
        self.accel = accel
        self.library = library
        self.rank_genes = bool(rank_genes)
        self.max_generators = int(max_generators)
        self._gens: "OrderedDict[bytes, object]" = OrderedDict()
        self._lock = threading.Lock()

    def group_key(self, req) -> Tuple:
        return (tuple(np.shape(req.inputs)), int(req.gen or 0))

    def _generator(self, point: OperatingPoint):
        from ..train.serve import Generator

        key = point.genome_array().tobytes()
        with self._lock:
            gen = self._gens.get(key)
            if gen is not None:
                self._gens.move_to_end(key)
                return gen
        policy = self.accel.policy_for_genome(
            point.genome_array(), self.library, rank_genes=self.rank_genes
        )
        gen = Generator(self.accel.cfg, policy=policy,
                        attn_chunk=32, scan_chunk=8)
        with self._lock:
            self._gens[key] = gen
            while len(self._gens) > self.max_generators:
                self._gens.popitem(last=False)
        return gen

    def run(self, point: OperatingPoint, reqs: Sequence) -> List[Dict]:
        prompts = np.stack(
            [np.asarray(r.inputs, dtype=np.int32) for r in reqs]
        )
        if prompts.ndim != 2:
            raise ValueError(
                f"LM requests carry 1-D prompt token arrays; got batch "
                f"shape {prompts.shape}"
            )
        n_gen = int(reqs[0].gen or 16)
        gen = self._generator(point)
        params = self.accel._ensure_params()
        tokens, tps = gen.generate(params, prompts, n_gen)
        results = []
        for i, r in enumerate(reqs):
            res = {
                # per-request QoR is the genome's catalog label (logits
                # PSNR of the policy'd model vs exact); a per-request
                # exact forward would double every group's cost
                "qor": float(point.labels.get("qor", float("nan"))),
                "tokens_per_s": tps,
                "n_generated": n_gen,
            }
            if r.return_outputs:
                res["tokens"] = np.asarray(tokens[i]).tolist()
            else:
                res["tokens"] = np.asarray(tokens[i, -n_gen:]).tolist()
            results.append(res)
        return results


def make_backend(accel, library, *, rank_genes: bool = False):
    """SimBackend for table-driven accelerators, LMBackend for
    ``lm:<arch>`` (anything exposing ``policy_for_genome``)."""
    if hasattr(accel, "policy_for_genome"):
        return LMBackend(accel, library, rank_genes=rank_genes)
    return SimBackend(accel, library, rank_genes=rank_genes)
