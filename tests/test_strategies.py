"""Ask/tell SearchStrategy protocol: seed-equivalence of the protocol
drive against the legacy blocking pipeline, state/restore resumability
(strategy-, campaign- and service-level, incl. the process eval
backend), BO end-to-end, and custom-strategy registration."""

import json
import os
import threading
import time

import numpy as np
import pytest

from repro.accel import MCMAccelerator
from repro.core.acl.library import default_library
from repro.core.dse import (
    DSEConfig,
    _objective_matrix,
    default_labeler,
    label_unique,
    random_search,
    run_dse,
)
from repro.core.nsga2 import NSGA2Config, NSGA2Result, nsga2
from repro.core.pareto import non_dominated_mask
from repro.core.strategies import (
    BOStrategy,
    Campaign,
    NSGA2Strategy,
    RandomStrategy,
    SearchStrategy,
    available_strategies,
    drive,
    make_strategy,
    register_strategy,
)
from repro.service import CampaignManager, CampaignSpec, JsonlLabelStore

LIB = default_library()

SMALL = dict(n_train=10, n_qor_samples=2, pop_size=8, n_parents=4,
             n_generations=2)

# deterministic labels only: synth_time/sim_time are wall-clock
TIME_KEYS = ("synth_time", "sim_time")


def small_cfg(seed=0, **kw):
    return DSEConfig(
        n_train=SMALL["n_train"], n_qor_samples=SMALL["n_qor_samples"],
        nsga=NSGA2Config(pop_size=SMALL["pop_size"],
                         n_parents=SMALL["n_parents"],
                         n_generations=SMALL["n_generations"], seed=seed),
        seed=seed, **kw,
    )


def _zdt1_like(genomes):
    x = genomes.astype(np.float64)
    f1 = x[:, 0] / 31.0
    g = 1.0 + 9.0 * x[:, 1:].mean(axis=1) / 31.0
    f2 = g * (1.0 - np.sqrt(f1 / g))
    return np.stack([f1, f2], axis=1)


def _drive_strategy(strat, evaluate, n_obj=2):
    while not strat.done:
        g = strat.ask()
        obj = evaluate(g) if len(g) else np.zeros((0, n_obj))
        strat.tell(g, obj)
    return strat.result()


def _legacy_run_dse(accel, cfg):
    """The seed repo's blocking three-stage pipeline, reproduced from
    public pieces — the equivalence anchor for the protocol drive."""
    from repro.core.features.pipelines import build_extractor
    from repro.core.surrogates import make

    rng = np.random.default_rng(cfg.seed)
    sizes = accel.gene_sizes(LIB, rank_genes=cfg.rank_genes)
    labeler = default_labeler(accel, LIB, rank_genes=cfg.rank_genes,
                              n_qor_samples=cfg.n_qor_samples)
    train = rng.integers(0, sizes[None, :],
                         size=(cfg.n_train, len(sizes)))
    train[0] = accel.exact_genome(LIB, rank_genes=cfg.rank_genes)
    tl = label_unique(labeler, train)
    ext = build_extractor(cfg.pipeline, accel, LIB,
                          rank_genes=cfg.rank_genes)
    X = ext(train)
    models = {}
    for obj in cfg.objectives:
        name = cfg.qor_model if obj == "qor" else cfg.hw_model
        models[obj] = make(name, seed=cfg.seed).fit(X, tl[obj])

    def evaluate(g):
        Xg = ext(g)
        return _objective_matrix(
            {o: models[o].predict(Xg) for o in cfg.objectives},
            cfg.objectives)

    init = train[: cfg.nsga.pop_size].copy()
    if cfg.warm_start and len(init) >= 4:
        from repro.accel.approxfpgas import circuit_level_front

        half = len(init) // 2
        choices = [
            [LIB.index(s.kind, c.name)
             for c in circuit_level_front(LIB, s.kind)]
            for s in accel.slots
        ]
        for t in range(half):
            for j, ch in enumerate(choices):
                init[t, j] = ch[rng.integers(0, len(ch))]
    search = nsga2(sizes, evaluate, cfg.nsga, init=init)
    fl = label_unique(labeler, search.genomes)
    allg = np.concatenate([search.genomes, train])
    all_labels = {k: np.concatenate([fl[k], tl[k]]) for k in fl}
    true_obj = _objective_matrix(all_labels, cfg.objectives)
    return allg, true_obj, non_dominated_mask(true_obj), search


@pytest.fixture(scope="module")
def mcm():
    return MCMAccelerator(1)


# ---------------------------------------------------------------------------
# protocol <-> legacy equivalence
# ---------------------------------------------------------------------------

def test_nsga2_strategy_seed_identical_to_loop():
    """Driving NSGA2Strategy by hand reproduces nsga2() exactly —
    genomes, objectives, history and the dedup'd evaluation count."""
    cfg = NSGA2Config(pop_size=24, n_parents=10, n_generations=6, seed=3)
    ref = nsga2([6] * 4, _zdt1_like, cfg)
    res = _drive_strategy(NSGA2Strategy([6] * 4, cfg), _zdt1_like)
    assert np.array_equal(ref.genomes, res.genomes)
    assert np.array_equal(ref.objectives, res.objectives)
    assert ref.n_evaluated == res.n_evaluated
    assert len(ref.history) == len(res.history)
    for a, b in zip(ref.history, res.history):
        assert np.array_equal(a.genomes, b.genomes)
        assert np.array_equal(a.objectives, b.objectives)
        assert a.n_evaluated == b.n_evaluated


def test_ask_is_idempotent_and_tell_validates():
    cfg = NSGA2Config(pop_size=8, n_parents=4, n_generations=2, seed=0)
    s = NSGA2Strategy([5] * 3, cfg)
    a1, a2 = s.ask(), s.ask()
    assert np.array_equal(a1, a2)      # no RNG consumed by the re-ask
    with pytest.raises(ValueError):
        s.tell(a1[:-1], _zdt1_like(a1[:-1]))
    s.tell(a1, _zdt1_like(a1))
    with pytest.raises(RuntimeError):
        s.tell(a1, _zdt1_like(a1))     # tell without ask


def test_campaign_protocol_matches_run_dse(mcm):
    """The manually stepped Campaign == run_dse byte-for-byte (and both
    == the seed repo's blocking pipeline, reproduced inline)."""
    cfg = small_cfg()
    ref = run_dse(mcm, LIB, cfg)

    campaign = Campaign(mcm, LIB, cfg)
    labeler = default_labeler(mcm, LIB, n_qor_samples=cfg.n_qor_samples)
    requests = []
    while not campaign.done:
        req = campaign.step()
        if req is not None:
            requests.append(req.stage)
            campaign.deliver(req, labeler(req.genomes))
    res = campaign.result()
    assert requests == ["train", "final"]  # EXPLORE never needs labels

    assert np.array_equal(ref.train_genomes, res.train_genomes)
    assert ref.val_pcc == res.val_pcc
    assert np.array_equal(ref.search.genomes, res.search.genomes)
    assert np.array_equal(ref.search.objectives, res.search.objectives)
    assert ref.search.n_evaluated == res.search.n_evaluated
    assert np.array_equal(ref.est_objectives, res.est_objectives)
    assert np.array_equal(ref.true_objectives, res.true_objectives)
    assert np.array_equal(ref.front_mask, res.front_mask)
    assert set(res.timings) == {"label", "train", "explore", "final_eval"}

    legacy_g, legacy_obj, legacy_mask, legacy_search = _legacy_run_dse(
        mcm, cfg)
    assert np.array_equal(res.search.genomes, legacy_g)
    assert np.array_equal(res.true_objectives, legacy_obj)
    assert np.array_equal(res.front_mask, legacy_mask)
    assert res.search.n_evaluated == legacy_search.n_evaluated


def test_random_search_seed_identical(mcm):
    """random_search through the ground-truth Campaign == the seed
    behavior: one uniform draw, one unique-labeled batch."""
    g, obj, mask = random_search(mcm, LIB, n=15, seed=3)

    rng = np.random.default_rng(3)
    sizes = mcm.gene_sizes(LIB)
    exp_g = rng.integers(0, sizes[None, :], size=(15, len(sizes)))
    labels = label_unique(default_labeler(mcm, LIB), exp_g)
    exp_obj = _objective_matrix(labels, ("qor", "energy"))
    assert np.array_equal(g, exp_g)
    assert np.array_equal(obj, exp_obj)
    assert np.array_equal(mask, non_dominated_mask(exp_obj))


# ---------------------------------------------------------------------------
# state() / restore()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_strat", [
    lambda: NSGA2Strategy([5] * 3, NSGA2Config(pop_size=16, n_parents=8,
                                               n_generations=8, seed=7)),
    lambda: RandomStrategy([5] * 3, n_total=64, batch_size=16, seed=7),
    lambda: BOStrategy([5] * 3, n_rounds=6, batch_size=8, n_parents=8,
                       seed=7),
])
def test_strategy_state_roundtrips_mid_run(make_strat):
    """Snapshot after round k, restore on a FRESH instance via a JSON
    round-trip, finish both: identical survivors and eval counts."""
    s1 = make_strat()
    for _ in range(3):
        g = s1.ask()
        s1.tell(g, _zdt1_like(g) if len(g) else np.zeros((0, 2)))
    snap = json.loads(json.dumps(s1.state()))
    s2 = make_strat().restore(snap)
    r1 = _drive_strategy(s1, _zdt1_like)
    r2 = _drive_strategy(s2, _zdt1_like)
    assert np.array_equal(r1.genomes, r2.genomes)
    assert np.array_equal(r1.objectives, r2.objectives)
    assert r1.n_evaluated == r2.n_evaluated


def test_campaign_state_roundtrips_mid_explore(mcm):
    """Campaign snapshot mid-EXPLORE -> fresh Campaign -> identical
    DSEResult (surrogates refit deterministically from the snapshotted
    training set)."""
    cfg = small_cfg()
    labeler = default_labeler(mcm, LIB, n_qor_samples=cfg.n_qor_samples)
    ref = run_dse(mcm, LIB, cfg, labeler=labeler)

    c1 = Campaign(mcm, LIB, cfg)
    # TRAIN tick + delivery, then one EXPLORE round
    req = c1.step()
    c1.deliver(req, labeler(req.genomes))
    assert c1.stage == "explore"
    c1.step()
    snap = json.loads(json.dumps(c1.state()))

    c2 = Campaign(mcm, LIB, cfg).restore(snap)
    assert c2.stage == "explore"
    res = drive(c2, labeler)
    assert np.array_equal(ref.search.genomes, res.search.genomes)
    assert np.array_equal(ref.true_objectives, res.true_objectives)
    assert np.array_equal(ref.front_mask, res.front_mask)
    assert ref.search.n_evaluated == res.search.n_evaluated


def test_campaign_refuses_finished_snapshot(mcm):
    cfg = small_cfg()
    labeler = default_labeler(mcm, LIB, n_qor_samples=cfg.n_qor_samples)
    c = Campaign(mcm, LIB, cfg)
    drive(c, labeler)
    with pytest.raises(ValueError, match="finished"):
        Campaign(mcm, LIB, cfg).restore(c.state())


# ---------------------------------------------------------------------------
# service: cooperative stepping, cancel/resume, live progress
# ---------------------------------------------------------------------------

def _wait_for_stage(mgr, cid, stages=("explore", "final"), timeout=120.0):
    t0 = time.time()
    while time.time() - t0 < timeout:
        st = mgr.status(cid)
        if st["state"] in ("done", "failed"):
            return st
        if (st.get("progress") or {}).get("stage") in stages:
            return st
        time.sleep(0.005)
    raise TimeoutError(f"campaign {cid} never reached {stages}")


def test_more_campaigns_than_workers_multiplex():
    """Cooperative stepping: 4 concurrent campaigns over ONE stepper
    thread all finish with seed-identical fronts."""
    spec = CampaignSpec(accel="mcm2", **SMALL)
    ref = run_dse(MCMAccelerator(1), LIB, spec.dse_config())
    mgr = CampaignManager(eval_workers=2, campaign_workers=1)
    try:
        cids = [mgr.submit(spec) for _ in range(4)]
        for cid in cids:
            assert mgr.wait(cid, timeout=600) == "done"
            assert np.allclose(mgr.result(cid).front_objectives,
                               ref.front_objectives)
    finally:
        mgr.shutdown()


def test_status_reports_live_progress():
    spec = CampaignSpec(accel="mcm2", **{**SMALL, "n_generations": 30})
    mgr = CampaignManager(eval_workers=2, campaign_workers=1)
    try:
        cid = mgr.submit(spec)
        st = _wait_for_stage(mgr, cid, stages=("explore",))
        pr = st.get("progress")
        assert pr is not None
        assert pr["stage"] == "explore"
        assert pr["strategy"] == "nsga2"
        assert "generation" in pr and "labels_requested" in pr
        assert mgr.wait(cid, timeout=600) == "done"
    finally:
        mgr.shutdown()


@pytest.mark.parametrize("eval_backend", ["thread", "process"])
def test_killed_then_resumed_matches_uninterrupted_twin(tmp_path,
                                                        eval_backend):
    """Acceptance: cancel mid-EXPLORE, resume, and the front matches the
    uninterrupted twin (under both eval backends; the process backend is
    the satellite-required configuration)."""
    if eval_backend == "process":
        kw = dict(eval_backend="process", process_workers=1)
    else:
        kw = {}
    spec = CampaignSpec(accel="mcm2",
                        **{**SMALL, "n_generations": 12})
    store = JsonlLabelStore(str(tmp_path / f"labels_{eval_backend}.jsonl"))
    mgr = CampaignManager(store, eval_workers=2, campaign_workers=2,
                          snapshot_path=str(tmp_path / "snaps.jsonl"), **kw)
    try:
        twin = mgr.submit(spec)
        assert mgr.wait(twin, timeout=600) == "done"
        twin_front = mgr.result(twin).front_objectives

        cid = mgr.submit(spec)
        st = _wait_for_stage(mgr, cid)
        if st["state"] != "done":
            mgr.cancel(cid)
        state = mgr.wait(cid, timeout=600)
        if state == "done":        # raced to completion before the cancel
            resumed_front = mgr.result(cid).front_objectives
        else:
            assert state == "cancelled"
            assert cid in mgr.snapshot_ids()
            mgr.resume(cid)
            assert mgr.wait(cid, timeout=600) == "done"
            resumed_front = mgr.result(cid).front_objectives
        assert np.array_equal(resumed_front, twin_front)
    finally:
        mgr.shutdown()
        store.close()


def test_resume_across_manager_restart(tmp_path):
    """A campaign killed WITH its manager resumes on a fresh manager
    from the persisted snapshot file — same id, same front as a clean
    run."""
    snap_path = str(tmp_path / "snaps.jsonl")
    store_path = str(tmp_path / "labels.jsonl")
    spec = CampaignSpec(accel="mcm2", **{**SMALL, "n_generations": 12})
    ref = run_dse(MCMAccelerator(1), LIB, spec.dse_config())

    store = JsonlLabelStore(store_path)
    mgr = CampaignManager(store, eval_workers=2, campaign_workers=1,
                          snapshot_path=snap_path)
    cid = mgr.submit(spec)
    st = _wait_for_stage(mgr, cid)
    if st["state"] != "done":
        mgr.cancel(cid)
    assert mgr.wait(cid, timeout=600) in ("cancelled", "done")
    mgr.shutdown()          # "kill" the process
    store.close()

    store2 = JsonlLabelStore(store_path)
    mgr2 = CampaignManager(store2, eval_workers=2, campaign_workers=1,
                           snapshot_path=snap_path)
    try:
        if cid in mgr2.snapshot_ids():     # not tombstoned by a race
            mgr2.resume(cid)
            assert mgr2.wait(cid, timeout=600) == "done"
            assert np.array_equal(mgr2.result(cid).front_objectives,
                                  ref.front_objectives)
    finally:
        mgr2.shutdown()
        store2.close()


def _free_port():
    import socket

    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _spawn_service(port, store, snaps):
    import subprocess
    import sys

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.Popen(
        [sys.executable, "-m", "repro.service", "--port", str(port),
         "--store", store, "--snapshots", snaps, "--synth-cache", "",
         "--eval-workers", "2", "--campaign-workers", "1"],
        env={**os.environ, "PYTHONPATH": src},
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
    )


def _wait_healthy(cli, proc, timeout=60.0):
    t0 = time.time()
    while time.time() - t0 < timeout:
        if proc.poll() is not None:
            raise RuntimeError(f"service died rc={proc.returncode}")
        try:
            if cli.health()["ok"]:
                return
        except Exception:
            time.sleep(0.1)
    raise TimeoutError("service never became healthy")


def test_kill9_service_restart_resume_front_is_byte_identical(tmp_path):
    """Crash-safety acceptance: SIGKILL the service process mid-EXPLORE
    (no atexit, no flush, no snapshot-on-shutdown), restart it on the
    same --store/--snapshots, resume the campaign over HTTP, and the
    finished front is byte-identical to an uninterrupted twin."""
    from repro.service.api import Client

    spec = {"accel": "mcm2", **SMALL, "n_generations": 12}
    ref = run_dse(MCMAccelerator(1), LIB,
                  CampaignSpec(**spec).dse_config())
    store = str(tmp_path / "labels.jsonl")
    snaps = str(tmp_path / "snaps.jsonl")
    port = _free_port()

    proc = _spawn_service(port, store, snaps)
    cli = Client(f"http://127.0.0.1:{port}", timeout=10.0)
    try:
        _wait_healthy(cli, proc)
        cid = cli.submit(**spec)
        t0 = time.time()
        while time.time() - t0 < 120:
            st = cli.status(cid)
            if st["state"] == "done":
                break  # raced to completion before we could kill
            if (st.get("progress") or {}).get("stage") in ("explore",
                                                           "final"):
                break
            time.sleep(0.01)
        if st["state"] == "done":        # raced: nothing left to kill mid-run
            assert np.array_equal(np.asarray(cli.result(cid)["front"]),
                                  ref.front_objectives)
            return
        proc.kill()                      # SIGKILL: no cleanup of any kind
        proc.wait(timeout=30)

        proc = _spawn_service(port, store, snaps)
        _wait_healthy(cli, proc)
        # the tick-boundary snapshot survived the kill
        cli.resume(cid)
        st = cli.wait(cid, timeout=600)
        assert st["state"] == "done"
        assert np.array_equal(np.asarray(cli.result(cid)["front"]),
                              ref.front_objectives)
        # the store the killed process was appending to reopened clean
        h = cli.health()
        assert h["ok"] and h["store"]["writable"]
    finally:
        proc.kill()
        proc.wait(timeout=30)


def test_cancel_validation():
    mgr = CampaignManager(eval_workers=1, campaign_workers=1)
    try:
        spec = CampaignSpec(accel="mcm2", **SMALL)
        cid = mgr.submit(spec)
        assert mgr.wait(cid, timeout=600) == "done"
        with pytest.raises(RuntimeError, match="already done"):
            mgr.cancel(cid)
        with pytest.raises(RuntimeError, match="only cancelled/failed"):
            mgr.resume(cid)
        with pytest.raises(KeyError):
            mgr.resume("nope")
    finally:
        mgr.shutdown()


# ---------------------------------------------------------------------------
# strategy plugging: bo / random / custom, spec + HTTP
# ---------------------------------------------------------------------------

def test_builtin_strategies_registered():
    for name in ("nsga2", "random", "bo"):
        assert name in available_strategies()
    s = make_strategy("bo", [4] * 3, small_cfg())
    assert isinstance(s, BOStrategy)
    with pytest.raises(ValueError, match="unknown strategy"):
        make_strategy("nope", [4] * 3, small_cfg())


def test_bo_campaign_end_to_end_via_service():
    """Acceptance: BOStrategy runs end-to-end through the service
    (POST /campaigns {"strategy": "bo"} equivalent)."""
    mgr = CampaignManager(eval_workers=2, campaign_workers=1)
    try:
        cid = mgr.submit(CampaignSpec(accel="mcm2", strategy="bo", **SMALL))
        assert mgr.wait(cid, timeout=600) == "done"
        res = mgr.result(cid)
        assert res.front_mask.any()
        assert non_dominated_mask(res.front_objectives).all()
        assert mgr.status(cid)["spec"]["strategy"] == "bo"
        with pytest.raises(ValueError, match="unknown strategy"):
            mgr.submit(CampaignSpec(accel="mcm2", strategy="nope", **SMALL))
    finally:
        mgr.shutdown()


def test_http_strategy_and_resume_roundtrip():
    from repro.service.api import Client, make_server

    mgr = CampaignManager(eval_workers=2, campaign_workers=2)
    srv = make_server(mgr, port=0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    try:
        cli = Client(f"http://127.0.0.1:{srv.server_address[1]}")
        assert set(cli.strategies()) >= {"nsga2", "random", "bo"}
        cid = cli.submit(accel="mcm2", strategy="bo", **SMALL)
        st = cli.wait(cid, timeout=600)
        assert st["state"] == "done"
        assert st["spec"]["strategy"] == "bo"
        # cancel/resume route validation on a finished campaign
        import urllib.error

        with pytest.raises(urllib.error.HTTPError) as exc:
            cli.cancel(cid)
        assert exc.value.code == 409
        with pytest.raises(urllib.error.HTTPError) as exc:
            cli.resume("nope")
        assert exc.value.code == 404
    finally:
        srv.shutdown()
        mgr.shutdown()


def test_custom_strategy_in_30_lines(mcm):
    """The STRATEGIES.md pitch: a hill-climber plugged in by name."""

    class HillClimb(SearchStrategy):
        name = "hillclimb"

        def __init__(self, sizes, cfg, *, init=None):
            self.sizes = np.asarray(sizes, dtype=np.int64)
            self.rng = np.random.default_rng(cfg.seed)
            self.rounds = cfg.nsga.n_generations + 1
            self.batch = cfg.nsga.pop_size
            self.round = 0
            self.best = None            # (genome, scalarized objective)
            self.obs = []
            self._pending = None

        @property
        def done(self):
            return self.round >= self.rounds and self._pending is None

        def ask(self):
            if self._pending is None:
                if self.best is None:
                    g = self.rng.integers(0, self.sizes[None, :],
                                          size=(self.batch, len(self.sizes)))
                else:
                    g = np.repeat(self.best[None, :], self.batch, axis=0)
                    mut = self.rng.random(g.shape) < 0.2
                    g = np.where(mut, self.rng.integers(
                        0, self.sizes[None, :], size=g.shape), g)
                self._pending = g
            return self._pending

        def tell(self, genomes, objectives):
            self.obs.append((np.array(genomes), np.array(objectives)))
            score = objectives.sum(axis=1)
            k = int(np.argmin(score))
            self.best = np.array(genomes[k])
            self.round += 1
            self._pending = None

        def result(self):
            G = np.concatenate([g for g, _ in self.obs])
            O = np.concatenate([o for _, o in self.obs])
            return NSGA2Result(genomes=G, objectives=O,
                               front_mask=non_dominated_mask(O),
                               n_evaluated=len(G))

    register_strategy("hillclimb", HillClimb)
    try:
        res = run_dse(mcm, LIB, small_cfg(strategy="hillclimb"))
        assert res.front_mask.any()
    finally:
        available_strategies()  # registry intact
        from repro.core.strategies import STRATEGIES

        STRATEGIES.pop("hillclimb", None)
