"""The ask/tell ``SearchStrategy`` protocol — the exploration loop as an
interruptible state machine instead of a blocking function call.

A strategy never evaluates anything itself.  It proposes genome batches
(``ask``), receives their objective values back (``tell``), and keeps
every bit of loop state — RNG, population, round counter, history —
inside itself, where it can be captured (``state``) and re-installed
(``restore``) at any round boundary:

    strat = NSGA2Strategy(gene_sizes, NSGA2Config(...))
    while not strat.done:
        genomes = strat.ask()           # fresh genomes needing objectives
        strat.tell(genomes, evaluate(genomes) if len(genomes) else
                   np.zeros((0, n_obj)))
    result = strat.result()             # an NSGA2Result

Who computes the objectives is the caller's business: the ``Campaign``
driver (strategies.campaign) evaluates surrogates during EXPLORE and
routes ground truth through a labeler; ``random_search`` feeds true
labels straight in.  That inversion is what lets the service step many
campaigns cooperatively over one worker pool and resume a killed
campaign from its snapshot.

``state()`` must return a JSON-serializable dict (numpy arrays as
lists, RNG as ``Generator.bit_generator.state``) so snapshots can be
persisted next to the label store and survive a process death.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np

__all__ = [
    "SearchStrategy",
    "STRATEGIES",
    "register_strategy",
    "make_strategy",
    "available_strategies",
    "encode_array",
    "decode_array",
]


def encode_array(a: Optional[np.ndarray]) -> Optional[list]:
    """numpy -> nested lists (None passes through)."""
    return None if a is None else np.asarray(a).tolist()


def decode_array(v, dtype=np.int64, width: Optional[int] = None
                 ) -> Optional[np.ndarray]:
    """Inverse of encode_array; ``width`` disambiguates empty 2-D arrays."""
    if v is None:
        return None
    a = np.asarray(v, dtype=dtype)
    if a.size == 0 and width is not None:
        a = a.reshape(0, width)
    return a


class SearchStrategy:
    """Base class for ask/tell explorers over integer genome spaces.

    Subclasses implement ``ask``/``tell``/``done``/``result`` and the
    ``state``/``restore`` pair.  Contract:

      * ``ask()`` returns an (n, g) int64 batch of genomes whose
        objectives the strategy has not seen (n may be 0 when every
        candidate this round is already known); calling it twice
        without an intervening ``tell`` returns the same batch and
        consumes no randomness (idempotent, so a driver can be
        re-entered safely).
      * ``tell(genomes, objectives)`` must receive exactly the last
        ``ask`` batch with an (n, m) float64 objective matrix
        (minimization convention).  It returns the round's
        ``GenerationLog`` when a round completed, else None.
      * ``done`` is True once the budget is exhausted; ``ask`` then
        raises.
      * ``state()``/``restore(state)`` round-trip the FULL loop state at
        a round boundary (never between ask and tell — drivers snapshot
        after tell).
    """

    name: str = "base"

    def ask(self) -> np.ndarray:
        raise NotImplementedError

    def tell(self, genomes: np.ndarray, objectives: np.ndarray):
        raise NotImplementedError

    @property
    def done(self) -> bool:
        raise NotImplementedError

    def result(self):
        """Final survivor set as an ``NSGA2Result`` (genomes, objectives,
        front_mask, history, n_evaluated)."""
        raise NotImplementedError

    def state(self) -> Dict:
        raise NotImplementedError

    def restore(self, state: Dict) -> "SearchStrategy":
        raise NotImplementedError

    def progress(self) -> Dict:
        """Small JSON-safe live-progress record (for GET /campaigns/<id>)."""
        return {"strategy": self.name, "done": bool(self.done)}

    # ------------------------------------------------------------------
    @staticmethod
    def _check_tell(expected: Optional[np.ndarray], genomes: np.ndarray
                    ) -> np.ndarray:
        """Validate a tell() batch against the outstanding ask()."""
        genomes = np.atleast_2d(np.asarray(genomes, dtype=np.int64))
        if expected is None:
            raise RuntimeError("tell() without a preceding ask()")
        if len(genomes) != len(expected) or (
                len(genomes) and not np.array_equal(genomes, expected)):
            raise ValueError(
                f"tell() batch does not match the last ask() batch "
                f"({len(genomes)} vs {len(expected)} genomes)"
            )
        return genomes


# ---------------------------------------------------------------------------
# registry: strategies plug in by name (CampaignSpec.strategy, --strategy)
# ---------------------------------------------------------------------------

# name -> factory(gene_sizes, dse_cfg, *, init=None) -> SearchStrategy.
# ``dse_cfg`` is a core.dse.DSEConfig: factories derive their budget from
# cfg.nsga (pop_size/n_parents/n_generations/seed) so every strategy
# spends a comparable number of objective evaluations per campaign.
STRATEGIES: Dict[str, Callable] = {}


def register_strategy(name: str, factory: Callable) -> None:
    """Register a strategy factory.  ``factory(gene_sizes, cfg, *,
    init=None)`` returns a fresh ``SearchStrategy``; ``init`` is the
    campaign's warm-started initial population (strategies may ignore
    it).  Last registration wins, so tests can shadow built-ins."""
    STRATEGIES[name] = factory


def make_strategy(name: str, gene_sizes, cfg, *, init=None) -> SearchStrategy:
    if name not in STRATEGIES:
        raise ValueError(
            f"unknown strategy {name!r}; known: {available_strategies()}"
        )
    return STRATEGIES[name](gene_sizes, cfg, init=init)


def available_strategies() -> List[str]:
    return sorted(STRATEGIES)
