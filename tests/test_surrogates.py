"""Surrogate regression models: every registry entry learns a smooth
target; key models recover known structure; determinism."""

import numpy as np
import pytest

from repro.core.surrogates import available, make, pcc, r2


def _toy(n=300, d=6, seed=0, noise=0.05):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d))
    w = rng.standard_normal(d)
    y = X @ w + 0.5 * np.sin(2 * X[:, 0]) + X[:, 1] * X[:, 2] * 0.3
    y = y + noise * rng.standard_normal(n)
    return X[:200], y[:200], X[200:], y[200:]


@pytest.mark.parametrize("name", available())
def test_model_learns_toy_function(name):
    Xtr, ytr, Xte, yte = _toy()
    m = make(name, seed=0).fit(Xtr, ytr)
    c = pcc(yte, m.predict(Xte))
    floor = {"sgd": 0.8, "knn_uniform": 0.7, "knn3": 0.7, "knn5": 0.7,
             "cart_shallow": 0.55, "cart": 0.7, "svr": 0.7,
             "kernel_ridge_rbf": 0.7}.get(name, 0.85)
    assert c > floor, (name, c)


@pytest.mark.parametrize("name", ["random_forest", "bayesian_ridge", "svr"])
def test_models_deterministic(name):
    Xtr, ytr, Xte, _ = _toy()
    p1 = make(name, seed=3).fit(Xtr, ytr).predict(Xte)
    p2 = make(name, seed=3).fit(Xtr, ytr).predict(Xte)
    assert np.array_equal(p1, p2)


def test_bayesian_ridge_recovers_linear_weights():
    rng = np.random.default_rng(1)
    X = rng.standard_normal((400, 5))
    w = np.array([1.0, -2.0, 0.5, 0.0, 3.0])
    y = X @ w + 0.01 * rng.standard_normal(400)
    m = make("bayesian_ridge").fit(X, y)
    # model standardizes; compare through predictions on a probe basis
    probe = np.eye(5)
    rec = m.predict(probe) - m.predict(np.zeros((1, 5)))
    assert np.allclose(rec, w, atol=0.05)


def test_bayesian_ridge_predictive_std():
    rng = np.random.default_rng(2)
    X = rng.standard_normal((100, 3))
    y = X @ np.array([1.0, 2.0, -1.0]) + 0.1 * rng.standard_normal(100)
    m = make("bayesian_ridge").fit(X, y)
    std = m.predict_std(X)
    assert (std > 0).all()
    far = m.predict_std(10 * np.ones((1, 3)))
    assert far[0] > std.mean()  # extrapolation is less certain


def test_random_forest_beats_single_tree_on_noise():
    Xtr, ytr, Xte, yte = _toy(noise=0.4, seed=5)
    tree = make("cart").fit(Xtr, ytr)
    forest = make("random_forest").fit(Xtr, ytr)
    assert r2(yte, forest.predict(Xte)) >= r2(yte, tree.predict(Xte)) - 0.02


def test_pcc_properties():
    a = np.arange(10.0)
    assert pcc(a, 2 * a + 1) == pytest.approx(1.0)
    assert pcc(a, -a) == pytest.approx(-1.0)
    assert pcc(a, np.ones(10)) == 0.0


@pytest.mark.parametrize("scale", [1.0, 1e-7, 1e7])
def test_trees_split_small_magnitude_targets(scale):
    """Regression: CART/RF must split targets of any magnitude (an
    absolute SSE-gain epsilon left ~1e-7-scale energy targets constant)."""
    rng = np.random.default_rng(0)
    X = rng.standard_normal((200, 4))
    y = (X[:, 0] * 2 + X[:, 1]) * scale
    for name in ("cart", "random_forest"):
        m = make(name).fit(X[:150], y[:150])
        c = pcc(y[150:], m.predict(X[150:]))
        assert c > 0.8, (name, scale, c)
