"""Fleet orchestrator: lease coalesced label batches to remote workers.

The coordinator is transport-agnostic — ``register`` / ``heartbeat`` /
``lease`` / ``result`` take and return JSON-safe dicts.  The service's
HTTP front end (``service/api.py``) mounts them under ``POST /fleet/*``;
``serve_fleet`` runs the same four routes standalone for CLI drivers,
benchmarks and tests that have no campaign manager.

Work flows PULL-style (the JetStream idiom): workers poll ``lease`` and
the coordinator hands out chunks of whatever batches are in flight, so
elastic join is trivial — a worker that registers mid-campaign starts
pulling chunks on its next poll, and one that leaves simply stops
polling.  Robustness invariants:

  * **zero-loss failure** — a lease that expires, or whose worker's
    heartbeats stop, requeues its chunk; chunks requeued past
    ``max_requeues`` (or stranded with no live worker) are labeled
    in-process by the orchestrator thread that owns the batch, so
    ``label()`` ALWAYS returns complete labels.
  * **at-most-once commit** — labels are deterministic and
    content-addressed; a late result from a presumed-dead worker either
    completes the chunk first (and the reissued lease's result is
    dropped as a duplicate) or finds it completed (and is dropped
    itself).  Either way the label store sees one record per key and a
    mid-run ``kill -9`` changes zero output bytes.
  * **drift safety** — a worker that derives a different context
    fingerprint than the parent rejects the lease; the fingerprint is
    pinned away from that worker, and away from the fleet entirely once
    every live worker has rejected it.
"""

from __future__ import annotations

import threading
import time
import uuid
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from .. import faults, obs
from .leases import Chunk, FleetBatch, Lease, WorkerRecord
from .protocol import (
    PROTOCOL_VERSION,
    context_is_portable,
    ctx_descriptor,
    decode_labels,
)

__all__ = ["FleetCoordinator", "handle_fleet_request", "serve_fleet"]


class FleetCoordinator:
    """Orchestrator state machine for a labeling fleet.

    ``label(ctx, genomes)`` is the blocking batch call the
    ``EvalScheduler`` makes on its worker threads; everything else is
    the worker-facing protocol surface."""

    def __init__(
        self,
        *,
        lease_ttl_s: float = 30.0,
        heartbeat_ttl_s: float = 15.0,
        chunk_size: Optional[int] = None,
        max_requeues: int = 3,
        idle_wait_s: float = 0.25,
    ):
        self.lease_ttl_s = float(lease_ttl_s)
        self.heartbeat_ttl_s = float(heartbeat_ttl_s)
        self.chunk_size = None if chunk_size is None else max(1, int(chunk_size))
        self.max_requeues = int(max_requeues)
        self.idle_wait_s = float(idle_wait_s)
        # how often blocked label() threads wake to run expiry
        self._tick = min(1.0, max(0.05,
                                  min(lease_ttl_s, heartbeat_ttl_s) / 4.0))
        self._cv = threading.Condition()
        self._workers: Dict[str, WorkerRecord] = {}
        self._pending: deque = deque()             # Chunk
        self._leases: Dict[str, Lease] = {}        # lease id -> Lease
        self._retired: Dict[str, Lease] = {}       # expired, awaiting late results
        self._portable: Dict[str, bool] = {}       # ctx fp -> parent-side gate
        self._drifted: set = set()                 # fps every worker rejected
        self._stopped = False
        # counters — registry instruments (scrape-safe without _cv)
        reg = obs.REGISTRY
        self.n_batches = reg.counter(
            "repro_fleet_batches_total", "batches split across the fleet")
        self.n_chunks = reg.counter(
            "repro_fleet_chunks_total", "chunks created for leasing")
        self.n_requeues = reg.counter(
            "repro_fleet_requeues_total", "chunks requeued after a failure")
        self.n_expired_leases = reg.counter(
            "repro_fleet_expired_leases_total",
            "leases reclaimed on deadline/heartbeat expiry")
        self.n_dead_workers = reg.counter(
            "repro_fleet_dead_workers_total",
            "workers declared dead by heartbeat TTL")
        self.n_duplicate_results = reg.counter(
            "repro_fleet_duplicate_results_total",
            "late/duplicate results dropped idempotently")
        self.n_local_chunks = reg.counter(
            "repro_fleet_local_chunks_total",
            "starved chunks labeled in-process")
        self.n_remote_labels = reg.counter(
            "repro_fleet_remote_labels_total", "labels from fleet workers")
        self.n_local_labels = reg.counter(
            "repro_fleet_local_labels_total",
            "labels from the in-process reclaim path")
        self.live_gauge = reg.gauge(
            "repro_fleet_live_workers", "workers within heartbeat TTL")
        self.pending_gauge = reg.gauge(
            "repro_fleet_pending_chunks", "chunks awaiting a lease")
        self.leases_gauge = reg.gauge(
            "repro_fleet_leases_in_flight", "chunks currently leased")

    # ------------------------------------------------------------------
    # scheduler-facing
    # ------------------------------------------------------------------
    def eligible(self, ctx) -> bool:
        """True iff this batch should go to the fleet: the context is
        portable (the PR-3 gate) and at least one live worker advertises
        capability for it.  An empty fleet answers False — the scheduler
        degrades to its in-process backend."""
        fp = ctx.fingerprint
        if fp in self._drifted:
            return False
        portable = self._portable.get(fp)
        if portable is None:
            # builds a reference context once per fingerprint; outside
            # the lock on purpose (first call pays an accelerator build)
            portable = context_is_portable(ctx)
            with self._cv:
                self._portable[fp] = portable
        if not portable:
            return False
        desc = ctx_descriptor(ctx)
        with self._cv:
            self._expire_locked(time.monotonic())
            return any(w.alive and w.can_serve(desc)
                       for w in self._workers.values())

    def label(self, ctx, genomes: np.ndarray) -> Dict[str, np.ndarray]:
        """Label a batch across the fleet (blocking).  Worker failures
        requeue; starved chunks are labeled in-process; the result is
        byte-identical to ``ctx.ground_truth(genomes)``."""
        genomes = np.atleast_2d(np.asarray(genomes, dtype=np.int64))
        desc = ctx_descriptor(ctx)
        with obs.span("fleet.batch", n=int(len(genomes))) as sp:
            # chunks carry the batch's trace context so lease-lifecycle
            # spans (granted on protocol threads) and worker-side spans
            # link back to the submitting campaign
            wire = obs.wire_context()
            with self._cv:
                live = sum(w.alive for w in self._workers.values())
                parts = self._split(len(genomes), live)
                batch = FleetBatch(ctx, len(parts))
                chunks = [
                    Chunk(batch=batch, index=i, desc=desc,
                          genomes=genomes[idx], wire=wire)
                    for i, idx in enumerate(parts)
                ]
                self._pending.extend(chunks)
                self.n_batches.inc()
                self.n_chunks.inc(len(chunks))
                self.pending_gauge.set(len(self._pending))
                self._cv.notify_all()
            sp.set(chunks=len(chunks), live_workers=live)
            n_local = 0
            while True:
                local: List[Chunk] = []
                with self._cv:
                    if batch.remaining == 0:
                        break
                    self._expire_locked(time.monotonic())
                    local = self._reclaim_locked(batch)
                    if not local and batch.remaining > 0:
                        self._cv.wait(timeout=self._tick)
                        continue
                for chunk in local:
                    # in-process fallback OUTSIDE the lock; complete()
                    # drops a racing late remote result for the chunk
                    with obs.span("fleet.local",
                                  n=int(len(chunk.genomes))):
                        labels = ctx.ground_truth(chunk.genomes)
                    with self._cv:
                        if batch.complete(chunk, {
                            k: np.asarray(v) for k, v in labels.items()
                        }):
                            chunk.worker = None
                            n_local += 1
                            self.n_local_chunks.inc()
                            self.n_local_labels.inc(len(chunk.genomes))
                        self._cv.notify_all()
            sp.set(local_chunks=n_local)
            return batch.assemble()

    def _split(self, n: int, live_workers: int) -> List[np.ndarray]:
        """Chunking mirrors the process pool: ~2 chunks per live worker
        (or fixed ``chunk_size`` rows) — small enough that a death
        requeues a slice, big enough to stay vectorized."""
        if self.chunk_size is not None:
            k = -(-n // self.chunk_size)
        else:
            k = max(1, 2 * max(live_workers, 1))
        return [c for c in np.array_split(np.arange(n), min(n, k)) if len(c)]

    def _reclaim_locked(self, batch: FleetBatch) -> List[Chunk]:
        """Pull this batch's starved chunks off the pending queue for
        in-process labeling: requeued past the cap, stranded with no
        live capable worker, or orphaned by shutdown."""
        keep: deque = deque()
        mine: List[Chunk] = []
        while self._pending:
            chunk = self._pending.popleft()
            if chunk.batch is not batch or chunk.state == "done":
                if chunk.state != "done":
                    keep.append(chunk)
                continue
            starved = (
                self._stopped
                or chunk.requeues > self.max_requeues
                or not any(w.alive and w.can_serve(chunk.desc)
                           for w in self._workers.values())
            )
            if starved:
                mine.append(chunk)
            else:
                keep.append(chunk)
        self._pending = keep
        return mine

    # ------------------------------------------------------------------
    # worker-facing protocol (JSON-safe dicts in and out)
    # ------------------------------------------------------------------
    def register(self, payload: Dict) -> Dict:
        """Join (or rejoin) the fleet.  Idempotent upsert by worker id;
        returns the cadence the worker should poll and heartbeat at."""
        proto = int(payload.get("protocol", PROTOCOL_VERSION))
        if proto != PROTOCOL_VERSION:
            return {"ok": False,
                    "error": f"protocol {proto} != {PROTOCOL_VERSION}"}
        wid = str(payload.get("worker") or f"w-{uuid.uuid4().hex[:8]}")
        now = time.monotonic()
        with self._cv:
            w = self._workers.get(wid)
            if w is None:
                w = WorkerRecord(id=wid)
                self._workers[wid] = w
            else:
                w.rejoin_count += 1
            w.alive = True
            w.last_seen = now
            w.host = str(payload.get("host", ""))
            w.pid = payload.get("pid")
            w.accels = set(payload.get("accels") or ["*"])
            w.fingerprints |= set(payload.get("fingerprints") or [])
            self._cv.notify_all()
        return {
            "ok": True,
            "worker": wid,
            "protocol": PROTOCOL_VERSION,
            "heartbeat_s": self.heartbeat_ttl_s / 3.0,
            "idle_wait_s": self.idle_wait_s,
            "lease_ttl_s": self.lease_ttl_s,
        }

    def heartbeat(self, payload: Dict) -> Dict:
        """Keep a worker alive; merges newly verified fingerprints.
        ``{"bye": true}`` is a polite leave: the worker is declared dead
        NOW and its in-flight leases requeue immediately, instead of the
        fleet waiting out the heartbeat TTL."""
        wid = str(payload.get("worker", ""))
        with self._cv:
            w = self._workers.get(wid)
            if payload.get("bye"):
                if w is not None and w.alive:
                    w.alive = False
                    self._expire_locked(time.monotonic())
                    self._cv.notify_all()
                return {"ok": True, "bye": True}
            if w is None or not w.alive:
                # orchestrator restarted (or the worker was declared
                # dead): tell it to re-register instead of silently
                # heartbeating into the void
                return {"ok": False, "reregister": True}
            w.last_seen = time.monotonic()
            w.fingerprints |= set(payload.get("fingerprints") or [])
        return {"ok": True}

    def lease(self, payload: Dict) -> Dict:
        """Hand the polling worker one pending chunk it can serve, or
        tell it how long to idle."""
        wid = str(payload.get("worker", ""))
        now = time.monotonic()
        with self._cv:
            self._expire_locked(now)
            w = self._workers.get(wid)
            if w is None or not w.alive:
                return {"ok": False, "reregister": True}
            w.last_seen = now
            chunk = None
            for i, cand in enumerate(self._pending):
                if w.can_serve(cand.desc):
                    chunk = cand
                    del self._pending[i]
                    break
            if chunk is None:
                return {"ok": True, "lease": None,
                        "idle_wait_s": self.idle_wait_s}
            lease = Lease(
                id=f"l-{uuid.uuid4().hex[:12]}", chunk=chunk, worker=wid,
                issued_at=now, deadline=now + self.lease_ttl_s,
            )
            chunk.state = "leased"
            self._leases[lease.id] = lease
            self.pending_gauge.set(len(self._pending))
            self.leases_gauge.set(len(self._leases))
            # grant→result/expiry lifecycle span, parented to the batch
            # that created the chunk (this thread is an HTTP handler, so
            # the ambient context is not the campaign's)
            with obs.attach(chunk.wire):
                lease.span = obs.start_span(
                    "fleet.lease", lease=lease.id, worker=wid,
                    n=int(len(chunk.genomes)), requeues=chunk.requeues,
                )
            f = faults.check("fleet.lease", worker=wid, lease=lease.id)
            if f is not None:
                if f.delay_s > 0:
                    time.sleep(f.delay_s)
                if f.kind in ("drop", "error"):
                    # grant lost in flight: the worker never sees it, so
                    # the lease rides the normal TTL-expiry requeue path
                    return {"ok": True, "lease": None,
                            "idle_wait_s": self.idle_wait_s}
            return {
                "ok": True,
                "lease": {
                    "id": lease.id,
                    "ctx": chunk.desc,
                    "genomes": chunk.genomes.tolist(),
                    "ttl_s": self.lease_ttl_s,
                    "trace": chunk.wire,
                },
            }

    def result(self, payload: Dict) -> Dict:
        """Accept a finished (or rejected) lease.  Duplicates and late
        results after a requeue are dropped idempotently — labels are
        deterministic, so whichever copy lands first is THE result."""
        f = faults.check("fleet.result", lease=payload.get("lease"),
                         worker=payload.get("worker"))
        if f is not None:
            if f.delay_s > 0:
                time.sleep(f.delay_s)  # late delivery past the TTL
            if f.kind in ("drop", "error"):
                # result lost before ingest: the lease expires, the
                # chunk requeues, and the (deterministic) labels are
                # recomputed — nothing is lost, only delayed
                return {"ok": True, "dropped": True}
            if f.kind == "duplicate":
                self._result_once(payload)  # second copy below dedupes
        return self._result_once(payload)

    def _result_once(self, payload: Dict) -> Dict:
        wid = str(payload.get("worker", ""))
        lid = str(payload.get("lease", ""))
        # worker-side spans piggyback on the result payload (the
        # process-pool idiom): fold them into the local ring/sink
        spans = payload.get("spans")
        if spans:
            obs.recorder().ingest(spans)
        with self._cv:
            w = self._workers.get(wid)
            if w is not None:
                w.last_seen = time.monotonic()
            lease = self._leases.pop(lid, None) or self._retired.pop(lid, None)
            self.leases_gauge.set(len(self._leases))
            if lease is None:
                self.n_duplicate_results.inc()
                return {"ok": True, "duplicate": True}
            chunk = lease.chunk
            lspan, lease.span = lease.span, None
            if payload.get("reject"):
                if lspan is not None:
                    lspan.end(outcome="rejected")
                # fingerprint drift: never lease this fp to this worker
                # again; once EVERY live worker has rejected it, pin the
                # fp off the fleet entirely
                fp = chunk.desc.get("fingerprint")
                if w is not None and fp:
                    w.rejected_fps.add(fp)
                live = [x for x in self._workers.values() if x.alive]
                if fp and live and all(fp in x.rejected_fps for x in live):
                    self._drifted.add(fp)
                self._requeue_locked(chunk)
                self._cv.notify_all()
                return {"ok": True, "rejected": True}
            try:
                labels = decode_labels(payload.get("labels") or {},
                                       n=len(chunk.genomes))
            except ValueError as exc:
                if lspan is not None:
                    lspan.end(outcome="error", error=str(exc)[:120])
                self._requeue_locked(chunk)
                self._cv.notify_all()
                return {"ok": False, "error": str(exc)}
            if chunk.batch.complete(chunk, labels):
                chunk.worker = wid
                self.n_remote_labels.inc(len(chunk.genomes))
                if w is not None:
                    w.labels += len(chunk.genomes)
                    w.chunks += 1
                    w.store_hits += int(payload.get("store_hits", 0))
                    w.busy_s += float(payload.get("busy_s", 0.0))
                if lspan is not None:
                    lspan.end(outcome="ok")
            else:
                self.n_duplicate_results.inc()
                if lspan is not None:
                    lspan.end(outcome="duplicate")
            self._cv.notify_all()
        return {"ok": True}

    # ------------------------------------------------------------------
    def _requeue_locked(self, chunk: Chunk) -> None:
        if chunk.state == "done":
            return
        chunk.state = "pending"
        chunk.requeues += 1
        self.n_requeues.inc()
        self._pending.append(chunk)
        self.pending_gauge.set(len(self._pending))

    def _expire_locked(self, now: float) -> None:
        """Declare silent workers dead and requeue expired leases —
        called opportunistically from every protocol entry point and
        every blocked ``label()`` wake, so no reaper thread is needed."""
        n_live = 0
        for w in self._workers.values():
            if w.alive and now - w.last_seen > self.heartbeat_ttl_s:
                w.alive = False
                self.n_dead_workers.inc()
            n_live += w.alive
        self.live_gauge.set(n_live)
        expired = [
            lid for lid, lease in self._leases.items()
            if now > lease.deadline
            or not self._workers[lease.worker].alive
        ]
        for lid in expired:
            lease = self._leases.pop(lid)
            self.n_expired_leases.inc()
            if lease.span is not None:
                lease.span.end(outcome="expired")
                lease.span = None
            # keep the retired lease so a late result can still land
            self._retired[lid] = lease
            while len(self._retired) > 256:
                self._retired.pop(next(iter(self._retired)))
            self._requeue_locked(lease.chunk)
        if expired:
            self.leases_gauge.set(len(self._leases))
            self._cv.notify_all()

    # ------------------------------------------------------------------
    def stats(self) -> Dict:
        now = time.monotonic()
        with self._cv:
            # a monitoring read must not report workers live past their
            # heartbeat TTL (nothing else runs expiry on an idle fleet)
            self._expire_locked(now)
            workers = {
                w.id: {
                    "alive": w.alive,
                    "host": w.host,
                    "pid": w.pid,
                    "accels": sorted(w.accels),
                    "last_heartbeat_age_s": round(now - w.last_seen, 3),
                    "rejoins": w.rejoin_count,
                    "labels": w.labels,
                    "chunks": w.chunks,
                    "store_hits": w.store_hits,
                    "labels_per_sec": round(w.labels_per_sec(), 3),
                }
                for w in self._workers.values()
            }
            return {
                "workers": workers,
                "registered": len(self._workers),
                "live": sum(w.alive for w in self._workers.values()),
                "leases_in_flight": len(self._leases),
                "pending_chunks": len(self._pending),
                "batches": int(self.n_batches.value),
                "chunks": int(self.n_chunks.value),
                "requeues": int(self.n_requeues.value),
                "expired_leases": int(self.n_expired_leases.value),
                "dead_workers": int(self.n_dead_workers.value),
                "duplicate_results": int(self.n_duplicate_results.value),
                "local_fallback_chunks": int(self.n_local_chunks.value),
                "remote_labels": int(self.n_remote_labels.value),
                "local_labels": int(self.n_local_labels.value),
                "drifted_fingerprints": len(self._drifted),
            }

    def shutdown(self, *, wait: bool = True) -> None:
        """Stop leasing; blocked ``label()`` calls reclaim their
        remaining chunks in-process and return complete labels."""
        with self._cv:
            self._stopped = True
            self._cv.notify_all()


# ---------------------------------------------------------------------------
# transport shims
# ---------------------------------------------------------------------------

_ACTIONS = ("register", "heartbeat", "lease", "result")


def handle_fleet_request(coordinator: Optional[FleetCoordinator],
                         action: str, payload: Dict) -> Tuple[int, Dict]:
    """Shared dispatch for ``POST /fleet/<action>`` — used by both the
    service front end and the standalone ``serve_fleet`` listener."""
    if coordinator is None:
        return 404, {"error": "fleet backend not enabled "
                              "(start with --eval-backend fleet)"}
    if action not in _ACTIONS:
        return 404, {"error": f"no fleet action {action!r}"}
    try:
        return 200, getattr(coordinator, action)(dict(payload or {}))
    except Exception as exc:  # noqa: BLE001 - JSON 500, keep serving
        return 500, {"error": f"{type(exc).__name__}: {exc}"}


def serve_fleet(coordinator: FleetCoordinator, host: str = "127.0.0.1",
                port: int = 0, *, quiet: bool = True):
    """Standalone HTTP listener for the four fleet routes (+ ``GET
    /fleet/stats`` and ``/healthz``), for drivers that embed the
    orchestrator without the campaign service.  Serves on a daemon
    thread; returns the ``ThreadingHTTPServer`` (``server_address[1]``
    carries the bound port; ``shutdown()`` stops it)."""
    import json
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, fmt, *args):  # noqa: A003 - stdlib API
            if not quiet:
                super().log_message(fmt, *args)

        def _send(self, obj, code=200):
            body = json.dumps(obj, default=float).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):  # noqa: N802 - stdlib API
            if self.path.rstrip("/") == "/healthz":
                return self._send({"ok": True})
            if self.path.rstrip("/") == "/fleet/stats":
                return self._send(coordinator.stats())
            return self._send({"error": f"no route {self.path}"}, 404)

        def do_POST(self):  # noqa: N802 - stdlib API
            action = self.path.rstrip("/").rsplit("/", 1)[-1]
            try:
                n = int(self.headers.get("Content-Length", 0))
                payload = json.loads(self.rfile.read(n) or b"{}")
            except json.JSONDecodeError:
                return self._send({"error": "bad JSON"}, 400)
            code, obj = handle_fleet_request(coordinator, action, payload)
            return self._send(obj, code)

    srv = ThreadingHTTPServer((host, port), Handler)
    threading.Thread(target=srv.serve_forever, name="fleet-http",
                     daemon=True).start()
    return srv
