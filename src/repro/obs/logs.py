"""One logging config for the service and fleet CLIs.

Every record carries the correlation ids from the current trace baggage
(campaign/worker/lease), so grep-by-campaign works across the service
log and any number of fleet worker logs without the call sites passing
ids around.  Call sites just use ``obs.get_logger(__name__)``.
"""

from __future__ import annotations

import logging
import sys
from typing import Optional

from . import trace

__all__ = ["setup_logging", "get_logger", "parse_level"]

_FORMAT = (
    "%(asctime)s %(levelname)-7s %(name)s "
    "[campaign=%(campaign)s worker=%(obs_worker)s] %(message)s"
)


class _ContextFilter(logging.Filter):
    """Stamp trace-baggage correlation ids onto every record."""

    def filter(self, record: logging.LogRecord) -> bool:
        bag = trace.current_baggage()
        record.campaign = bag.get("campaign", "-")
        # "worker" collides with nothing, but LogRecord reserves no
        # namespace — prefix defensively
        record.obs_worker = bag.get("worker", "-")
        return True


def parse_level(level: str) -> int:
    v = getattr(logging, str(level).upper(), None)
    if not isinstance(v, int):
        raise ValueError(f"unknown log level {level!r}")
    return v


def setup_logging(level: str = "info", *, stream=None,
                  root: str = "repro") -> logging.Logger:
    """Configure the ``repro`` logger tree once; idempotent (re-calls
    just update the level).  Returns the root ``repro`` logger."""
    logger = logging.getLogger(root)
    logger.setLevel(parse_level(level))
    if not any(getattr(h, "_repro_obs", False) for h in logger.handlers):
        handler = logging.StreamHandler(stream or sys.stderr)
        handler.setFormatter(
            logging.Formatter(_FORMAT, datefmt="%H:%M:%S")
        )
        handler.addFilter(_ContextFilter())
        handler._repro_obs = True
        logger.addHandler(handler)
        logger.propagate = False
    return logger


def get_logger(name: Optional[str] = None) -> logging.Logger:
    """A logger under the ``repro`` tree.  Dotted module paths like
    ``repro.fleet.worker`` pass through; bare names nest under it."""
    if not name:
        return logging.getLogger("repro")
    if not name.startswith("repro"):
        name = f"repro.{name}"
    return logging.getLogger(name)
