"""Fused population kernels: the batched behavioral hot path as jitted
XLA programs.

The numpy batched engine (`_batchsim`) spends its time in
``grouped_apply``: one Python-level call per distinct adder circuit per
slot, each allocating int64 temporaries over boolean-masked
sub-populations.  This module compiles the whole ``(genomes, inputs) →
outputs`` pipeline per accelerator into ONE XLA program — LUT gather,
adder-tree reduction, normalization, and (where the outputs are
integral) the QoR reduction itself — with no ``(G, M, S)`` intermediate
ever materialized in host memory.

Design constraints, in priority order:

* **Bit-exactness.**  Three engines coexist (per-genome loop, numpy
  batched, fused) and must be provably identical.  Genomes are traced,
  so the adder choice per slot cannot branch: the engine evaluates every
  adder circuit's closed-form int32 twin on the full operand stack and
  per-genome-selects the result (the twins are O(log) bit-trick forms —
  e.g. the speculative adder's carry is ``c_exact & ~window-AND(p)`` —
  verified against the numpy models at build time; an unknown or
  divergent circuit unfuses the library).  LUT widening is verified
  (int64 tables must fit int32), adders operate on 16-bit-masked
  operands so int32 intermediates match the int64 semantics, and the
  device QoR tail returns an exact integer SSE (`core.qor.sse_batch_jax`).
  On top of the static proofs, the PR-5 verification scheme applies
  dynamically: each plan's first calls ALSO run the numpy engine and
  compare; a divergent accelerator family is pinned back to numpy for
  the process lifetime.

* **Zero steady-state recompiles.**  Population sizes are bucketed (pad
  G up to a power of two with repeats of the first genome, slice the
  results); the jit cache is keyed on (plan structural key, bucket,
  input signature) where the structural key rides the PR-5
  ``deploy_signature`` family, so campaigns over structurally identical
  accelerators share compiles and process workers warm-start the same
  way the synth cache does.

* **Observability + kill switch.**  ``REPRO_SIM_FUSED=0`` falls back to
  the numpy engine wholesale; compiles / bucket hits / verify calls /
  pins are counted (``stats()``, mirrored into ``repro.obs`` counters)
  and every device execution runs under a ``sim.fused`` span.
"""

from __future__ import annotations

import functools
import logging
import os
import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.acl import adders as _adders
from ..core.acl.library import Library, library_fingerprint

log = logging.getLogger("repro.sim.fused")

__all__ = [
    "enabled", "try_simulate_batch", "try_qor_batch", "register_fused",
    "register_coupling", "stats", "reset", "warm", "FusedPlan",
]

_M16 = (1 << 16) - 1

# ---------------------------------------------------------------------------
# knobs / module state
# ---------------------------------------------------------------------------

_STATS_LOCK = threading.Lock()
_STATS: Dict[str, int] = {}
_JIT_CACHE: Dict[tuple, Callable] = {}
_PLAN_CACHE: Dict[tuple, Optional["FusedPlan"]] = {}
_ENGINES: Dict[str, "_Engine"] = {}
_PINNED: Dict[tuple, str] = {}          # plan key -> reason
_VERIFY_LEFT: Dict[tuple, int] = {}
_BUILDERS: Dict[type, Callable] = {}
_COUPLINGS: Dict[str, Optional[Callable]] = {"identity": None}
_GUARD = threading.local()              # re-entrancy guard (verification)


def enabled() -> bool:
    return os.environ.get("REPRO_SIM_FUSED", "1") != "0"


def _verify_budget() -> int:
    try:
        return int(os.environ.get("REPRO_SIM_FUSED_VERIFY", "2"))
    except ValueError:
        return 2


def _bump(key: str, n: int = 1) -> None:
    with _STATS_LOCK:
        _STATS[key] = _STATS.get(key, 0) + n
    try:
        from .. import obs

        obs.REGISTRY.counter(
            f"repro_sim_fused_{key}_total", f"fused sim engine: {key}"
        ).inc(n)
    except Exception:
        pass


def stats() -> Dict[str, int]:
    """Snapshot of the engine counters (plus pin/cache gauges)."""
    with _STATS_LOCK:
        out = dict(_STATS)
    for k in ("fused_calls", "fused_qor_calls", "compiles", "bucket_hits",
              "verify_calls", "pins", "fallback_calls"):
        out.setdefault(k, 0)
    out["pinned_plans"] = len(_PINNED)
    out["compiled_programs"] = len(_JIT_CACHE)
    return out


def reset() -> None:
    """Cold-start the engine (tests): drop compiled programs, plans,
    pins, verification history and counters."""
    with _STATS_LOCK:
        _STATS.clear()
    _JIT_CACHE.clear()
    _PLAN_CACHE.clear()
    _ENGINES.clear()
    _PINNED.clear()
    _VERIFY_LEFT.clear()


# ---------------------------------------------------------------------------
# closed-form adder twins
# ---------------------------------------------------------------------------
# Each twin is written with plain operators so the SAME code runs under
# numpy (build-time verification against the library's int64 models) and
# under jit tracing (int32 device math).  Operands arrive 16-bit masked;
# results may carry bit 16 (the adders' carry-out), exactly like the
# numpy models.

def _shared(a, b):
    """Subexpressions shared across all adder circuit twins."""
    a = a & _M16
    b = b & _M16
    s = a + b
    p = a ^ b
    return {"a": a, "b": b, "s": s, "p": p, "ab": a & b, "c": s ^ p}


def _tw_exact(sh):
    return sh["s"]


def _tw_loa(sh, k):
    # LOA: high sum + OR of low bits == s - (a AND b AND lowmask)
    return sh["s"] - (sh["ab"] & ((1 << k) - 1))


def _tw_trunc(sh, k):
    m = (1 << k) - 1
    return sh["s"] - (sh["a"] & m) - (sh["b"] & m)


def _tw_seg(sh, seg):
    # independent per-segment sums; only the top segment keeps its carry
    a, b = sh["a"], sh["b"]
    out = None
    nseg = 16 // seg
    for i in range(nseg):
        lo = i * seg
        m = (1 << seg) - 1
        ssum = ((a >> lo) & m) + ((b >> lo) & m)
        if i < nseg - 1:
            ssum = ssum & m
        part = ssum << lo
        out = part if out is None else out + part
    return out


def _tw_eta1(sh, k):
    # ETA1 low part: OR of the operands, flooded to ones strictly below
    # the highest generate position (downward smear of a AND b)
    lowm = (1 << k) - 1
    g = sh["ab"] & lowm
    g = g | (g >> 1)
    g = g | (g >> 2)
    g = g | (g >> 4)  # k <= 8
    low = ((sh["p"] | sh["ab"]) & lowm) | (g >> 1)
    return (((sh["a"] >> k) + (sh["b"] >> k)) << k) + low


def _tw_aca(sh, la):
    # ACA(la): carry into bit i is the exact carry unless ALL la
    # propagate bits below i are set (a carry chain longer than the
    # window); window-AND of p computes in log2(la) shift-ANDs.
    r = sh["p"]
    shift = 1
    while shift < la:
        r = r & (r >> shift)
        shift <<= 1
    c_aca = sh["c"] & ~(r << la)
    return sh["p"] ^ c_aca


_TWIN_FAMILIES = {
    "add_exact": lambda kw: _tw_exact,
    "add_loa": lambda kw: functools.partial(_tw_loa, k=kw["k"]),
    "add_trunc": lambda kw: functools.partial(_tw_trunc, k=kw["k"]),
    "add_segmented": lambda kw: functools.partial(_tw_seg, seg=kw["seg"]),
    "add_eta1": lambda kw: functools.partial(_tw_eta1, k=kw["k"]),
    "add_speculative": lambda kw: functools.partial(_tw_aca, la=kw["la"]),
}


def _resolve_twin(fn) -> Optional[Callable]:
    """Map a library adder model to its closed-form twin by introspecting
    the ``functools.partial`` over the ``core.acl.adders`` module."""
    base, kw = fn, {}
    if isinstance(fn, functools.partial):
        base, kw = fn.func, dict(fn.keywords)
    if getattr(_adders, getattr(base, "__name__", ""), None) is not base:
        return None  # not a stock adder model: unfusible
    maker = _TWIN_FAMILIES.get(base.__name__)
    return None if maker is None else maker(kw)


def _probe_operands() -> Tuple[np.ndarray, np.ndarray]:
    """Dense verification probe: random 16-bit pairs + a corner grid of
    carry-chain patterns (all-ones runs, alternating bits, boundaries)."""
    rng = np.random.default_rng(0xF05ED)
    a = rng.integers(0, 1 << 16, size=1 << 15, dtype=np.int64)
    b = rng.integers(0, 1 << 16, size=1 << 15, dtype=np.int64)
    corners = np.array(
        [0, 1, 2, 3, 0x000F, 0x00FF, 0x0FFF, 0x7FFF, 0x8000, 0x8001,
         0xAAAA, 0x5555, 0xFF00, 0xF0F0, 0xFFFE, 0xFFFF],
        dtype=np.int64,
    )
    ca, cb = np.meshgrid(corners, corners)
    return (np.concatenate([a, ca.ravel()]),
            np.concatenate([b, cb.ravel()]))


class _Engine:
    """Per-library fused-engine state: verified adder twins + device LUTs."""

    def __init__(self, library: Library):
        self.library = library
        self.fingerprint = library_fingerprint(library)
        self.twins: Optional[List[Callable]] = self._build_twins(library)
        self._luts: Dict[tuple, object] = {}

    @staticmethod
    def _build_twins(library: Library) -> Optional[List[Callable]]:
        pa, pb = _probe_operands()
        ref_shared = _shared(pa, pb)
        twins: List[Callable] = []
        for c in library.kind("add16"):
            twin = _resolve_twin(c.fn)
            if twin is None:
                log.warning("fused sim: no twin for adder %r — unfusible", c.name)
                return None
            want = np.asarray(c.fn(pa, pb), dtype=np.int64)
            got = np.asarray(twin(ref_shared), dtype=np.int64)
            if not np.array_equal(want, got):
                log.warning(
                    "fused sim: twin for %r diverges on probe — unfusible",
                    c.name,
                )
                return None
            twins.append(twin)
        return twins

    def lut(self, kind: str, constants, tag: str):
        """Device (C, S, 256) int32 LUT stack with verified widening."""
        key = (kind, tag, tuple(int(c) for c in constants))
        dev = self._luts.get(key)
        if dev is None:
            import jax.numpy as jnp

            from ._batchsim import mul_lut

            lut64 = mul_lut(self.library, kind, constants, tag=tag)
            info = np.iinfo(np.int32)
            if lut64.max() > info.max or lut64.min() < info.min:
                raise OverflowError(
                    f"LUT for {kind}/{tag} exceeds int32 — unfusible"
                )
            dev = jnp.asarray(lut64.astype(np.int32))
            self._luts[key] = dev
        return dev

    def gather(self, lut_dev, genes, cols, *, per_genome: bool):
        """Traceable population LUT gather (Pallas on TPU, XLA gather
        elsewhere — on CPU an interpreted Pallas round-trip would cost
        more than the gather saves)."""
        from ..kernels.population_lut import gather_xla
        from ..kernels.population_lut.ops import on_tpu

        S = lut_dev.shape[1]
        if on_tpu():
            return self._gather_pallas(lut_dev, genes, cols, per_genome)
        return gather_xla(
            lut_dev.reshape(-1), genes, cols, nslots=S, per_genome=per_genome
        )

    def _gather_pallas(self, lut_dev, genes, cols, per_genome: bool):
        import jax.numpy as jnp

        from ..kernels.population_lut import population_lut_gather_pallas

        S = lut_dev.shape[1]
        M = cols.shape[-2]
        bm = 256
        pad = (-M) % bm
        if pad:
            width = [(0, 0)] * (cols.ndim - 2) + [(0, pad), (0, 0)]
            cols = jnp.pad(cols, width)
        out = population_lut_gather_pallas(
            lut_dev, genes, cols, per_genome=per_genome,
            bg=genes.shape[0], bm=min(bm, M + pad),
        )
        return out[:, :M] if pad else out

    def select_add(self, gene_col, a, b, *, signed: bool):
        """All-circuits adder stack + per-genome selection.  ``a``/``b``:
        (G, ...) operand stacks; ``gene_col``: (G,) circuit indices."""
        import jax.numpy as jnp

        sh = _shared(a, b)
        allr = jnp.stack([tw(sh) for tw in self.twins])  # (A, G, ...)
        idx = gene_col.reshape((1, -1) + (1,) * (a.ndim - 1))
        r = jnp.take_along_axis(allr, idx, axis=0)[0]
        if signed:
            # signed16 semantics: wrap to 16 bits, sign-extend
            r = r & _M16
            r = (r ^ 0x8000) - 0x8000
        return r


def _engine_for(library: Library) -> Optional[_Engine]:
    fp = library_fingerprint(library)
    eng = _ENGINES.get(fp)
    if eng is None:
        eng = _Engine(library)
        _ENGINES[fp] = eng
    return eng if eng.twins is not None else None


def warm(library: Library) -> bool:
    """Pre-build (and probe-verify) the library's adder twins so the
    first labeled batch doesn't pay them; True iff the library fuses."""
    if not enabled():
        return False
    return _engine_for(library) is not None


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------

@dataclass
class FusedPlan:
    """One accelerator's fused pipeline.

    ``stage_fn(genes, x, per_genome)`` is the traceable core: slot genes
    in, natural-layout (numpy-``simulate_batch``-shaped) outputs out, so
    plans chain through ``StagedPipeline`` couplings inside one program.
    ``prep``/``post`` are the host-side dtype shims; ``qor_ref`` (when
    set) provides the integer exact reference that lets the QoR reduce
    on-device (``sse_batch_jax``)."""

    key: tuple
    stage_fn: Callable
    prep: Callable
    post: Callable
    qor_ref: Optional[Callable] = None
    # True iff stage_fn's device output IS the numpy simulate_batch
    # output (modulo dtype).  Plans with a host-side tail (e.g. the
    # DCT's float64 reconstruction) set False and can only terminate a
    # fused pipeline, not feed a later stage.
    device_natural: bool = True


def register_fused(cls):
    """Decorator: ``@register_fused(Accel)`` marks ``builder(accel,
    library, engine) -> Optional[FusedPlan pieces]`` as the fused-plan
    builder for ``cls`` (and, via MRO lookup, its subclasses)."""

    def deco(builder):
        _BUILDERS[cls] = builder
        return builder

    return deco


def register_unfused(cls) -> None:
    """Pin an accelerator type to the numpy path (e.g. non-LUT
    workloads like the LM, whose custom qor path isn't table-driven)."""
    _BUILDERS[cls] = None


def register_coupling(name: str, fn: Callable) -> None:
    """Traceable twin of a ``Coupling.sim`` map, by coupling name.
    Pipelines fuse end-to-end only when every coupling has a twin."""
    _COUPLINGS[name] = fn


def _builder_for(accel):
    for cls in type(accel).__mro__:
        if cls in _BUILDERS:
            return _BUILDERS[cls]
    return None


def _family_key(accel) -> tuple:
    """The PR-5 structural-signature family of this accelerator's
    deployment graph: plans/compiles are shared exactly where the synth
    cache shares compiles.  Name and slot constants ride along — two
    accelerators may share a deployment family (e.g. MCM rows) while
    simulating different constants."""
    try:
        sig = accel.deploy_signature([])
        fam = tuple(sig[0]) if sig else ()
    except Exception:
        fam = ()
    try:
        consts = tuple(
            int(c) if c is not None else None
            for c in accel.mul_slot_constants()
        )
    except Exception:
        consts = ()
    return (type(accel).__qualname__, accel.name, fam, consts)


def _plan_for(accel, library: Library) -> Optional[FusedPlan]:
    key = _family_key(accel) + (library_fingerprint(library),)
    if key in _PLAN_CACHE:
        return _PLAN_CACHE[key]
    plan: Optional[FusedPlan] = None
    builder = _builder_for(accel)
    if builder is not None:
        eng = _engine_for(library)
        if eng is not None:
            try:
                plan = builder(accel, library, eng)
            except Exception:
                log.exception("fused sim: plan build failed for %s", accel.name)
                plan = None
    if plan is not None:
        plan.key = key
    _PLAN_CACHE[key] = plan
    return plan


# generic StagedPipeline chaining: fuse the whole chain into ONE program
# when every stage has a plan and every coupling has a registered twin
def _staged_builder(pipe, library: Library, eng: _Engine) -> Optional[FusedPlan]:
    stage_plans = []
    for i, st in enumerate(pipe.stages):
        p = _plan_for(st, library)
        if p is None or p.key in _PINNED:
            return None
        if not p.device_natural and i < len(pipe.stages) - 1:
            return None  # host-tailed plan can't feed a later stage
        stage_plans.append(p)
    twins = []
    for c in pipe.couplings:
        name = "identity" if c.sim is None else c.name
        if name not in _COUPLINGS:
            return None
        twins.append(_COUPLINGS[name])
    counts = pipe.stage_slot_counts()
    last = len(stage_plans) - 1

    def stage_fn(genes, x, per_genome):
        per = per_genome
        off = 0
        for i, (sp, ns) in enumerate(zip(stage_plans, counts)):
            y = sp.stage_fn(genes[:, off:off + ns], x, per)
            off += ns
            per = True  # stage outputs always carry the genome axis
            x = twins[i](y) if (i < last and twins[i] is not None) else y
        return x

    tail = stage_plans[last]
    return FusedPlan(
        key=(), stage_fn=stage_fn, prep=stage_plans[0].prep,
        post=tail.post, qor_ref=tail.qor_ref,
        device_natural=tail.device_natural,
    )


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _bucket(G: int) -> int:
    """Pad-to-bucket population size: next power of two (min 4), so
    steady-state searches with drifting survivor counts never retrace."""
    return max(4, 1 << (int(G) - 1).bit_length())


def _pad_rows(arr: np.ndarray, B: int) -> np.ndarray:
    G = len(arr)
    if G == B:
        return arr
    reps = np.repeat(arr[:1], B - G, axis=0)
    return np.concatenate([arr, reps], axis=0)


def _compiled(plan: FusedPlan, *, bucket: int, per_genome: bool,
              x_sig: tuple, want_sse: bool, n_genes: int) -> Callable:
    """Jit-cache lookup keyed on (plan structural key, bucket, input
    signature); a miss compiles (counted), a hit is a bucket hit."""
    key = (plan.key, bucket, per_genome, x_sig, want_sse, n_genes)
    fn = _JIT_CACHE.get(key)
    if fn is not None:
        _bump("bucket_hits")
        return fn
    import jax

    if want_sse:
        from ..core.qor import sse_batch_jax

        def run(genes, x, ref):
            out = plan.stage_fn(genes, x, per_genome)
            return sse_batch_jax(ref, out)
    else:
        def run(genes, x):
            return plan.stage_fn(genes, x, per_genome)

    fn = jax.jit(run)
    _JIT_CACHE[key] = fn
    _bump("compiles")
    return fn


def _execute(plan: FusedPlan, genomes: np.ndarray, x: np.ndarray,
             *, per_genome: bool, ref: Optional[np.ndarray] = None):
    """Bucket, pad, run the compiled program, slice back to G."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from .. import obs

    G = len(genomes)
    B = _bucket(G)
    g_pad = _pad_rows(np.ascontiguousarray(genomes, dtype=np.int32), B)
    x_np = plan.prep(x)
    if per_genome:
        x_np = _pad_rows(x_np, B)
    x_sig = (x_np.shape[1:] if per_genome else x_np.shape, x_np.dtype.str)
    want_sse = ref is not None
    fn = _compiled(plan, bucket=B, per_genome=per_genome, x_sig=x_sig,
                   want_sse=want_sse, n_genes=g_pad.shape[1])
    with obs.span("sim.fused", g=G, bucket=B, sse=bool(want_sse)):
        # x64 at trace AND call time: the jax jit cache keys on the flag,
        # and the SSE tail accumulates exact int64
        with enable_x64():
            args = [jnp.asarray(g_pad), jnp.asarray(x_np)]
            if want_sse:
                args.append(jnp.asarray(ref))
            out = np.asarray(fn(*args))
    return out[:G]


def _numpy_reference(kind: str, accel, genomes, library, inputs, *,
                     rank_genes: bool, per_genome_inputs: bool = False,
                     peak=None):
    """Run the numpy engine with fused dispatch disabled (re-entrancy
    guard), for verification and for pinned fallbacks."""
    _GUARD.active = True
    try:
        if kind == "sim":
            return accel.simulate_batch(
                genomes, library, inputs,
                rank_genes=rank_genes, per_genome_inputs=per_genome_inputs,
            )
        return accel.qor_batch(
            genomes, library, inputs, rank_genes=rank_genes, peak=peak,
        )
    finally:
        _GUARD.active = False


def _verify_or_pin(plan: FusedPlan, got: np.ndarray, want: np.ndarray,
                   what: str) -> bool:
    """True iff the fused result is byte-identical to the numpy engine;
    divergence pins the plan's whole family back to numpy."""
    _bump("verify_calls")
    ok = (
        got.shape == want.shape
        and got.dtype == want.dtype
        and np.array_equal(got, want)
    )
    if not ok:
        _PINNED[plan.key] = what
        _bump("pins")
        log.warning(
            "fused sim: %s diverged from numpy engine for %s — pinning "
            "family to the numpy path", what, plan.key[:2],
        )
    return ok


def _gate(accel, library) -> Optional[FusedPlan]:
    if not enabled() or getattr(_GUARD, "active", False):
        return None
    plan = _plan_for(accel, library)
    if plan is None or plan.key in _PINNED:
        return None
    return plan


def try_simulate_batch(
    accel, genomes, library, inputs, *,
    rank_genes: bool = False, per_genome_inputs: bool = False,
) -> Optional[np.ndarray]:
    """Fused ``simulate_batch``; None routes the caller to its numpy
    body (kill switch, re-entrant verification, unfused or pinned
    accelerator)."""
    plan = _gate(accel, library)
    if plan is None:
        return None
    genomes = np.atleast_2d(np.asarray(genomes))
    try:
        raw = _execute(plan, genomes, inputs, per_genome=per_genome_inputs)
        out = plan.post(raw, inputs, per_genome_inputs)
    except Exception:
        log.exception("fused sim failed for %s — pinning", accel.name)
        _PINNED[plan.key] = "error"
        _bump("pins")
        return None
    left = _VERIFY_LEFT.get(plan.key, _verify_budget())
    if left > 0:
        want = _numpy_reference(
            "sim", accel, genomes, library, inputs,
            rank_genes=rank_genes, per_genome_inputs=per_genome_inputs,
        )
        if not _verify_or_pin(plan, out, want, "simulate_batch"):
            return want
        _VERIFY_LEFT[plan.key] = left - 1
    _bump("fused_calls")
    return out


def try_qor_batch(
    accel, genomes, library, inputs, *,
    rank_genes: bool = False, peak=None,
) -> Optional[np.ndarray]:
    """Fully fused ``(genomes, inputs) → QoR``: device-side integer SSE
    against the exact reference, host-side PSNR finish.  Only plans with
    an integer exact reference (``qor_ref``) qualify — float tails (the
    DCT's float64 reconstruction) return None here and instead run the
    generic qor path over the fused ``simulate_batch``."""
    plan = _gate(accel, library)
    if plan is None or plan.qor_ref is None:
        return None
    genomes = np.atleast_2d(np.asarray(genomes))
    try:
        ref = plan.qor_ref(accel, inputs)
        if peak is None:
            pk = float(np.max(np.abs(ref))) or 1.0
        else:
            pk = float(peak)
        sse = _execute(plan, genomes, inputs, per_genome=False, ref=ref)
        from ..core.qor import psnr_from_sse

        vals = psnr_from_sse(sse, ref.size, pk)
    except Exception:
        log.exception("fused qor failed for %s — pinning", accel.name)
        _PINNED[plan.key] = "error"
        _bump("pins")
        return None
    left = _VERIFY_LEFT.get(plan.key, _verify_budget())
    if left > 0:
        want = _numpy_reference(
            "qor", accel, genomes, library, inputs,
            rank_genes=rank_genes, peak=peak,
        )
        if not _verify_or_pin(plan, vals, want, "qor_batch"):
            return want
        _VERIFY_LEFT[plan.key] = left - 1
    _bump("fused_qor_calls")
    return vals


def note_fallback() -> None:
    """Callers that consciously took the numpy path report it here so
    the fused/fallback ratio is observable."""
    _bump("fallback_calls")


def _register_staged() -> None:
    # registered lazily to dodge the accel <-> hierarchy import cycle
    from ..hierarchy.staged import StagedPipeline

    if StagedPipeline not in _BUILDERS:
        _BUILDERS[StagedPipeline] = _staged_builder
