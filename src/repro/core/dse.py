"""End-to-end DSE driver — the three framework stages of paper Fig. 2:

  1. Model Training       sample + label n_train random variants (XLA
                          synthesis + behavioral sim), build the pipeline's
                          feature extractor, fit the two surrogates.
  2. Architecture          NSGA-II over the genome space, objectives
     Exploration           evaluated by the surrogates only.
  3. Final Evaluation      the surviving parent set is re-synthesized and
                          re-simulated; the *true* Pareto front is returned.

Every stage is timed; the result object carries everything the Fig. 5/7/8/9
benchmarks need.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (accel depends on core.acl)
    from ..accel.base import Accelerator
from .acl.library import Library, default_library
from .features import synth
from .features.pipelines import build_extractor
from .nsga2 import NSGA2Config, NSGA2Result, nsga2
from .pareto import non_dominated_mask
from .surrogates import make, pcc

__all__ = ["DSEConfig", "DSEResult", "run_dse", "random_search",
           "default_labeler", "label_unique"]

# A labeler maps a (n, g) genome batch to the ground-truth label dict of
# synth.label_variants.  run_dse takes one by injection so the labeling
# substrate is swappable: the default is the old in-process path (per-call
# synthesis cache, discarded at return); the service layer
# (repro.service) injects a scheduler-backed labeler with a persistent
# cross-campaign store, in-flight dedup and coalesced batching.


def default_labeler(
    accel: "Accelerator",
    library: Library,
    *,
    rank_genes: bool = False,
    n_qor_samples: int = 4,
    qor_seed: int = synth.DEFAULT_QOR_SEED,
    cache: Optional[dict] = None,
):
    """The in-process labeler ``run_dse`` uses when none is injected."""
    synth_cache = {} if cache is None else cache
    qor_inputs = accel.sample_inputs(n_qor_samples, seed=qor_seed)

    def labeler(genomes: np.ndarray) -> Dict[str, np.ndarray]:
        return synth.label_variants(
            accel, genomes, library,
            rank_genes=rank_genes, qor_inputs=qor_inputs, cache=synth_cache,
        )

    return labeler


def label_unique(labeler, genomes: np.ndarray) -> Dict[str, np.ndarray]:
    """Label a batch paying ground truth only for UNIQUE genomes.

    NSGA-II survivor sets routinely contain repeated genomes (elitism
    keeps copies of strong designs); labels are a pure function of the
    genome, so duplicates are labeled once and scattered back."""
    genomes = np.atleast_2d(genomes)
    uniq, inverse = np.unique(genomes, axis=0, return_inverse=True)
    labels = labeler(uniq)
    # scatter back (also undoes np.unique's row sort)
    return {k: np.asarray(v)[inverse] for k, v in labels.items()}


@dataclass(frozen=True)
class DSEConfig:
    pipeline: str = "D"                     # paper's winner
    hw_model: str = "bayesian_ridge"        # paper Fig. 6: best for power
    qor_model: str = "random_forest"        # paper Fig. 6: best for QoR
    objectives: Tuple[str, ...] = ("qor", "energy")  # qor auto-negated
    n_train: int = 1000                     # paper: 1000 random variants
    n_qor_samples: int = 4
    rank_genes: bool = False                # beyond-paper axis
    # beyond-paper: seed half the NSGA-II population from the
    # circuit-level Pareto subspace (the SoA's pre-filter, used as a
    # warm start instead of a hard restriction) — on the TPU the slot
    # costs are separable, so that subspace is a strong prior while the
    # full-space search still covers interactions the pre-filter misses
    warm_start: bool = True
    nsga: NSGA2Config = field(default_factory=NSGA2Config)
    seed: int = 0


@dataclass
class DSEResult:
    accel_name: str
    config: DSEConfig
    # stage 1
    train_genomes: np.ndarray
    train_labels: Dict[str, np.ndarray]
    val_pcc: Dict[str, float]
    # stage 2
    search: NSGA2Result
    est_objectives: np.ndarray          # surrogate objectives of parents
    # stage 3
    final_labels: Dict[str, np.ndarray]
    true_objectives: np.ndarray
    front_mask: np.ndarray
    timings: Dict[str, float]

    @property
    def front_genomes(self) -> np.ndarray:
        return self.search.genomes[self.front_mask]

    @property
    def front_objectives(self) -> np.ndarray:
        return self.true_objectives[self.front_mask]


def _objective_matrix(labels: Dict[str, np.ndarray], names: Sequence[str]) -> np.ndarray:
    cols = []
    for nm in names:
        v = np.asarray(labels[nm], dtype=np.float64)
        cols.append(-v if nm == "qor" else v)  # maximize QoR -> minimize -QoR
    return np.stack(cols, axis=1)


def run_dse(
    accel: Accelerator,
    library: Optional[Library] = None,
    cfg: DSEConfig = DSEConfig(),
    *,
    labeler=None,
    surrogate_provider=None,
    verbose: bool = False,
) -> DSEResult:
    """The three-stage DSE.  ``labeler`` (genomes -> label dict) and
    ``surrogate_provider`` ((obj, model_name, X, y) -> fitted model) are
    injectable so the service layer can swap in its persistent label
    store / coalescing scheduler / warm surrogate registry; the defaults
    reproduce the classic one-shot in-process behavior exactly."""
    library = library or default_library()
    rng = np.random.default_rng(cfg.seed)
    gene_sizes = accel.gene_sizes(library, rank_genes=cfg.rank_genes)
    timings: Dict[str, float] = {}
    if labeler is None:
        labeler = default_labeler(
            accel, library,
            rank_genes=cfg.rank_genes, n_qor_samples=cfg.n_qor_samples,
        )
    if surrogate_provider is None:
        def surrogate_provider(obj, name, X, y):
            return make(name, seed=cfg.seed).fit(X, y)

    # ---------------- stage 1: model training -----------------------------
    t0 = time.perf_counter()
    train_genomes = rng.integers(0, gene_sizes[None, :],
                                 size=(cfg.n_train, len(gene_sizes)))
    # always include the exact reference design (standard DSE practice:
    # the known-good corner anchors both the surrogates and the front)
    train_genomes[0] = accel.exact_genome(library, rank_genes=cfg.rank_genes)
    train_labels = label_unique(labeler, train_genomes)
    timings["label"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    extractor = build_extractor(cfg.pipeline, accel, library,
                                rank_genes=cfg.rank_genes)
    X = extractor(train_genomes)
    n_val = max(cfg.n_train // 5, 1)
    tr, va = slice(n_val, None), slice(0, n_val)
    models = {}
    val_pcc = {}
    for obj in cfg.objectives:
        name = cfg.qor_model if obj == "qor" else cfg.hw_model
        m = make(name, seed=cfg.seed).fit(X[tr], train_labels[obj][tr])
        models[obj] = m
        val_pcc[obj] = pcc(train_labels[obj][va], m.predict(X[va]))
    # refit on everything for the search (via the provider, so a warm
    # surrogate registry can reuse/extend fitted models across campaigns)
    for obj in cfg.objectives:
        name = cfg.qor_model if obj == "qor" else cfg.hw_model
        models[obj] = surrogate_provider(obj, name, X, train_labels[obj])
    timings["train"] = time.perf_counter() - t0
    if verbose:
        print(f"[dse:{accel.name}] val PCC: "
              + ", ".join(f"{k}={v:.3f}" for k, v in val_pcc.items()))

    # ---------------- stage 2: architecture exploration -------------------
    t0 = time.perf_counter()

    def evaluate(genomes: np.ndarray) -> np.ndarray:
        Xg = extractor(genomes)
        labels = {obj: models[obj].predict(Xg) for obj in cfg.objectives}
        return _objective_matrix(labels, cfg.objectives)

    init = train_genomes[: cfg.nsga.pop_size].copy()
    if cfg.warm_start and len(init) >= 4:
        from ..accel.approxfpgas import circuit_level_front

        half = len(init) // 2
        per_slot_choices = []
        for slot in accel.slots:
            front = circuit_level_front(library, slot.kind)
            per_slot_choices.append(
                [library.index(slot.kind, c.name) for c in front]
            )
        for t in range(half):
            for j, choices in enumerate(per_slot_choices):
                init[t, j] = choices[rng.integers(0, len(choices))]
    search = nsga2(gene_sizes, evaluate, cfg.nsga, init=init)
    timings["explore"] = time.perf_counter() - t0

    # ---------------- stage 3: final evaluation ---------------------------
    # dedupe before labeling: elitist survivors repeat, and each repeat
    # would otherwise pay full ground truth whenever the labeler's cache
    # keys miss (e.g. across rank-gene settings)
    t0 = time.perf_counter()
    final_labels = label_unique(labeler, search.genomes)
    timings["final_eval"] = time.perf_counter() - t0

    # the delivered Pareto front is over EVERY synthesized point (search
    # survivors + the stage-1 training sample — their ground truth is
    # already paid for)
    all_genomes = np.concatenate([search.genomes, train_genomes])
    all_labels = {
        k: np.concatenate([final_labels[k], train_labels[k]])
        for k in final_labels
    }
    true_obj = _objective_matrix(all_labels, cfg.objectives)

    return DSEResult(
        accel_name=accel.name,
        config=cfg,
        train_genomes=train_genomes,
        train_labels=train_labels,
        val_pcc=val_pcc,
        search=NSGA2Result(
            genomes=all_genomes,
            objectives=np.concatenate(
                [search.objectives, _objective_matrix(train_labels,
                                                      cfg.objectives)]
            ),
            front_mask=non_dominated_mask(true_obj),
            history=search.history,
            n_evaluated=search.n_evaluated,
        ),
        est_objectives=search.objectives,
        final_labels=all_labels,
        true_objectives=true_obj,
        front_mask=non_dominated_mask(true_obj),
        timings=timings,
    )


def random_search(
    accel: Accelerator,
    library: Optional[Library] = None,
    *,
    n: int = 1000,
    objectives: Tuple[str, ...] = ("qor", "energy"),
    rank_genes: bool = False,
    seed: int = 0,
    labeler=None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Baseline for Figs. 8/9: label n random variants, return
    (genomes, objectives, front_mask)."""
    library = library or default_library()
    rng = np.random.default_rng(seed)
    gene_sizes = accel.gene_sizes(library, rank_genes=rank_genes)
    genomes = rng.integers(0, gene_sizes[None, :], size=(n, len(gene_sizes)))
    # same default labeler as run_dse (QoR inputs from DEFAULT_QOR_SEED),
    # so injected-labeler and in-process baselines are apples-to-apples
    if labeler is None:
        labeler = default_labeler(accel, library, rank_genes=rank_genes)
    labels = label_unique(labeler, genomes)
    obj = _objective_matrix(labels, objectives)
    return genomes, obj, non_dominated_mask(obj)
