"""Mamba-1 selective-SSM layer, TPU-adapted.

The CUDA selective-scan kernel fuses the (B, S, d_inner, N) state update
in SRAM.  The TPU-native rethink (DESIGN.md §2): a *chunked* scan —
``lax.associative_scan`` (parallel prefix, stable (a, b) combine) inside
fixed-size chunks that fit VMEM-scale working sets, with a sequential
``lax.scan`` carrying the (B, d_inner, N) state across chunks.  Decode is
the O(1) single-step recurrence with a (conv, ssm) cache.

Parameterization follows Mamba-1 (falcon-mamba): in_proj -> (x, z),
depthwise causal conv (k=4), x_proj -> (dt, B, C), dt via softplus,
A = -exp(A_log), y = C.h + D*x, out = out_proj(y * silu(z)).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from .approx_linear import ApproxPolicy, linear
from .common import ParamSpec, rms_norm
from .config import ModelConfig

__all__ = ["mamba_param_specs", "mamba_layer", "mamba_cache_spec",
           "set_scan_dtype"]

# §Perf knob: dtype of the (b, L, d_inner, N) selective-scan streams.
# f32 is the reference; bf16 halves the dominant SSM HBM traffic at a
# bounded precision cost (the cross-chunk carry stays f32).
SCAN_DTYPE = "float32"


def set_scan_dtype(dt: str) -> None:
    global SCAN_DTYPE
    SCAN_DTYPE = dt


def mamba_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, di = cfg.d_model, cfg.d_inner
    n, dtr, ck = cfg.ssm_state, cfg.resolved_dt_rank, cfg.ssm_conv
    return {
        "norm": ParamSpec((d,), ("norm",), init="zeros"),
        "in_proj": ParamSpec((d, 2 * di), ("embed", "mlp")),
        "conv_w": ParamSpec((ck, di), ("conv", "mlp"), scale=0.1),
        "conv_b": ParamSpec((di,), ("mlp",), init="zeros"),
        "x_proj": ParamSpec((di, dtr + 2 * n), ("mlp", None)),
        "dt_proj": ParamSpec((dtr, di), ("dt", "mlp")),
        "dt_bias": ParamSpec((di,), ("mlp",), init="ones", scale=1.0),
        "A_log": ParamSpec((di, n), ("mlp", "state"), init="ones"),
        "D": ParamSpec((di,), ("mlp",), init="ones"),
        "out_proj": ParamSpec((di, d), ("mlp", "embed")),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv via shifted adds (kernel k is tiny).
    x: (b, s, di), w: (k, di)."""
    k = w.shape[0]
    out = x * w[-1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[-1 - i]
    return out + b


def _scan_combine(left, right):
    a1, b1 = left
    a2, b2 = right
    return a1 * a2, b2 + a2 * b1


def _selective_scan_chunked(
    xc: jnp.ndarray,     # (b, s, di)  conv'd, silu'd input
    dt: jnp.ndarray,     # (b, s, di)
    A: jnp.ndarray,      # (di, n)  (negative)
    Bc: jnp.ndarray,     # (b, s, n)
    Cc: jnp.ndarray,     # (b, s, n)
    chunk: int,
    h0: Optional[jnp.ndarray] = None,   # (b, di, n)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (b, s, di), h_final (b, di, n))."""
    b, s, di = xc.shape
    n = A.shape[1]
    chunk = min(chunk, s)
    s_orig = s
    if s % chunk:
        # pad with dt=0 steps: a=exp(0)=1, bx=0 — identity state updates,
        # so h_final is still the state at the last valid position
        pad = chunk - s % chunk
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nchunks = s // chunk
    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)

    xcs = xc.reshape(b, nchunks, chunk, di).transpose(1, 0, 2, 3)
    dts = dt.reshape(b, nchunks, chunk, di).transpose(1, 0, 2, 3)
    Bs = Bc.reshape(b, nchunks, chunk, n).transpose(1, 0, 2, 3)
    Cs = Cc.reshape(b, nchunks, chunk, n).transpose(1, 0, 2, 3)

    sdt = jnp.dtype(SCAN_DTYPE)

    def chunk_body(h, inp):
        xci, dti, Bi, Ci = inp                      # (b, L, ...)
        dtA = dti[..., None] * A[None, None]        # (b, L, di, n)
        a = jnp.exp(dtA).astype(sdt)
        bx = ((dti * xci)[..., None] * Bi[:, :, None, :]).astype(sdt)
        aa, hh = jax.lax.associative_scan(_scan_combine, (a, bx), axis=1)
        hh = hh.astype(jnp.float32) + aa.astype(jnp.float32) * h[:, None]
        y = jnp.einsum("blin,bln->bli", hh, Ci)     # (b, L, di)
        return hh[:, -1], y

    # remat each chunk: without this, backward saves every chunk's
    # (b, L, d_inner, N) residuals — tens of GB for the 16k-wide configs
    chunk_body = jax.checkpoint(chunk_body)

    h_final, ys = jax.lax.scan(chunk_body, h0, (xcs, dts, Bs, Cs))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)[:, :s_orig]
    return y, h_final


def mamba_layer(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                     # (b, s, d)
    cfg: ModelConfig,
    *,
    policy: Optional[ApproxPolicy] = None,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    decode: bool = False,
    scan_chunk: int = 128,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """cache: {"conv": (b, k-1, di), "ssm": (b, di, n)}.

    Modes: cache=None -> training; cache + decode=False -> prefill (runs
    the chunked scan and returns the post-prompt state); cache +
    decode=True -> single-step recurrence (s == 1)."""
    b, s, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    dtr = cfg.resolved_dt_rank
    h = rms_norm(x, p["norm"], cfg.rms_eps)
    xz = linear(h, p["in_proj"], "ssm_in", policy)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = constrain(x_in, ("batch", "seq", "act_mlp"))

    new_cache = None
    if not decode:
        xc = _causal_conv(
            x_in.astype(jnp.float32), p["conv_w"].astype(jnp.float32),
            p["conv_b"].astype(jnp.float32),
        )
    else:
        # decode: s == 1; conv over (cached k-1 inputs, current)
        window = jnp.concatenate(
            [cache["conv"], x_in.astype(jnp.float32)], axis=1
        )  # (b, k, di)
        xc = (
            jnp.einsum("bki,ki->bi", window, p["conv_w"].astype(jnp.float32))
            + p["conv_b"]
        )[:, None]
        new_conv = window[:, 1:]
    xc = jax.nn.silu(xc)

    proj = linear(xc.astype(x.dtype), p["x_proj"], "ssm_out", policy)
    dt_raw = proj[..., :dtr]
    Bc = proj[..., dtr : dtr + n].astype(jnp.float32)
    Cc = proj[..., dtr + n :].astype(jnp.float32)
    dt = jax.nn.softplus(
        linear(dt_raw, p["dt_proj"], "ssm_out", policy).astype(jnp.float32)
        + p["dt_bias"]
    )
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if not decode:
        h0 = cache["ssm"] if cache is not None else None
        y, h_final = _selective_scan_chunked(xc, dt, A, Bc, Cc, scan_chunk, h0)
        if cache is not None:  # prefill: persist post-prompt state
            k = cfg.ssm_conv
            tail = x_in.astype(jnp.float32)[:, -(k - 1):, :]
            new_cache = {"conv": tail, "ssm": h_final}
    else:
        a = jnp.exp(dt[:, 0, :, None] * A[None])            # (b, di, n)
        bx = (dt[:, 0] * xc[:, 0])[..., None] * Bc[:, 0, None, :]
        hnew = a * cache["ssm"] + bx
        y = jnp.einsum("bin,bn->bi", hnew, Cc[:, 0])[:, None]
        new_cache = {"conv": new_conv, "ssm": hnew}

    y = y + xc * p["D"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = constrain(y, ("batch", "seq", "act_mlp"))
    return linear(y, p["out_proj"], "ssm_out", policy), new_cache


def mamba_cache_spec(cfg: ModelConfig, batch: int) -> Dict[str, ParamSpec]:
    di, n, ck = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv": ParamSpec((batch, ck - 1, di), ("batch", None, "mlp"),
                          dtype="float32", init="zeros"),
        "ssm": ParamSpec((batch, di, n), ("batch", "mlp", "state"),
                         dtype="float32", init="zeros"),
    }
