"""The DSE-selectable approximate projection — the paper's technique as a
first-class feature of the LM stack.

Every heavy projection in the model calls ``linear(x, w, cls, policy)``
with a *projection class* name ("qkv", "attn_out", "ffn_in", "ffn_out",
"expert_in", "expert_out", "ssm_in", "ssm_out", "lm_head").  An
``ApproxPolicy`` (decoded from a DSE genome) maps classes to (circuit,
rank): such projections run as int8-quantized rank-k-corrected MXU
matmuls (kernels/approx_matmul); unmapped classes run exact bf16.

The compiled HLO of an approximated projection contains (1 + rank) MXU
matmuls plus two 256-entry gathers — exactly the cost model the paper's
surrogates learn (DESIGN.md §2).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["ApproxPolicy", "linear", "PROJ_CLASSES"]

PROJ_CLASSES = (
    "qkv",
    "attn_out",
    "ffn_in",
    "ffn_out",
    "expert_in",
    "expert_out",
    "ssm_in",
    "ssm_out",
    "lm_head",
)


@dataclass(frozen=True)
class ApproxPolicy:
    """class name -> (circuit_name, rank|None).  Specs are resolved once
    at construction (cached SVD factors from the ACL)."""

    assignments: Mapping[str, Tuple[str, Optional[int]]] = field(
        default_factory=dict
    )
    _specs: Dict[str, object] = field(default_factory=dict, compare=False)

    def __post_init__(self):
        from ..core.acl.library import default_library
        from ..kernels.approx_matmul import from_circuit

        lib = default_library()
        for cls, (name, rank) in self.assignments.items():
            c = lib[name]
            assert c.kind == "mul8s", (
                f"LM projections quantize to signed int8; {name} is {c.kind}"
            )
            object.__setattr__(
                self, "_specs", {**self._specs, cls: from_circuit(c, rank)}
            )

    def spec(self, cls: str):
        return self._specs.get(cls)

    @staticmethod
    def exact() -> "ApproxPolicy":
        return ApproxPolicy({})


def _approx_matmul_nd(x: jnp.ndarray, w: jnp.ndarray, spec) -> jnp.ndarray:
    """x (..., k) @ w (k, n) under an ApproxSpec, with dynamic per-tensor
    symmetric int8 quantization."""
    from ..kernels.approx_matmul import quantize_sym

    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    qx, sx = quantize_sym(x2)
    qw, sw = quantize_sym(w)
    if spec.trunc_bits:
        # natively-truncating circuit: reduced-width integer operands
        t = spec.trunc_bits
        qx = jnp.sign(qx) * ((jnp.abs(qx) >> t) << t)
        qw = jnp.sign(qw) * ((jnp.abs(qw) >> t) << t)
    xi = qx + 128
    wi = qw + 128
    out = qx.astype(jnp.float32) @ qw.astype(jnp.float32)
    if spec.rank:
        u = jnp.asarray(spec.u)
        v = jnp.asarray(spec.v)
        ux = jnp.take(u, xi, axis=0)          # (m, k, r)
        vw = jnp.take(v, wi, axis=0)          # (k, n, r)
        m, n, r = x2.shape[0], w.shape[1], spec.rank
        out = out + jnp.einsum(
            "mkr,knr->mn",
            ux,
            vw,
            preferred_element_type=jnp.float32,
        )
    out = out * (sx * sw)
    return out.reshape(*lead, w.shape[1])


def linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    cls: str,
    policy: Optional[ApproxPolicy] = None,
    *,
    compute_dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """Projection with optional DSE-assigned approximation."""
    spec = policy.spec(cls) if policy is not None else None
    if spec is None:
        return jnp.einsum(
            "...k,kn->...n", x.astype(compute_dtype), w.astype(compute_dtype)
        )
    return _approx_matmul_nd(x, w, spec).astype(compute_dtype)
