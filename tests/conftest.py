# NOTE: no XLA_FLAGS here on purpose — smoke tests and benchmarks must
# see the real single CPU device; only launch/dryrun.py (and the explicit
# subprocess tests) force 512/8 host devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _reset_synth_engine_state():
    """The synthesis engine keeps module-global verification state (fast-
    codegen verdicts, structural verdicts, the shared compile cache).
    One test's verification history or cached compiles must never leak
    into another, so every test starts from a cold engine."""
    from repro.core.features import synth

    synth.reset_fast_codegen()
    yield


@pytest.fixture(autouse=True)
def _reset_fault_plan():
    """Fault injection is module-global (an armed plan fires at every
    instrumented point in the process); a test that installs a plan
    must never leave it armed for the next one."""
    from repro import faults

    faults.uninstall()
    yield
    faults.uninstall()


@pytest.fixture(autouse=True)
def _reset_fused_sim_state():
    """The fused population-sim engine keeps module-global state too
    (compiled programs, plan/pin/verification history, counters); tests
    must not inherit another test's pins or verification budget."""
    from repro.accel import fused

    fused.reset()
    yield
    fused.reset()
