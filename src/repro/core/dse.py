"""End-to-end DSE driver — the three framework stages of paper Fig. 2:

  1. Model Training       sample + label n_train random variants (XLA
                          synthesis + behavioral sim), build the pipeline's
                          feature extractor, fit the two surrogates.
  2. Architecture          NSGA-II over the genome space, objectives
     Exploration           evaluated by the surrogates only.
  3. Final Evaluation      the surviving parent set is re-synthesized and
                          re-simulated; the *true* Pareto front is returned.

Every stage is timed; the result object carries everything the Fig. 5/7/8/9
benchmarks need.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (accel depends on core.acl)
    from ..accel.base import Accelerator
from .acl.library import Library, default_library
from .features import synth
from .features.pipelines import build_extractor
from .nsga2 import NSGA2Config, NSGA2Result, nsga2
from .pareto import non_dominated_mask
from .surrogates import make, pcc

__all__ = ["DSEConfig", "DSEResult", "run_dse", "random_search"]


@dataclass(frozen=True)
class DSEConfig:
    pipeline: str = "D"                     # paper's winner
    hw_model: str = "bayesian_ridge"        # paper Fig. 6: best for power
    qor_model: str = "random_forest"        # paper Fig. 6: best for QoR
    objectives: Tuple[str, ...] = ("qor", "energy")  # qor auto-negated
    n_train: int = 1000                     # paper: 1000 random variants
    n_qor_samples: int = 4
    rank_genes: bool = False                # beyond-paper axis
    # beyond-paper: seed half the NSGA-II population from the
    # circuit-level Pareto subspace (the SoA's pre-filter, used as a
    # warm start instead of a hard restriction) — on the TPU the slot
    # costs are separable, so that subspace is a strong prior while the
    # full-space search still covers interactions the pre-filter misses
    warm_start: bool = True
    nsga: NSGA2Config = field(default_factory=NSGA2Config)
    seed: int = 0


@dataclass
class DSEResult:
    accel_name: str
    config: DSEConfig
    # stage 1
    train_genomes: np.ndarray
    train_labels: Dict[str, np.ndarray]
    val_pcc: Dict[str, float]
    # stage 2
    search: NSGA2Result
    est_objectives: np.ndarray          # surrogate objectives of parents
    # stage 3
    final_labels: Dict[str, np.ndarray]
    true_objectives: np.ndarray
    front_mask: np.ndarray
    timings: Dict[str, float]

    @property
    def front_genomes(self) -> np.ndarray:
        return self.search.genomes[self.front_mask]

    @property
    def front_objectives(self) -> np.ndarray:
        return self.true_objectives[self.front_mask]


def _objective_matrix(labels: Dict[str, np.ndarray], names: Sequence[str]) -> np.ndarray:
    cols = []
    for nm in names:
        v = np.asarray(labels[nm], dtype=np.float64)
        cols.append(-v if nm == "qor" else v)  # maximize QoR -> minimize -QoR
    return np.stack(cols, axis=1)


def run_dse(
    accel: Accelerator,
    library: Optional[Library] = None,
    cfg: DSEConfig = DSEConfig(),
    *,
    verbose: bool = False,
) -> DSEResult:
    library = library or default_library()
    rng = np.random.default_rng(cfg.seed)
    gene_sizes = accel.gene_sizes(library, rank_genes=cfg.rank_genes)
    timings: Dict[str, float] = {}
    synth_cache: dict = {}
    qor_inputs = accel.sample_inputs(cfg.n_qor_samples, seed=1234)

    # ---------------- stage 1: model training -----------------------------
    t0 = time.perf_counter()
    train_genomes = rng.integers(0, gene_sizes[None, :],
                                 size=(cfg.n_train, len(gene_sizes)))
    # always include the exact reference design (standard DSE practice:
    # the known-good corner anchors both the surrogates and the front)
    train_genomes[0] = accel.exact_genome(library, rank_genes=cfg.rank_genes)
    train_labels = synth.label_variants(
        accel, train_genomes, library,
        rank_genes=cfg.rank_genes, qor_inputs=qor_inputs, cache=synth_cache,
    )
    timings["label"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    extractor = build_extractor(cfg.pipeline, accel, library,
                                rank_genes=cfg.rank_genes)
    X = extractor(train_genomes)
    n_val = max(cfg.n_train // 5, 1)
    tr, va = slice(n_val, None), slice(0, n_val)
    models = {}
    val_pcc = {}
    for obj in cfg.objectives:
        name = cfg.qor_model if obj == "qor" else cfg.hw_model
        m = make(name, seed=cfg.seed).fit(X[tr], train_labels[obj][tr])
        models[obj] = m
        val_pcc[obj] = pcc(train_labels[obj][va], m.predict(X[va]))
    # refit on everything for the search
    for obj in cfg.objectives:
        name = cfg.qor_model if obj == "qor" else cfg.hw_model
        models[obj] = make(name, seed=cfg.seed).fit(X, train_labels[obj])
    timings["train"] = time.perf_counter() - t0
    if verbose:
        print(f"[dse:{accel.name}] val PCC: "
              + ", ".join(f"{k}={v:.3f}" for k, v in val_pcc.items()))

    # ---------------- stage 2: architecture exploration -------------------
    t0 = time.perf_counter()

    def evaluate(genomes: np.ndarray) -> np.ndarray:
        Xg = extractor(genomes)
        labels = {obj: models[obj].predict(Xg) for obj in cfg.objectives}
        return _objective_matrix(labels, cfg.objectives)

    init = train_genomes[: cfg.nsga.pop_size].copy()
    if cfg.warm_start and len(init) >= 4:
        from ..accel.approxfpgas import circuit_level_front

        half = len(init) // 2
        per_slot_choices = []
        for slot in accel.slots:
            front = circuit_level_front(library, slot.kind)
            per_slot_choices.append(
                [library.index(slot.kind, c.name) for c in front]
            )
        for t in range(half):
            for j, choices in enumerate(per_slot_choices):
                init[t, j] = choices[rng.integers(0, len(choices))]
    search = nsga2(gene_sizes, evaluate, cfg.nsga, init=init)
    timings["explore"] = time.perf_counter() - t0

    # ---------------- stage 3: final evaluation ---------------------------
    t0 = time.perf_counter()
    final_labels = synth.label_variants(
        accel, search.genomes, library,
        rank_genes=cfg.rank_genes, qor_inputs=qor_inputs, cache=synth_cache,
    )
    timings["final_eval"] = time.perf_counter() - t0

    # the delivered Pareto front is over EVERY synthesized point (search
    # survivors + the stage-1 training sample — their ground truth is
    # already paid for)
    all_genomes = np.concatenate([search.genomes, train_genomes])
    all_labels = {
        k: np.concatenate([final_labels[k], train_labels[k]])
        for k in final_labels
    }
    true_obj = _objective_matrix(all_labels, cfg.objectives)

    return DSEResult(
        accel_name=accel.name,
        config=cfg,
        train_genomes=train_genomes,
        train_labels=train_labels,
        val_pcc=val_pcc,
        search=NSGA2Result(
            genomes=all_genomes,
            objectives=np.concatenate(
                [search.objectives, _objective_matrix(train_labels,
                                                      cfg.objectives)]
            ),
            front_mask=non_dominated_mask(true_obj),
            history=search.history,
            n_evaluated=search.n_evaluated,
        ),
        est_objectives=search.objectives,
        final_labels=all_labels,
        true_objectives=true_obj,
        front_mask=non_dominated_mask(true_obj),
        timings=timings,
    )


def random_search(
    accel: Accelerator,
    library: Optional[Library] = None,
    *,
    n: int = 1000,
    objectives: Tuple[str, ...] = ("qor", "energy"),
    rank_genes: bool = False,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Baseline for Figs. 8/9: label n random variants, return
    (genomes, objectives, front_mask)."""
    library = library or default_library()
    rng = np.random.default_rng(seed)
    gene_sizes = accel.gene_sizes(library, rank_genes=rank_genes)
    genomes = rng.integers(0, gene_sizes[None, :], size=(n, len(gene_sizes)))
    labels = synth.label_variants(accel, genomes, library,
                                  rank_genes=rank_genes, cache={})
    obj = _objective_matrix(labels, objectives)
    return genomes, obj, non_dominated_mask(obj)
