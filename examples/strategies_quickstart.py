"""Strategy-protocol quickstart: pluggable explorers + resumable campaigns.

    PYTHONPATH=src python examples/strategies_quickstart.py

Three acts:

  1. the same campaign explored by NSGA-II and by expected-improvement
     Bayesian optimization (``strategy="bo"``) — one spec field,
  2. a custom hill-climbing strategy registered in ~30 lines and driven
     through ``run_dse`` by name,
  3. a service campaign cancelled mid-EXPLORE and resumed from its
     snapshot — the resumed front is identical to an uninterrupted twin.

Set REPRO_SMOKE=1 for the CI-sized fast mode."""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.accel import MCMAccelerator
from repro.core.dse import DSEConfig, run_dse
from repro.core.nsga2 import NSGA2Config, NSGA2Result
from repro.core.pareto import non_dominated_mask
from repro.core.strategies import SearchStrategy, register_strategy
from repro.service import CampaignManager, CampaignSpec

SMOKE = bool(os.environ.get("REPRO_SMOKE"))
SIZES = dict(n_train=10 if SMOKE else 32, n_qor_samples=2,
             pop_size=8 if SMOKE else 16, n_parents=4 if SMOKE else 8,
             n_generations=3 if SMOKE else 8)


def cfg_for(strategy):
    return DSEConfig(
        strategy=strategy, n_train=SIZES["n_train"],
        n_qor_samples=SIZES["n_qor_samples"],
        nsga=NSGA2Config(pop_size=SIZES["pop_size"],
                         n_parents=SIZES["n_parents"],
                         n_generations=SIZES["n_generations"]),
    )


# --- act 2's custom strategy: ~30 lines ---------------------------------
class HillClimb(SearchStrategy):
    name = "hillclimb"

    def __init__(self, sizes, cfg, *, init=None):
        self.sizes = np.asarray(sizes, dtype=np.int64)
        self.rng = np.random.default_rng(cfg.seed)
        self.rounds, self.batch = cfg.nsga.n_generations + 1, cfg.nsga.pop_size
        self.round, self.best, self.obs, self._pending = 0, None, [], None

    @property
    def done(self):
        return self.round >= self.rounds and self._pending is None

    def ask(self):
        if self._pending is None:
            if self.best is None:
                g = self.rng.integers(0, self.sizes[None, :],
                                      size=(self.batch, len(self.sizes)))
            else:
                g = np.repeat(self.best[None, :], self.batch, axis=0)
                mut = self.rng.random(g.shape) < 0.2
                g = np.where(mut, self.rng.integers(
                    0, self.sizes[None, :], size=g.shape), g)
            self._pending = g
        return self._pending

    def tell(self, genomes, objectives):
        self.obs.append((np.array(genomes), np.array(objectives)))
        self.best = np.array(genomes[int(np.argmin(objectives.sum(axis=1)))])
        self.round, self._pending = self.round + 1, None

    def result(self):
        G = np.concatenate([g for g, _ in self.obs])
        O = np.concatenate([o for _, o in self.obs])
        return NSGA2Result(genomes=G, objectives=O,
                           front_mask=non_dominated_mask(O),
                           n_evaluated=len(G))


def main():
    accel = MCMAccelerator(1)

    print("-- act 1: one spec field swaps the explorer --")
    for strategy in ("nsga2", "bo"):
        res = run_dse(accel, cfg=cfg_for(strategy))
        print(f"  {strategy:6s} front={int(res.front_mask.sum()):2d} designs  "
              f"surrogate evals={res.search.n_evaluated}")

    print("\n-- act 2: custom strategy, registered by name --")
    register_strategy("hillclimb", HillClimb)
    res = run_dse(accel, cfg=cfg_for("hillclimb"))
    print(f"  hillclimb front={int(res.front_mask.sum())} designs")

    print("\n-- act 3: cancel mid-EXPLORE, resume from the snapshot --")
    spec = CampaignSpec(accel="mcm2", **{**SIZES,
                                         "n_generations": 8 if SMOKE else 20})
    mgr = CampaignManager(eval_workers=2, campaign_workers=2)
    twin = mgr.submit(spec)
    assert mgr.wait(twin, timeout=600) == "done"

    cid = mgr.submit(spec)
    while True:
        st = mgr.status(cid)
        pr = st.get("progress") or {}
        if pr.get("stage") in ("explore", "final") or st["state"] == "done":
            break
        time.sleep(0.005)
    if st["state"] != "done":
        mgr.cancel(cid)
        state = mgr.wait(cid, timeout=600)
        print(f"  cancelled at stage={pr.get('stage')!r} "
              f"gen={pr.get('generation')} -> state={state}")
        if state == "cancelled":
            mgr.resume(cid)
            assert mgr.wait(cid, timeout=600) == "done"
    same = np.array_equal(mgr.result(cid).front_objectives,
                          mgr.result(twin).front_objectives)
    print(f"  resumed front identical to uninterrupted twin: {same}")
    assert same
    mgr.shutdown()


if __name__ == "__main__":
    main()
