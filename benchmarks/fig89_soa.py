"""Figs. 8/9 — autoXFPGAs vs the state of the art (ApproxFPGAs [15]) vs
random search, on the four MCM accelerators + application level.

Derived metric per accelerator: hypervolume ratio of the autoXFPGAs front
vs the SoA front (>= 1 reproduces the paper's claim)."""

from __future__ import annotations

import numpy as np

from repro.accel import MCMAccelerator
from repro.accel.approxfpgas import approxfpgas_search
from repro.core.acl.library import default_library
from repro.core.dse import DSEConfig, random_search, run_dse
from repro.core.nsga2 import NSGA2Config
from repro.core.pareto import hypervolume_2d

from .common import emit


def run(budget: int = 60, generations: int = 8, seed: int = 0, rows=(0, 1)):
    lib = default_library()
    wins = 0
    for row in rows:
        accel = MCMAccelerator(row)
        qor_inputs = accel.sample_inputs(2, seed=1234)

        # autoXFPGAs: surrogate-guided NSGA-II, synthesis budget =
        # n_train + final parents
        cfg = DSEConfig(
            n_train=budget, n_qor_samples=2,
            nsga=NSGA2Config(pop_size=48, n_parents=16,
                             n_generations=generations, seed=seed),
            seed=seed,
        )
        ours = run_dse(accel, lib, cfg)
        obj_ours = ours.true_objectives

        # SoA: pre-filtered circuit-level Pareto library + random search
        # with the same synthesis budget
        _, obj_soa, _, _ = approxfpgas_search(
            accel, lib, n_budget=budget + cfg.nsga.n_parents,
            seed=seed, qor_inputs=qor_inputs,
        )
        # random search over the full library, same budget
        _, obj_rand, _ = random_search(
            accel, lib, n=budget + cfg.nsga.n_parents, seed=seed + 1,
        )

        allobj = np.concatenate([obj_ours, obj_soa, obj_rand])
        ref = allobj.max(axis=0) + 1e-9
        hv_ours = hypervolume_2d(obj_ours, ref)
        hv_soa = hypervolume_2d(obj_soa, ref)
        hv_rand = hypervolume_2d(obj_rand, ref)
        ratio_soa = hv_ours / max(hv_soa, 1e-12)
        ratio_rand = hv_ours / max(hv_rand, 1e-12)
        wins += int(ratio_soa >= 0.999)
        emit(f"fig89.mcm{row+1}.hv_ratio_vs_soa", 0.0, round(ratio_soa, 3))
        emit(f"fig89.mcm{row+1}.hv_ratio_vs_random", 0.0,
             round(ratio_rand, 3))
    emit("fig89.wins_vs_soa", 0.0, f"{wins}/{len(rows)}")
    return wins
