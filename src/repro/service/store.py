"""Persistent, content-addressed ground-truth label store.

A label is the full ``synth.label_variants`` record for ONE genome under
ONE evaluation context.  The key is a digest of everything the label is
a pure function of:

    (accelerator fingerprint, library fingerprint, rank_genes,
     QoR-input signature, genome bytes)

so a store written by one campaign (or one process) is safely readable
by any later campaign: a hit is bit-identical to re-running synthesis +
simulation, and a context change (different circuit library, different
accelerator wiring, different QoR sample set) changes the key and misses
cleanly instead of serving stale labels.

Two implementations of the small ``LabelStore`` interface:

  * ``InMemoryLabelStore`` — a dict; the service's hot tier and the
    drop-in replacement for the old per-call ``synth_cache``,
  * ``JsonlLabelStore``    — append-only JSON-lines file on disk with an
    in-memory index; concurrent writers append under a lock, readers
    see every record from any prior process.
"""

from __future__ import annotations

import contextlib
import hashlib
import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None

import numpy as np

from .. import faults, obs
from ..core.acl.library import Library, library_fingerprint
from ..core.features import synth
from ..segments import SegmentedLog

__all__ = [
    "LABEL_KEYS",
    "STORE_SCHEMA_VERSION",
    "EvalContext",
    "label_key",
    "LabelStore",
    "InMemoryLabelStore",
    "JsonlLabelStore",
    "SegmentedLabelStore",
    "open_label_store",
]

_log = obs.get_logger("store")

# the per-genome record produced by synth.label_variants
LABEL_KEYS = synth.LABEL_KEYS

# bump when the label semantics change (e.g. a new energy model): old
# store files then miss instead of serving stale ground truth
STORE_SCHEMA_VERSION = 1


# Content digest of a library (moved to core.acl.library so the batched
# sim's LUT caches can key on it without importing the service tier).
_library_fingerprint = library_fingerprint


def _accel_fingerprint(accel) -> str:
    """Digest of the accelerator's labeling-relevant structure.

    Accelerators may expose ``label_fingerprint()`` for extra state their
    labels depend on; otherwise common identity knobs (init seed, input
    batch/seq) are picked up by attribute convention."""
    try:
        shape = tuple(int(v) for v in accel.matmul_shape())
    except NotImplementedError:
        shape = ()
    sig = {
        "name": accel.name,
        "slots": [(s.name, s.kind, float(s.weight)) for s in accel.slots],
        "matmul_shape": shape,
        "passes": int(getattr(accel, "deploy_passes", 1)),
    }
    if hasattr(accel, "label_fingerprint"):
        sig["extra"] = str(accel.label_fingerprint())
    else:
        sig["extra"] = {
            k: repr(getattr(accel, k))
            for k in ("seed", "batch", "seq") if hasattr(accel, k)
        }
    return hashlib.sha256(
        json.dumps(sig, sort_keys=True).encode()
    ).hexdigest()[:16]


@dataclass
class EvalContext:
    """Everything a ground-truth label is conditioned on, bundled with
    the machinery to produce labels for a genome batch.

    ``fingerprint`` keys the store; ``ground_truth`` is the slow path
    (XLA synthesis + behavioral simulation).  A per-context synthesis
    cache keeps the old spec-level compile reuse within a process."""

    accel: object
    library: Library
    rank_genes: bool = False
    n_qor_samples: int = 4
    qor_seed: int = synth.DEFAULT_QOR_SEED
    # shared/persistent compile cache (synth.SynthCache); None uses the
    # process-wide default.  Machinery, not semantics: deliberately NOT
    # part of the fingerprint — labels are identical with or without it
    synth_cache: Optional[object] = field(default=None, repr=False)
    _fp: Optional[str] = field(default=None, repr=False)
    _qor_inputs: Optional[np.ndarray] = field(default=None, repr=False)
    _synth_cache: dict = field(default_factory=dict, repr=False)

    @property
    def fingerprint(self) -> str:
        if self._fp is None:
            sig = "|".join([
                f"v{STORE_SCHEMA_VERSION}",
                _accel_fingerprint(self.accel),
                _library_fingerprint(self.library),
                f"rank_genes={int(self.rank_genes)}",
                f"qor={self.n_qor_samples}@{self.qor_seed}",
            ])
            self._fp = hashlib.sha256(sig.encode()).hexdigest()[:24]
        return self._fp

    @property
    def qor_inputs(self) -> np.ndarray:
        if self._qor_inputs is None:
            self._qor_inputs = self.accel.sample_inputs(
                self.n_qor_samples, seed=self.qor_seed
            )
        return self._qor_inputs

    def key(self, genome: np.ndarray) -> str:
        return label_key(self.fingerprint, genome)

    def ground_truth(self, genomes: np.ndarray) -> Dict[str, np.ndarray]:
        """The slow path: label a genome batch from scratch."""
        return synth.label_variants(
            self.accel, np.atleast_2d(genomes), self.library,
            rank_genes=self.rank_genes, qor_inputs=self.qor_inputs,
            cache=self._synth_cache, synth_cache=self.synth_cache,
        )


def label_key(ctx_fingerprint: str, genome: np.ndarray) -> str:
    g = np.asarray(genome, dtype=np.int64)
    h = hashlib.sha256(ctx_fingerprint.encode())
    h.update(g.tobytes())
    return h.hexdigest()[:32]


class LabelStore:
    """Interface: map ``key -> {label name -> float}`` with hit/miss
    accounting.  Implementations must be thread-safe."""

    def __init__(self):
        self._lock = threading.Lock()
        # standalone sharded instruments (race-free increments from any
        # worker thread); register_metrics() publishes THIS instance's
        # instruments to the scrape registry — the scheduler does that
        # for the store it owns, so GET /metrics shows the service
        # store, not whichever ephemeral store was built last
        self.hits = obs.Counter(
            "repro_store_hits_total", "label store lookups served")
        self.misses = obs.Counter(
            "repro_store_misses_total", "label store lookups missed")

    def register_metrics(self, registry=None) -> None:
        reg = registry or obs.REGISTRY
        for inst in (self.hits, self.misses):
            reg._register(inst)
        self._entries_gauge = reg.gauge(
            "repro_store_entries", "unique labels in the store")
        with self._lock:
            self._entries_gauge.set(self._len())

    def get(self, key: str) -> Optional[Dict[str, float]]:
        with self._lock:
            rec = self._get(key)
        if rec is None:
            self.misses.inc()
        else:
            self.hits.inc()
        return rec

    def put(self, key: str, labels: Dict[str, float]) -> None:
        rec = {k: float(labels[k]) for k in LABEL_KEYS}
        with self._lock:
            self._put(key, rec)

    def put_many(self, items) -> None:
        """Store a labeled batch under ONE lock acquisition.  ``items``
        is an iterable of ``(key, labels)`` pairs; implementations may
        override ``_put_batch`` to buffer the batch into a single
        backing write."""
        recs = [
            (key, {k: float(labels[k]) for k in LABEL_KEYS})
            for key, labels in items
        ]
        if not recs:
            return
        with self._lock:
            self._put_batch(recs)
            g = getattr(self, "_entries_gauge", None)
            if g is not None:
                g.set(self._len())

    def __len__(self) -> int:
        with self._lock:
            return self._len()

    def stats(self) -> Dict[str, float]:
        hits = int(self.hits.value)
        misses = int(self.misses.value)
        total = hits + misses
        with self._lock:
            n = self._len()
        return {
            "entries": n,
            "hits": hits,
            "misses": misses,
            "hit_rate": (hits / total) if total else 0.0,
        }

    def health(self) -> Dict[str, object]:
        """Readiness probe for ``GET /health``: can this store still
        accept writes?  Disk-backed stores check their directory."""
        with self._lock:
            n = self._len()
        return {"writable": True, "entries": n}

    # implementations override (called under the lock):
    def _get(self, key: str) -> Optional[Dict[str, float]]:
        raise NotImplementedError

    def _put(self, key: str, rec: Dict[str, float]) -> None:
        raise NotImplementedError

    def _put_batch(self, recs) -> None:
        for key, rec in recs:
            self._put(key, rec)

    def _len(self) -> int:
        raise NotImplementedError


class InMemoryLabelStore(LabelStore):
    """Dict-backed store — the service's hot tier, and what the old
    per-``run_dse`` ``synth_cache`` becomes under the store interface."""

    def __init__(self):
        super().__init__()
        self._data: Dict[str, Dict[str, float]] = {}

    def _get(self, key):
        return self._data.get(key)

    def _put(self, key, rec):
        self._data[key] = rec

    def _len(self):
        return len(self._data)


class JsonlLabelStore(LabelStore):
    """Append-only JSON-lines store with an in-memory index.

    One record per line: ``{"k": <key>, "l": {<labels>}, "t": <unix>}``.
    Appends are flushed per batch; a fresh process replays the file into
    its index at construction, so labels persist across campaigns AND
    processes.  Duplicate keys are benign (last write wins on replay —
    labels are deterministic, so duplicates carry identical values).

    Duplicates DO accumulate when several processes label overlapping
    genome sets against one file, making replay O(lines) instead of
    O(unique labels).  ``compact()`` rewrites the log with one line per
    key; ``auto_compact_ratio=r`` (opt-in) compacts automatically
    whenever the file holds more than ``r``x as many lines as unique
    keys.  Compaction is safe against concurrent writer PROCESSES (the
    fleet case): appends and the compaction's replay-rewrite-rename all
    run under one cross-process advisory file lock (``<path>.lock``),
    and every writer re-checks the backing inode under that lock — a
    writer whose handle points at a replaced file reopens and rescans
    instead of appending into the dropped inode."""

    def __init__(self, path: str, *, auto_compact_ratio: Optional[float] = None):
        super().__init__()
        if auto_compact_ratio is not None and auto_compact_ratio <= 1.0:
            raise ValueError("auto_compact_ratio must be > 1")
        self.path = str(path)
        self.auto_compact_ratio = auto_compact_ratio
        self.compactions = 0
        self.quarantined = 0  # malformed/torn records dropped, counted
        self._data: Dict[str, Dict[str, float]] = {}
        self._offset = 0  # bytes already replayed; refresh parses the tail
        self._n_lines = 0  # complete lines in the file (incl. duplicates)
        self._ino: Optional[int] = None  # inode the offset refers to
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        # append handle; opened lazily on first put
        self._fh = None
        self._replay()
        self._maybe_auto_compact()

    @contextlib.contextmanager
    def _write_lock(self):
        """Cross-process advisory lock serializing appends with
        compaction (``flock`` on a sidecar, so lock acquisition never
        touches — or keeps alive — the replaced data inode)."""
        faults.hit("store.lock", path=self.path)
        if fcntl is None:  # pragma: no cover - non-POSIX
            yield
            return
        with open(self.path + ".lock", "a+") as lk:
            fcntl.flock(lk.fileno(), fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lk.fileno(), fcntl.LOCK_UN)

    def _replay(self) -> None:
        """Parse records appended since the last replay (tail-seek, so a
        refresh is O(new bytes), not O(file)).  Detects a compaction by
        another process (inode change) and rescans the new file from the
        top — the index is keyed, so re-reading is idempotent."""
        if not os.path.exists(self.path):
            return
        # errors="replace": undecodable bit-rot must fail a line's CRC,
        # not crash the replay
        with open(self.path, errors="replace") as f:
            ino = os.fstat(f.fileno()).st_ino
            if self._ino is not None and ino != self._ino:
                # the path was atomically replaced under us: our offset
                # and line count describe the old inode
                self._offset = 0
                self._n_lines = 0
                if self._fh is not None:
                    self._fh.close()
                    self._fh = None
            self._ino = ino
            f.seek(self._offset)
            while True:
                pos = f.tell()
                line = f.readline()
                if not line or not line.endswith("\n"):
                    # EOF, or a torn tail from a concurrent writer:
                    # leave the offset here so it is re-read next time
                    self._offset = pos
                    return
                self._n_lines += 1
                try:
                    rec = json.loads(line)
                    self._data[rec["k"]] = rec["l"]
                except (json.JSONDecodeError, KeyError):
                    # malformed complete line: skipped permanently, but
                    # never silently — drills and /stats see the count
                    self.quarantined += 1
                    _log.warning("quarantined malformed record in %s @%d",
                                 self.path, pos)

    def refresh(self) -> int:
        """Re-read the backing file (pick up other processes' appends).
        Returns the number of entries after the refresh."""
        with self._lock:
            self._replay()
            self._maybe_auto_compact()
            return len(self._data)

    # --- compaction ---------------------------------------------------
    def compact(self) -> int:
        """Rewrite the log with one line per unique key (atomic rename).
        Returns the number of duplicate/malformed lines dropped."""
        with self._lock:
            return self._compact_locked()

    def _compact_locked(self) -> int:
        # the write lock spans replay -> rewrite -> rename: concurrent
        # appender processes either land before the replay (and are
        # folded into the compacted file) or block until the rename is
        # visible (and their next append detects the new inode) — no
        # torn tail, no dropped foreign records
        with obs.span("store.compact", path=self.path), self._write_lock():
            self._replay()
            dropped = max(self._n_lines - len(self._data), 0)
            if self._fh is not None:
                self._fh.close()
                self._fh = None
            tmp = self.path + ".compact.tmp"
            with open(tmp, "w") as f:
                now = time.time()
                for k, rec in self._data.items():
                    f.write(json.dumps({"k": k, "l": rec, "t": now},
                                       sort_keys=True) + "\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
            # a kill here (mid-rename window) loses nothing: the rename
            # was atomic and the next writer re-checks the inode
            faults.hit("store.compact", path=self.path)
            self._offset = os.path.getsize(self.path)
            self._n_lines = len(self._data)
            self._ino = os.stat(self.path).st_ino
        self.compactions += 1
        return dropped

    def _maybe_auto_compact(self) -> None:
        r = self.auto_compact_ratio
        if r is None or self._n_lines <= len(self._data):
            return
        if self._n_lines >= r * max(len(self._data), 1):
            self._compact_locked()

    # ------------------------------------------------------------------
    def _get(self, key):
        return self._data.get(key)

    def _put(self, key, rec):
        self._put_batch([(key, rec)])

    def _put_batch(self, recs) -> None:
        """One buffered append/flush for a whole labeled batch (the
        per-label path syscalls once per record); duplicates of known
        keys update the index only (labels are deterministic)."""
        fresh = []
        for key, rec in recs:
            known = key in self._data
            self._data[key] = rec
            if not known:
                fresh.append((key, rec))
        if not fresh:
            return
        # the cross-process lock makes append-vs-compact atomic: the
        # replay consumes any foreign tail (and detects a compaction's
        # inode swap, reopening the handle) BEFORE we append, so
        # advancing the offset below cannot skip another process's
        # records and our records cannot land in a dropped inode
        with obs.span("store.put", n=len(fresh)), self._write_lock():
            self._replay()
            f = faults.check("store.append", n=len(fresh))
            if f is not None:
                if f.kind == "torn_write":
                    # simulate a foreign writer dying mid-append
                    with open(self.path, "a") as gf:
                        gf.write('{"k": "__torn__", "l": {')
                elif f.kind == "error":
                    f.raise_()
                elif f.delay_s > 0:
                    time.sleep(f.delay_s)
            if self._fh is None:
                self._fh = open(self.path, "a")
            # a torn tail left by a dead writer would merge with our
            # first record and destroy both; terminate it so it becomes
            # its own quarantined malformed line instead
            try:
                size = os.path.getsize(self.path)
            except OSError:
                size = 0
            if size > self._offset:
                torn = size - self._offset
                self._fh.write("\n")
                self._fh.flush()
                self._offset = self._fh.tell()
                self._n_lines += 1
                self.quarantined += 1
                _log.warning("repaired torn tail in %s (%d bytes"
                             " quarantined)", self.path, torn)
            now = time.time()
            self._fh.write("".join(
                json.dumps({"k": key, "l": rec, "t": now},
                           sort_keys=True) + "\n"
                for key, rec in fresh
            ))
            self._fh.flush()
            self._n_lines += len(fresh)
            self._offset = self._fh.tell()

    def _len(self):
        return len(self._data)

    def stats(self) -> Dict[str, float]:
        s = super().stats()
        with self._lock:
            s["lines"] = self._n_lines
            s["compactions"] = self.compactions
            s["quarantined"] = self.quarantined
        return s

    def health(self) -> Dict[str, object]:
        h = super().health()
        d = os.path.dirname(os.path.abspath(self.path)) or "."
        h["writable"] = os.access(d, os.W_OK)
        h["path"] = self.path
        h["quarantined"] = self.quarantined
        return h

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __del__(self):  # best-effort
        try:
            self.close()
        except Exception:
            pass


class SegmentedLabelStore(LabelStore):
    """Label store on the segmented, CRC-framed log — the persistence
    tier for 10^6+ labels (see :mod:`repro.segments`).

    Warm start is O(manifest + key sidecars), not O(records): sealed
    segments enter the in-memory index as *lazy references* (key →
    segment name) and a segment's bodies are parsed only when one of its
    keys is actually read (``segments_loaded`` counts those).  Damage is
    survived, not fatal: a CRC-failing record is quarantined and
    counted; a damaged sealed segment is moved to ``quarantine/`` and
    its unsalvaged keys become clean misses (relabeled on demand) while
    the campaign continues.  Appends, seals and retention run under one
    cross-process ``flock``, preserving the multi-writer-process safety
    the fleet relies on.  ``retention_segments`` (opt-in) bounds disk by
    evicting the oldest sealed segments — evicted keys miss and relabel.
    """

    def __init__(self, root: str, *, segment_records: int = 4096,
                 retention_segments: Optional[int] = None):
        super().__init__()
        self.root = str(root)
        self.segments_loaded = 0
        self._seglog = SegmentedLog(
            self.root, segment_records=segment_records,
            retention_segments=retention_segments,
            index_field="k", name="labels")
        # key -> label dict (loaded) | segment name (lazy reference)
        self._data: Dict[str, object] = {}
        self._known_segs = set()
        with self._seglog.lock():
            self._sync_locked()

    # -- reconcile index with the log ----------------------------------
    def _sync_locked(self) -> None:
        m, tail = self._seglog.sync_locked()
        live = {e["name"] for e in m["sealed"]}
        for e in m["sealed"]:
            name = e["name"]
            if name in self._known_segs:
                continue
            self._known_segs.add(name)
            keys = self._seglog.read_index(name)
            if keys is None:
                # sidecar missing/damaged: fall back to reading bodies
                self._load_segment_locked(name)
                continue
            for k in keys:
                cur = self._data.get(k)
                if cur is None or isinstance(cur, str):
                    self._data[k] = name
        # a foreign process may have quarantined/retired segments we
        # still reference: turn those refs back into clean misses
        stale = self._known_segs - live
        if stale:
            self._known_segs &= live
            for k in [k for k, v in self._data.items()
                      if isinstance(v, str) and v in stale]:
                del self._data[k]
        for rec in tail:
            if isinstance(rec, dict) and "k" in rec and "l" in rec:
                self._data[rec["k"]] = rec["l"]

    def _load_segment_locked(self, name: str) -> None:
        """Parse one sealed segment's bodies into the index; damaged
        segments are quarantined and their lost keys dropped."""
        self.segments_loaded += 1
        try:
            recs, bad = self._seglog.read_segment(name)
        except OSError as e:
            recs, bad = [], -1
            reason = f"unreadable: {e}"
        else:
            reason = f"{bad} damaged records"
        for rec in recs:
            if isinstance(rec, dict) and "k" in rec and "l" in rec:
                cur = self._data.get(rec["k"])
                if cur is None or isinstance(cur, str):
                    self._data[rec["k"]] = rec["l"]
        if bad:
            if bad > 0:
                self._seglog.quarantined_records += bad
            self._seglog.quarantine_locked(name, reason)
            self._known_segs.discard(name)
            for k in [k for k, v in self._data.items() if v == name]:
                del self._data[k]

    # -- LabelStore interface ------------------------------------------
    def _get(self, key):
        v = self._data.get(key)
        if v is None or isinstance(v, dict):
            return v
        with self._seglog.lock():  # lazy ref: materialize its segment
            if isinstance(self._data.get(key), str):
                self._load_segment_locked(v)
        v = self._data.get(key)
        return v if isinstance(v, dict) else None

    def _put(self, key, rec):
        self._put_batch([(key, rec)])

    def _put_batch(self, recs) -> None:
        fresh = []
        now = time.time()
        for key, rec in recs:
            known = key in self._data  # lazy ref counts: labels are
            self._data[key] = rec      # deterministic, values identical
            if not known:
                fresh.append({"k": key, "l": rec, "t": now})
        if not fresh:
            return
        with obs.span("store.put", n=len(fresh)), self._seglog.lock():
            self._sync_locked()
            res = self._seglog.append_locked(fresh)
            for k in res["dropped_keys"]:  # retention evictions
                self._data.pop(k, None)

    def _len(self):
        return len(self._data)

    def refresh(self) -> int:
        """Pick up other processes' appends/seals (fleet warm reuse)."""
        with self._lock:
            with self._seglog.lock():
                self._sync_locked()
            return len(self._data)

    def stats(self) -> Dict[str, float]:
        s = super().stats()
        with self._lock:
            s.update(self._seglog.stats())
            s["segments_loaded"] = self.segments_loaded
        return s

    def health(self) -> Dict[str, object]:
        h = super().health()
        h["writable"] = os.access(self.root, os.W_OK)
        h["path"] = self.root
        h["quarantined"] = self._seglog.quarantined_records
        h["quarantined_segments"] = self._seglog.quarantined_segments
        return h

    def close(self) -> None:
        with self._lock:
            self._seglog.close()

    def __del__(self):  # best-effort
        try:
            self.close()
        except Exception:
            pass


def open_label_store(path: str, *, migrate: bool = False,
                     **kw) -> LabelStore:
    """Open the right disk store for ``path``.

    * an existing directory (or any path without a ``.jsonl`` suffix)
      → :class:`SegmentedLabelStore` rooted there;
    * a legacy single-file ``<name>.jsonl`` with ``migrate=True`` (the
      service CLI) → a segmented store rooted at ``<name>.segd`` with
      the legacy records auto-migrated *warm* (every old label answers
      without recompute; the old file is kept as ``.jsonl.migrated``);
    * a ``.jsonl`` path without ``migrate`` (fleet workers, launch
      CLIs) → the already-migrated segmented root if one exists, else a
      plain :class:`JsonlLabelStore` — replicas never migrate a file
      another process may still be appending to.
    """
    p = str(path)
    if not p.endswith(".jsonl"):
        return SegmentedLabelStore(p, **kw)
    root = p[:-len(".jsonl")] + ".segd"
    if not migrate:
        if os.path.isdir(root) and not os.path.isfile(p):
            return SegmentedLabelStore(root, **kw)
        return JsonlLabelStore(p, **kw)
    store = SegmentedLabelStore(root, **kw)
    if os.path.isfile(p):
        migrated = 0
        batch = []
        with open(p) as f:
            for line in f:
                if not line.endswith("\n"):
                    continue  # torn legacy tail
                try:
                    rec = json.loads(line)
                    batch.append((rec["k"], rec["l"]))
                    migrated += 1
                except (json.JSONDecodeError, KeyError, TypeError):
                    continue
                if len(batch) >= 10000:
                    store.put_many(batch)
                    batch = []
        if batch:
            store.put_many(batch)
        try:
            os.replace(p, p + ".migrated")
        except OSError:  # a concurrent migrator beat us to the rename
            pass
        _log.info("migrated %d records from %s into %s",
                  migrated, p, root)
    return store
