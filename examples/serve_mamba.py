"""Serving example: batched greedy decoding from the attention-free
falcon-mamba backbone (O(1) decode state — the long_500k family).

    PYTHONPATH=src python examples/serve_mamba.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.launch.serve import serve_batch
from repro.models import reduced


def main():
    cfg = reduced(get_config("falcon-mamba-7b"))
    print(f"serving {cfg.name}: layers={cfg.n_layers} d={cfg.d_model} "
          f"(attention-free: decode state is O(1) in context length)")
    tokens, tps = serve_batch(cfg, batch=4, prompt_len=32, gen=24)
    print(f"generated {tokens.shape[0]}x{tokens.shape[1]} tokens "
          f"@ {tps:.1f} tok/s (CPU, reduced config)")
    print("sample:", tokens[0, -24:].tolist())


if __name__ == "__main__":
    main()
