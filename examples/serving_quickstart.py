"""Serving-tier quickstart: search, then serve the front.

    PYTHONPATH=src python examples/serving_quickstart.py

Runs one mcm2 campaign, then serves inference requests off the
resulting Pareto front through the continuous-batching serving engine:
named tiers (exact / balanced / budget), per-request SLA budgets with
nearest-feasible degrade, and a live hot-swap — a second campaign
completes mid-stream and the engine picks up the refreshed front
without dropping a request.

Set REPRO_SMOKE=1 for the CI-sized fast mode."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.service import CampaignManager, CampaignSpec, make_accelerator

SMOKE = bool(os.environ.get("REPRO_SMOKE"))

SPEC = dict(accel="mcm2",
            n_train=10 if SMOKE else 48, n_qor_samples=2,
            pop_size=8 if SMOKE else 16,
            n_parents=4 if SMOKE else 8,
            n_generations=2 if SMOKE else 4)


def main():
    mgr = CampaignManager(eval_workers=2, campaign_workers=2)

    print("-- search: one mcm2 campaign --")
    cid = mgr.submit(CampaignSpec(**SPEC))
    state = mgr.wait(cid, timeout=1800)
    print(f"campaign {cid}: {state}")

    print("\n-- serve: the front as a product --")
    # the hub snapshots the merged global front into a FrontCatalog and
    # materializes the named operating tiers
    engine = mgr.serving.engine_for("mcm2")
    cat = engine.catalog
    print(f"catalog v{cat.version}: {len(cat)} operating points")
    for name, i in sorted(cat.tiers.items()):
        p = cat.points[i]
        labels = " ".join(f"{k}={v:.3g}" for k, v in p.labels.items())
        print(f"  tier {name:<9} genome={list(p.genome)} ({labels})")

    accel = make_accelerator("mcm2")
    X = accel.sample_inputs(4, seed=1)
    for tier in ("exact", "balanced", "budget"):
        r = engine.serve(X, tier=tier)
        print(f"  serve tier={tier:<9} measured qor={r['qor']:.1f} dB "
              f"(batch group of {r['group_size']})")

    # per-request SLA: a budget instead of a named tier
    emax = cat.points[cat.tiers["budget"]].labels["energy"]
    r = engine.serve(X, budget={"energy": emax + 1.0})
    print(f"  serve budget(energy<={emax + 1.0:.3g}): "
          f"genome={r['genome']} feasible={r['feasible']}")
    r = engine.serve(X, budget={"qor": 1e6})  # impossible: degrade
    print(f"  serve budget(qor>=1e6): nearest-feasible degrade -> "
          f"qor={r['labels']['qor']:.1f} feasible={r['feasible']}")

    print("\n-- hot-swap: search while serving --")
    # the hub subscribed to the manager: when this campaign finishes,
    # the engine's catalog refreshes between batches automatically
    v0 = engine.catalog.version
    cid2 = mgr.submit(CampaignSpec(**dict(SPEC, seed=1)))
    mgr.wait(cid2, timeout=1800)
    r = engine.serve(X, tier="budget")
    cat = engine.catalog
    swapped = cat.version > v0
    print(f"second campaign done: catalog v{v0} -> v{cat.version} "
          f"({'hot-swapped' if swapped else 'front unchanged, no swap'})")
    print(f"  serve tier=budget now: v{r['catalog_version']} "
          f"qor={r['qor']:.1f}")

    s = mgr.serving_stats()["engines"]["mcm2"]
    print(f"\nserving stats: {s['responses']} responses in "
          f"{s['batches']} batches / {s['groups']} groups, "
          f"tier selections {s['tier_selections']}, "
          f"{s['hot_swaps']} hot-swaps")
    mgr.shutdown()


if __name__ == "__main__":
    main()
