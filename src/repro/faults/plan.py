"""Seeded fault plans: what to break, where, and on which hit.

A :class:`FaultPlan` is a list of :class:`FaultRule`\\ s, each matched by
glob against a *named injection point* (``store.append``,
``http.request``, ``fleet.result``, ...).  Rules fire deterministically:
the decision for the *n*-th hit of a rule is a pure function of
``(plan.seed, rule index, point name, n)`` — no wall clock, no global
RNG — so a chaos drill replays bit-identically and a failure found once
can be reproduced forever by re-running the same plan.

Plans serialize to plain JSON so they travel to worker subprocesses via
``REPRO_FAULTS=plan.json`` (see :mod:`repro.faults.inject`).
"""

from __future__ import annotations

import fnmatch
import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional

__all__ = ["FaultRule", "FaultPlan", "KINDS", "POINTS"]

# What a rule does when it fires.  ``error``/``latency`` are handled by
# the injection runtime itself; the site-specific kinds are returned to
# the call site as a directive (see inject.hit):
#   error      raise FaultInjected (optionally styled as HTTP ``status``)
#   latency    sleep ``delay_s`` then continue
#   torn_write the store writes ``fraction`` of a record, no newline
#   drop       the site discards the message/lease/result
#   duplicate  the site delivers the message twice
#   exit       os._exit — simulate a kill between two non-atomic steps
KINDS = ("error", "latency", "torn_write", "drop", "duplicate", "exit")

# The injection points threaded through the stack (documentation — a
# rule may glob-match any name, including ones added later).
POINTS = (
    "store.append",        # label/synth store: before records are written
    "store.seal",          # segment seal / compact: between rename+manifest
    "store.lock",          # flock acquisition (latency = lock contention)
    "http.request",        # fleet/http.request_json, per attempt
    "fleet.lease",         # orchestrator lease grant (drop = starve)
    "fleet.result",        # orchestrator result ingest (drop/duplicate)
    "fleet.heartbeat",     # worker heartbeat send (drop = go dark)
    "sched.dispatch",      # scheduler batch dispatch
    "synth.compile",       # structural synthesis compile (latency = slow)
    "serving.backend",     # serving engine backend.run
)


@dataclass
class FaultRule:
    """One thing to break.  ``point`` is an fnmatch glob over injection
    point names; ``after``/``times`` schedule the rule over the point's
    hit sequence (skip the first ``after`` hits, fire at most ``times``
    times); ``p`` is the per-hit probability once eligible."""

    point: str
    kind: str = "error"
    p: float = 1.0
    delay_s: float = 0.0          # latency kind, or pre-raise stall
    status: Optional[int] = None  # error kind: style as this HTTP status
    message: str = ""
    times: Optional[int] = None   # max firings (None = unlimited)
    after: int = 0                # skip the first N eligible hits
    fraction: float = 0.5         # torn_write: fraction of bytes written

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if not (0.0 <= float(self.p) <= 1.0):
            raise ValueError(f"p must be in [0,1], got {self.p}")
        if self.delay_s < 0:
            raise ValueError("delay_s must be >= 0")
        if not (0.0 <= float(self.fraction) < 1.0):
            raise ValueError("fraction must be in [0,1)")

    def matches(self, point: str) -> bool:
        return fnmatch.fnmatchcase(point, self.point)

    def to_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        # keep plans tidy: drop fields at their defaults
        for k, v in (("p", 1.0), ("delay_s", 0.0), ("status", None),
                     ("message", ""), ("times", None), ("after", 0),
                     ("fraction", 0.5)):
            if d[k] == v:
                del d[k]
        return d


@dataclass
class FaultPlan:
    """A named, seeded set of fault rules."""

    seed: int = 0
    rules: List[FaultRule] = field(default_factory=list)
    name: str = ""

    def add(self, point: str, kind: str = "error", **kw: Any) -> "FaultPlan":
        """Append a rule; returns self so plans chain fluently."""
        self.rules.append(FaultRule(point=point, kind=kind, **kw))
        return self

    # ---- serialization ------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "version": 1,
            "name": self.name,
            "seed": self.seed,
            "rules": [r.to_dict() for r in self.rules],
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1, sort_keys=True)

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FaultPlan":
        rules = [FaultRule(**r) for r in d.get("rules", [])]
        return cls(seed=int(d.get("seed", 0)), rules=rules,
                   name=str(d.get("name", "")))

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls.from_dict(json.loads(text))

    @classmethod
    def from_file(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(f.read())
