"""FrontCatalog: a composed Pareto front materialized as operating tiers.

A catalog is an immutable snapshot of one accelerator's front — the
(genome, labels) pairs a campaign (or the service's merged global front)
found non-dominated — ordered canonically and annotated with named
*operating tiers*:

  * ``exact``    — the highest-QoR point (ties: cheapest, then genome),
  * ``budget``   — the cheapest point on the primary cost objective
                   (ties: best QoR, then genome),
  * ``balanced`` — the knee: the point closest (L2) to the ideal corner
                   after min-max normalizing every objective over the
                   front (ties: canonical order).

``select`` is the SLA knob: a named tier, or a per-request budget
(``{"energy": <= x, "latency": <= y, "qor": >= z}``) resolved to the
best feasible point — or, when NO point is feasible, degraded
deterministically to the nearest-feasible point (minimum total relative
violation).  Every code path tie-breaks deterministically (objective
values, then genome bytes), so two replicas holding the same front
always pick the same genome for the same request.

Catalogs are cheap value objects: the serving engine hot-swaps them
atomically between batches and keeps recent versions around so requests
pinned to an old version stay byte-identical.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "DEFAULT_TIERS",
    "EmptyFrontError",
    "FrontCatalog",
    "NoFrontError",
    "OperatingPoint",
    "Selection",
]

# objectives where bigger is better (everything else is a cost);
# mirrors the sign convention of core.dse (qor auto-negated there)
HIGHER_BETTER = frozenset({"qor"})

DEFAULT_TIERS = ("exact", "balanced", "budget")


class EmptyFrontError(ValueError):
    """select() on a catalog with no operating points."""


class NoFrontError(LookupError):
    """No completed campaign has produced a front for this accelerator."""


@dataclass(frozen=True)
class OperatingPoint:
    """One front point: a genome and its ground-truth labels."""

    genome: Tuple[int, ...]
    labels: Dict[str, float]

    def genome_array(self) -> np.ndarray:
        return np.array(self.genome, dtype=np.int64)


@dataclass(frozen=True)
class Selection:
    """What the SLA knob resolved to."""

    tier: Optional[str]          # named tier, or None for a budget pick
    index: int                   # canonical index into catalog.points
    point: OperatingPoint
    feasible: bool = True        # False: nearest-feasible degrade


def _obj_key(labels: Dict[str, float], objectives: Sequence[str]) -> Tuple:
    """Minimization-convention sort key over the objective columns."""
    return tuple(
        -labels[o] if o in HIGHER_BETTER else labels[o] for o in objectives
    )


class FrontCatalog:
    """An ordered front snapshot + named tiers + the SLA selector."""

    def __init__(
        self,
        accel: str,
        points: Sequence[OperatingPoint],
        objectives: Sequence[str] = ("qor", "energy"),
        *,
        version: int = 1,
        source: str = "",
        rank_genes: bool = False,
    ):
        self.accel = str(accel)
        self.objectives = tuple(objectives)
        self.version = int(version)
        self.source = str(source)
        self.rank_genes = bool(rank_genes)
        for p in points:
            missing = [o for o in self.objectives if o not in p.labels]
            if missing:
                raise ValueError(
                    f"operating point {p.genome} lacks objective(s) {missing}"
                )
        # canonical order: best QoR first, then cheaper, then genome
        # bytes — every downstream tie-break reduces to "first in order"
        self.points: List[OperatingPoint] = sorted(
            points,
            key=lambda p: (_obj_key(p.labels, self.objectives), p.genome),
        )
        self.tiers: Dict[str, int] = self._build_tiers()

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def from_front(
        cls,
        accel: str,
        genomes,
        front,
        objectives: Sequence[str] = ("qor", "energy"),
        **kw,
    ) -> "FrontCatalog":
        """Build from minimization-convention front columns — the shape
        ``core.dse`` emits (qor stored NEGATED, ``-v if nm == "qor"``)
        and every ``/front`` payload carries.  Labels on the resulting
        operating points are RAW (qor = PSNR dB, higher better)."""
        genomes = np.atleast_2d(np.asarray(genomes, dtype=np.int64))
        front = np.atleast_2d(np.asarray(front, dtype=np.float64))
        objectives = tuple(objectives)
        if genomes.size == 0 and front.size == 0:
            return cls(accel, [], objectives, **kw)
        if len(front) and front.shape[1] != len(objectives):
            raise ValueError(
                f"front has {front.shape[1]} columns for "
                f"{len(objectives)} objectives {objectives}"
            )
        pts = [
            OperatingPoint(
                tuple(int(v) for v in g),
                {
                    o: float(-row[j] if o in HIGHER_BETTER else row[j])
                    for j, o in enumerate(objectives)
                },
            )
            for g, row in zip(genomes, front)
        ]
        return cls(accel, pts, objectives, **kw)

    @classmethod
    def from_json(cls, d: Dict, **kw) -> "FrontCatalog":
        """The ``GET /front`` / ``GET /campaigns/<id>/front`` payload
        shape (also what ``to_json`` emits)."""
        kw.setdefault("version", int(d.get("version", 1)))
        kw.setdefault("rank_genes", bool(d.get("rank_genes", False)))
        kw.setdefault("source", str(d.get("source", "json")))
        return cls.from_front(
            d["accel"], d.get("genomes", []), d.get("front", []),
            tuple(d.get("objectives", ("qor", "energy"))), **kw,
        )

    @classmethod
    def from_file(cls, path: str, **kw) -> "FrontCatalog":
        with open(path) as f:
            d = json.load(f)
        kw.setdefault("source", path)
        return cls.from_json(d, **kw)

    @classmethod
    def from_manager(
        cls,
        manager,
        accel: str,
        objectives: Optional[Sequence[str]] = None,
        **kw,
    ) -> "FrontCatalog":
        """Snapshot the service's merged global front for ``accel``
        (every completed campaign's non-dominated union)."""
        objectives = tuple(objectives or ("qor", "energy"))
        d = manager.global_front(accel, objectives)
        kw.setdefault("source", "manager")
        return cls.from_front(accel, d["genomes"], d["front"], objectives,
                              **kw)

    def to_json(self) -> Dict:
        # "front" rows round-trip in the minimization convention that
        # from_front consumes (qor re-negated); "tiers" carry raw labels
        return {
            "accel": self.accel,
            "objectives": list(self.objectives),
            "genomes": [list(p.genome) for p in self.points],
            "front": [
                [
                    -p.labels[o] if o in HIGHER_BETTER else p.labels[o]
                    for o in self.objectives
                ]
                for p in self.points
            ],
            "version": self.version,
            "rank_genes": self.rank_genes,
            "source": self.source,
            "digest": self.digest,
            "tiers": {
                name: {
                    "index": i,
                    "genome": list(self.points[i].genome),
                    "labels": dict(self.points[i].labels),
                }
                for name, i in self.tiers.items()
            },
        }

    # ------------------------------------------------------------------
    @property
    def empty(self) -> bool:
        return not self.points

    def __len__(self) -> int:
        return len(self.points)

    @property
    def digest(self) -> str:
        """Content hash of the front (NOT the version): hot-swap
        triggers only when the actual front changed."""
        h = hashlib.sha256()
        h.update(json.dumps(
            {
                "accel": self.accel,
                "objectives": self.objectives,
                "rank_genes": self.rank_genes,
                "points": [
                    (p.genome, [p.labels[o] for o in self.objectives])
                    for p in self.points
                ],
            },
            sort_keys=True,
        ).encode())
        return h.hexdigest()[:16]

    # ------------------------------------------------------------------
    # tiers
    # ------------------------------------------------------------------
    def _primary_cost(self) -> Optional[str]:
        for o in self.objectives:
            if o not in HIGHER_BETTER:
                return o
        return None

    def _build_tiers(self) -> Dict[str, int]:
        if not self.points:
            return {}
        n = len(self.points)
        cost = self._primary_cost()
        # exact: canonical order already leads with best QoR
        exact = 0
        if cost is None:
            budget = n - 1
        else:
            budget = min(
                range(n),
                key=lambda i: (
                    self.points[i].labels[cost],
                    _obj_key(self.points[i].labels, self.objectives),
                    self.points[i].genome,
                ),
            )
        balanced = self._knee()
        return {"exact": exact, "balanced": balanced, "budget": budget}

    def _knee(self) -> int:
        """Min-max normalize each objective over the front (as a loss:
        0 = best seen, 1 = worst seen) and pick the point closest to the
        all-best corner; ties break to canonical order."""
        vals = np.array(
            [[p.labels[o] for o in self.objectives] for p in self.points],
            dtype=np.float64,
        )
        for j, o in enumerate(self.objectives):
            if o in HIGHER_BETTER:
                vals[:, j] = -vals[:, j]
        lo, hi = vals.min(axis=0), vals.max(axis=0)
        span = np.where(hi > lo, hi - lo, 1.0)
        norm = (vals - lo) / span
        dist = np.sqrt((norm ** 2).sum(axis=1))
        return int(np.argmin(dist))  # argmin: first index on ties

    # ------------------------------------------------------------------
    # the SLA knob
    # ------------------------------------------------------------------
    def select(
        self,
        tier: Optional[str] = None,
        budget: Optional[Dict[str, float]] = None,
    ) -> Selection:
        """Resolve a request's SLA to one operating point.

        Exactly one of ``tier``/``budget`` (neither defaults to the
        ``balanced`` tier).  A budget maps objective names to bounds:
        an upper bound for cost objectives, a LOWER bound for
        higher-is-better objectives (``qor``).  When no point satisfies
        every bound the selection degrades to the point with the
        smallest total relative violation (``feasible=False``)."""
        if self.empty:
            raise EmptyFrontError(
                f"catalog for {self.accel!r} holds no operating points"
            )
        if tier is not None and budget is not None:
            raise ValueError("pass either tier or budget, not both")
        if budget is None:
            name = tier if tier is not None else "balanced"
            if name not in self.tiers:
                raise ValueError(
                    f"unknown tier {name!r}; known: {sorted(self.tiers)}"
                )
            i = self.tiers[name]
            return Selection(name, i, self.points[i])
        unknown = sorted(set(budget) - set(self.objectives))
        if unknown:
            raise ValueError(
                f"unknown budget objective(s) {unknown}; "
                f"known: {list(self.objectives)}"
            )
        if not budget:
            raise ValueError("budget cannot be empty")
        bounds = {k: float(v) for k, v in budget.items()}

        def violation(p: OperatingPoint) -> float:
            total = 0.0
            for o, b in bounds.items():
                v = p.labels[o]
                over = (b - v) if o in HIGHER_BETTER else (v - b)
                if over > 0.0:
                    total += over / max(abs(b), 1e-12)
            return total

        feasible = [
            i for i, p in enumerate(self.points) if violation(p) == 0.0
        ]
        if feasible:
            # canonical order leads with best QoR, so the first feasible
            # index IS the deterministic best pick
            i = feasible[0]
            return Selection(None, i, self.points[i])
        # nearest-feasible degrade: minimal total relative violation,
        # ties to canonical order (best QoR, cheapest, genome bytes)
        i = min(range(len(self.points)),
                key=lambda j: (violation(self.points[j]), j))
        return Selection(None, i, self.points[i], feasible=False)
