"""Hierarchical multi-stage search over a ``StagedPipeline``.

The paper's scalability strategy (§V), on top of the PR-1 campaign
service:

  1. **Per-stage campaigns** — one full three-stage DSE per pipeline
     stage, submitted concurrently through a ``CampaignManager``
     (shared label store, coalesced evaluation batches).  Each stage's
     QoR is measured in situ with every other stage exact
     (``StageView``); its hardware labels are the stage's own deployment.
  2. **Composition** — the surviving per-stage fronts are composed with
     incremental non-dominated pruning (compose.py); the flat product
     space is never enumerated.
  3. **End-to-end verification** — only the composed candidates are
     re-labeled through the chained behavioral simulation + chained MXU
     deployment (the ``run_dse`` stage-3 analogue), yielding the
     verified application-level front.

``HierarchicalResult`` carries per-stage timings, composition stats and
ground-truth-call counts against the flat-equivalent space size.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.acl.library import Library, default_library
from ..core.dse import _objective_matrix, label_unique
from ..core.pareto import non_dominated_mask
from ..service.campaigns import (
    CampaignManager,
    CampaignSpec,
    register_accelerator,
)
from ..service.store import EvalContext
from .compose import ComposeStats, StageFront, compose_fronts
from .staged import StagedPipeline

__all__ = ["HierarchicalConfig", "HierarchicalResult", "run_hierarchical"]


@dataclass(frozen=True)
class HierarchicalConfig:
    """Per-stage campaign budget + composition knobs."""

    # per-stage campaign (CampaignSpec fields)
    pipeline: str = "D"                   # feature pipeline, paper's winner
    qor_model: str = "random_forest"
    hw_model: str = "bayesian_ridge"
    strategy: str = "nsga2"               # explorer for every stage campaign
    objectives: Tuple[str, ...] = ("qor", "energy")
    n_train: int = 48
    n_qor_samples: int = 2
    rank_genes: bool = False
    warm_start: bool = True
    pop_size: int = 24
    n_parents: int = 12
    n_generations: int = 6
    seed: int = 0
    # composition
    k_per_stage: Optional[int] = 12       # per-stage front truncation
    max_candidates: int = 64              # end-to-end re-label budget
    stage_timeout_s: float = 3600.0       # per-stage campaign wait

    def stage_spec(self, accel_name: str, overrides: Optional[Dict] = None
                   ) -> CampaignSpec:
        d = dict(
            accel=accel_name,
            pipeline=self.pipeline,
            qor_model=self.qor_model,
            hw_model=self.hw_model,
            strategy=self.strategy,
            objectives=tuple(self.objectives),
            n_train=self.n_train,
            n_qor_samples=self.n_qor_samples,
            rank_genes=self.rank_genes,
            warm_start=self.warm_start,
            pop_size=self.pop_size,
            n_parents=self.n_parents,
            n_generations=self.n_generations,
            seed=self.seed,
        )
        d.update(overrides or {})
        return CampaignSpec(**d)


@dataclass
class HierarchicalResult:
    pipeline_name: str
    config: HierarchicalConfig
    # stage campaigns
    stage_campaign_ids: List[str]
    stage_fronts: List[StageFront]
    val_pcc: Dict[str, float]             # {"stage<i>/<obj>": pcc}
    # composition
    compose_stats: ComposeStats
    est_objectives: np.ndarray            # composed estimates (pre-dedup)
    # end-to-end verification
    candidate_genomes: np.ndarray         # unique pipeline genomes relabeled
    final_labels: Dict[str, np.ndarray]
    true_objectives: np.ndarray
    front_mask: np.ndarray
    # accounting
    timings: Dict[str, float] = field(default_factory=dict)
    ground_truth_calls: Dict[str, int] = field(default_factory=dict)
    flat_space_size: float = 0.0
    max_concurrent_stages: int = 0

    @property
    def accel_name(self) -> str:
        return self.pipeline_name

    @property
    def front_genomes(self) -> np.ndarray:
        return self.candidate_genomes[self.front_mask]

    @property
    def front_objectives(self) -> np.ndarray:
        return self.true_objectives[self.front_mask]


def _max_overlap(intervals: Sequence[Tuple[float, float]]) -> int:
    """Max number of intervals simultaneously open (campaign concurrency)."""
    events = []
    for a, b in intervals:
        if a is None or b is None:
            continue
        events.append((a, 1))
        events.append((b, -1))
    best = cur = 0
    for _, d in sorted(events):
        cur += d
        best = max(best, cur)
    return best


def run_hierarchical(
    pipeline: StagedPipeline,
    library: Optional[Library] = None,
    cfg: Optional[HierarchicalConfig] = None,
    *,
    manager: Optional[CampaignManager] = None,
    stage_overrides: Optional[Sequence[Dict]] = None,
    verbose: bool = False,
) -> HierarchicalResult:
    """Hierarchical search: concurrent per-stage campaigns -> composed
    front -> end-to-end verification.  Uses the given ``manager`` (and
    its label store) or owns a temporary one.  The per-stage campaigns
    ride the manager's cooperative ask/tell stepping, so stages share
    the campaign worker pool with everything else the service runs (and
    ``cfg.strategy`` picks each stage's explorer)."""
    cfg = cfg if cfg is not None else HierarchicalConfig()
    library = library or default_library()
    n_stages = len(pipeline.stages)
    overrides = list(stage_overrides or [])
    if overrides and len(overrides) != n_stages:
        raise ValueError(
            f"stage_overrides has {len(overrides)} entries for "
            f"{n_stages} stages"
        )

    # make the pipeline resolvable by name for the campaign workers.
    # The stage campaigns search whatever the name resolves to, so if the
    # name currently resolves to a DIFFERENT structure (e.g. the pipeline
    # was edited and re-run in a live process), re-register THIS object —
    # latest wins, and the campaigns stay consistent with the end-to-end
    # verification below
    from ..service.campaigns import make_accelerator

    try:
        resolved = make_accelerator(pipeline.name)
        same = (getattr(resolved, "label_fingerprint", lambda: None)()
                == pipeline.label_fingerprint())
    except ValueError:
        same = False
    if not same:
        register_accelerator(pipeline.name, lambda: pipeline)

    own_manager = manager is None
    if own_manager:
        manager = CampaignManager(
            eval_workers=2, campaign_workers=max(2, n_stages)
        )
    timings: Dict[str, float] = {}
    t_total = time.perf_counter()
    try:
        # ---- 1. one concurrent campaign per stage ------------------------
        t0 = time.perf_counter()
        cids = [
            manager.submit(cfg.stage_spec(
                f"{pipeline.name}/stage{i}",
                overrides[i] if overrides else None,
            ))
            for i in range(n_stages)
        ]
        for i, cid in enumerate(cids):
            state = manager.wait(cid, timeout=cfg.stage_timeout_s)
            if state == "failed":
                raise RuntimeError(
                    f"stage {i} campaign {cid} failed: "
                    f"{manager.status(cid).get('error')}"
                )
            if state != "done":
                raise RuntimeError(
                    f"stage {i} campaign {cid} still {state} after "
                    f"{cfg.stage_timeout_s:.0f}s (raise "
                    f"HierarchicalConfig.stage_timeout_s; the stage "
                    f"campaigns keep running on the manager and can be "
                    f"collected via their ids {cids})"
                )
        timings["stage_campaigns"] = time.perf_counter() - t0

        statuses = [manager.status(cid) for cid in cids]
        max_conc = _max_overlap(
            [(s["started_at"], s["finished_at"]) for s in statuses]
        )
        val_pcc: Dict[str, float] = {}
        fronts: List[StageFront] = []
        stage_labeled = 0
        for i, cid in enumerate(cids):
            res = manager.result(cid)
            timings[f"stage{i}"] = statuses[i]["wall_s"]
            for k, v in res.val_pcc.items():
                val_pcc[f"stage{i}/{k}"] = v
            fronts.append(StageFront(
                genomes=np.asarray(res.front_genomes),
                objectives=np.asarray(res.front_objectives),
            ))
            lab = manager.scheduler.campaign_stats(cid)
            stage_labeled += int(lab["labeled"]) if lab else 0
        if verbose:
            sizes = [len(f.genomes) for f in fronts]
            print(f"[hier:{pipeline.name}] stage fronts {sizes}, "
                  f"max {max_conc} campaigns in flight")

        # ---- 2. composition ----------------------------------------------
        t0 = time.perf_counter()
        qor_index = (cfg.objectives.index("qor")
                     if "qor" in cfg.objectives else None)
        comp = compose_fronts(
            fronts,
            qor_index=qor_index,
            k_per_stage=cfg.k_per_stage,
            max_survivors=cfg.max_candidates,
        )
        genomes = np.stack([
            pipeline.assemble_genome(
                [comp.stage_genomes[s][comp.indices[t, s]]
                 for s in range(n_stages)],
                rank_genes=cfg.rank_genes,
            )
            for t in range(len(comp.indices))
        ])
        # anchor with the exact reference design, dedupe before labeling
        exact = pipeline.exact_genome(library, rank_genes=cfg.rank_genes)
        genomes = np.unique(
            np.concatenate([genomes, exact[None, :]]), axis=0
        )
        timings["compose"] = time.perf_counter() - t0
        if verbose:
            print(f"[hier:{pipeline.name}] composed "
                  f"{comp.stats.pairs_evaluated} pairs of a "
                  f"{comp.stats.cross_product_size:.0f}-product -> "
                  f"{len(genomes)} candidates")

        # ---- 3. end-to-end verification ----------------------------------
        t0 = time.perf_counter()
        final_tag = f"{pipeline.name}/final-{cids[0]}"
        ctx = EvalContext(
            pipeline, library,
            rank_genes=cfg.rank_genes, n_qor_samples=cfg.n_qor_samples,
            synth_cache=getattr(manager, "synth_cache", None),
        )

        def labeler(g):
            return manager.scheduler.label(ctx, g, campaign=final_tag)

        final_labels = label_unique(labeler, genomes)
        timings["final_eval"] = time.perf_counter() - t0
        true_obj = _objective_matrix(final_labels, cfg.objectives)
        front_mask = non_dominated_mask(true_obj)

        final_stats = manager.scheduler.campaign_stats(final_tag)
        final_labeled = int(final_stats["labeled"]) if final_stats else 0
        # the tag is not a campaign id, so the manager's retention would
        # never reclaim its accounting — drop it now that it's been read
        manager.scheduler.forget_campaign(final_tag)
        flat_space = float(np.prod([
            float(s) for s in
            pipeline.gene_sizes(library, rank_genes=cfg.rank_genes)
        ]))
        timings["total"] = time.perf_counter() - t_total
        if verbose:
            print(f"[hier:{pipeline.name}] verified front "
                  f"{int(front_mask.sum())}/{len(genomes)}; ground truth "
                  f"{stage_labeled}+{final_labeled} calls vs flat space "
                  f"{flat_space:.2e}")

        return HierarchicalResult(
            pipeline_name=pipeline.name,
            config=cfg,
            stage_campaign_ids=cids,
            stage_fronts=fronts,
            val_pcc=val_pcc,
            compose_stats=comp.stats,
            est_objectives=comp.objectives,
            candidate_genomes=genomes,
            final_labels=final_labels,
            true_objectives=true_obj,
            front_mask=front_mask,
            timings=timings,
            ground_truth_calls={
                "stage_campaigns": stage_labeled,
                "final": final_labeled,
                "total": stage_labeled + final_labeled,
            },
            flat_space_size=flat_space,
            max_concurrent_stages=max_conc,
        )
    finally:
        if own_manager:
            manager.shutdown()
