"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (16, 16) = 256 v5e chips; multi
pod: (2, 16, 16) = 512 chips, where the "pod" axis carries only data
parallelism (gradient reduction over DCN) and "data"/"model" are the
intra-pod FSDP/TP axes (DESIGN.md §5).
"""

from __future__ import annotations

from ..dist.compat import make_mesh

__all__ = ["make_production_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
