"""The assigned input-shape cells and their abstract input specs.

Four shapes per architecture (40 cells):
    train_4k     seq 4,096   global batch 256   -> train_step
    prefill_32k  seq 32,768  global batch 32    -> prefill_step
    decode_32k   seq 32,768  global batch 128   -> serve_step (1 token,
                                                  KV cache of seq_len)
    long_500k    seq 524,288 global batch 1     -> serve_step; SSM/hybrid
                                                  only (sub-quadratic);
                                                  SKIP for full-attention
                                                  archs per the brief.

``input_specs`` returns weak-type-correct ShapeDtypeStructs with resolved
NamedShardings — no device allocation — plus per-cell sharding-rule
overrides (decode cells shard the KV sequence on "model"; long-context
also on "data").
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import AxisRules, sharding_for
from ..models.common import ParamSpec, abstract_tree
from ..models.config import ModelConfig
from ..models.transformer import cache_specs, param_specs

__all__ = ["ShapeCell", "SHAPES", "cell_rules", "input_specs", "runnable",
           "n_microbatches", "ENC_CONTEXT"]

ENC_CONTEXT = 4096  # encoder context length for enc-dec decode cells


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def runnable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runnable?, reason-if-skip) for one (arch, shape) cell."""
    cell = SHAPES[shape]
    if cell.name == "long_500k" and not cfg.supports_long_context:
        return False, "SKIP(full-attn): 512k dense-KV decode out of scope"
    return True, ""


def cell_rules(cfg: ModelConfig, shape: str, mesh=None) -> AxisRules:
    """Per-cell sharding-rule overrides (see module docstring)."""
    cell = SHAPES[shape]
    n_pods = mesh.shape.get("pod", 1) if mesh is not None else 1
    # kv_seq -> "model" is the global default (dist.sharding); it must
    # match the constraint the model applies internally.  Arch-level
    # overrides (e.g. jamba's cross-pod FSDP) come from the config; perf
    # experiments pass rules_override explicitly on top.
    rules: AxisRules = dict(cfg.sharding_rules)
    # §Perf-validated defaults for archs whose head count cannot shard on
    # the 16-way model axis (gemma 8, granite-moe 24): attention would
    # REPLICATE across TP, so
    #   * prefill: context-parallel queries (seq -> model): 8.6-13.8x
    #   * train:   batch over (pod, data, model): 10.8x on gemma
    # (no-ops for shardable-head archs: the heads rule wins the axis)
    if cfg.n_heads % 16 != 0 and not cfg.is_attention_free:
        if cell.kind == "prefill" and not (cfg.n_experts and n_pods > 1):
            # (exception: on the multi-pod mesh the MoE routing-group
            # reshape crosses seq shards and regresses — §Perf)
            rules.setdefault("seq", "model")
        if cell.kind == "train":
            rules.setdefault("batch", ("pod", "data", "model"))
    return rules


def n_microbatches(cfg: ModelConfig, mesh) -> int:
    """Gradient-accumulation depth for train_4k: enough that a per-device
    microbatch is 1-2 rows (activation memory), shard-aligned to the
    cell's batch sharding (cell_rules)."""
    from ..dist.sharding import DEFAULT_RULES

    rules = {**DEFAULT_RULES, **cell_rules(cfg, "train_4k", mesh)}
    axes = rules.get("batch") or ()
    if isinstance(axes, str):
        axes = (axes,)
    b = SHAPES["train_4k"].global_batch
    batch_shards = 1
    for a in axes:
        n = mesh.shape.get(a, 1)
        if b % (batch_shards * n) == 0:
            batch_shards *= n
    per_dev = b // batch_shards
    rows = 1 if cfg.d_model >= 4096 else 2
    return max(per_dev // rows, 1)


def _tok_sds(shape, mesh, rules, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(
        shape, dtype,
        sharding=sharding_for(("batch",) + (None,) * (len(shape) - 1),
                              shape, mesh, rules),
    )


def _embed_sds(b, s, d, mesh, rules):
    return jax.ShapeDtypeStruct(
        (b, s, d), jnp.bfloat16,
        sharding=sharding_for(("batch", None, None), (b, s, d), mesh, rules),
    )


def input_specs(
    cfg: ModelConfig,
    shape: str,
    mesh,
    *,
    serve_dtype: str = "bfloat16",
    rules_override: Optional[AxisRules] = None,
) -> Dict[str, Any]:
    """Abstract inputs for one cell.

    Returns {"kind", "rules", "batch"| ("caches","tokens","pos"),
    "params" (spec tree), ...} — everything dryrun/launch needs.
    ``rules_override`` lets perf experiments re-shard a cell."""
    cell = SHAPES[shape]
    rules = {**cell_rules(cfg, shape, mesh), **(rules_override or {})}
    d = cfg.d_model
    out: Dict[str, Any] = {"kind": cell.kind, "rules": rules, "cell": cell}

    pspecs = param_specs(cfg)
    # train: master-weight dtype from the config (jamba: bf16 to fit HBM);
    # serving: bf16 weights
    dtype = cfg.param_dtype if cell.kind == "train" else serve_dtype
    pspecs = jax.tree.map(
        lambda s: ParamSpec(s.shape, s.logical, dtype, s.init, s.scale),
        pspecs, is_leaf=lambda s: isinstance(s, ParamSpec),
    )
    out["param_specs"] = pspecs
    out["params"] = abstract_tree(pspecs, mesh, rules)

    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        batch: Dict[str, Any] = {}
        if cfg.is_encoder_decoder:
            batch["enc_embeds"] = _embed_sds(b, s, d, mesh, rules)
            batch["tokens"] = _tok_sds((b, s), mesh, rules)
        elif cfg.frontend == "vision":
            batch["embeds"] = _embed_sds(b, cfg.frontend_len, d, mesh, rules)
            batch["tokens"] = _tok_sds((b, s - cfg.frontend_len), mesh, rules)
        else:
            batch["tokens"] = _tok_sds((b, s), mesh, rules)
        batch["labels"] = _tok_sds(batch["tokens"].shape, mesh, rules)
        out["batch"] = batch
        return out

    if cell.kind == "prefill":
        batch = {}
        cache_len = s
        if cfg.is_encoder_decoder:
            # long source (the 32k audio), short decoder prime
            batch["enc_embeds"] = _embed_sds(b, s, d, mesh, rules)
            batch["tokens"] = _tok_sds((b, 128), mesh, rules)
            cache_len = 128
        elif cfg.frontend == "vision":
            batch["embeds"] = _embed_sds(b, cfg.frontend_len, d, mesh, rules)
            batch["tokens"] = _tok_sds((b, s - cfg.frontend_len), mesh, rules)
        else:
            batch["tokens"] = _tok_sds((b, s), mesh, rules)
        out["batch"] = batch
        cspecs = cache_specs(cfg, b, max_len=cache_len, enc_len=s)
        out["caches"] = abstract_tree(cspecs, mesh, rules)
        return out

    # decode
    enc_len = ENC_CONTEXT if cfg.is_encoder_decoder else 0
    cspecs = cache_specs(cfg, b, max_len=s, enc_len=enc_len)
    out["caches"] = abstract_tree(cspecs, mesh, rules)
    out["tokens"] = _tok_sds((b, 1), mesh, rules)
    out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    if cfg.is_encoder_decoder:
        out["enc_out"] = _embed_sds(b, enc_len, d, mesh, rules)
    return out
