from . import ckpt
from .fault_tolerance import FailureInjector, run_resilient

__all__ = ["ckpt", "FailureInjector", "run_resilient"]
