from .approx_linear import PROJ_CLASSES, ApproxPolicy, linear
from .config import LayerKind, ModelConfig, reduced
from .transformer import cache_specs, decode_step, encode, forward, param_specs

__all__ = [
    "ModelConfig", "LayerKind", "reduced",
    "ApproxPolicy", "linear", "PROJ_CLASSES",
    "param_specs", "cache_specs", "forward", "decode_step", "encode",
]
