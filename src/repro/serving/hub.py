"""ServingHub: one ServingEngine per accelerator, fed by a manager.

The hub is the glue between the search tier and the serving tier: it
lazily builds an engine the first time an accelerator is served (seeding
its catalog from the manager's merged global front), subscribes once to
the manager's front-update notifications so every engine hot-swaps when
a campaign improves its front, and aggregates per-engine stats for
``GET /serving/stats``.  ``service.campaigns.CampaignManager`` owns one
hub (created on first use) and closes it at shutdown.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence

from .. import obs
from .catalog import FrontCatalog, NoFrontError
from .engine import ServingEngine

__all__ = ["ServingHub"]

_log = obs.get_logger("repro.serving")


class ServingHub:
    """Engines keyed by accelerator name over one CampaignManager."""

    def __init__(self, manager, **engine_kw):
        self.manager = manager
        self.engine_kw = dict(engine_kw)
        self._engines: Dict[str, ServingEngine] = {}
        self._lock = threading.Lock()
        self._closed = False
        manager.subscribe_front(self._on_front)

    def engine_for(
        self,
        accel: str,
        objectives: Optional[Sequence[str]] = None,
        *,
        rank_genes: bool = False,
        create: bool = True,
    ) -> ServingEngine:
        """The engine serving ``accel``, building it (and its catalog,
        from the manager's merged global front) on first use.  Raises
        NoFrontError when no completed campaign has produced a front."""
        with self._lock:
            if self._closed:
                raise RuntimeError("serving hub is closed")
            eng = self._engines.get(accel)
        if eng is not None:
            return eng
        if not create:
            raise NoFrontError(f"no serving engine for {accel!r}")
        objectives = tuple(objectives or ("qor", "energy"))
        cat = FrontCatalog.from_manager(
            self.manager, accel, objectives, rank_genes=rank_genes,
        )
        if cat.empty:
            raise NoFrontError(
                f"no completed campaign has produced a front for "
                f"{accel!r} over objectives {list(objectives)}"
            )
        with self._lock:
            if self._closed:
                raise RuntimeError("serving hub is closed")
            eng = self._engines.get(accel)
            if eng is None:
                eng = ServingEngine(
                    accel, catalog=cat, rank_genes=rank_genes,
                    **self.engine_kw,
                )
                eng._manager = self.manager
                self._engines[accel] = eng
                _log.info("serving hub: engine for %s (%d-point front)",
                          accel, len(cat))
        return eng

    def _on_front(self, accel: str) -> None:
        """Manager callback: a campaign finished for ``accel`` — refresh
        the engine already serving it (never auto-creates one)."""
        with self._lock:
            eng = self._engines.get(accel)
        if eng is None:
            return
        try:
            eng.refresh_from(self.manager)
        except Exception:  # noqa: BLE001 - must not break the campaign tick
            _log.exception("serving hub: front refresh failed for %s", accel)

    def stats(self) -> Dict:
        with self._lock:
            engines = dict(self._engines)
        return {
            "engines": {name: eng.stats() for name, eng in engines.items()},
        }

    def close(self) -> None:
        with self._lock:
            self._closed = True
            engines = list(self._engines.values())
            self._engines.clear()
        for eng in engines:
            eng.close()
