"""Re-implementation of the state-of-the-art baseline ApproxFPGAs [15]
(Prabakaran et al., DAC'20), as used for the paper's Figs. 8 and 9.

ApproxFPGAs' strategy (as characterized by the paper §I/§IV):
  1. circuit-level DSE first — identify the ACs that are Pareto-optimal
     *in isolation* on the target platform (error vs hardware cost),
  2. restrict the accelerator search to combinations of those
     pre-filtered ACs,
  3. explore the (much smaller) restricted space.

The paper's criticism — which Figs. 8/9 substantiate — is that per-circuit
pre-filtering 'overlook[s] certain trade-offs that can prove to be
Pareto-optimal for the application'.  We reproduce that behaviour: the
restricted search explores the same budget of variants as autoXFPGAs'
final evaluation but only over the circuit-level Pareto set.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.acl.library import Circuit, Library, default_library
from ..core.features import synth
from ..core.pareto import non_dominated_mask
from .base import Accelerator

__all__ = ["circuit_level_front", "restricted_library", "approxfpgas_search"]


def circuit_level_front(library: Library, kind: str) -> List[Circuit]:
    """Per-circuit Pareto front on (error, TPU deployment cost) —
    error = mae, cost = the dtype-aware MXU deployment cost factor
    (DESIGN.md §9a).  The exact circuit is always on the front."""
    circuits = library.kind(kind)
    obj = np.array(
        [[c.stats.mae,
          (c.deploy_cost_factor() if c.kind != "add16"
           else float(16 - c.carry_window))]
         for c in circuits]
    )
    mask = non_dominated_mask(obj)
    front = [c for c, m in zip(circuits, mask) if m]
    if not any(c.is_exact for c in front):
        front.append(circuits[library.exact_index(kind)])
    return front


def restricted_library(library: Optional[Library] = None) -> Library:
    """The ApproxFPGAs-style pre-filtered library."""
    library = library or default_library()
    names: List[str] = []
    for kind in library.by_kind:
        names += [c.name for c in circuit_level_front(library, kind)]
    return library.subset(names)


def approxfpgas_search(
    accel: Accelerator,
    library: Optional[Library] = None,
    *,
    n_budget: int = 200,
    objectives: Tuple[str, ...] = ("qor", "energy"),
    rank_genes: bool = False,
    seed: int = 0,
    qor_inputs: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Library]:
    """Run the SoA baseline: random exploration of the restricted space
    with full synthesis labels (matching [15]'s final-evaluation budget).

    Returns (genomes, objectives, front_mask, restricted_lib); genomes are
    indices into the *restricted* library."""
    from ..core.dse import _objective_matrix

    full = library or default_library()
    rlib = restricted_library(full)
    rng = np.random.default_rng(seed)
    gene_sizes = accel.gene_sizes(rlib, rank_genes=rank_genes)
    genomes = rng.integers(0, gene_sizes[None, :], size=(n_budget, len(gene_sizes)))
    labels = synth.label_variants(
        accel, genomes, rlib, rank_genes=rank_genes,
        qor_inputs=qor_inputs, cache={},
    )
    obj = _objective_matrix(labels, objectives)
    return genomes, obj, non_dominated_mask(obj), rlib
