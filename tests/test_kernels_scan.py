"""Selective-scan kernel: Pallas (interpret) and the chunked associative
implementation vs the sequential oracle, swept over shapes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.selective_scan import (
    selective_scan_pallas,
    selective_scan_reference,
)
from repro.models.ssm import _selective_scan_chunked


def _inputs(rng, b, s, di, n):
    x = jnp.asarray(rng.standard_normal((b, s, di)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, di)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (di, n)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((b, di, n)) * 0.1, jnp.float32)
    return x, dt, A, B, C, h0


@pytest.mark.parametrize("b,s,di,n", [(1, 16, 8, 4), (2, 64, 32, 8),
                                      (1, 128, 16, 16)])
def test_pallas_scan_matches_reference(rng, b, s, di, n):
    x, dt, A, B, C, h0 = _inputs(rng, b, s, di, n)
    y_ref, h_ref = selective_scan_reference(x, dt, A, B, C, h0)
    y, hT = selective_scan_pallas(x, dt, A, B, C, h0, bd=di,
                                  chunk=min(32, s), interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_associative_matches_reference(rng, chunk):
    b, s, di, n = 2, 64, 16, 8
    x, dt, A, B, C, h0 = _inputs(rng, b, s, di, n)
    y_ref, h_ref = selective_scan_reference(x, dt, A, B, C, h0)
    y, hT = _selective_scan_chunked(x, dt, A, B, C, chunk, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


def test_pallas_scan_block_sweep(rng):
    b, s, di, n = 1, 64, 64, 4
    x, dt, A, B, C, h0 = _inputs(rng, b, s, di, n)
    y_ref, _ = selective_scan_reference(x, dt, A, B, C, h0)
    for bd in (16, 32, 64):
        for chunk in (16, 32):
            y, _ = selective_scan_pallas(x, dt, A, B, C, h0, bd=bd,
                                         chunk=chunk, interpret=True)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       rtol=1e-5, atol=1e-5, err_msg=f"{bd},{chunk}")
