"""jax version compatibility for mesh construction and mesh contexts.

The launch/dryrun drivers (and the distributed tests) target the newer
explicit-mesh API (``jax.make_mesh(..., axis_types=...)`` +
``jax.set_mesh``).  Older jax (<= 0.4.x, what this container ships)
predates ``AxisType``/``set_mesh``; there the legacy ``with mesh:``
context provides the ambient mesh that ``dist.sharding.constrain``
reads.  Everything mesh-shaped in this repo goes through these two
helpers instead of calling jax directly."""

from __future__ import annotations

from typing import Sequence, Tuple

__all__ = ["make_mesh", "mesh_context", "compiled_cost_analysis", "opt_barrier"]


def make_mesh(shape: Tuple[int, ...], axes: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where supported."""
    import jax

    axes = tuple(axes)
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (AttributeError, TypeError):
        pass
    if hasattr(jax, "make_mesh"):  # >= 0.4.35, no AxisType yet
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils  # pre-make_mesh versions

    devices = mesh_utils.create_device_mesh(shape)
    return jax.sharding.Mesh(devices, axes)


def mesh_context(mesh):
    """Context manager making ``mesh`` ambient: ``jax.set_mesh`` on new
    jax, ``jax.sharding.use_mesh`` on the transitional releases that
    shipped it first, the legacy ``with mesh:`` resource context
    otherwise."""
    import jax

    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # Mesh is itself a context manager on older jax


def compiled_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict.  Older jax returns a
    one-element list of dicts (per computation); newer returns the dict
    directly."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def opt_barrier(tree):
    """``jax.lax.optimization_barrier`` that is differentiable on every
    jax version (older jax has no VJP rule for the barrier primitive —
    wrap it in a custom VJP that barriers the cotangent too)."""
    import jax

    return _build_barrier(jax)(tree)


def _build_barrier(jax):
    global _BARRIER
    if _BARRIER is None:
        @jax.custom_vjp
        def barrier(tree):
            return jax.lax.optimization_barrier(tree)

        def fwd(tree):
            return jax.lax.optimization_barrier(tree), None

        def bwd(_res, g):
            return (jax.lax.optimization_barrier(g),)

        barrier.defvjp(fwd, bwd)
        _BARRIER = barrier
    return _BARRIER


_BARRIER = None
