"""Deterministic synthetic token pipeline.

Every (seed, step, row) is independently addressable: any host can
recompute any shard of any batch without coordination.  That property is
the straggler/elasticity story (DESIGN.md §5): on a resize or a restart
from step k, hosts regenerate exactly the batches they now own — no data
state to checkpoint, no skew between replicas.

Sequences are learnable-but-nontrivial: each row is a noisy modular
arithmetic progression (next = prev + stride mod V, per-row stride), so
small models show decreasing loss within a few hundred steps (used by the
examples).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["TokenPipeline"]


def _row_rng(seed: int, step: int, row: int) -> np.random.Generator:
    # Philox is counter-based: cheap keyed access, no sequential state
    return np.random.Generator(np.random.Philox(key=seed, counter=[0, 0, step, row]))


@dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    noise: float = 0.05

    def row(self, step: int, r: int) -> np.ndarray:
        rng = _row_rng(self.seed, step, r)
        v = self.vocab_size
        start = int(rng.integers(0, v))
        stride = int(rng.integers(1, min(v, 97)))
        seq = (start + stride * np.arange(self.seq_len + 1)) % v
        flips = rng.random(self.seq_len + 1) < self.noise
        seq = np.where(flips, rng.integers(0, v, self.seq_len + 1), seq)
        return seq.astype(np.int32)

    def batch_at(
        self, step: int, *, rows: Optional[range] = None
    ) -> Dict[str, np.ndarray]:
        """Full global batch (or the given row range for one host's shard)."""
        rows = rows if rows is not None else range(self.batch)
        data = np.stack([self.row(step, r) for r in rows])
        return {"tokens": data[:, :-1], "labels": data[:, 1:]}
