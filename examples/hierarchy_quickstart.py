"""Hierarchical-search quickstart: a staged pipeline searched per stage.

    PYTHONPATH=src python examples/hierarchy_quickstart.py

Submits a hierarchical job for the ``smoothed_dct`` pipeline (Gaussian
3x3 pre-filter -> HEVC 4x4 DCT) to an in-process CampaignManager: one
DSE campaign runs PER STAGE (concurrently, sharing the label store), the
per-stage Pareto fronts are composed with incremental non-dominated
pruning, and only the composed candidates are re-labeled end-to-end.
The printed front is application-level ground truth.

Set REPRO_SMOKE=1 for the CI-sized fast mode.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.service import CampaignManager, HierarchicalSpec

SMOKE = bool(os.environ.get("REPRO_SMOKE"))

SPEC = dict(
    accel="smoothed_dct",
    n_train=8 if SMOKE else 24,
    n_qor_samples=2,
    pop_size=8 if SMOKE else 24,
    n_parents=4 if SMOKE else 12,
    n_generations=1 if SMOKE else 4,
    k_per_stage=4 if SMOKE else 10,
    max_candidates=8 if SMOKE else 24,
)


def main():
    mgr = CampaignManager(eval_workers=2, campaign_workers=2)
    print(f"submitting hierarchical job: {SPEC}")
    cid = mgr.submit_hierarchical(HierarchicalSpec(**SPEC))
    state = mgr.wait(cid, timeout=3600)
    assert state == "done", mgr.status(cid).get("error")

    st = mgr.status(cid)
    res = mgr.result(cid)
    gt = st["ground_truth_calls"]
    print(f"\nstage campaigns: {st['stage_campaigns']} "
          f"(max {st['max_concurrent_stages']} in flight)")
    print(f"ground truth: {gt['stage_campaigns']} stage + {gt['final']} "
          f"end-to-end = {gt['total']} calls "
          f"(flat space {st['flat_space_size']:.2e})")
    cs = res.compose_stats
    print(f"composition: stage fronts {cs.stage_sizes} -> "
          f"{cs.pairs_evaluated} pairs -> {cs.survivors} survivors")

    front = res.front_objectives
    print(f"\nverified application front ({len(front)} designs, "
          f"PSNR dB vs energy J):")
    for i in np.argsort(front[:, 0])[:10]:
        print(f"  psnr={-front[i, 0]:7.2f}  energy={front[i, 1]:.3e}")
    mgr.shutdown()


if __name__ == "__main__":
    main()
