"""Serving driver: batched prefill + autoregressive decode, CPU-runnable
at reduced scale.

    PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import ApproxPolicy, reduced
from ..models.common import init_tree
from ..models.transformer import cache_specs, param_specs
from ..train.serve import make_decode_step, make_prefill_step

__all__ = ["serve_batch", "main"]


def serve_batch(
    cfg,
    *,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    policy: ApproxPolicy | None = None,
    seed: int = 0,
):
    """Greedy-decode `gen` tokens for a batch of synthetic prompts.
    Returns (tokens (b, prompt+gen), tokens/s)."""
    key = jax.random.PRNGKey(seed)
    params = init_tree(param_specs(cfg), key)
    vis = cfg.frontend_len if cfg.frontend == "vision" else 0
    max_len = prompt_len + gen + vis
    enc_len = 16 if cfg.is_encoder_decoder else 0
    caches = init_tree(cache_specs(cfg, batch, max_len, enc_len=enc_len), key)

    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    batch_in = {"tokens": prompts}
    if cfg.is_encoder_decoder:
        batch_in["enc_embeds"] = jax.random.normal(
            key, (batch, enc_len, cfg.d_model), jnp.float32) * 0.1
    if cfg.frontend == "vision":
        batch_in["embeds"] = jax.random.normal(
            key, (batch, cfg.frontend_len, cfg.d_model), jnp.float32) * 0.1

    prefill = jax.jit(make_prefill_step(cfg, policy=policy, attn_chunk=32,
                                        scan_chunk=8))
    decode = jax.jit(make_decode_step(cfg, policy=policy))

    # NOTE: prefill writes K/V at positions [0, prompt_len) of the cache
    out = prefill(params, batch_in, caches)
    enc_out = None
    if cfg.is_encoder_decoder:
        logits, caches, enc_out = out
    else:
        logits, caches = out
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]

    toks = [prompts, nxt]
    pos0 = prompt_len + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    t0 = time.perf_counter()
    for i in range(gen - 1):
        nxt, logits, caches = decode(
            params, caches, nxt, jnp.int32(pos0 + i), enc_out=enc_out
        )
        toks.append(nxt)
    dt = time.perf_counter() - t0
    tokens = jnp.concatenate(toks, axis=1)
    tps = batch * (gen - 1) / max(dt, 1e-9)
    return tokens, tps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="falcon-mamba-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--approx", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    policy = None
    if args.approx:
        policy = ApproxPolicy({
            "ffn_in": (args.approx, None), "ffn_out": (args.approx, None),
        })
    tokens, tps = serve_batch(
        cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen,
        policy=policy,
    )
    print(f"[serve] {cfg.name}: generated {tokens.shape} @ {tps:.1f} tok/s")
    print(tokens[0])


if __name__ == "__main__":
    main()
