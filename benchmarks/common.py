"""Shared benchmark utilities: timing + the CSV row protocol.

Every benchmark prints rows:  name,us_per_call,derived
where `derived` is the benchmark's headline quantity (PCC, hypervolume
ratio, roofline fraction, ...).
"""

from __future__ import annotations

import sys
import time
from typing import Callable, Optional

ROWS = []


def emit(name: str, us_per_call: float, derived) -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn: Callable, *args, repeat: int = 3, warmup: int = 1, **kw) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args, **kw)
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def section(title: str) -> None:
    print(f"# --- {title} ---", file=sys.stderr, flush=True)
