"""JSONL span sink → Chrome trace event JSON (Perfetto-loadable).

``python -m repro.obs.export --chrome-trace runs/dse.trace.jsonl``
writes ``runs/dse.trace.json`` with complete ("X") events: one slice
per span, placed on the pid/tid track it ran on, with the trace/span/
parent ids and correlation baggage in ``args`` so Perfetto's query/
flow UI can follow a campaign across the service process, the labeler
pool's worker processes, and fleet worker hosts.

Torn tails are expected (the sink is append-only and runs die): bad
lines are skipped and counted, never fatal.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterable, List, Tuple

__all__ = ["load_jsonl", "to_chrome_trace", "main"]


def load_jsonl(path: str) -> Tuple[List[Dict], int]:
    """Parse a span sink file; returns (spans, skipped_lines)."""
    spans: List[Dict] = []
    skipped = 0
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                skipped += 1
                continue
            if isinstance(rec, dict) and "name" in rec and "t0" in rec:
                spans.append(rec)
            else:
                skipped += 1
    return spans, skipped


def to_chrome_trace(spans: Iterable[Dict]) -> Dict:
    """Chrome trace-event format: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU"""
    events: List[Dict] = []
    procs: Dict[int, str] = {}
    for rec in spans:
        pid = int(rec.get("pid", 0))
        tid = int(rec.get("tid", 0))
        attrs = rec.get("attrs") or {}
        name = str(rec.get("name", "?"))
        events.append({
            "ph": "X",
            "name": name,
            "cat": name.split(".", 1)[0],
            "ts": float(rec["t0"]) * 1e6,          # µs epoch
            "dur": max(float(rec.get("dur", 0.0)) * 1e6, 1.0),
            "pid": pid,
            "tid": tid,
            "args": {
                "trace": rec.get("trace"),
                "span": rec.get("span"),
                "parent": rec.get("parent"),
                **attrs,
            },
        })
        if pid not in procs:
            w = attrs.get("worker")
            procs[pid] = f"fleet worker {w} (pid {pid})" if w else f"pid {pid}"
    for pid, label in procs.items():
        events.append({
            "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "args": {"name": label},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="Convert a --trace JSONL span sink for trace viewers.",
    )
    ap.add_argument("input", help="span sink file (JSONL, one span per line)")
    ap.add_argument(
        "--chrome-trace", action="store_true",
        help="emit Chrome trace event JSON (open in Perfetto / about:tracing)",
    )
    ap.add_argument(
        "-o", "--output", default=None,
        help="output path (default: <input minus .jsonl>.trace.json)",
    )
    args = ap.parse_args(argv)
    if not args.chrome_trace:
        ap.error("pick an output format (--chrome-trace)")
    spans, skipped = load_jsonl(args.input)
    out = args.output
    if out is None:
        base = args.input
        if base.endswith(".jsonl"):
            base = base[: -len(".jsonl")]
        if base.endswith(".trace"):
            base = base[: -len(".trace")]
        out = base + ".trace.json"
    doc = to_chrome_trace(spans)
    with open(out, "w", encoding="utf-8") as fh:
        json.dump(doc, fh)
    traces = {s.get("trace") for s in spans}
    print(
        f"[obs.export] {len(spans)} spans ({len(traces)} traces, "
        f"{skipped} bad lines skipped) -> {out}",
        file=sys.stderr,
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
