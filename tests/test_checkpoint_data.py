"""Checkpointing, fault tolerance, data-pipeline determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import FailureInjector, ckpt, run_resilient
from repro.data.pipeline import TokenPipeline


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 10, (3,)), jnp.int32),
                   "c": jnp.asarray(rng.standard_normal(()), jnp.float32)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    assert ckpt.latest_step(str(tmp_path)) == 7
    back = ckpt.restore(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_ignores_partial(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    # simulate a crash mid-write: orphan .tmp directory
    os.makedirs(tmp_path / "step_000000009.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_latest_of_many(tmp_path):
    t = _tree()
    for s in (1, 10, 3):
        ckpt.save(str(tmp_path), s, t)
    assert ckpt.list_steps(str(tmp_path)) == [1, 3, 10]
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_run_resilient_recovers_and_matches(tmp_path):
    """Injected failures + restart produce the same final state as an
    uninterrupted run (determinism across restarts)."""

    def init():
        return {"x": jnp.zeros(()), "step_sum": jnp.zeros(())}

    def step_fn(state, step):
        pipe = TokenPipeline(97, 4, 8, seed=0)
        b = pipe.batch_at(step)
        inc = float(b["tokens"].sum() % 1000)
        return (
            {"x": state["x"] + 1.0, "step_sum": state["step_sum"] + inc},
            {"inc": inc},
        )

    clean, _ = run_resilient(init, step_fn, n_steps=20,
                             ckpt_dir=str(tmp_path / "clean"), ckpt_every=5)
    inj = FailureInjector(fail_at=[7, 13])
    faulty, report = run_resilient(init, step_fn, n_steps=20,
                                   ckpt_dir=str(tmp_path / "faulty"),
                                   ckpt_every=5, injector=inj)
    assert report.restarts == 2
    assert float(faulty["x"]) == float(clean["x"]) == 20.0
    assert float(faulty["step_sum"]) == pytest.approx(float(clean["step_sum"]))


def test_restart_budget_enforced(tmp_path):
    def init():
        return {"x": jnp.zeros(())}

    def bad_step(state, step):
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError, match="restart budget"):
        run_resilient(init, bad_step, n_steps=5,
                      ckpt_dir=str(tmp_path), max_restarts=2)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_row_addressable():
    p = TokenPipeline(1000, batch=8, seq_len=16, seed=42)
    b1 = p.batch_at(3)
    b2 = p.batch_at(3)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    # any host can recompute just its rows (straggler/elastic story)
    sub = p.batch_at(3, rows=range(2, 5))
    assert np.array_equal(sub["tokens"], b1["tokens"][2:5])
    # labels are next-token targets
    row = p.row(3, 0)
    assert np.array_equal(b1["tokens"][0], row[:-1])
    assert np.array_equal(b1["labels"][0], row[1:])


def test_pipeline_steps_differ():
    p = TokenPipeline(1000, batch=2, seq_len=32, seed=0)
    assert not np.array_equal(p.batch_at(0)["tokens"], p.batch_at(1)["tokens"])


def test_pipeline_learnable_structure():
    """Consecutive deltas are mostly a constant stride (learnable)."""
    p = TokenPipeline(1000, batch=1, seq_len=64, seed=1, noise=0.0)
    t = p.row(0, 0)
    deltas = np.diff(t) % 1000
    assert (deltas == deltas[0]).mean() == 1.0
