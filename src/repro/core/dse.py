"""End-to-end DSE driver — the three framework stages of paper Fig. 2:

  1. Model Training       sample + label n_train random variants (XLA
                          synthesis + behavioral sim), build the pipeline's
                          feature extractor, fit the two surrogates.
  2. Architecture          NSGA-II over the genome space, objectives
     Exploration           evaluated by the surrogates only.
  3. Final Evaluation      the surviving parent set is re-synthesized and
                          re-simulated; the *true* Pareto front is returned.

Every stage is timed; the result object carries everything the Fig. 5/7/8/9
benchmarks need.

The loop itself lives in ``core.strategies`` as an ask/tell state machine
(``Campaign`` + pluggable ``SearchStrategy``); ``run_dse`` and
``random_search`` are its drive-to-completion wrappers and return results
byte-identical to the historical blocking implementations.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (accel depends on core.acl)
    from ..accel.base import Accelerator
from .acl.library import Library, default_library
from .features import synth
from .nsga2 import NSGA2Config, NSGA2Result
from .pareto import non_dominated_mask

__all__ = ["DSEConfig", "DSEResult", "run_dse", "random_search",
           "default_labeler", "label_unique"]

# A labeler maps a (n, g) genome batch to the ground-truth label dict of
# synth.label_variants.  run_dse takes one by injection so the labeling
# substrate is swappable: the default is the old in-process path (per-call
# synthesis cache, discarded at return); the service layer
# (repro.service) injects a scheduler-backed labeler with a persistent
# cross-campaign store, in-flight dedup and coalesced batching.


def default_labeler(
    accel: "Accelerator",
    library: Library,
    *,
    rank_genes: bool = False,
    n_qor_samples: int = 4,
    qor_seed: int = synth.DEFAULT_QOR_SEED,
    cache: Optional[dict] = None,
):
    """The in-process labeler ``run_dse`` uses when none is injected."""
    synth_cache = {} if cache is None else cache
    qor_inputs = accel.sample_inputs(n_qor_samples, seed=qor_seed)

    def labeler(genomes: np.ndarray) -> Dict[str, np.ndarray]:
        return synth.label_variants(
            accel, genomes, library,
            rank_genes=rank_genes, qor_inputs=qor_inputs, cache=synth_cache,
        )

    return labeler


def label_unique(labeler, genomes: np.ndarray) -> Dict[str, np.ndarray]:
    """Label a batch paying ground truth only for UNIQUE genomes.

    NSGA-II survivor sets routinely contain repeated genomes (elitism
    keeps copies of strong designs); labels are a pure function of the
    genome, so duplicates are labeled once and scattered back."""
    genomes = np.atleast_2d(genomes)
    uniq, inverse = np.unique(genomes, axis=0, return_inverse=True)
    labels = labeler(uniq)
    # scatter back (also undoes np.unique's row sort)
    return {k: np.asarray(v)[inverse] for k, v in labels.items()}


@dataclass(frozen=True)
class DSEConfig:
    pipeline: str = "D"                     # paper's winner
    hw_model: str = "bayesian_ridge"        # paper Fig. 6: best for power
    qor_model: str = "random_forest"        # paper Fig. 6: best for QoR
    strategy: str = "nsga2"                 # explorer (strategies registry)
    objectives: Tuple[str, ...] = ("qor", "energy")  # qor auto-negated
    n_train: int = 1000                     # paper: 1000 random variants
    n_qor_samples: int = 4
    rank_genes: bool = False                # beyond-paper axis
    # beyond-paper: seed half the NSGA-II population from the
    # circuit-level Pareto subspace (the SoA's pre-filter, used as a
    # warm start instead of a hard restriction) — on the TPU the slot
    # costs are separable, so that subspace is a strong prior while the
    # full-space search still covers interactions the pre-filter misses
    warm_start: bool = True
    nsga: NSGA2Config = field(default_factory=NSGA2Config)
    seed: int = 0


@dataclass
class DSEResult:
    accel_name: str
    config: DSEConfig
    # stage 1
    train_genomes: np.ndarray
    train_labels: Dict[str, np.ndarray]
    val_pcc: Dict[str, float]
    # stage 2
    search: NSGA2Result
    est_objectives: np.ndarray          # surrogate objectives of parents
    # stage 3
    final_labels: Dict[str, np.ndarray]
    true_objectives: np.ndarray
    front_mask: np.ndarray
    timings: Dict[str, float]

    @property
    def front_genomes(self) -> np.ndarray:
        return self.search.genomes[self.front_mask]

    @property
    def front_objectives(self) -> np.ndarray:
        return self.true_objectives[self.front_mask]


def _objective_matrix(labels: Dict[str, np.ndarray], names: Sequence[str]) -> np.ndarray:
    cols = []
    for nm in names:
        v = np.asarray(labels[nm], dtype=np.float64)
        cols.append(-v if nm == "qor" else v)  # maximize QoR -> minimize -QoR
    return np.stack(cols, axis=1)


def run_dse(
    accel: Accelerator,
    library: Optional[Library] = None,
    cfg: Optional[DSEConfig] = None,
    *,
    labeler=None,
    surrogate_provider=None,
    strategy=None,
    verbose: bool = False,
) -> DSEResult:
    """The three-stage DSE, driven to completion.  ``labeler`` (genomes
    -> label dict) and ``surrogate_provider`` ((obj, model_name, X, y) ->
    fitted model) are injectable so the service layer can swap in its
    persistent label store / coalescing scheduler / warm surrogate
    registry; ``strategy`` picks the explorer (a ``strategies`` registry
    name, a factory, or None for ``cfg.strategy``).  The defaults
    reproduce the classic one-shot in-process behavior exactly.

    This is now a thin wrapper over the ask/tell ``strategies.Campaign``
    state machine — interruptible callers (the campaign service) step
    and snapshot the campaign themselves."""
    from .strategies.campaign import Campaign, drive

    cfg = cfg if cfg is not None else DSEConfig()
    library = library or default_library()
    if labeler is None:
        labeler = default_labeler(
            accel, library,
            rank_genes=cfg.rank_genes, n_qor_samples=cfg.n_qor_samples,
        )
    campaign = Campaign(
        accel, library, cfg,
        strategy=strategy,
        surrogate_provider=surrogate_provider,
        verbose=verbose,
    )
    return drive(campaign, labeler)


def random_search(
    accel: Accelerator,
    library: Optional[Library] = None,
    *,
    n: int = 1000,
    objectives: Tuple[str, ...] = ("qor", "energy"),
    rank_genes: bool = False,
    seed: int = 0,
    labeler=None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Baseline for Figs. 8/9: label n random variants, return
    (genomes, objectives, front_mask).

    Drives a ``RandomStrategy`` through a ground-truth ``Campaign`` (no
    surrogates, no final stage) — one ask covering the whole budget, so
    the labeler sees exactly the legacy unique batch."""
    from .strategies.campaign import Campaign, drive
    from .strategies.random import RandomStrategy

    library = library or default_library()
    # same default labeler as run_dse (QoR inputs from DEFAULT_QOR_SEED),
    # so injected-labeler and in-process baselines are apples-to-apples
    if labeler is None:
        labeler = default_labeler(accel, library, rank_genes=rank_genes)
    cfg = DSEConfig(objectives=tuple(objectives), rank_genes=rank_genes,
                    seed=seed)
    campaign = Campaign(
        accel, library, cfg,
        strategy=lambda sizes, _cfg, init=None: RandomStrategy(
            sizes, n_total=n, seed=seed),
        ground_truth_explore=True,
    )
    genomes, obj, mask, _labels = drive(campaign, labeler)
    return genomes, obj, mask
