"""The six model-training pipelines of paper Fig. 3 (A)–(F).

  A: exhaustive — every variant synthesized (no surrogate).  PCC = 1 by
     construction; time = |space| x t_synth.
  B: per-AC features from *synthesis* (Vivado->XLA analogue), composed to
     variant features; surrogate trained on synth-labeled sample.
  C: per-AC features from the *cheap* extractor (ABC analogue), composed.
  D: cheap per-AC features + cheap accelerator-level features (the
     paper's winner).
  E: synth per-AC features + cheap accelerator-level features.
  F: cheap accelerator-level features only.

``build_extractor`` returns a vectorized genomes->X function plus its
setup cost; ``evaluate_pipeline`` reproduces one Fig. 5 bar: train the
surrogate on a labeled sample, report test PCC and per-variant
exploration time.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import numpy as np

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import
    from ...accel.base import Accelerator
from ...core.acl.library import Library
from ..surrogates import make, pcc
from . import cheap, synth

__all__ = ["PIPELINES", "Extractor", "build_extractor", "evaluate_pipeline"]

PIPELINES = ("A", "B", "C", "D", "E", "F")


@dataclass
class Extractor:
    pipeline: str
    extract: Callable[[np.ndarray], np.ndarray]   # genomes -> (n, d)
    setup_time: float                              # one-time feature setup
    per_variant_time: float = 0.0                  # measured at first call

    def __call__(self, genomes: np.ndarray) -> np.ndarray:
        t0 = time.perf_counter()
        X = self.extract(np.atleast_2d(genomes))
        dt = time.perf_counter() - t0
        self.per_variant_time = dt / max(len(np.atleast_2d(genomes)), 1)
        return X


def _ac_feature_tables(
    accel: Accelerator, library: Library, mode: str
) -> Dict[str, np.ndarray]:
    """{kind: (n_circuits, d)} per-AC feature tables, cheap or synth."""
    kinds = sorted({s.kind for s in accel.slots})
    out = {}
    for kind in kinds:
        rows = []
        for c in library.kind(kind):
            if mode == "cheap":
                rows.append(cheap.circuit_features_cheap(c))
            else:
                rows.append(synth.circuit_features_synth(c)[:-1])  # drop wall
        out[kind] = np.stack(rows)
    return out


def build_extractor(
    pipeline: str,
    accel: Accelerator,
    library: Library,
    *,
    rank_genes: bool = False,
) -> Extractor:
    pipeline = pipeline.upper()
    assert pipeline in PIPELINES
    t0 = time.perf_counter()
    ac_tables = None
    accel_level = pipeline in ("D", "E", "F")
    if pipeline in ("B", "E"):
        ac_tables = _ac_feature_tables(accel, library, "synth")
    elif pipeline in ("C", "D"):
        ac_tables = _ac_feature_tables(accel, library, "cheap")
    setup = time.perf_counter() - t0

    if pipeline == "A":
        def extract(genomes):
            raise RuntimeError(
                "pipeline A has no feature extractor: every variant is "
                "synthesized (use features.synth.label_variants)"
            )
        return Extractor("A", extract, setup)

    def extract(genomes):
        return cheap.variant_features(
            accel,
            genomes,
            library,
            ac_features=ac_tables,
            accel_level=accel_level,
            rank_genes=rank_genes,
        )

    return Extractor(pipeline, extract, setup)


@dataclass
class PipelineReport:
    pipeline: str
    pcc_hw: float                  # correlation on the hardware target
    pcc_qor: float
    setup_time: float
    per_variant_time: float        # feature+predict per variant (s)
    train_time: float
    explore_time_1m: float         # extrapolated exploration of 1e6 variants
    details: dict = field(default_factory=dict)


def evaluate_pipeline(
    pipeline: str,
    accel: Accelerator,
    library: Library,
    train_genomes: np.ndarray,
    train_labels: Dict[str, np.ndarray],
    test_genomes: np.ndarray,
    test_labels: Dict[str, np.ndarray],
    *,
    hw_target: str = "energy",
    hw_model: str = "bayesian_ridge",
    qor_model: str = "random_forest",
    rank_genes: bool = False,
    synth_time_per_variant: Optional[float] = None,
) -> PipelineReport:
    """One Fig. 5 bar: PCC + exploration-time for a pipeline."""
    if pipeline == "A":
        tpv = synth_time_per_variant or float(
            np.mean(train_labels["synth_time"] + train_labels["sim_time"])
        )
        return PipelineReport(
            pipeline="A",
            pcc_hw=1.0,
            pcc_qor=1.0,
            setup_time=0.0,
            per_variant_time=tpv,
            train_time=0.0,
            explore_time_1m=tpv * 1e6,
        )

    ext = build_extractor(pipeline, accel, library, rank_genes=rank_genes)
    Xtr = ext(train_genomes)
    Xte = ext(test_genomes)

    t0 = time.perf_counter()
    m_hw = make(hw_model).fit(Xtr, train_labels[hw_target])
    m_qor = make(qor_model).fit(Xtr, train_labels["qor"])
    train_time = time.perf_counter() - t0

    t0 = time.perf_counter()
    pred_hw = m_hw.predict(Xte)
    pred_qor = m_qor.predict(Xte)
    predict_time = (time.perf_counter() - t0) / max(len(test_genomes), 1)

    per_variant = ext.per_variant_time + predict_time
    return PipelineReport(
        pipeline=pipeline,
        pcc_hw=pcc(test_labels[hw_target], pred_hw),
        pcc_qor=pcc(test_labels["qor"], pred_qor),
        setup_time=ext.setup_time,
        per_variant_time=per_variant,
        train_time=train_time,
        explore_time_1m=ext.setup_time + train_time + per_variant * 1e6,
        details={"hw_model": hw_model, "qor_model": qor_model},
    )
