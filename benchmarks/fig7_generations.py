"""Fig. 7 — per-generation front analysis: the paper's own observation
that near-optimal solutions appear early (motivating a further 10x
exploration-time cut).

Derived metric: the first generation reaching 95% of the final
hypervolume (expected << total generations)."""

from __future__ import annotations

import numpy as np

from repro.accel import HEVCDct
from repro.core.acl.library import default_library
from repro.core.dse import DSEConfig, run_dse
from repro.core.nsga2 import NSGA2Config
from repro.core.pareto import hypervolume_2d

from .common import emit, time_fn


def run(generations: int = 20, pop: int = 64, n_train: int = 50, seed: int = 0):
    lib = default_library()
    accel = HEVCDct()
    cfg = DSEConfig(
        n_train=n_train, n_qor_samples=2,
        nsga=NSGA2Config(pop_size=pop, n_parents=max(pop // 4, 8),
                         n_generations=generations, seed=seed),
        seed=seed,
    )
    res = run_dse(accel, lib, cfg)

    # hypervolume of the surrogate-estimated front per generation
    all_obj = np.concatenate([lg.objectives for lg in res.search.history])
    ref = all_obj.max(axis=0) + 1e-9
    hvs = []
    for lg in res.search.history:
        hvs.append(hypervolume_2d(lg.objectives[:, :2], ref[:2]))
    hvs = np.maximum.accumulate(np.asarray(hvs))
    final = hvs[-1] if hvs[-1] > 0 else 1.0
    first95 = int(np.argmax(hvs >= 0.95 * final))

    emit("fig7.generations", 0.0, generations)
    emit("fig7.first_gen_at_95pct_hv", 0.0, first95)
    emit("fig7.early_convergence",
         0.0, int(first95 <= max(generations // 2, 1)))
    emit("fig7.final_front_size", 0.0, int(res.front_mask.sum()))
    for g in (0, generations // 2, generations - 1):
        emit(f"fig7.hv_gen{g}", 0.0, round(float(hvs[g] / final), 4))
    return first95, hvs
