"""Multi-host cluster bootstrap — the piece that turns the single-process
drivers into a real pod launch.

On a TPU pod each host runs the same program; JAX's distributed runtime
assembles the global device mesh. This module:

  * initializes jax.distributed from standard env vars
    (COORDINATOR_ADDRESS, NUM_PROCESSES, PROCESS_ID — or TPU metadata
    auto-detection when none are set),
  * computes each host's shard of the global batch (the data pipeline is
    counter-based, so hosts need no coordination — straggler/elastic
    story, DESIGN.md §5),
  * wraps train_loop/serve_batch with host-local data feeding via
    jax.make_array_from_process_local_data.

    # per host (example: 2 pods x 64 hosts x 4 chips):
    COORDINATOR_ADDRESS=host0:1234 NUM_PROCESSES=128 PROCESS_ID=$i \
      python -m repro.launch.cluster --arch granite-8b --steps 1000

scripts/launch_pod.sh shows the full invocation.
"""

from __future__ import annotations

import argparse
import os
from typing import Optional

__all__ = ["init_distributed", "host_rows", "main"]


def init_distributed() -> tuple:
    """Initialize jax.distributed from the environment; returns
    (process_index, process_count).  No-op fallback for single-process
    (CPU container) runs so the module stays testable offline."""
    import jax

    coord = os.environ.get("COORDINATOR_ADDRESS")
    nproc = os.environ.get("NUM_PROCESSES")
    pid = os.environ.get("PROCESS_ID")
    if coord and nproc:
        jax.distributed.initialize(
            coordinator_address=coord,
            num_processes=int(nproc),
            process_id=int(pid or 0),
        )
    elif os.environ.get("TPU_WORKER_HOSTNAMES"):
        jax.distributed.initialize()  # TPU metadata auto-detection
    return jax.process_index(), jax.process_count()


def host_rows(global_batch: int, process_index: int, process_count: int) -> range:
    """The contiguous row range of the global batch this host produces.
    Contiguity matches the mesh's device order so host data lands on the
    host's own devices (no cross-host scatter)."""
    per = global_batch // process_count
    return range(process_index * per, (process_index + 1) * per)


def make_global_batch(pipe, step: int, mesh, rules=None):
    """Assemble the globally-sharded batch from host-local rows."""
    import jax
    import jax.numpy as jnp

    from ..dist.sharding import sharding_for

    pi, pc = jax.process_index(), jax.process_count()
    local = pipe.batch_at(step, rows=host_rows(pipe.batch, pi, pc))
    out = {}
    for k, v in local.items():
        gshape = (pipe.batch,) + v.shape[1:]
        sh = sharding_for(("batch",) + (None,) * (v.ndim - 1), gshape,
                          mesh, rules)
        if pc == 1:
            out[k] = jax.device_put(jnp.asarray(v), sh)
        else:
            out[k] = jax.make_array_from_process_local_data(sh, v, gshape)
    return out


def main(argv: Optional[list] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (single-host validation)")
    args = ap.parse_args(argv)

    pi, pc = init_distributed()
    import jax

    print(f"[cluster] process {pi}/{pc}, local devices: "
          f"{jax.local_device_count()}, global: {jax.device_count()}")

    from ..configs import get_config
    from ..data.pipeline import TokenPipeline
    from ..dist.sharding import rule_overrides
    from ..models import reduced as reduce_cfg
    from ..models.common import abstract_tree, init_tree
    from ..models.transformer import param_specs
    from ..optim.adamw import AdamW
    from ..train.step import init_state, make_train_step
    from ..checkpoint import ckpt
    from .mesh import make_production_mesh
    from .shapes import cell_rules, n_microbatches

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)

    if jax.device_count() >= 512:
        mesh = make_production_mesh(multi_pod=True)
    elif jax.device_count() >= 256:
        mesh = make_production_mesh()
    else:  # validation mesh on whatever is available
        from ..dist.compat import make_mesh

        mesh = make_mesh((jax.device_count(),), ("data",))

    rules = cell_rules(cfg, "train_4k", mesh)
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, seed=0)
    opt = AdamW(moment_dtype=cfg.moment_dtype)
    nm = n_microbatches(cfg, mesh) if not args.reduced else 1
    step_fn = jax.jit(
        make_train_step(cfg, opt, n_micro=nm), donate_argnums=(0,)
    )

    from ..dist.compat import mesh_context

    with mesh_context(mesh), rule_overrides(rules):
        specs = param_specs(cfg)
        latest = ckpt.latest_step(args.ckpt_dir) if pi == 0 else None
        params = init_tree(specs, jax.random.PRNGKey(0))
        state = init_state(params, opt)
        start = 0
        if latest is not None:
            state = ckpt.restore(args.ckpt_dir, latest, state)
            start = latest
            print(f"[cluster] restored step {latest}")
        for step in range(start, args.steps):
            batch = make_global_batch(pipe, step, mesh, rules)
            state, metrics = step_fn(state, batch)
            if step % 10 == 0 and pi == 0:
                print(f"[cluster] step {step} loss={float(metrics['loss']):.4f}",
                      flush=True)
            if (step + 1) % 100 == 0 and pi == 0:
                ckpt.save(args.ckpt_dir, step + 1, state)


if __name__ == "__main__":
    main()
