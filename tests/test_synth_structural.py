"""Structural synthesis engine (core/features/synth.py): signature-keyed
compile reuse is LABEL-EXACT — structurally-equal specs from different
named circuits compile to identical cost numbers, ``synthesize_batch``
reproduces the serial per-genome loop byte-identically across every
registered accelerator (incl. staged pipelines and stage views), the
persistent JSONL compile cache does ZERO compiles on a warm rerun, the
first-K verification scheme and its kill switch behave, and a stage
view shares the standalone accelerator's compiles."""

import numpy as np
import pytest

from repro.accel import GaussianFilter, HEVCDct, MCMAccelerator
from repro.accel.smoothed_dct import SmoothedDct
from repro.core.acl.library import default_library
from repro.core.features import synth

LIB = default_library()

# compile-derived label keys (deterministic); latency/energy are
# recomputed per variant from circuits/ranks on top of these
HW_KEYS = ("flops", "hbm_bytes", "latency", "energy")


def _variant(kind, names, n_adds=4):
    by = {c.name: c for c in LIB.kind(kind)}
    adds = list(LIB.kind("add16"))[:n_adds]
    circuits = [by[n] for n in names] + adds
    return circuits, [None] * len(names)


def _random_variants(accel, n, seed, rank_genes=False):
    rng = np.random.default_rng(seed)
    sizes = accel.gene_sizes(LIB, rank_genes=rank_genes)
    genomes = rng.integers(0, sizes[None, :], size=(n, len(sizes)))
    genomes[-1] = genomes[0]     # an exact duplicate rides the batch
    return genomes


def _serial_reference(accel, genomes, rank_genes=False):
    """The PR-4 engine: per-genome synthesize_variant, identity-keyed
    per-context dict cache, structural tier off."""
    synth.reset_fast_codegen()
    keep = synth.STRUCTURAL_KEYS
    synth.STRUCTURAL_KEYS = False
    try:
        cache = {}
        out = []
        for g in genomes:
            circuits, ranks = accel.decode(g, LIB, rank_genes=rank_genes)
            out.append(synth.synthesize_variant(
                accel, circuits, ranks, cache=cache,
            ))
        return out
    finally:
        synth.STRUCTURAL_KEYS = keep
        synth.reset_fast_codegen()


# ---------------------------------------------------------------------------
# (a) structural equality property
# ---------------------------------------------------------------------------

def test_structurally_equal_specs_compile_to_identical_cost_numbers():
    """Different named circuits of one deployment class (same rank /
    trunc bits / signedness), and slot PERMUTATIONS of them, produce
    identical compiled flops and bytes — the invariant the structural
    cache is keyed on.  Compiled with the structural tier OFF so every
    variant really goes through XLA."""
    accel = GaussianFilter()
    variants = [
        _variant("mul8u", ["mul8u_perf1"] * 3 + ["mul8u_drum3"] * 3
                 + ["mul8u_trunc2"] * 3),
        # same classes, different circuits
        _variant("mul8u", ["mul8u_perf4"] * 3 + ["mul8u_drum6"] * 3
                 + ["mul8u_trunc2"] * 3),
        # same classes, permuted slots
        _variant("mul8u", ["mul8u_trunc2"] * 3 + ["mul8u_perf2"] * 3
                 + ["mul8u_drum5"] * 3),
    ]
    keep = synth.STRUCTURAL_KEYS
    synth.STRUCTURAL_KEYS = False
    try:
        recs = [synth.synthesize_variant(accel, c, r) for c, r in variants]
    finally:
        synth.STRUCTURAL_KEYS = keep
    assert len({r["flops"] for r in recs}) == 1
    assert len({r["hbm_bytes"] for r in recs}) == 1
    # and the signature agrees that they are one structure
    from repro.kernels.approx_matmul import from_circuit

    sigs = {
        accel.deploy_signature(
            [from_circuit(c, r) for c, r in zip(cs[:9], rs)]
        )
        for cs, rs in variants
    }
    assert len(sigs) == 1


def test_deploy_signature_distinguishes_real_structure():
    """Rank and truncated width changes MUST re-key: different classes,
    different signature (and genuinely different compiled numbers)."""
    accel = GaussianFilter()
    from repro.kernels.approx_matmul import from_circuit

    def sig(names):
        circuits, ranks = _variant("mul8u", names)
        specs = [from_circuit(c, r)
                 for c, r in zip(circuits[:9], ranks)]
        return accel.deploy_signature(specs)

    base = sig(["mul8u_perf1"] * 9)
    assert sig(["mul8u_perf4"] * 9) == base            # same class
    assert sig(["mul8u_drum3"] * 9) != base            # rank 1 -> 2
    assert sig(["mul8u_trunc2"] * 9) != sig(["mul8u_trunc4"] * 9)


# ---------------------------------------------------------------------------
# (b) synthesize_batch == the serial per-genome loop, everywhere
# ---------------------------------------------------------------------------

def _accelerators():
    return [
        GaussianFilter(),
        MCMAccelerator(0),
        HEVCDct(),
        SmoothedDct(),
    ] + SmoothedDct().stage_views()


@pytest.mark.parametrize("rank_genes", [False, True])
def test_synthesize_batch_matches_serial_loop_all_accelerators(rank_genes):
    for seed, accel in enumerate(_accelerators()):
        genomes = _random_variants(accel, 4, 300 + seed, rank_genes)
        ref = _serial_reference(accel, genomes, rank_genes)
        synth.reset_fast_codegen()
        variants = [accel.decode(g, LIB, rank_genes=rank_genes)
                    for g in genomes]
        recs = synth.synthesize_batch(accel, variants)
        for t, (a, b) in enumerate(zip(ref, recs)):
            for k in HW_KEYS:
                assert a[k] == b[k], (accel.name, t, k)


def test_label_variants_rides_batch_and_matches(tmp_path):
    accel = MCMAccelerator(1)
    genomes = _random_variants(accel, 5, 17)
    inputs = accel.sample_inputs(2, seed=5)
    synth.reset_fast_codegen()
    keep = synth.STRUCTURAL_KEYS
    synth.STRUCTURAL_KEYS = False
    try:
        ref = synth.label_variants(accel, genomes, LIB, qor_inputs=inputs,
                                   cache={})
    finally:
        synth.STRUCTURAL_KEYS = keep
    synth.reset_fast_codegen()
    new = synth.label_variants(accel, genomes, LIB, qor_inputs=inputs,
                               cache={})
    for k in ("qor",) + HW_KEYS:
        assert np.array_equal(ref[k], new[k]), k


# ---------------------------------------------------------------------------
# (c) persistent cache: cold-then-warm does zero compiles, labels exact
# ---------------------------------------------------------------------------

def test_persistent_cache_cold_then_warm_zero_compiles(tmp_path):
    accel = GaussianFilter()
    genomes = _random_variants(accel, 4, 23)
    inputs = accel.sample_inputs(2, seed=2)
    path = str(tmp_path / "synth.jsonl")

    cold = synth.JsonlSynthCache(path)
    ref = synth.label_variants(accel, genomes, LIB, qor_inputs=inputs,
                               synth_cache=cold)
    assert cold.stats()["compiles"] > 0
    cold.close()

    # 'restart': cold module state, fresh cache object on the same file
    synth.reset_fast_codegen()
    warm = synth.JsonlSynthCache(path)
    new = synth.label_variants(accel, genomes, LIB, qor_inputs=inputs,
                               synth_cache=warm)
    assert warm.stats()["compiles"] == 0, warm.stats()
    assert warm.stats()["identity_hits"] > 0
    for k in ("qor",) + HW_KEYS:
        assert np.array_equal(ref[k], new[k]), k


def test_persistent_cache_verification_state_survives_restart(tmp_path):
    """A family verified cold stays verified warm: a NEVER-seen identity
    of a known structure is served with zero compiles after a restart."""
    accel = GaussianFilter()
    path = str(tmp_path / "synth.jsonl")
    same_class = [
        ["mul8u_perf1"] * 9, ["mul8u_perf2"] * 9, ["mul8u_perf3"] * 9,
        ["mul8u_perf4"] * 9,
    ]
    cold = synth.JsonlSynthCache(path)
    synth.synthesize_batch(
        accel, [_variant("mul8u", n) for n in same_class],
        synth_cache=cold,
    )
    s = cold.stats()
    assert s["compiles"] == 3 and s["verify_compiles"] == 2   # 1 fresh + K
    assert s["structural_hits"] == 1
    cold.close()

    synth.reset_fast_codegen()
    warm = synth.JsonlSynthCache(path)
    synth.synthesize_batch(
        accel, [_variant("mul8u", ["mul8u_perf5"] * 9)], synth_cache=warm,
    )
    assert warm.stats()["compiles"] == 0, warm.stats()
    assert warm.stats()["structural_hits"] == 1


# ---------------------------------------------------------------------------
# verification scheme + kill switch
# ---------------------------------------------------------------------------

def test_structural_kill_switch_pins_to_identity_keys():
    accel = MCMAccelerator(2)
    v1 = _variant("mul8s", ["mul8s_perf1"] * 4, n_adds=3)
    v2 = _variant("mul8s", ["mul8s_perf2"] * 4, n_adds=3)
    keep = synth.STRUCTURAL_KEYS
    try:
        synth.STRUCTURAL_KEYS = False
        cache = synth.SynthCache()
        synth.synthesize_batch(accel, [v1, v2], synth_cache=cache)
        s = cache.stats()
        assert s["compiles"] == 2 and s["structural_hits"] == 0
    finally:
        synth.STRUCTURAL_KEYS = keep


def test_pinned_family_stops_structural_serving():
    """A family whose verification diverged must compile every identity
    exactly (structural records stop serving)."""
    accel = MCMAccelerator(3)
    cache = synth.SynthCache()
    v1 = _variant("mul8s", ["mul8s_perf1"] * 4, n_adds=3)
    synth.synthesize_batch(accel, [v1], synth_cache=cache)
    from repro.kernels.approx_matmul import from_circuit

    specs = [from_circuit(c, r) for c, r in zip(v1[0][:4], v1[1])]
    family, _ = accel.deploy_signature(specs)
    fam = synth._digest("fam", tuple(family))
    cache.verdict_pin(fam)
    assert cache.verdict(fam) is False
    v2 = _variant("mul8s", ["mul8s_perf3"] * 4, n_adds=3)
    synth.synthesize_batch(accel, [v2], synth_cache=cache)
    s = cache.stats()
    assert s["compiles"] == 2 and s["structural_hits"] == 0
    assert s["pinned_families"] == 1


def test_pin_after_verified_persists_across_restart(tmp_path):
    """``False == 0`` in Python: a pin landing AFTER the countdown
    reached 0 (verified) must still be appended to the cache file — a
    warm replay that resurrects the family as 'verified' would serve
    structural records for a family proven divergent."""
    path = str(tmp_path / "synth.jsonl")
    cache = synth.JsonlSynthCache(path)
    fam = "famX"
    for _ in range(synth._STRUCT_VERIFY_SAMPLES):
        cache.verdict_pass(fam)
    assert cache.verdict(fam) == 0 and cache.verdict(fam) is not False
    cache.verdict_pin(fam)       # concurrent verifier saw a divergence
    assert cache.verdict(fam) is False
    assert cache.stats()["verified_families"] == 0
    cache.close()
    warm = synth.JsonlSynthCache(path)
    assert warm.verdict(fam) is False, "pin lost across restart"
    warm.close()


def test_reset_fast_codegen_clears_all_verification_state():
    synth._FAST_VERDICT["accel:test"] = False
    shared = synth.shared_synth_cache()
    shared.store({"k": "x", "s": "y", "fam": "z",
                  "flops": 1.0, "hbm_bytes": 2.0})
    synth.reset_fast_codegen()
    assert synth._FAST_VERDICT == {}
    assert len(synth.shared_synth_cache()) == 0
    assert synth.shared_synth_cache() is not shared


# ---------------------------------------------------------------------------
# cross-accelerator sharing: stage view == standalone accelerator
# ---------------------------------------------------------------------------

def test_stage0_view_shares_standalone_gaussian_compiles():
    """smoothed_dct/stage0 deploys the very graphs gaussian3x3 deploys
    (same shapes, same in-situ input): their structural signatures are
    EQUAL, so labeling the view after the standalone accelerator costs
    only the family's first-K verification compiles — after which every
    further view identity is served without touching XLA."""
    pipe = SmoothedDct()
    stage0 = pipe.stage_views()[0]
    gauss = GaussianFilter()
    rng = np.random.default_rng(41)
    sizes = gauss.gene_sizes(LIB)
    genomes = rng.integers(0, sizes[None, :], size=(4, len(sizes)))

    cache = synth.SynthCache()
    synth.synthesize_batch(
        gauss, [gauss.decode(g, LIB) for g in genomes], synth_cache=cache,
    )
    n0 = cache.stats()["compiles"]
    recs = synth.synthesize_batch(
        stage0, [stage0.decode(g, LIB) for g in genomes], synth_cache=cache,
    )
    s = cache.stats()
    # the view's identities are new (different accel name) but its
    # structures are gaussian3x3's: only verification compiles are paid
    assert s["compiles"] == n0 + synth._STRUCT_VERIFY_SAMPLES, s
    assert s["verify_compiles"] == synth._STRUCT_VERIFY_SAMPLES
    assert s["structural_hits"] >= 2
    assert all(r["flops"] > 0 for r in recs)
    # family now verified: NEW view identities of KNOWN structures
    # (multiplier genes rotated -> same sorted class multiset) are free
    more = np.array(genomes[:2])
    more[:, :9] = np.roll(more[:, :9], 1, axis=1)
    synth.synthesize_batch(
        stage0, [stage0.decode(g, LIB) for g in more], synth_cache=cache,
    )
    assert cache.stats()["compiles"] == n0 + synth._STRUCT_VERIFY_SAMPLES


# ---------------------------------------------------------------------------
# process-pool labeler surfaces synth counters
# ---------------------------------------------------------------------------

def test_process_pool_stats_surface_synth_counters(tmp_path):
    from repro.service import EvalContext
    from repro.service.workers import ProcessPoolLabeler

    path = str(tmp_path / "synth.jsonl")
    pool = ProcessPoolLabeler(1, synth_cache_path=path)
    try:
        ctx = EvalContext(MCMAccelerator(0), LIB, n_qor_samples=2)
        assert pool.can_label(ctx)
        genomes = _random_variants(ctx.accel, 3, 7)
        pool.label(ctx, genomes)
        s = pool.stats()
        assert s["synth"]["workers_reporting"] == 1
        assert s["synth"]["compiles"] > 0
        assert s["synth_cache_path"] == path
        import os

        assert os.path.exists(path)
    finally:
        pool.shutdown()
