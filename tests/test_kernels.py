"""Per-kernel validation: shape/dtype sweeps against the pure-jnp/numpy
oracles (assignment requirement), Pallas interpret mode, quantization
properties."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.acl.library import default_library
from repro.kernels.approx_matmul import (
    approx_matmul,
    dequantize,
    from_circuit,
    grouped_matmul,
    lut_matmul,
    lut_matmul_pallas,
    quantize_sym,
    rank_k_matmul,
    rank_k_mxu,
)
from repro.kernels.flash_attention import (
    attention,
    chunked_attention,
    flash_attention_fwd,
    mha_reference,
)

LIB = default_library()


def _numpy_lut_matmul(c, x, w):
    out = np.zeros((x.shape[0], w.shape[1]), np.int64)
    for k in range(x.shape[1]):
        out += np.asarray(c.fn(x[:, k : k + 1], w[k : k + 1, :]))
    return out


@pytest.mark.parametrize("name", ["mul8u_exact", "mul8u_trunc2", "mul8u_mitchell",
                                  "mul8s_exact", "mul8s_drum4", "mul8s_perf3"])
@pytest.mark.parametrize("shape", [(8, 16, 8), (32, 64, 16)])
def test_lut_matmul_matches_behavioral(name, shape, rng):
    c = LIB[name]
    m, k, n = shape
    lo, hi = (-128, 128) if c.signed else (0, 256)
    x = rng.integers(lo, hi, (m, k))
    w = rng.integers(lo, hi, (k, n))
    got = np.asarray(lut_matmul(jnp.asarray(x), jnp.asarray(w),
                                jnp.asarray(c.table), signed=c.signed))
    assert np.array_equal(got, _numpy_lut_matmul(c, x, w))


@pytest.mark.parametrize("name", ["mul8u_trunc3", "mul8s_trunc2"])
@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (64, 64, 64)])
def test_pallas_lut_kernel_interpret(name, bm, bn, bk, rng):
    c = LIB[name]
    m, k, n = bm * 2, bk * 2, bn
    lo, hi = (-128, 128) if c.signed else (0, 256)
    x = rng.integers(lo, hi, (m, k))
    w = rng.integers(lo, hi, (k, n))
    want = _numpy_lut_matmul(c, x, w)
    got = np.asarray(lut_matmul_pallas(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(c.table.astype(np.int32)),
        signed=c.signed, bm=bm, bn=bn, bk=bk, interpret=True,
    ))
    assert np.array_equal(got.astype(np.int64), want)


@pytest.mark.parametrize("name", ["mul8u_trunc2", "mul8u_bam4", "mul8s_mitchell"])
def test_rank_full_reconstructs_behavioral(name, rng):
    c = LIB[name]
    lo, hi = (-128, 128) if c.signed else (0, 256)
    x = rng.integers(lo, hi, (16, 32))
    w = rng.integers(lo, hi, (32, 8))
    spec = from_circuit(c, rank=256)
    got = np.asarray(approx_matmul(jnp.asarray(x), jnp.asarray(w), spec))
    want = _numpy_lut_matmul(c, x, w).astype(np.float64)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < 1e-4


@pytest.mark.parametrize("name", ["mul8u_trunc2", "mul8u_drum4", "mul8u_perf2"])
def test_eff_rank_error_within_energy_bound(name, rng):
    c = LIB[name]
    x = rng.integers(0, 256, (64, 64))
    w = rng.integers(0, 256, (64, 64))
    spec = from_circuit(c)  # 99%-energy rank
    got = np.asarray(approx_matmul(jnp.asarray(x), jnp.asarray(w), spec))
    want = _numpy_lut_matmul(c, x, w).astype(np.float64)
    exact = (x.astype(np.float64) @ w)
    # residual of the rank-k correction vs the behavioral error magnitude
    res = np.abs(got - want).mean()
    err_mag = np.abs(want - exact).mean() + 1.0
    assert res <= 0.35 * err_mag, (name, res, err_mag)


def test_rank_k_pallas_matches_ref(rng):
    c = LIB["mul8u_perf3"]
    spec = from_circuit(c, rank=4)
    x = rng.integers(0, 256, (128, 128))
    w = rng.integers(0, 256, (128, 128))
    ref = np.asarray(rank_k_matmul(jnp.asarray(x), jnp.asarray(w),
                                   jnp.asarray(spec.u), jnp.asarray(spec.v)))
    got = np.asarray(rank_k_mxu(jnp.asarray(x), jnp.asarray(w),
                                jnp.asarray(spec.u), jnp.asarray(spec.v),
                                interpret=True))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=0.5)


def test_grouped_matmul_mixes_circuits(rng):
    c1, c2 = LIB["mul8u_exact"], LIB["mul8u_trunc3"]
    x = rng.integers(0, 256, (8, 6))
    w = rng.integers(0, 256, (6, 4))
    out = np.asarray(grouped_matmul(
        jnp.asarray(x), jnp.asarray(w),
        [from_circuit(c1), from_circuit(c2)],
        [(0, 3), (3, 6)],
    ))
    want = (x[:, :3].astype(np.float64) @ w[:3]) + _numpy_lut_matmul(
        c2, x[:, 3:], w[3:]
    )
    scale = np.abs(want).max()
    assert np.abs(out - want).max() / scale < 0.02


@given(st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_error_bound(seed):
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.standard_normal((32, 16)) * rng.uniform(0.1, 10))
    q, s = quantize_sym(t)
    back = dequantize(q, s)
    assert float(jnp.abs(back - t).max()) <= float(s) * 0.5 + 1e-6
    assert int(jnp.abs(q).max()) <= 127


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("h,kvh", [(4, 4), (4, 2), (8, 1)])
def test_chunked_attention_matches_naive(causal, h, kvh, rng):
    b, s, d = 2, 96, 32
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, kvh, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, kvh, s, d)), jnp.float32)
    ref = mha_reference(q, k, v, causal=causal)
    out = chunked_attention(q, k, v, causal=causal, chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("sq,sk", [(128, 128), (128, 256)])
def test_pallas_flash_matches_naive(sq, sk, rng):
    bh, d = 4, 64
    q = jnp.asarray(rng.standard_normal((bh, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, sk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, sk, d)), jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=True, interpret=True)
    ref = mha_reference(q[:, None].transpose(1, 0, 2, 3),
                        k[:, None].transpose(1, 0, 2, 3),
                        v[:, None].transpose(1, 0, 2, 3), causal=True)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_attention_decode_offset(rng):
    b, h, s, d = 1, 2, 64, 16
    q = jnp.asarray(rng.standard_normal((b, h, 1, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    pos = 40
    ref = mha_reference(q, k[:, :, : pos + 1], v[:, :, : pos + 1], causal=False)
    out = attention(q, k, v, causal=True, impl="chunked", chunk=16, q_offset=pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
