"""Per-campaign search telemetry time series.

``BENCH_strategies.json`` only shows hypervolume-per-label curves after
a run finishes; this module samples the same signals live at campaign
tick boundaries so ``GET /campaigns/<id>/timeline`` can answer "is this
campaign still buying front?" while it runs.

Each campaign gets a bounded ring of samples.  Hypervolume is computed
against a per-campaign reference point frozen at the first sample that
carries objectives (2-D only — the exact ``hypervolume_2d`` kernel);
freezing the reference keeps the series monotone-comparable even as the
front pushes past early extremes.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from ..core.pareto import hypervolume_2d, non_dominated_mask

__all__ = ["Timeline"]


class Timeline:
    """Bounded per-campaign sample rings, thread-safe."""

    def __init__(self, maxlen: int = 1024):
        self.maxlen = int(maxlen)
        self._lock = threading.Lock()
        self._series: Dict[str, deque] = {}
        self._refs: Dict[str, np.ndarray] = {}
        self._t0: Dict[str, float] = {}

    def sample(
        self,
        campaign: str,
        *,
        objectives: Optional[np.ndarray] = None,
        **fields,
    ) -> Dict:
        """Append one sample.  ``objectives`` (n, 2) adds hypervolume +
        front_size; other keyword fields pass through verbatim (labels
        requested/served, cache hit rate, stage, ...)."""
        now = time.time()
        rec: Dict = {"t": round(now, 3)}
        if objectives is not None:
            obj = np.asarray(objectives, dtype=np.float64)
            obj = obj[np.all(np.isfinite(obj), axis=1)] if obj.size else obj
            if obj.ndim == 2 and obj.shape[0] and obj.shape[1] == 2:
                with self._lock:
                    ref = self._refs.get(campaign)
                if ref is None:
                    # frozen at first sight: worst corner plus 10% of the
                    # span (or +1 on a degenerate axis) so boundary
                    # points contribute nonzero volume
                    span = obj.max(axis=0) - obj.min(axis=0)
                    pad = np.where(span > 0, 0.1 * span, 1.0)
                    ref = obj.max(axis=0) + pad
                    with self._lock:
                        self._refs.setdefault(campaign, ref)
                        ref = self._refs[campaign]
                rec["hypervolume"] = hypervolume_2d(obj, ref)
                rec["front_size"] = int(non_dominated_mask(obj).sum())
        for k, v in fields.items():
            if v is None:
                continue
            rec[k] = float(v) if isinstance(v, (int, float, np.floating,
                                                np.integer)) else v
        with self._lock:
            ring = self._series.get(campaign)
            if ring is None:
                ring = self._series[campaign] = deque(maxlen=self.maxlen)
                self._t0[campaign] = now
            rec["rel_s"] = round(now - self._t0[campaign], 3)
            ring.append(rec)
        return rec

    def series(self, campaign: str) -> List[Dict]:
        with self._lock:
            ring = self._series.get(campaign)
            return list(ring) if ring is not None else []

    def reference(self, campaign: str) -> Optional[List[float]]:
        with self._lock:
            ref = self._refs.get(campaign)
            return [float(x) for x in ref] if ref is not None else None

    def campaigns(self) -> List[str]:
        with self._lock:
            return sorted(self._series)

    def forget(self, campaign: str) -> None:
        with self._lock:
            self._series.pop(campaign, None)
            self._refs.pop(campaign, None)
            self._t0.pop(campaign, None)
