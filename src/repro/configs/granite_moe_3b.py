"""granite-moe-3b-a800m [moe] — 40 experts top-8 (padded to 48 for 16-way
EP divisibility; pads masked out of routing)
[hf:ibm-granite/granite-3.0-*-base]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    n_experts=40, n_experts_active=8, moe_period=1,
)
