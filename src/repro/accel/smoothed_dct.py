"""Smoothed DCT: the classic pre-filter + transform image pipeline.

Stage 0 is the paper's motivational 3x3 Gaussian filter (Fig. 1), stage 1
the HEVC 4x4 integer DCT evaluation application (§IV), coupled by the
pipeline's re-quantization: the filtered image is clipped back to the
unsigned 8-bit pixel domain before block extraction (approximate
multipliers can push the weighted sum outside [0, 255]).

This is the repo's first multi-stage application — the workload the
hierarchical search (repro.hierarchy) decomposes.  The flat joint genome
spans 45 slots (9 mul8u + 8 add16 Gaussian, 16 mul8s + 12 add16 DCT);
per-stage spaces are the factors of that product.

Deployment chains the two stages' rank-k MXU matmuls: the Gaussian's
im2col matmul output is renormalized (>>4), clipped to u8, re-centred and
re-blocked into DCT row operands inside the compiled function, so the
compiled cost_analysis sees the whole application.
"""

from __future__ import annotations

import numpy as np

from ..hierarchy.staged import Coupling, StagedPipeline
from . import fused
from .gaussian import GaussianFilter
from .hevc_dct import HEVCDct

__all__ = ["SmoothedDct"]


def _sim_coupling(y: np.ndarray) -> np.ndarray:
    """Behavioral: filtered image -> u8 pixel domain for block extraction."""
    return np.clip(y, 0, 255)


def _sim_coupling_fused(y):
    """Traceable twin of ``_sim_coupling`` for whole-pipeline fusion."""
    import jax.numpy as jnp

    return jnp.clip(y, 0, 255)


fused.register_coupling("u8_clip_reblock", _sim_coupling_fused)


def _deploy_coupling(y):
    """Deployment: Gaussian matmul output (n*windows, 1) -> DCT block rows.

    The Gaussian deploy emits the raw adder-tree accumulation; renormalize
    (>>4 as in the behavioral path), clip to u8, reshape to the filtered
    image, crop to whole 4x4 blocks and emit (n_blocks*4, 4) signed
    residual rows — HEVCDct.build_deploy's activation layout.
    """
    import jax.numpy as jnp

    side = 30  # 32x32 input -> 30x30 filtered image
    img = jnp.clip(jnp.round(y.reshape(-1, side, side) / 16.0), 0, 255)
    crop = side - side % 4
    x = img[:, :crop, :crop].astype(jnp.int32) - 128
    n = x.shape[0]
    b = x.reshape(n, crop // 4, 4, crop // 4, 4).transpose(0, 1, 3, 2, 4)
    return b.reshape(-1, 4, 4).reshape(-1, 4)


class SmoothedDct(StagedPipeline):
    """Gaussian 3x3 -> HEVC 4x4 DCT staged pipeline."""

    def __init__(self):
        super().__init__(
            "smoothed_dct",
            [GaussianFilter(), HEVCDct()],
            [Coupling(name="u8_clip_reblock",
                      sim=_sim_coupling, deploy=_deploy_coupling)],
        )
