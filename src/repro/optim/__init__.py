from .adamw import AdamW, clip_by_global_norm
from .compress import compressed_psum, ef_quantize

__all__ = ["AdamW", "clip_by_global_norm", "ef_quantize", "compressed_psum"]
