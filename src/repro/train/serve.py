"""Serving steps: prefill (prompt -> last-token logits + filled caches)
and decode (one token against the cache, greedy or sampled).

Prefill slices the residual stream to the final position *before* the
LM head — materializing (B, 32k, vocab) logits would be tens of GB per
device for the large-vocab archs.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import ApproxPolicy
from ..models.config import ModelConfig
from ..models.transformer import (
    _embed,
    _logits,
    _scan_blocks,
    encode,
)
from ..models.common import make_rope

__all__ = ["make_prefill_step", "make_decode_step"]


def _inv_freq(cfg: ModelConfig):
    return jnp.asarray(
        make_rope(cfg.resolved_head_dim, cfg.rope_theta,
                  fraction=0.5 if cfg.rope_style == "half" else 1.0)
    )


def make_prefill_step(cfg: ModelConfig, *, policy: Optional[ApproxPolicy] = None,
                      attn_chunk: int = 1024, scan_chunk: int = 128):
    def prefill(params, batch: Dict[str, jnp.ndarray], caches):
        """-> (last_logits (b, 1, V), caches, enc_out|None)"""
        parts = []
        if batch.get("embeds") is not None:
            parts.append(batch["embeds"].astype(jnp.bfloat16))
        if batch.get("tokens") is not None:
            parts.append(_embed(params, cfg, batch["tokens"]))
        x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        if cfg.name.startswith("gemma"):
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = encode(params, cfg, batch["enc_embeds"],
                             policy=policy, remat=False)
        x, caches, _ = _scan_blocks(
            params, cfg, x, _inv_freq(cfg), policy=policy, causal=True,
            caches=caches, pos=None, enc_out=enc_out, remat=False,
            attn_chunk=attn_chunk, scan_chunk=scan_chunk,
        )
        logits = _logits(params, cfg, x[:, -1:, :])
        if cfg.is_encoder_decoder:
            return logits, caches, enc_out
        return logits, caches

    return prefill


def make_decode_step(cfg: ModelConfig, *, policy: Optional[ApproxPolicy] = None,
                     greedy: bool = True):
    from ..models.transformer import decode_step as _ds

    def serve_step(params, caches, tokens, pos, enc_out=None):
        """-> (next_tokens (b, 1), logits, caches)"""
        logits, caches = _ds(params, cfg, caches, tokens, pos,
                             policy=policy, enc_out=enc_out)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, caches

    return serve_step
