"""Multi-host labeling fleet: retrying HTTP helper, coordinator
lease/requeue state machine, zero-loss worker kill (byte-identical
front), elastic mid-campaign join, and empty-fleet degradation."""

import json
import os
import signal
import subprocess
import sys
import threading
import time
import types

import numpy as np
import pytest

from repro.accel import MCMAccelerator
from repro.core.acl.library import default_library
from repro.fleet import (
    FleetCoordinator,
    HttpError,
    context_is_portable,
    encode_labels,
    request_json,
    serve_fleet,
)
from repro.service import (
    CampaignManager,
    CampaignSpec,
    EvalContext,
    EvalScheduler,
    InMemoryLabelStore,
)
from repro.service.api import make_server
from repro.service.store import LABEL_KEYS

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# label keys that are a pure function of (context, genome) — timing keys
# (synth_time / sim_time) legitimately differ between runs/backends
DET_KEYS = ("qor", "latency", "energy", "flops", "hbm_bytes")

SMALL = dict(n_train=10, n_qor_samples=2, pop_size=8, n_parents=4,
             n_generations=3)


def _wait_for(pred, timeout=60.0, every=0.01, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(every)
    raise AssertionError(f"timed out waiting for {what}")


# ---------------------------------------------------------------------------
# http helper
# ---------------------------------------------------------------------------

def _flaky_server(script):
    """A one-route HTTP server that pops (status, body) pairs per hit."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    hits = []

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *a):
            pass

        def _serve(self):
            status, body = script[min(len(hits), len(script) - 1)]
            hits.append(self.path)
            payload = json.dumps(body).encode()
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(payload)))
            self.end_headers()
            self.wfile.write(payload)

        do_GET = do_POST = lambda self: self._serve()  # noqa: E731

    srv = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    return srv, f"http://127.0.0.1:{srv.server_address[1]}", hits


def test_request_json_retries_transient_statuses():
    srv, base, hits = _flaky_server([
        (503, {"error": "warming up"}),
        (503, {"error": "warming up"}),
        (200, {"ok": True}),
    ])
    try:
        out = request_json(base + "/x", retries=4, backoff_s=0.01,
                           backoff_max_s=0.02)
        assert out == {"ok": True} and len(hits) == 3
    finally:
        srv.shutdown()


def test_request_json_does_not_retry_client_errors():
    srv, base, hits = _flaky_server([(404, {"error": "no route"})])
    try:
        with pytest.raises(HttpError) as ei:
            request_json(base + "/x", retries=4, backoff_s=0.01)
        assert ei.value.code == 404 and "no route" in ei.value.detail
        assert len(hits) == 1                      # no retry on 4xx
        # and it is still catchable as plain urllib.error.HTTPError
        import urllib.error

        assert isinstance(ei.value, urllib.error.HTTPError)
    finally:
        srv.shutdown()


def test_request_json_retries_exhausted_connection_refused():
    t0 = time.monotonic()
    with pytest.raises(HttpError) as ei:
        request_json("http://127.0.0.1:1/x", retries=2, backoff_s=0.01,
                     backoff_max_s=0.02, timeout=0.5)
    assert ei.value.code is None                   # transport, not HTTP
    assert time.monotonic() - t0 < 30


def test_request_json_zero_retries_is_single_shot():
    srv, base, hits = _flaky_server([(503, {"error": "busy"})])
    try:
        with pytest.raises(HttpError):
            request_json(base + "/x", {"a": 1}, retries=0)
        assert len(hits) == 1
    finally:
        srv.shutdown()


# ---------------------------------------------------------------------------
# coordinator state machine (fake contexts: no synthesis, no sockets)
# ---------------------------------------------------------------------------

def _fake_ctx(fp="fp-test"):
    ctx = types.SimpleNamespace(
        accel=types.SimpleNamespace(name="mcm1"),
        rank_genes=False, n_qor_samples=2, qor_seed=0, fingerprint=fp,
    )

    def ground_truth(genomes):
        g = np.atleast_2d(genomes)
        v = g.sum(axis=1).astype(np.float64)
        return {k: v * (i + 1) for i, k in enumerate(LABEL_KEYS)}

    ctx.ground_truth = ground_truth
    return ctx


def _serve_leases(coord, wid, *, n=None, delay=0.0, drop_result=False):
    """Fake remote worker: poll leases and answer with ground truth.
    ``n=None`` serves until the coordinator shuts down."""
    served = 0
    while n is None or served < n:
        if coord._stopped:
            return served
        resp = coord.lease({"worker": wid})
        lease = resp.get("lease")
        if lease is None:
            time.sleep(0.005)
            continue
        served += 1
        if delay:
            time.sleep(delay)
        if drop_result:
            continue                          # simulates a kill -9
        labels = _fake_ctx().ground_truth(np.asarray(lease["genomes"]))
        coord.result({"worker": wid, "lease": lease["id"],
                      "labels": encode_labels(labels)})
    return served


def test_coordinator_roundtrip_and_stats():
    coord = FleetCoordinator(lease_ttl_s=5.0, heartbeat_ttl_s=5.0)
    reg = coord.register({"worker": "w0", "host": "h", "pid": 1,
                          "accels": ["*"]})
    assert reg["ok"] and reg["worker"] == "w0"
    ctx = _fake_ctx()
    genomes = np.arange(24).reshape(12, 2)

    t = threading.Thread(target=_serve_leases, args=(coord, "w0"),
                         kwargs={"n": None}, daemon=True)
    t.start()
    out = coord.label(ctx, genomes)
    ref = ctx.ground_truth(genomes)
    for k in LABEL_KEYS:
        assert np.array_equal(out[k], ref[k])

    s = coord.stats()
    assert s["live"] == 1 and s["batches"] == 1
    assert s["remote_labels"] == 12 and s["local_labels"] == 0
    assert s["requeues"] == 0
    assert s["workers"]["w0"]["labels"] == 12
    assert s["workers"]["w0"]["alive"]
    coord.shutdown()


def test_lease_expiry_requeues_to_surviving_worker():
    """A worker that leases a chunk and dies silently (kill -9): the
    lease expires, the chunk requeues, a surviving worker completes it,
    and the batch result is identical to plain ground truth."""
    coord = FleetCoordinator(lease_ttl_s=0.3, heartbeat_ttl_s=60.0)
    coord.register({"worker": "dead", "accels": ["*"]})
    coord.register({"worker": "live", "accels": ["*"]})
    ctx = _fake_ctx()
    genomes = np.arange(16).reshape(8, 2)

    # the doomed worker grabs leases and never answers
    threading.Thread(target=_serve_leases, args=(coord, "dead"),
                     kwargs={"n": 2, "drop_result": True},
                     daemon=True).start()
    # the survivor starts polling only after the leases are gone
    def survivor():
        time.sleep(0.1)
        _serve_leases(coord, "live", n=None)

    threading.Thread(target=survivor, daemon=True).start()
    out = coord.label(ctx, genomes)
    ref = ctx.ground_truth(genomes)
    for k in LABEL_KEYS:
        assert np.array_equal(out[k], ref[k])
    s = coord.stats()
    assert s["requeues"] >= 1 and s["expired_leases"] >= 1
    assert s["workers"]["live"]["labels"] >= 1
    coord.shutdown()


def test_heartbeat_expiry_kills_worker_and_reclaims_locally():
    """Heartbeat silence declares the worker dead; with no live worker
    left the blocked label() reclaims every chunk in-process."""
    coord = FleetCoordinator(lease_ttl_s=60.0, heartbeat_ttl_s=0.3)
    coord.register({"worker": "w0", "accels": ["*"]})
    ctx = _fake_ctx()
    genomes = np.arange(8).reshape(4, 2)
    # w0 leases one chunk then goes silent; no other worker exists
    threading.Thread(target=_serve_leases, args=(coord, "w0"),
                     kwargs={"n": 1, "drop_result": True},
                     daemon=True).start()
    out = coord.label(ctx, genomes)
    ref = ctx.ground_truth(genomes)
    for k in LABEL_KEYS:
        assert np.array_equal(out[k], ref[k])
    s = coord.stats()
    assert s["live"] == 0 and s["dead_workers"] == 1
    assert s["local_labels"] == 4 and s["remote_labels"] == 0
    # a heartbeat from the declared-dead worker is told to re-register
    assert coord.heartbeat({"worker": "w0"}) == {"ok": False,
                                                 "reregister": True}
    coord.shutdown()


def test_late_duplicate_result_is_dropped():
    """At-most-once commit: a late result from a presumed-dead worker
    lands after the requeued copy completed — it must change nothing."""
    coord = FleetCoordinator(lease_ttl_s=0.2, heartbeat_ttl_s=60.0,
                             chunk_size=100)   # one chunk per batch
    coord.register({"worker": "slow", "accels": ["*"]})
    coord.register({"worker": "fast", "accels": ["*"]})
    ctx = _fake_ctx()
    genomes = np.arange(8).reshape(4, 2)

    resp = coord.lease({"worker": "slow"})     # slow takes THE chunk...
    lease_box = {}

    def run_label():
        lease_box["out"] = coord.label(ctx, genomes)

    # label() must be running before lease() has work to hand out, so
    # grab the lease after the batch is enqueued
    t = threading.Thread(target=run_label, daemon=True)
    t.start()
    _wait_for(lambda: coord.lease({"worker": "slow"}).get("lease")
              is not None or lease_box.get("out"),
              what="slow worker to lease the chunk")
    # ...the lease expires and fast serves the requeue
    _serve_leases(coord, "fast", n=1)
    t.join(timeout=30)
    assert "out" in lease_box

    # slow finally reports, against a retired lease id it never knew
    # expired; fabricate the report through the protocol
    before = coord.stats()["duplicate_results"]
    stale = [lid for lid in list(coord._retired)]
    labels = encode_labels(ctx.ground_truth(genomes))
    for lid in stale:
        coord.result({"worker": "slow", "lease": lid, "labels": labels})
    after = coord.stats()
    assert after["duplicate_results"] >= before
    ref = ctx.ground_truth(genomes)
    for k in LABEL_KEYS:
        assert np.array_equal(lease_box["out"][k], ref[k])
    coord.shutdown()


def test_fingerprint_drift_rejection_pins_worker_then_fleet():
    coord = FleetCoordinator(lease_ttl_s=5.0, heartbeat_ttl_s=60.0)
    coord.register({"worker": "w0", "accels": ["*"]})
    ctx = _fake_ctx(fp="fp-drifty")
    genomes = np.arange(4).reshape(2, 2)

    def reject_all():
        while True:
            resp = coord.lease({"worker": "w0"})
            lease = resp.get("lease")
            if lease is None:
                if coord.stats()["drifted_fingerprints"]:
                    return
                time.sleep(0.005)
                continue
            coord.result({"worker": "w0", "lease": lease["id"],
                          "reject": True, "error": "fingerprint drift"})

    threading.Thread(target=reject_all, daemon=True).start()
    out = coord.label(ctx, genomes)           # completes via local reclaim
    ref = ctx.ground_truth(genomes)
    for k in LABEL_KEYS:
        assert np.array_equal(out[k], ref[k])
    s = coord.stats()
    assert s["drifted_fingerprints"] == 1
    # the drifted fp no longer leases to w0
    w = coord._workers["w0"]
    assert not w.can_serve({"fingerprint": "fp-drifty", "accel": "mcm1"})
    coord.shutdown()


def test_worker_bye_requeues_immediately():
    """A polite leave (heartbeat bye) requeues the worker's lease NOW
    instead of waiting out the heartbeat TTL."""
    coord = FleetCoordinator(lease_ttl_s=60.0, heartbeat_ttl_s=60.0)
    coord.register({"worker": "w0", "accels": ["*"]})
    ctx = _fake_ctx()
    genomes = np.arange(4).reshape(2, 2)
    done = {}
    t = threading.Thread(
        target=lambda: done.update(out=coord.label(ctx, genomes)),
        daemon=True)
    t.start()
    _wait_for(lambda: coord.lease({"worker": "w0"}).get("lease")
              is not None, what="w0 to hold a lease")
    t0 = time.monotonic()
    assert coord.heartbeat({"worker": "w0", "bye": True})["bye"]
    t.join(timeout=30)
    assert "out" in done and time.monotonic() - t0 < 30
    assert coord.stats()["live"] == 0
    coord.shutdown()


# ---------------------------------------------------------------------------
# scheduler integration: empty fleet degrades to the in-process backend
# ---------------------------------------------------------------------------

def test_empty_fleet_falls_back_to_process_backend():
    lib = default_library()
    ctx = EvalContext(MCMAccelerator(1), lib, n_qor_samples=2)
    sched = EvalScheduler(InMemoryLabelStore(), n_workers=2,
                          backend="fleet", fleet_fallback="process",
                          process_workers=1, max_wait_s=0.005)
    try:
        g = ctx.accel.exact_genome(lib)
        genomes = np.tile(g, (3, 1))
        genomes[:, 0] = [0, 1, 2]
        out = sched.label(ctx, genomes)
        ref = ctx.ground_truth(genomes)
        for k in DET_KEYS:
            assert np.array_equal(out[k], ref[k])
        s = sched.stats()
        assert s["fleet_fallbacks"] >= 1 and s["fleet_batches"] == 0
        assert s["fleet"]["registered"] == 0
        assert s["labeler"]["labeled"] == 3     # the process pool ran it
    finally:
        sched.shutdown()


def test_unportable_context_stays_off_the_fleet():
    """A context the portability gate rejects must never be leased, even
    with live workers."""
    lib = default_library()
    sub = lib.subset([c.name for c in lib.circuits[:40]])
    ctx = EvalContext(MCMAccelerator(1), sub, n_qor_samples=2)
    assert not context_is_portable(ctx)
    coord = FleetCoordinator()
    coord.register({"worker": "w0", "accels": ["*"]})
    assert not coord.eligible(ctx)


# ---------------------------------------------------------------------------
# end to end over real HTTP: kill -9 mid-campaign, elastic join,
# byte-identical front
# ---------------------------------------------------------------------------

def _spawn_worker(base, wid, store=None):
    cmd = [sys.executable, "-m", "repro.fleet.worker",
           "--orchestrator", base, "--id", wid, "--no-warm",
           "--max-idle-s", "120"]
    if store:
        cmd += ["--store", store]
    return subprocess.Popen(
        cmd, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
        env={**os.environ, "PYTHONPATH": SRC},
    )


def test_kill9_mid_campaign_front_is_byte_identical():
    """The fleet acceptance invariant: a worker kill -9 mid-batch plus
    an elastic join halfway through must not change ONE byte of the
    campaign's front versus the plain single-process run."""
    spec = CampaignSpec(accel="mcm1", **SMALL)
    # single-host reference: the SAME campaign path, thread backend
    ref_mgr = CampaignManager(eval_workers=2, campaign_workers=1)
    ref_cid = ref_mgr.submit(spec)
    assert ref_mgr.wait(ref_cid, timeout=600) == "done"
    ref = ref_mgr.result(ref_cid)
    ref_mgr.shutdown()

    mgr = CampaignManager(eval_workers=2, campaign_workers=1,
                          eval_backend="fleet",
                          lease_ttl_s=3.0, heartbeat_ttl_s=3.0)
    srv = make_server(mgr, port=0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    fleet = mgr.scheduler.fleet
    procs = []
    try:
        procs.append(_spawn_worker(base, "wA"))
        _wait_for(lambda: fleet.stats()["live"] >= 1, timeout=120,
                  what="worker A to register")

        cid = mgr.submit(spec)
        # elastic join: worker B starts only after the campaign is
        # already labeling on worker A
        _wait_for(lambda: fleet.stats()["batches"] >= 1, timeout=120,
                  what="first fleet batch")
        procs.append(_spawn_worker(base, "wB"))
        _wait_for(lambda: fleet.stats()["live"] >= 2, timeout=120,
                  what="worker B to register")

        # kill -9 worker A the moment IT holds a lease (B keeps serving);
        # that chunk can then only complete via expiry -> requeue
        def a_holds_lease():
            with fleet._cv:
                return any(l.worker == "wA" for l in fleet._leases.values())
        _wait_for(a_holds_lease, timeout=120, every=0.002,
                  what="worker A to hold a lease")
        procs[0].send_signal(signal.SIGKILL)

        assert mgr.wait(cid, timeout=600) == "done"
        res = mgr.result(cid)
        # byte-identical front: genomes AND objectives
        assert np.array_equal(ref.front_genomes, res.front_genomes)
        assert np.array_equal(ref.front_objectives, res.front_objectives)

        s = fleet.stats()
        assert s["remote_labels"] > 0           # the fleet did real work
        assert s["workers"]["wB"]["labels"] > 0  # the late joiner served
        # the killed worker's in-flight lease expired and requeued —
        # the campaign could not have completed otherwise
        assert s["expired_leases"] >= 1 and s["requeues"] >= 1
        # B's continued polling notices A's heartbeat silence
        _wait_for(lambda: fleet.stats()["dead_workers"] >= 1, timeout=30,
                  what="the kill to be noticed via heartbeat expiry")
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
        srv.shutdown()
        mgr.shutdown()


def test_fleet_worker_warm_starts_from_shared_store(tmp_path):
    """A worker pointed at the shared JSONL store answers already-known
    genomes from its replica instead of recomputing."""
    from repro.service import JsonlLabelStore

    path = str(tmp_path / "labels.jsonl")
    lib = default_library()
    ctx = EvalContext(MCMAccelerator(1), lib, n_qor_samples=2)
    g = ctx.accel.exact_genome(lib)
    genomes = np.tile(g, (4, 1))
    genomes[:, 0] = [0, 1, 2, 3]
    # pre-label everything into the shared store
    labels = ctx.ground_truth(genomes)
    store = JsonlLabelStore(path)
    store.put_many(
        (ctx.key(genomes[i]), {k: labels[k][i] for k in LABEL_KEYS})
        for i in range(len(genomes))
    )
    store.close()

    coord = FleetCoordinator(lease_ttl_s=30.0, heartbeat_ttl_s=30.0)
    srv = serve_fleet(coord, port=0)
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    proc = _spawn_worker(base, "warm", store=path)
    try:
        _wait_for(lambda: coord.stats()["live"] >= 1, timeout=120,
                  what="warm worker to register")
        out = coord.label(ctx, genomes)
        for k in DET_KEYS:
            assert np.array_equal(out[k], labels[k])
        s = coord.stats()
        assert s["workers"]["warm"]["store_hits"] == 4
    finally:
        if proc.poll() is None:
            proc.kill()
        srv.shutdown()
        coord.shutdown()
