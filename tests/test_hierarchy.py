"""Hierarchical search subsystem: staged pipelines (chained behavioral
sim, genome plumbing, in-situ stage views), front composition (incremental
pruning == brute force), run_hierarchical and its service integration."""

import itertools
import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.accel import GaussianFilter, HEVCDct, SmoothedDct
from repro.core.acl.library import default_library
from repro.core.pareto import non_dominated_mask
from repro.hierarchy import (
    HierarchicalConfig,
    StageFront,
    StageView,
    compose_fronts,
    run_hierarchical,
    truncate_front,
)
from repro.hierarchy.compose import _combine, compose_qor
from repro.service import (
    CampaignManager,
    HierarchicalSpec,
    make_accelerator,
)

LIB = default_library()

TINY = dict(n_train=8, n_qor_samples=2, pop_size=8, n_parents=4,
            n_generations=1)


@pytest.fixture(scope="module")
def pipe():
    return SmoothedDct()


@pytest.fixture(scope="module")
def images(pipe):
    return pipe.sample_inputs(2, seed=0)


# ---------------------------------------------------------------------------
# StagedPipeline behavior
# ---------------------------------------------------------------------------

def test_staged_exact_is_bit_identical_to_hand_chain(pipe, images):
    """All-exact pipeline sim == chaining the stage sims by hand."""
    circuits, _ = pipe.decode(pipe.exact_genome(LIB), LIB)
    out = pipe.simulate(circuits, images)
    gauss, dct = GaussianFilter(), HEVCDct()
    smoothed = np.clip(gauss.exact_output(images), 0, 255)
    hand = dct.exact_output(smoothed)
    assert np.array_equal(out, hand)
    assert np.array_equal(pipe.exact_output(images), hand)
    assert pipe.qor(circuits, images) == 100.0


def test_staged_approx_matches_hand_chain(pipe, images):
    """Arbitrary genome: the pipeline chains the stage sims + coupling."""
    rng = np.random.default_rng(3)
    g = rng.integers(0, pipe.gene_sizes(LIB))
    circuits, _ = pipe.decode(g, LIB)
    out = pipe.simulate(circuits, images)

    gauss, dct = GaussianFilter(), HEVCDct()
    per_stage = pipe.split_circuits(circuits)
    smoothed = np.clip(gauss.simulate(per_stage[0], images), 0, 255)
    hand = dct.simulate(per_stage[1], smoothed)
    assert np.array_equal(out, hand)


def test_staged_slot_concat_and_genome_roundtrip(pipe):
    assert len(pipe.slots) == 17 + 28
    assert len(pipe.mul_slot_indices()) == 9 + 16
    assert len(pipe.mul_slot_constants()) == 25
    rng = np.random.default_rng(0)
    for rank_genes in (False, True):
        sizes = pipe.gene_sizes(LIB, rank_genes=rank_genes)
        g = rng.integers(0, sizes)
        parts = pipe.split_genome(g, rank_genes=rank_genes)
        assert len(parts) == 2
        back = pipe.assemble_genome(parts, rank_genes=rank_genes)
        assert np.array_equal(g, back)
        # per-stage genomes decode in each stage's own convention
        for view, part in zip(pipe.stage_views(), parts):
            assert len(part) == len(view.gene_sizes(LIB,
                                                    rank_genes=rank_genes))


def test_stage_view_measures_in_situ(pipe, images):
    """A stage view's sim == the pipeline with every other stage exact."""
    rng = np.random.default_rng(5)
    view = StageView(pipe, 1)
    g1 = rng.integers(0, view.gene_sizes(LIB))
    circuits, _ = view.decode(g1, LIB)
    out = view.simulate(circuits, images)

    # hand version: exact gaussian -> coupling -> approx dct
    smoothed = np.clip(GaussianFilter().exact_output(images), 0, 255)
    hand = HEVCDct().simulate(circuits, smoothed)
    assert np.array_equal(out, hand)
    # exact stage genome in situ is exact end-to-end
    exact, _ = view.decode(view.exact_genome(LIB), LIB)
    assert view.qor(exact, images) == 100.0


def test_stage_views_resolve_by_name():
    v0 = make_accelerator("smoothed_dct/stage0")
    v1 = make_accelerator("smoothed_dct/stage1")
    assert isinstance(v0, StageView) and v0.stage.name == "gaussian3x3"
    assert isinstance(v1, StageView) and v1.stage.name == "hevc_dct4x4"
    with pytest.raises(ValueError):
        make_accelerator("smoothed_dct/stage7")
    with pytest.raises(ValueError):
        make_accelerator("smoothed_dct/stage-1")   # no negative indexing
    with pytest.raises(ValueError):
        make_accelerator("mcm2/stage0")
    with pytest.raises(ValueError):                # KeyError -> ValueError
        make_accelerator("lm:nope-such-arch")


def test_run_hierarchical_reregisters_edited_pipeline():
    """If a name resolves to a DIFFERENT structure (pipeline edited and
    re-run in a live process), run_hierarchical re-registers its own
    object — stage campaigns and end-to-end verification must agree."""
    from repro.hierarchy.staged import StagedPipeline
    from repro.service import unregister_accelerator

    edited = StagedPipeline("smoothed_dct", [GaussianFilter()])
    try:
        cfg = HierarchicalConfig(k_per_stage=3, max_candidates=4, **TINY)
        res = run_hierarchical(edited, LIB, cfg)
        # the campaigns ran on the single-stage edit, not the builtin
        assert len(res.stage_campaign_ids) == 1
        assert res.candidate_genomes.shape[1] == len(edited.slots) == 17
        assert len(res.front_objectives) > 0
        assert make_accelerator("smoothed_dct").label_fingerprint() \
            == edited.label_fingerprint()
    finally:
        unregister_accelerator("smoothed_dct")   # restore the builtin
    assert len(make_accelerator("smoothed_dct").stages) == 2


# ---------------------------------------------------------------------------
# composition
# ---------------------------------------------------------------------------

def _random_fronts(rng, n_stages, m, qor_index):
    fronts = []
    for _ in range(n_stages):
        n = int(rng.integers(3, 7))
        obj = rng.normal(size=(n, m))
        if qor_index is not None:
            # -psnr values in a realistic range
            obj[:, qor_index] = -rng.uniform(5, 100, size=n)
        fronts.append(StageFront(genomes=np.arange(n)[:, None],
                                 objectives=obj))
    return fronts


def _brute_force(fronts, qor_index):
    """Full cross-product (same combine op, left fold, NO pruning)."""
    objs = fronts[0].objectives.astype(np.float64)
    for f in fronts[1:]:
        objs = _combine(objs, f.objectives.astype(np.float64), qor_index)
    return objs[non_dominated_mask(objs)]


@pytest.mark.parametrize("n_stages,m,qor_index", [
    (2, 2, 0), (3, 2, 0), (2, 3, 1), (3, 3, None),
])
def test_compose_equals_bruteforce(n_stages, m, qor_index):
    """Property: incremental non-dominated pruning yields exactly the
    brute-force cross-product front (no caps applied)."""
    for seed in range(6):
        rng = np.random.default_rng(100 * seed + n_stages)
        fronts = _random_fronts(rng, n_stages, m, qor_index)
        res = compose_fronts(fronts, qor_index=qor_index)
        brute = _brute_force(fronts, qor_index)
        a = res.objectives[np.lexsort(res.objectives.T)]
        b = brute[np.lexsort(brute.T)]
        assert a.shape == b.shape, f"seed {seed}"
        assert np.allclose(a, b), f"seed {seed}"
        # indices reconstruct the composed objectives
        assert res.stats.survivors == len(res.indices)
        assert res.stats.cross_product_size == float(np.prod(
            [len(f.objectives) for f in fronts]))


def test_compose_qor_is_monotone_noise_addition():
    # an exact stage (psnr 100 -> -100) barely degrades the other stage
    assert compose_qor(np.array(-40.0), np.array(-100.0)) < -39.9
    # two equal stages lose 10*log10(2) ~ 3 dB
    assert np.isclose(compose_qor(np.array(-40.0), np.array(-40.0)),
                      -40 + 10 * np.log10(2))
    # monotone: a worse stage never improves the composition
    a = compose_qor(np.array(-30.0), np.array(-50.0))
    b = compose_qor(np.array(-20.0), np.array(-50.0))
    assert b > a


def test_truncate_front_keeps_extremes():
    obj = np.stack([np.arange(10.0), -np.arange(10.0)], axis=1)
    sel = truncate_front(obj, 4)
    assert len(sel) == 4
    assert 0 in obj[sel][:, 0] and 9 in obj[sel][:, 0]
    assert len(truncate_front(obj, None)) == 10
    assert len(truncate_front(obj, 20)) == 10


def test_compose_respects_caps():
    rng = np.random.default_rng(7)
    fronts = _random_fronts(rng, 3, 2, 0)
    res = compose_fronts(fronts, qor_index=0, k_per_stage=3,
                         max_survivors=4)
    assert all(t <= 3 for t in res.stats.truncated_sizes)
    assert len(res.objectives) <= 4
    # indices point into the truncated genome arrays
    for t in range(len(res.indices)):
        for s, gidx in enumerate(res.indices[t]):
            assert 0 <= gidx < len(res.stage_genomes[s])


# ---------------------------------------------------------------------------
# run_hierarchical + service integration
# ---------------------------------------------------------------------------

def test_run_hierarchical_end_to_end(pipe):
    cfg = HierarchicalConfig(k_per_stage=4, max_candidates=8, **TINY)
    res = run_hierarchical(pipe, LIB, cfg)
    assert len(res.stage_campaign_ids) == 2
    assert len(res.front_objectives) > 0
    # exact anchor survives end-to-end verification on the front
    assert np.isclose(res.true_objectives[:, 0].min(), -100.0)
    # candidates were deduped + labeled end-to-end
    assert len(np.unique(res.candidate_genomes, axis=0)) == len(
        res.candidate_genomes)
    assert res.candidate_genomes.shape[1] == len(pipe.slots)
    gt = res.ground_truth_calls
    assert gt["total"] == gt["stage_campaigns"] + gt["final"]
    assert 0 < gt["final"] <= len(res.candidate_genomes)
    assert gt["total"] < res.flat_space_size
    assert res.max_concurrent_stages >= 1
    assert set(res.timings) >= {"stage_campaigns", "compose",
                                "final_eval", "total", "stage0", "stage1"}


def test_hierarchical_service_job_and_global_front():
    mgr = CampaignManager(eval_workers=2, campaign_workers=2)
    spec = HierarchicalSpec(accel="smoothed_dct", k_per_stage=4,
                            max_candidates=8, **TINY)
    cid = mgr.submit_hierarchical(spec)
    assert mgr.wait(cid, timeout=1200) == "done"
    st = mgr.status(cid)
    assert st["kind"] == "hierarchical"
    assert st["front_size"] > 0
    assert len(st["stage_campaigns"]) == 2
    assert st["max_concurrent_stages"] >= 1
    assert st["ground_truth_calls"]["total"] > 0
    fr = mgr.front(cid)
    assert len(fr["front"]) == st["front_size"]
    # the hierarchical front merges into the pipeline's global front
    gf = mgr.global_front("smoothed_dct")
    assert gf["campaigns"] == [cid]
    # stage campaigns are ordinary campaigns on the same manager
    kinds = {c["id"]: c["kind"] for c in mgr.list_campaigns()}
    assert kinds[cid] == "hierarchical"
    assert all(kinds[sc] == "dse" for sc in st["stage_campaigns"])
    # retention compaction keeps the hierarchical summary queryable
    from repro.service.campaigns import _CompactResult

    mgr.keep_results = 0
    mgr._evict()
    assert isinstance(mgr.result(cid), _CompactResult)
    st2 = mgr.status(cid)
    assert st2["front_size"] == st["front_size"]
    assert st2["ground_truth_calls"] == st["ground_truth_calls"]
    assert len(mgr.front(cid)["front"]) == st["front_size"]
    mgr.shutdown()


def test_register_unregister_accelerator():
    from repro.service import register_accelerator, unregister_accelerator

    register_accelerator("tmp-gauss", GaussianFilter)
    assert make_accelerator("tmp-gauss").name == "gaussian3x3"
    assert unregister_accelerator("tmp-gauss")
    assert not unregister_accelerator("tmp-gauss")
    with pytest.raises(ValueError):
        make_accelerator("tmp-gauss")


def test_hierarchical_spec_validation():
    mgr = CampaignManager(eval_workers=1, campaign_workers=1)
    with pytest.raises(ValueError, match="not a staged pipeline"):
        mgr.submit_hierarchical(HierarchicalSpec(accel="mcm2", **TINY))
    with pytest.raises(ValueError, match="stages"):
        mgr.submit_hierarchical(HierarchicalSpec(
            accel="smoothed_dct", stages=({"n_train": 4},), **TINY))
    with pytest.raises(ValueError, match="max_candidates"):
        mgr.submit_hierarchical(HierarchicalSpec(
            accel="smoothed_dct", max_candidates=0, **TINY))
    # per-stage override CONTENTS are validated at submit too
    with pytest.raises(ValueError, match="bad stage 0 spec"):
        mgr.submit_hierarchical(HierarchicalSpec(
            accel="smoothed_dct", stages=({"n_train": 0}, {}), **TINY))
    with pytest.raises(ValueError, match="bad stage 1 override"):
        mgr.submit_hierarchical(HierarchicalSpec(
            accel="smoothed_dct", stages=({}, {"n_trian": 8}), **TINY))
    assert mgr.list_campaigns() == []
    mgr.shutdown()


def test_hierarchical_final_tag_accounting_is_reclaimed(pipe):
    """The end-to-end verification's scheduler tag must not leak
    per-campaign accounting entries in a long-lived service."""
    mgr = CampaignManager(eval_workers=2, campaign_workers=2)
    cfg = HierarchicalConfig(k_per_stage=3, max_candidates=4, **TINY)
    res = run_hierarchical(pipe, LIB, cfg, manager=mgr)
    per = mgr.scheduler.stats()["per_campaign"]
    assert not any(k.endswith(tuple(
        f"final-{cid}" for cid in res.stage_campaign_ids)) for k in per)
    assert not any("/final-" in k for k in per)
    mgr.shutdown()


def test_http_hierarchical_roundtrip_and_400s():
    from repro.service.api import Client, make_server

    mgr = CampaignManager(eval_workers=2, campaign_workers=2)
    srv = make_server(mgr, port=0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    try:
        cli = Client(f"http://127.0.0.1:{srv.server_address[1]}")

        def post_expect_400(payload, needle):
            req = urllib.request.Request(
                cli.base + "/campaigns", data=json.dumps(payload).encode(),
                headers={"Content-Type": "application/json"}, method="POST",
            )
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(req, timeout=60)
            assert ei.value.code == 400
            body = json.loads(ei.value.read())
            assert needle in body["error"]

        post_expect_400({"accel": "nope-such-accel"}, "unknown accelerator")
        post_expect_400({"accel": "mcm2", "n_train": 0}, "n_train")
        post_expect_400({"accel": "mcm2", "pop_size": 4, "n_parents": 8},
                        "n_parents")
        post_expect_400({"accel": "mcm2", "objectives": ["qor", "nope"]},
                        "objectives")
        post_expect_400({"hierarchical": True, "accel": "mcm2"},
                        "not a staged pipeline")
        post_expect_400({"accel": "mcm2", "no_such_field": 1}, "spec")

        # an explicit "hierarchical": false is a valid flat spec
        flat = cli._req("/campaigns",
                        {"accel": "mcm2", "hierarchical": False, **TINY})
        assert flat["state"] == "queued"

        cid = cli.submit_hierarchical(accel="smoothed_dct", k_per_stage=4,
                                      max_candidates=8, **TINY)
        st = cli.wait(cid, timeout=1200)
        assert st["state"] == "done" and st["kind"] == "hierarchical"
        assert len(cli.front(cid)["front"]) == st["front_size"]
    finally:
        srv.shutdown()
        mgr.shutdown()
