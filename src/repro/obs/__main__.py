"""Alias: ``python -m repro.obs`` == ``python -m repro.obs.export``."""

from .export import main

raise SystemExit(main())
