"""Multi-host labeling fleet quickstart.

    PYTHONPATH=src python examples/fleet_quickstart.py

One machine's process pool is the labeling ceiling; the fleet tier
splits ground-truth labeling across hosts.  This demo runs the whole
topology locally: an in-process CampaignManager with
``eval_backend="fleet"`` exposes an orchestrator HTTP endpoint, and two
real ``python -m repro.fleet.worker`` subprocesses join it — the second
one ELASTICALLY, after the campaign is already running.  Watch the
stats: every label is computed remotely, the late worker picks up
leases mid-campaign, and when both workers leave, a second campaign
degrades transparently to the in-process backend (``fleet_fallbacks``).

Set REPRO_SMOKE=1 for the CI-sized fast mode."""

import dataclasses
import os
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.service import CampaignManager, CampaignSpec, JsonlLabelStore

SMOKE = bool(os.environ.get("REPRO_SMOKE"))
SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "src"))


def spawn_worker(base, wid):
    """A real fleet worker process, as `python -m repro.fleet.worker`."""
    return subprocess.Popen(
        [sys.executable, "-m", "repro.fleet.worker",
         "--orchestrator", base, "--id", wid, "--no-warm",
         "--max-idle-s", "300"],
        env={**os.environ, "PYTHONPATH": SRC},
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)


def wait_for(pred, timeout, what):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if pred():
            return
        time.sleep(0.1)
    raise TimeoutError(f"timed out waiting for {what}")


def main():
    from repro.fleet import serve_fleet

    store_path = os.path.join(tempfile.mkdtemp(prefix="fleet_demo_"),
                              "labels.jsonl")
    spec = CampaignSpec(accel="mcm2",
                        n_train=10 if SMOKE else 24, n_qor_samples=2,
                        pop_size=8 if SMOKE else 12,
                        n_parents=4 if SMOKE else 6,
                        n_generations=2 if SMOKE else 3)

    store = JsonlLabelStore(store_path)
    mgr = CampaignManager(store, eval_workers=2, eval_backend="fleet",
                          lease_ttl_s=30.0, heartbeat_ttl_s=6.0)
    fleet = mgr.scheduler.fleet
    srv = serve_fleet(fleet, port=0)
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    print(f"orchestrator: {base}  (join with: python -m repro.fleet.worker "
          f"--orchestrator {base})")

    workers = {}
    try:
        print("\n-- worker w0 joins, campaign starts --")
        workers["w0"] = spawn_worker(base, "w0")
        wait_for(lambda: fleet.stats()["live"] >= 1, 120, "w0 to register")
        c1 = mgr.submit(spec)

        # w1 joins ELASTICALLY: the campaign is already labeling
        wait_for(lambda: fleet.stats()["batches"] >= 1, 120, "first batch")
        print("-- worker w1 joins mid-campaign --")
        workers["w1"] = spawn_worker(base, "w1")
        mgr.wait(c1)

        s = fleet.stats()
        print(f"remote labels={s['remote_labels']}  "
              f"local={s['local_labels']}  batches={s['batches']}  "
              f"chunks={s['chunks']}  requeues={s['requeues']}")
        for wid, w in s["workers"].items():
            print(f"  {wid}: labels={w['labels']}  "
                  f"{w['labels_per_sec']:.2f} labels/s  "
                  f"alive={w['alive']}")

        print("\n-- both workers leave; next campaign degrades in-process --")
        for p in workers.values():
            p.terminate()
        wait_for(lambda: fleet.stats()["live"] == 0, 60, "workers to leave")
        spec2 = dataclasses.replace(spec, seed=7)
        c2 = mgr.submit(spec2)
        mgr.wait(c2)
        ss = mgr.scheduler.stats()
        print(f"fleet batches={ss['fleet_batches']}  "
              f"in-process fallbacks={ss['fleet_fallbacks']}")

        front = mgr.result(c1).front_objectives
        print(f"\ntrue Pareto front ({len(front)} designs, "
              f"PSNR dB vs energy J):")
        for i in np.argsort(front[:, 0])[:8]:
            print(f"  psnr={-front[i, 0]:7.2f}  energy={front[i, 1]:.3e}")
    finally:
        for p in workers.values():
            if p.poll() is None:
                p.kill()
        mgr.shutdown()
        srv.shutdown()
        store.close()


if __name__ == "__main__":
    main()
